import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Supplementary dry-run: the PAPER'S OWN ENGINE at pod scale.

One Verdict serving step over an 8.6-billion-row relation sharded across the
production mesh:
  1. distributed multi-snippet scan (predicate mask + masked aggregation,
     the range_mask_agg pattern) over row shards, psum-reduced;
  2. CLT raw answers;
  3. batched improved answers against a C=2048 synopsis: K = analytic SE
     double-integral covariance (se_covariance pattern), then the Eq. 11/12
     fused blend (gp_batch_infer pattern);
  4. model validation gate.

Lowered + compiled AOT exactly like the LM cells; roofline terms recorded to
the same JSONL under arch='verdict-aqp'.

  PYTHONPATH=src python -m repro.launch.verdict_cell [--rows-log2 33]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.launch import hlo_analysis as H  # noqa: E402
from repro.launch import roofline as R  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def build(mesh, multi_pod: bool, *, rows_log2=33, q=1024, c=2048, l=4, m=2):
    """Returns (step_fn, abstract_args). Rows shard over the WHOLE mesh
    (an AQP scan is pure data parallelism — every chip scans its shard)."""
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n_rows = 2**rows_log2

    def sds(shape, dtype, *spec):
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, P(*spec)))

    rel = {
        "num": sds((n_rows, l), jnp.float32, axes),
        "meas": sds((n_rows, m), jnp.float32, axes),
    }
    snips = {
        "lo": sds((q, l), jnp.float32, None),
        "hi": sds((q, l), jnp.float32, None),
        "measure": sds((q,), jnp.int32, None),
    }
    syn = {
        "lo": sds((c, l), jnp.float32, None),
        "hi": sds((c, l), jnp.float32, None),
        "sinv": sds((c, c), jnp.float32, None),
        "alpha": sds((c,), jnp.float32, None),
        "ls": sds((l,), jnp.float32, None),
        "sigma2": sds((), jnp.float32),
        "mu": sds((q,), jnp.float32, None),
    }

    def step(rel, snips, syn):
        from jax.scipy.special import erf

        def local(num, meas, lo, hi, measure):
            # multi-snippet masked aggregation (range_mask_agg pattern)
            mask = jnp.all(
                (num[:, None, :] >= lo[None]) & (num[:, None, :] <= hi[None]),
                axis=-1).astype(jnp.float32)  # (T, Q)
            payload = jnp.concatenate(
                [meas, meas * meas, jnp.ones((num.shape[0], 1), jnp.float32)], 1)
            out = mask.T @ payload  # (Q, 2m+1)
            return jax.lax.psum(out, axes)

        out = jax.shard_map(
            local, mesh=mesh,
            in_specs=(P(axes), P(axes), P(None), P(None), P(None)),
            out_specs=P(None), check_vma=False,
        )(rel["num"], rel["meas"], snips["lo"], snips["hi"], snips["measure"])
        sums = jnp.take_along_axis(out[:, :m], snips["measure"][:, None], 1)[:, 0]
        sumsq = jnp.take_along_axis(out[:, m:2 * m], snips["measure"][:, None], 1)[:, 0]
        cnt = jnp.maximum(out[:, -1], 1.0)
        theta = sums / cnt
        beta2 = jnp.maximum(sumsq / cnt - theta**2, 0.0) / cnt

        # K: analytic SE double integral (se_covariance pattern), (Q, C)
        def anti(u, z):
            return (-0.5 * z * z * jnp.exp(-((u / z) ** 2))
                    - 0.886226925 * z * u * erf(u / z))

        def integral(a, b, cc, d, z):
            return anti(b - d, z) - anti(b - cc, z) - anti(a - d, z) + anti(a - cc, z)

        g = integral(snips["lo"][:, None, :], snips["hi"][:, None, :],
                     syn["lo"][None], syn["hi"][None], syn["ls"])  # (Q,C,l)
        wq = jnp.prod(jnp.maximum(snips["hi"] - snips["lo"], 1e-6), -1)
        wc = jnp.prod(jnp.maximum(syn["hi"] - syn["lo"], 1e-6), -1)
        k_mat = syn["sigma2"] * jnp.prod(jnp.maximum(g, 0.0), -1) \
            / (wq[:, None] * wc[None])
        gq = integral(snips["lo"], snips["hi"], snips["lo"], snips["hi"], syn["ls"])
        kappa2 = syn["sigma2"] * jnp.prod(jnp.maximum(gq, 0.0), -1) / (wq * wq)

        # Eq. 11/12 blend (gp_batch_infer pattern) + validation gate
        t = k_mat @ syn["sinv"]
        gamma2 = jnp.maximum(kappa2 - jnp.sum(t * k_mat, -1), 1e-30)
        prior = syn["mu"] + k_mat @ syn["alpha"]
        denom = beta2 + gamma2
        theta_dd = (beta2 * prior + gamma2 * theta) / denom
        beta2_dd = beta2 * gamma2 / denom
        accept = jnp.abs(theta - theta_dd) <= 2.576 * jnp.sqrt(beta2)
        return (jnp.where(accept, theta_dd, theta),
                jnp.where(accept, beta2_dd, beta2))

    return step, (rel, snips, syn)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows-log2", type=int, default=33)
    ap.add_argument("--out", default="experiments/dryrun.jsonl")
    args = ap.parse_args()
    for multi_pod in (False, True):
        mesh = make_production_mesh(multi_pod=multi_pod)
        t0 = time.time()
        step, abstract = build(mesh, multi_pod, rows_log2=args.rows_log2)
        with mesh:
            compiled = jax.jit(step).lower(*abstract).compile()
        ca = compiled.cost_analysis()
        ma = compiled.memory_analysis()
        coll = H.collective_bytes(compiled.as_text())
        chips = 512 if multi_pod else 256
        roof = R.roofline(float(ca.get("flops", 0.0)),
                          float(ca.get("bytes accessed", 0.0)),
                          coll["wire_bytes_total"])
        rec = {
            "arch": "verdict-aqp", "shape": f"scan_2e{args.rows_log2}_q1024",
            "kind": "serve", "label": "baseline",
            "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
            "ok": True, "compile_s": round(time.time() - t0, 1),
            "flops_per_device": float(ca.get("flops", 0.0)),
            "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
            "collectives": coll, "roofline": roof,
            "memory": {"argument_gb": ma.argument_size_in_bytes / 1e9,
                       "output_gb": ma.output_size_in_bytes / 1e9,
                       "alias_gb": ma.alias_size_in_bytes / 1e9,
                       "temp_gb": ma.temp_size_in_bytes / 1e9},
            "probes": {}, "useful_flops_ratio": 1.0,
        }
        print(json.dumps(rec["roofline"], indent=None))
        print("args GB/dev:", rec["memory"]["argument_gb"])
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
