"""Production mesh builders.

Single pod: 16 x 16 = 256 chips (data x model).
Multi-pod:  2 x 16 x 16 = 512 chips (pod x data x model); the 'pod' axis is
the cross-pod data-parallel axis (gradient all-reduce crosses DCN — see
repro.distributed.compression for the int8 error-feedback compressor).

Functions, not module constants: importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh():
    """Whatever this host has (tests / examples): (n_devices,) as 'data'."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
