"""Dry-run cells: (arch x shape) -> step function + abstract sharded inputs.

Shapes (assigned):
  train_4k     seq 4096,   global_batch 256   -> train_step
  prefill_32k  seq 32768,  global_batch 32    -> prefill_step
  decode_32k   seq 32768,  global_batch 128   -> serve_step (1 new token)
  long_500k    seq 524288, global_batch 1     -> serve_step; ONLY for
               sub-quadratic archs (rwkv6, hymba, gemma2) — DESIGN.md skips.

Each cell also carries *probes*: one-layer-group (and, for SSM archs, one
chunk-body) compile targets at full shapes/shardings whose costs, multiplied
by known trip counts, correct cost_analysis()'s scan-body-counted-once
semantics (see DESIGN.md §5 and launch.roofline).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as Pspec

from repro import configs
from repro.distributed import sharding as SH
from repro.models import mamba as MB
from repro.models import params as PM
from repro.models import rwkv as RW
from repro.models import transformer as T
from repro.models.common import ShardCtx
from repro.serving.engine import make_prefill_step, make_serve_step
from repro.training.optimizer import adafactor, adamw
from repro.training.train_loop import make_train_step

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}

LONG_OK = {"rwkv6-3b", "hymba-1.5b", "gemma2-2b"}

# >=100B MoE: adafactor + bf16 params (AdamW fp32 m/v would exceed v5e HBM).
BIG_ARCHS = {"arctic-480b", "llama4-maverick-400b-a17b"}


def cell_list() -> List[Tuple[str, str]]:
    cells = []
    for arch in configs.names():
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_OK:
                continue  # documented skip: no sub-quadratic attention path
            cells.append((arch, shape))
    return cells


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    step: Callable
    args: tuple  # abstract (ShapeDtypeStruct) args, sharded
    kwargs: dict
    donate: tuple
    probes: list  # [(label, multiplier, fn, abstract_args)]
    cfg: object
    meta: dict


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _named(mesh, *spec):
    return NamedSharding(mesh, Pspec(*spec))


def _shard_abstract(tree, shard_tree):
    return jax.tree.map(lambda a, s: _sds(a.shape, a.dtype, s), tree, shard_tree)


def _plans(cfg):
    """[(groups_key, plan, is_encoder)] covering the whole model."""
    if cfg.enc_dec:
        return [("dec_groups", cfg.decoder_plan(), False),
                ("enc_groups", cfg.encoder_plan(), True)]
    return [("groups", cfg.layer_plan(), False)]


def build_cell(arch: str, shape_name: str, mesh, multi_pod: bool,
               rules: Optional[dict] = None, accum: Optional[int] = None,
               cache_seq_axis: Optional[str] = None) -> Cell:
    info = SHAPES[shape_name]
    seq, batch, kind = info["seq"], info["batch"], info["kind"]
    tp = mesh.shape["model"]
    rules = dict(rules or SH.DEFAULT_RULES)
    b_ax = rules.pop("_batch_axes", None)
    pure_dp = b_ax is not None
    if pure_dp and multi_pod:
        b_ax = ("pod",) + tuple(b_ax)
    cfg = configs.get(arch).with_tp(1 if pure_dp else tp)
    if pure_dp and cfg.moe:
        raise ValueError("pure-DP rules are for dense archs (MoE needs EP)")
    b_ax = b_ax or SH.batch_axes(multi_pod)
    sctx = ShardCtx(mesh=mesh, batch_axes=b_ax, gather_weights=pure_dp)
    pshard = PM.shardings(cfg, mesh, rules)
    aparams = _shard_abstract(PM.abstract_params(cfg), pshard)
    import numpy as _np
    dshards = int(_np.prod([mesh.shape[a] for a in b_ax]))
    meta = {"tp": tp, "data_shards": dshards, "multi_pod": multi_pod,
            "rules": {k: str(v) for k, v in rules.items()},
            "seq": seq, "batch": batch,
            "n_params": cfg.n_params, "n_active_params": cfg.n_active_params}

    n_ctx = cfg.cross_attn.n_ctx if cfg.cross_attn else 0
    d = cfg.d_model
    cdt = jnp.dtype(cfg.compute_dtype)
    s_tok = seq // 2 if cfg.enc_dec else seq  # enc-dec splits the budget
    enc_len = seq - s_tok if cfg.enc_dec else 0

    if kind == "train":
        accum = accum or max(batch // dshards, 1)
        micro = batch // accum
        meta.update(accum=accum, micro=micro)
        opt = adafactor() if arch in BIG_ARCHS else adamw()
        meta["optimizer"] = opt.name
        step = make_train_step(cfg, opt, sctx, accum=accum)
        tok_s = _named(mesh, None, b_ax, None)
        batch_tree = {
            "tokens": _sds((accum, micro, s_tok), jnp.int32, tok_s),
            "labels": _sds((accum, micro, s_tok), jnp.int32, tok_s),
        }
        if cfg.cross_attn:
            batch_tree["ctx"] = _sds((accum, micro, n_ctx, d), cdt,
                                     _named(mesh, None, b_ax, None, None))
        if cfg.enc_dec:
            batch_tree["enc"] = _sds((accum, micro, enc_len, d), cdt,
                                     _named(mesh, None, b_ax, None, None))
        astate = jax.eval_shape(opt.init, aparams)
        oshard = SH.opt_state_shardings(opt.name, pshard, astate)
        astate = _shard_abstract(astate, oshard)
        lr = _sds((), jnp.float32, _named(mesh))
        args = (aparams, astate, batch_tree, lr)
        # Correction algebra (see roofline.py): the accum scan AND the layer
        # scans are each counted once by cost_analysis, so
        #   total = step + (accum-1) x microbatch + accum·(R-1) x layer
        #         + accum·R·(n_chunks-1) x ssm_chunk.
        probes = []
        if accum > 1:
            from repro.training.losses import lm_loss

            def micro_fwd_bwd(params, mb):
                return jax.value_and_grad(
                    lambda p: lm_loss(cfg, p, mb, sctx))(params)

            def _drop_lead(a):
                spec = tuple(a.sharding.spec)[1:] if a.sharding.spec else ()
                spec = spec + (None,) * (len(a.shape) - 1 - len(spec))
                return _sds(a.shape[1:], a.dtype, NamedSharding(mesh, Pspec(*spec)))

            mb_tree = jax.tree.map(_drop_lead, batch_tree)
            probes.append(("microbatch_vjp", accum - 1, micro_fwd_bwd,
                           (aparams, mb_tree)))
        gp = _group_probes(cfg, sctx, mesh, b_ax, micro, s_tok, n_ctx,
                           enc_len, train=True, rules=rules)
        probes += [(lbl, mult * accum, fn, a) for lbl, mult, fn, a in gp]
        cp = _ssm_chunk_probes(cfg, mesh, b_ax, micro,
                               s_tok + cfg.meta_tokens, train=True)
        probes += [(lbl, mult * accum, fn, a) for lbl, mult, fn, a in cp]
        return Cell(arch, shape_name, kind, step, args, {}, (0, 1), probes,
                    cfg, meta)

    if kind == "prefill":
        prefill = make_prefill_step(
            cfg, sctx, max_len=s_tok + cfg.meta_tokens + 1,
            n_ctx=n_ctx or enc_len)
        tok = _sds((batch, s_tok), jnp.int32, _named(mesh, b_ax, None))
        kwargs = {}
        if cfg.cross_attn:
            kwargs["ctx_tokens"] = _sds((batch, n_ctx, d), cdt,
                                        _named(mesh, b_ax, None, None))
        if cfg.enc_dec:
            kwargs["enc_embeds"] = _sds((batch, enc_len, d), cdt,
                                        _named(mesh, b_ax, None, None))
        probes = _group_probes(cfg, sctx, mesh, b_ax, batch, s_tok, n_ctx,
                               enc_len, train=False, rules=rules)
        probes += _ssm_chunk_probes(cfg, mesh, b_ax, batch,
                                    s_tok + cfg.meta_tokens, train=False)
        return Cell(arch, shape_name, kind, prefill, (aparams, tok), kwargs,
                    (), probes, cfg, meta)

    # ---- decode
    serve = make_serve_step(cfg, sctx)
    plan = cfg.decoder_plan() if cfg.enc_dec else cfg.layer_plan()
    s_cache = -(-(s_tok + cfg.meta_tokens + 2) // 16) * 16  # shardable length
    n_ctx_dec = n_ctx or enc_len
    acache = jax.eval_shape(
        lambda: T.init_cache(cfg, plan, batch, s_cache, n_ctx_dec))
    batch_sharded = batch > 1
    if cache_seq_axis is None and shape_name == "long_500k":
        cache_seq_axis = "data"  # batch=1: shard the KV sequence dim instead
    meta["cache_seq_axis"] = cache_seq_axis
    cshard = SH.cache_shardings(mesh, multi_pod, acache, cfg,
                                seq_axis=cache_seq_axis,
                                batch_sharded=batch_sharded)
    acache = _shard_abstract(acache, cshard)
    tok_spec = (b_ax, None) if batch_sharded else (None, None)
    tok = _sds((batch, 1), jnp.int32, _named(mesh, *tok_spec))
    pos = _sds((), jnp.int32, _named(mesh))
    args = (aparams, acache, tok, pos)
    probes = _decode_probes(cfg, sctx, mesh, b_ax, batch, s_cache, n_ctx_dec,
                            cache_seq_axis, batch_sharded, multi_pod, rules)
    return Cell(arch, shape_name, kind, serve, args, {}, (1,), probes, cfg, meta)


# ------------------------------------------------------------ layer probes
def _one_layer_abstract(cfg, mesh, rules, groups_key, gi, repeat):
    """Abstract one-layer (unstacked) params of group gi with shardings."""
    gspec = PM.param_specs(cfg)[groups_key][gi]

    def one(p):
        shape = p.shape[1:] if repeat > 1 else p.shape
        axes = p.axes[1:] if repeat > 1 else p.axes
        spec = tuple(rules.get(a) if a else None for a in axes)
        return _sds(shape, jnp.dtype(cfg.param_dtype),
                    NamedSharding(mesh, Pspec(*spec)))

    return jax.tree.map(one, gspec, is_leaf=lambda z: isinstance(z, PM.P))


def _group_probes(cfg, sctx, mesh, b_ax, micro, s_tok, n_ctx, enc_len, *,
                  train: bool, rules=None):
    """fwd (+vjp when training) per scanned group, multiplier repeat-1.

    Train cost per extra layer = fwd (fwd scan) + vjp (remat-fwd + bwd).
    """
    rules = dict(rules or SH.DEFAULT_RULES)
    cdt = jnp.dtype(cfg.compute_dtype)
    probes = []
    for groups_key, plan, is_enc in _plans(cfg):
        s_here = enc_len if is_enc else s_tok + cfg.meta_tokens
        for gi, (unit, repeat) in enumerate(plan):
            if repeat <= 1:
                continue
            x = _sds((micro, s_here, cfg.d_model), cdt,
                     _named(mesh, b_ax, None, None))
            lp = _one_layer_abstract(cfg, mesh, rules, groups_key, gi, repeat)
            pos = _sds((micro, s_here), jnp.int32, _named(mesh, b_ax, None))
            ctx = None
            if any(sp.cross for sp in unit):
                ctx = _sds((micro, n_ctx or enc_len, cfg.d_model), cdt,
                           _named(mesh, b_ax, None, None))

            def fwd(x_, lp_, pos_, ctx_=None, unit=unit):
                out, _ = T._unit_fwd(cfg, unit, lp_, x_, pos_, sctx,
                                     mode="train", ctx_tokens=ctx_, remat=False)
                return out

            def vjp(x_, lp_, pos_, ctx_=None, unit=unit):
                def f(x__, lp__):
                    out, _ = T._unit_fwd(cfg, unit, lp__, x__, pos_, sctx,
                                         mode="train", ctx_tokens=ctx_,
                                         remat=False)
                    return jnp.sum(out.astype(jnp.float32))

                return jax.grad(f, argnums=(0, 1))(x_, lp_)

            args = (x, lp, pos) + ((ctx,) if ctx is not None else ())
            probes.append((f"{groups_key}{gi}_fwd", repeat - 1, fwd, args))
            if train:
                probes.append((f"{groups_key}{gi}_vjp", repeat - 1, vjp, args))
    return probes


def _decode_probes(cfg, sctx, mesh, b_ax, batch, s_cache, n_ctx,
                   cache_seq_axis, batch_sharded, multi_pod, rules=None):
    cdt = jnp.dtype(cfg.compute_dtype)
    probes = []
    rules = dict(rules or SH.DEFAULT_RULES)
    for groups_key, plan, is_enc in _plans(cfg):
        if is_enc:
            continue  # encoder does not run at decode time
        for gi, (unit, repeat) in enumerate(plan):
            if repeat <= 1:
                continue
            x_spec = (b_ax, None, None) if batch_sharded else (None, None, None)
            x = _sds((batch, 1, cfg.d_model), cdt, _named(mesh, *x_spec))
            lp = _one_layer_abstract(cfg, mesh, rules, groups_key, gi, repeat)
            ac = jax.eval_shape(lambda u=unit: {
                f"sub{i}": T.init_layer_cache(cfg, sp, batch, s_cache, n_ctx)
                for i, sp in enumerate(u)})
            cs = SH.cache_shardings(mesh, multi_pod, ac, cfg,
                                    seq_axis=cache_seq_axis,
                                    batch_sharded=batch_sharded)
            ac = _shard_abstract(ac, cs)
            pos = _sds((), jnp.int32, _named(mesh))

            def dec(x_, lp_, cache_, pos_, unit=unit):
                out, nc = T._unit_fwd(cfg, unit, lp_, x_, None, sctx,
                                      mode="decode", cache=cache_, pos=pos_)
                return out, nc

            probes.append((f"{groups_key}{gi}_dec", repeat - 1, dec,
                           (x, lp, ac, pos)))
    return probes


def _ssm_chunk_probes(cfg, mesh, b_ax, micro, s_total, *, train: bool):
    """Inner chunk-scan correction: multiplier = sum_g R_g·n_ssm·(n_chunks-1)."""
    if not cfg.ssm:
        return []
    chunk = RW.CHUNK if cfg.ssm.kind == "rwkv6" else MB.CHUNK
    n_chunks = -(-s_total // chunk)
    if n_chunks <= 1:
        return []
    layers = 0
    for _, plan, is_enc in _plans(cfg):
        for unit, repeat in plan:
            layers += repeat * sum(1 for sp in unit if sp.ssm)
    mult = layers * (n_chunks - 1)
    d = cfg.d_model
    di = cfg.ssm.d_inner or d
    bsp = _named(mesh, b_ax, None, None, None)
    probes = []
    if cfg.ssm.kind == "rwkv6":
        h = di // cfg.head_dim
        hd = cfg.head_dim
        state = _sds((micro, h, hd, hd), jnp.float32,
                     _named(mesh, b_ax, None, None, None))
        seq4 = _sds((micro, chunk, h, hd), jnp.float32, bsp)
        u = _sds((h, hd), jnp.float32, _named(mesh, None, None))

        def fwd(state_, r, k, v, lw, u_):
            return RW._chunk_step(state_, (r, k, v, lw), u_)

        args = (state, seq4, seq4, seq4, seq4, u)
        probes.append(("ssm_chunk_fwd", mult, fwd, args))
        if train:
            def vjp(state_, r, k, v, lw, u_):
                def f(s_, r_, k_, v_, lw_):
                    ns, y = RW._chunk_step(s_, (r_, k_, v_, lw_), u_)
                    return jnp.sum(ns) + jnp.sum(y)

                return jax.grad(f, argnums=(0, 1, 2, 3, 4))(state_, r, k, v, lw)

            probes.append(("ssm_chunk_vjp", mult, vjp, args))
    else:
        n = cfg.ssm.state
        hsp = _named(mesh, b_ax, "model", None)
        h0 = _sds((micro, di, n), jnp.float32, hsp)
        uu = _sds((micro, chunk, di), jnp.float32,
                  _named(mesh, b_ax, None, "model"))
        bb = _sds((micro, chunk, n), jnp.float32, _named(mesh, b_ax, None, None))
        ll = _sds((micro, chunk, di), jnp.float32,
                  _named(mesh, b_ax, None, "model"))

        def fwd(h_, uu_, bb_, cc_, ll_):
            return MB._chunk_step(h_, (uu_, bb_, cc_, ll_))

        args = (h0, uu, bb, bb, ll)
        probes.append(("ssm_chunk_fwd", mult, fwd, args))
        if train:
            def vjp(h_, uu_, bb_, cc_, ll_):
                def f(a, b, c, d_, e):
                    ns, y = MB._chunk_step(a, (b, c, d_, e))
                    return jnp.sum(ns) + jnp.sum(y)

                return jax.grad(f, argnums=(0, 1, 2, 3, 4))(h_, uu_, bb_, cc_, ll_)

            probes.append(("ssm_chunk_vjp", mult, vjp, args))
    return probes
