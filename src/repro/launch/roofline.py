"""Roofline terms from compiled dry-run artifacts (TPU v5e-class targets).

    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
    memory term     = HLO_bytes / (chips x 819 GB/s)
    collective term = wire_bytes / (chips x 50 GB/s/link)

cost_analysis() is per-device and counts scan bodies once (measured fact,
DESIGN.md §5), so per-cell totals are assembled as

    total = step_cost + sum_probes multiplier x probe_cost

where probes re-compile one scanned layer group (and, for SSM archs, one
chunk-scan body) at full shapes/shardings. Collective bytes come from the HLO
parser, which multiplies loop bodies by their trip counts directly.
"""
from __future__ import annotations

from typing import Dict

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link


def combine_costs(step_cost: Dict, probe_costs) -> Dict[str, float]:
    flops = step_cost.get("flops", 0.0)
    byts = step_cost.get("bytes accessed", 0.0)
    for mult, cost in probe_costs:
        flops += mult * cost.get("flops", 0.0)
        byts += mult * cost.get("bytes accessed", 0.0)
    return {"flops_per_device": float(flops), "bytes_per_device": float(byts)}


def roofline(flops_per_device: float, bytes_per_device: float,
             wire_bytes_per_device: float) -> Dict[str, float]:
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = wire_bytes_per_device / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms.update({
        "dominant": dom,
        "step_time_lower_bound_s": bound,
        # fraction of the bound the compute term occupies = roofline fraction
        "roofline_fraction": compute_s / bound if bound > 0 else 0.0,
    })
    return terms


def model_flops(cfg, kind: str, seq: int, batch: int) -> float:
    """MODEL_FLOPS (all devices): 6·N_active·D train; 2·N_active·tokens decode."""
    n_act = cfg.n_active_params
    if kind == "train":
        return 6.0 * n_act * seq * batch
    if kind == "prefill":
        return 2.0 * n_act * seq * batch
    return 2.0 * n_act * batch  # decode: one token per sequence
