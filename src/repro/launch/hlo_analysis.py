"""Post-SPMD HLO analysis: collective-byte accounting with loop attribution.

``compiled.as_text()`` (per-device, post-partitioning) is parsed into
computations; collective ops (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute) are attributed to their enclosing
computation; while-loop bodies multiply by their trip count (recovered from
the loop condition's comparison constant); nesting multiplies. Wire-cost
factors: all-reduce 2x (RS+AG), others 1x (ring (n-1)/n ~ 1).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
WIRE_FACTOR = {"all-reduce": 2.0}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> float:
    """Sum bytes over every tensor literal in a result-shape string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text.

    Computation headers look like ``%name (args...) -> type {`` with possibly
    nested parentheses in tuple types, so we key on the trailing '{' plus a
    '->' and take the leading token as the name.
    """
    comps = {}
    cur = None
    buf = []
    for line in hlo.splitlines():
        stripped = line.strip()
        is_header = (
            stripped.endswith("{") and "->" in stripped
            and not stripped.startswith("ROOT")
            and re.match(r"^(ENTRY\s+)?%?[\w\.\-]+\s*\(", stripped)
        )
        if is_header and cur is None:
            name = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", stripped).group(1)
            cur = name
            buf = []
            continue
        if cur is not None:
            if line.startswith("}"):
                comps[cur] = "\n".join(buf)
                cur = None
            else:
                buf.append(line)
    return comps


def _loop_info(comps):
    """(parent, cond_comp, body_comp) for every while op."""
    loops = []
    for parent, body_txt in comps.items():
        for line in body_txt.splitlines():
            if " while(" not in line:
                continue
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            mc = re.search(r"condition=%?([\w\.\-]+)", line)
            if mb and mc:
                loops.append((parent, mc.group(1), mb.group(1)))
    return loops


def _trip_count(cond_txt: str) -> int:
    """Largest s32 constant compared in the loop condition ~ trip count."""
    consts = [int(c) for c in re.findall(r"constant\((\d+)\)", cond_txt)]
    return max(consts) if consts else 1


def _call_edges(comps):
    """parent -> [(child, multiplier)] via while bodies and calls/fusions."""
    edges = defaultdict(list)
    loops = _loop_info(comps)
    loop_bodies = set()
    for parent, cond, body in loops:
        trips = _trip_count(comps.get(cond, ""))
        edges[parent].append((body, trips))
        loop_bodies.add(body)
    for parent, txt in comps.items():
        for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", txt):
            child = m.group(1)
            if child not in loop_bodies and child in comps:
                edges[parent].append((child, 1))
    return edges


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Per-collective-kind wire bytes (per device), loop-trip multiplied."""
    comps = parse_computations(hlo)
    edges = _call_edges(comps)
    # effective multiplier per computation (DFS from entry computations —
    # those never referenced as children)
    referenced = {c for kids in edges.values() for c, _ in kids}
    mult = defaultdict(float)
    roots = [c for c in comps if c not in referenced]

    def visit(comp, m):
        mult[comp] += m
        for child, k in edges.get(comp, []):
            visit(child, m * k)

    for r in roots:
        visit(r, 1.0)

    out = defaultdict(float)
    op_counts = defaultdict(int)
    for comp, txt in comps.items():
        m = mult.get(comp, 0.0)
        if m == 0:
            continue
        for line in txt.splitlines():
            if " = " not in line:
                continue
            rhs = line.split(" = ", 1)[1]
            for kind in COLLECTIVES:
                # result shape precedes the op name: "bf16[...] all-reduce(".
                idx = rhs.find(f" {kind}(")
                if idx < 0:
                    idx = rhs.find(f" {kind}-start(")
                if idx >= 0:
                    nbytes = _shape_bytes(rhs[:idx])
                    out[kind] += nbytes * m
                    op_counts[kind] += int(m)
                    break
    out_wire = {k: v * WIRE_FACTOR.get(k, 1.0) for k, v in out.items()}
    return {
        "bytes_by_kind": dict(out),
        "wire_bytes_by_kind": out_wire,
        "wire_bytes_total": float(sum(out_wire.values())),
        "op_counts": dict(op_counts),
    }
