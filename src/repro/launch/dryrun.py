import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks the device count on init.

"""Multi-pod dry-run: AOT lower+compile every (arch x shape x mesh) cell.

Per cell:
  1. compile the full (scanned) step -> memory_analysis (fit proof),
     cost_analysis (per-device base cost), HLO text (collective schedule);
  2. compile the cell's probes (one layer group / SSM chunk body at full
     shapes+shardings) -> exact per-layer FLOPs/bytes; combine with known
     trip counts (launch.roofline);
  3. parse collective wire bytes from the HLO (loop-trip multiplied);
  4. emit one JSON record (appended to the output JSONL immediately).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --cells all --meshes both \
      --out experiments/dryrun.jsonl
  PYTHONPATH=src python -m repro.launch.dryrun --cells qwen2.5-3b:train_4k
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.launch import cells as C  # noqa: E402
from repro.launch import hlo_analysis as H  # noqa: E402
from repro.launch import roofline as R  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def run_cell(arch: str, shape: str, multi_pod: bool, rules=None,
             label: str = "baseline", skip_probes: bool = False,
             accum=None, cache_seq_axis=None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = C.build_cell(arch, shape, mesh, multi_pod, rules=rules,
                        accum=accum, cache_seq_axis=cache_seq_axis)
    rec = {"arch": arch, "shape": shape, "kind": cell.kind, "label": label,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "chips": 512 if multi_pod else 256, **cell.meta}
    with mesh:
        jitted = jax.jit(cell.step, donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args, **cell.kwargs)
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)

        ma = compiled.memory_analysis()
        print(ma)
        rec["memory"] = {
            "argument_gb": ma.argument_size_in_bytes / 1e9,
            "output_gb": ma.output_size_in_bytes / 1e9,
            "alias_gb": ma.alias_size_in_bytes / 1e9,
            "temp_gb": ma.temp_size_in_bytes / 1e9,
        }
        ca = compiled.cost_analysis()
        print({k: ca.get(k) for k in ("flops", "bytes accessed")})
        step_cost = {"flops": float(ca.get("flops", 0.0)),
                     "bytes accessed": float(ca.get("bytes accessed", 0.0))}

        hlo = compiled.as_text()
        rec["collectives"] = H.collective_bytes(hlo)

        probe_costs = []
        rec["probes"] = {}
        if not skip_probes:
            for lbl, mult, fn, pargs in cell.probes:
                pl = jax.jit(fn).lower(*pargs)
                pc = pl.compile().cost_analysis()
                cost = {"flops": float(pc.get("flops", 0.0)),
                        "bytes accessed": float(pc.get("bytes accessed", 0.0))}
                probe_costs.append((mult, cost))
                rec["probes"][lbl] = {"multiplier": mult, **cost}

        totals = R.combine_costs(step_cost, probe_costs)
        rec.update(totals)
        wire = rec["collectives"]["wire_bytes_total"]
        rec["roofline"] = R.roofline(totals["flops_per_device"],
                                     totals["bytes_per_device"], wire)
        mf = R.model_flops(cell.cfg, cell.kind, cell.meta["seq"],
                           cell.meta["batch"])
        rec["model_flops_total"] = mf
        per_dev = totals["flops_per_device"]
        rec["model_flops_per_device"] = mf / rec["chips"]
        rec["useful_flops_ratio"] = (mf / rec["chips"]) / per_dev if per_dev else 0.0
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default="all",
                    help="'all' or comma list of arch:shape")
    ap.add_argument("--meshes", default="both", choices=["both", "single", "multi"])
    ap.add_argument("--out", default="experiments/dryrun.jsonl")
    ap.add_argument("--rules", default="default",
                    choices=["default", "fsdp", "pure_dp"])
    ap.add_argument("--label", default="baseline")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--cache-seq-axis", default=None,
                    choices=[None, "data", "model"])
    ap.add_argument("--skip-probes", action="store_true")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already present in --out")
    args = ap.parse_args()

    if args.cells == "all":
        todo = C.cell_list()
    else:
        todo = [tuple(c.split(":")) for c in args.cells.split(",")]
    meshes = {"both": [False, True], "single": [False], "multi": [True]}[args.meshes]

    from repro.distributed.sharding import (DEFAULT_RULES, FSDP_RULES,
                                            PURE_DP_RULES)
    rules = {"default": DEFAULT_RULES, "fsdp": FSDP_RULES,
             "pure_dp": PURE_DP_RULES}[args.rules]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.skip_done and os.path.exists(args.out):
        for line in open(args.out):
            try:
                r = json.loads(line)
                if r.get("ok"):
                    done.add((r["arch"], r["shape"], r["mesh"], r["label"]))
            except json.JSONDecodeError:
                pass

    n_fail = 0
    for arch, shape in todo:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            if (arch, shape, mesh_name, args.label) in done:
                continue
            print(f"=== {arch} x {shape} x {mesh_name} [{args.label}]", flush=True)
            try:
                rec = run_cell(arch, shape, mp, rules=rules, label=args.label,
                               skip_probes=args.skip_probes, accum=args.accum,
                               cache_seq_axis=args.cache_seq_axis)
                rec["ok"] = True
            except Exception as e:  # record and continue — failures are bugs
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "label": args.label, "ok": False, "error": repr(e)}
                n_fail += 1
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
            print(f"    -> ok={rec['ok']}", flush=True)
    print(f"done; failures={n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
