"""End-to-end training driver with checkpoint/restart and failure simulation.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
        --steps 50 --ckpt-every 10 --ckpt-dir /tmp/run1
    # kill it any time; rerunning the same command resumes from the last
    # committed checkpoint (including data-pipeline position and the Verdict
    # synopsis if attached).  --simulate-failure N aborts at step N to
    # exercise the restart path deterministically.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import TokenPipeline
from repro.ft.checkpoint import CheckpointManager
from repro.models import params as PM
from repro.models.common import ShardCtx
from repro.training.optimizer import adamw, adafactor, cosine_schedule
from repro.training.train_loop import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "adafactor"])
    ap.add_argument("--simulate-failure", type=int, default=-1)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    opt = adamw() if args.optimizer == "adamw" else adafactor()
    sched = cosine_schedule(args.lr, warmup=max(args.steps // 10, 1),
                            total=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt, ShardCtx(), accum=args.accum))

    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch * args.accum, seed=0,
                         over_factor=1)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    params = PM.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    start = 0
    if mgr.latest_step() is not None:
        (params, opt_state), extra = mgr.restore((params, opt_state))
        start = extra["step"] + 1
        pipe.load_state_dict(extra["pipe"])
        print(f"[restore] resumed from step {extra['step']}")

    t0 = time.time()
    for step in range(start, args.steps):
        toks, labels = pipe.next_batch()
        batch = {
            "tokens": jnp.asarray(toks.reshape(args.accum, args.batch, args.seq)),
            "labels": jnp.asarray(labels.reshape(args.accum, args.batch, args.seq)),
        }
        if cfg.cross_attn:
            batch["ctx"] = jnp.zeros(
                (args.accum, args.batch, cfg.cross_attn.n_ctx, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        if cfg.enc_dec:
            batch["enc"] = jnp.zeros((args.accum, args.batch, args.seq, cfg.d_model),
                                     jnp.dtype(cfg.compute_dtype))
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             sched(step))
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if step == args.simulate_failure:
            print(f"[failure] simulated crash at step {step}")
            raise SystemExit(42)
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            mgr.save_async(step, (params, opt_state),
                           {"step": step, "pipe": pipe.state_dict()})
    mgr.wait()
    mgr.save(args.steps - 1, (params, opt_state),
             {"step": args.steps - 1, "pipe": pipe.state_dict()})
    print("done")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
