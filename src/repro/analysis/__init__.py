"""Static analysis of the engine's compiled artifacts and source tree.

Two layers (see ``trace_rules`` / ``ast_rules``), one CLI
(``python -m repro.analysis --strict``), one benchmark metric
(``analysis/violations``). This module stays import-light: jax is only
pulled in when a trace rule actually runs.
"""
from repro.analysis.cli import run_repo_analysis, violation_count
from repro.analysis.findings import (ERROR, INFO, WARN, Finding, gate_count,
                                     render_json, render_text, sort_findings)

__all__ = [
    "ERROR", "INFO", "WARN", "Finding", "gate_count", "render_json",
    "render_text", "sort_findings", "run_repo_analysis", "violation_count",
]
