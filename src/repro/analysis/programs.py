"""Representative-shape lowering harness for the trace/HLO rules.

The parity and performance contracts of the scan plane are properties of the
*compiled artifacts*, not of the Python source: the 512x128 canonical fold,
the collective-free sharded mask build, the fused kernel's (T, Q)-mask-free
HBM footprint and the end-to-end f64 policy all live in the jaxpr /
StableHLO the engine actually runs. This module lowers the engine's jitted
programs once, for one deliberately awkward representative shape (tuple and
snippet counts that are NOT tile multiples, so every padding branch is
exercised), and hands the artifacts to ``repro.analysis.trace_rules``.

Nothing here executes a scan: ``jax.make_jaxpr`` and ``.lower()`` trace and
lower without running the computation.

Every program carries *tags* naming which rules apply:

``fold-dot``     the canonical tuple-axis fold: every contraction over the
                 tuple axis must be a fixed (512, 128) x (512, P) dot.
``fold-order``   the fold must be an ascending left-fold (checkable only
                 where the tile slices are static, i.e. the jnp paths).
``partials-f64`` feeds ``Partials``: interpret-mode f64 end to end, no
                 f64->f32 truncation anywhere on the path.
``mask-build``   the sharded predicate-mask build: ZERO collectives.
``agg``          an aggregation program: collective count bounded by
                 ``PSUM_BOUND``.
``fused``        the fused-kernel path: no intermediate >= (T, Q) may appear
                 in the lowered module (the mask must stay tiled in VMEM).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import SnippetBatch

# Representative abstract shape: every axis chosen to be tile-unaligned so
# the lowered programs contain the padding + multi-tile structure (T pads to
# 1536 = 3 tuple tiles, Q pads to 256 = 2 snippet tiles).
REP_T = 1500  # tuples per block
REP_Q = 200  # snippets per fused batch
REP_L = 2  # numeric dimension attributes
REP_C = 1  # categorical dimension attributes
REP_V = 3  # padded one-hot width
REP_M = 2  # measure attributes

# Collective budget of aggregation programs. The current design needs ZERO
# (the gathered mask is reduced on one device, replaying the oracle order);
# a future per-shard partial-reduction would be allowed at most one psum.
PSUM_BOUND = 1


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def abstract_snippets(q: int = REP_Q, l: int = REP_L, c: int = REP_C,
                      v: int = REP_V) -> SnippetBatch:
    """A ShapeDtypeStruct SnippetBatch (tracing/lowering only, no data)."""
    return SnippetBatch(
        lo=_sds((q, l), jnp.float64),
        hi=_sds((q, l), jnp.float64),
        cat=_sds((q, c, v), jnp.bool_),
        agg=_sds((q,), jnp.int32),
        measure=_sds((q,), jnp.int32),
    )


def block_structs(t: int = REP_T, l: int = REP_L, c: int = REP_C,
                  m: int = REP_M):
    """(num_normalized, cat, measures, valid) structs for one tuple block."""
    return (
        _sds((t, l), jnp.float64),
        _sds((t, c), jnp.int32),
        _sds((t, m), jnp.float64),
        _sds((t,), jnp.float64),
    )


@dataclasses.dataclass
class Program:
    """One lowered engine program plus its rule applicability tags."""

    name: str
    fn: Callable
    args: tuple
    tags: frozenset
    # The true (unpadded) block shape the args describe — what the
    # no-(T, Q)-buffer rule measures "escaped to HBM" against.
    t: int = REP_T
    q: int = REP_Q
    _jaxpr: Optional[jax.core.ClosedJaxpr] = None
    _stablehlo: Optional[str] = None

    def jaxpr(self) -> jax.core.ClosedJaxpr:
        if self._jaxpr is None:
            self._jaxpr = jax.make_jaxpr(self.fn)(*self.args)
        return self._jaxpr

    def stablehlo(self) -> str:
        if self._stablehlo is None:
            fn = self.fn
            lower = getattr(fn, "lower", None)
            if lower is None:
                lower = jax.jit(fn).lower
            self._stablehlo = lower(*self.args).as_text()
        return self._stablehlo


def _mesh_for_analysis():
    """A 1-D mesh over every visible device (the CLI forces 8 fake host
    devices before jax initializes, mirroring conftest.py; under a pre-locked
    single-device topology the mesh degenerates to one shard — the rules
    still apply, shard_map lowers either way)."""
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()), ("data",))


def engine_programs() -> List[Program]:
    """Lower the scan plane's jitted programs for the representative shape.

    The program set mirrors the bitwise-parity contract surface: the
    canonical fold and its three consumers (oracle, sharded gathered-mask,
    fused kernel), the sharded mask builder, and the remaining public kernel
    ops (`repro.kernels`).
    """
    from repro.aqp import executor
    from repro.kernels.fused_masked_scan import ops as fms_ops
    from repro.kernels.gp_batch_infer import ops as gp_ops
    from repro.kernels.range_mask_agg import ops as rma_ops
    from repro.kernels.se_covariance import ops as se_ops

    num, cat, meas, valid = block_structs()
    snips = abstract_snippets()
    mask = _sds((REP_T, REP_Q), jnp.float64)
    payload = _sds((REP_T, 2 * REP_M + 1), jnp.float64)
    scanned = _sds((), jnp.float64)

    progs = [
        Program(
            "masked_tile_fold", executor.masked_tile_fold, (mask, payload),
            frozenset({"fold-dot", "fold-order", "partials-f64"}),
        ),
        Program(
            "_partials_from_mask", executor._partials_from_mask,
            (mask, meas, snips, scanned),
            frozenset({"fold-dot", "fold-order", "partials-f64", "agg"}),
        ),
        Program(
            "eval_partials", executor.eval_partials,
            (num, cat, meas, snips, valid),
            frozenset({"fold-dot", "fold-order", "partials-f64"}),
        ),
        # The fused Pallas kernel (interpret mode): the grid accumulation is
        # dynamic (no static slice offsets to order-check), but the fold-dot
        # shape, the f64 policy and the no-(T, Q)-in-HBM contract all hold in
        # its lowered module.
        Program(
            "eval_partials_fused", fms_ops.eval_partials_fused,
            (num, cat, meas, snips, valid),
            frozenset({"fold-dot", "partials-f64", "fused"}),
        ),
        Program(
            "masked_partials_fused", fms_ops.masked_partials_fused,
            (mask, meas, snips, scanned),
            frozenset({"fold-dot", "partials-f64", "agg"}),
        ),
        # Legacy partial-coverage scan kernel: off the engine path since
        # PR 6. Deliberately NOT tagged partials-f64 — it accumulates in
        # f32 by design (TPU-style) and casts back at the epilogue; running
        # check_partials_f64 over it emits ~18 truncation diagnostics,
        # which is precisely why fused_masked_scan replaced it. Kept under
        # the collective-bound rule only.
        Program(
            "range_mask_agg.eval_partials_kernel",
            rma_ops.eval_partials_kernel, (num, cat, meas, snips, valid),
            frozenset({"agg"}),
        ),
        Program(
            "se_cov_matrix", se_ops.se_cov_matrix,
            (_sds((REP_Q, REP_L), jnp.float64),
             _sds((REP_Q, REP_L), jnp.float64),
             _sds((REP_Q, REP_L), jnp.float64),
             _sds((REP_Q, REP_L), jnp.float64),
             _sds((REP_L,), jnp.float64), 1.0,
             _sds((REP_Q,), jnp.float64), _sds((REP_Q,), jnp.float64)),
            frozenset({"agg"}),
        ),
        Program(
            "gp_batch_infer", gp_ops.gp_batch_infer,
            (_sds((REP_Q, 64), jnp.float64), _sds((64, 64), jnp.float64),
             _sds((64,), jnp.float64), _sds((REP_Q,), jnp.float64),
             _sds((REP_Q,), jnp.float64), _sds((REP_Q,), jnp.float64),
             _sds((REP_Q,), jnp.float64)),
            frozenset({"agg"}),
        ),
    ]
    mesh = _mesh_for_analysis()
    sharded_fn = executor._sharded_mask_fn(mesh, "data")
    # The mask builder consumes the PADDED block (what eval_partials_sharded
    # hands it): pad the tuple axis to the mesh-divisible power-of-two tile.
    t_pad = executor.padded_tuple_count(REP_T, mesh.shape["data"])
    num_p, cat_p, _, valid_p = (
        _sds((t_pad, REP_L), jnp.float64),
        _sds((t_pad, REP_C), jnp.int32),
        None,
        _sds((t_pad,), jnp.float64),
    )
    progs.append(Program(
        "sharded_mask_build", sharded_fn, (num_p, cat_p, valid_p, snips),
        frozenset({"mask-build", "partials-f64"}),
        t=t_pad,
    ))
    return progs


def by_tag(programs: List[Program]) -> Dict[str, List[Program]]:
    out: Dict[str, List[Program]] = {}
    for p in programs:
        for tag in p.tags:
            out.setdefault(tag, []).append(p)
    return out
