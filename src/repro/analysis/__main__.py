import sys

from repro.analysis.cli import force_topology, main

force_topology()  # before anything imports jax
sys.exit(main())
