"""Structured findings: what every analysis rule emits.

A ``Finding`` is one violation (or inventory note) with a stable rule id, a
severity, a location (``file:line`` for AST rules, a program name for trace
rules), a human message and a fix hint. Severities:

``error``  -- a broken contract; always fails the gate.
``warn``   -- a suspicious state that needs an explicit allowlist entry;
              fails only under ``--strict`` (the CI mode).
``info``   -- inventory (e.g. idle modules with a recorded keep-reason);
              never fails.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Sequence

ERROR = "error"
WARN = "warn"
INFO = "info"

_SEVERITY_ORDER = {ERROR: 0, WARN: 1, INFO: 2}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str  # stable rule id, e.g. "T001"
    severity: str  # ERROR | WARN | INFO
    location: str  # "path/to/file.py:42" or "program:masked_tile_fold"
    message: str  # what is wrong, concretely
    hint: str = ""  # how to fix it

    def __post_init__(self):
        if self.severity not in _SEVERITY_ORDER:
            raise ValueError(f"unknown severity {self.severity!r}")

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def sort_findings(findings: Sequence[Finding]) -> List[Finding]:
    return sorted(
        findings,
        key=lambda f: (_SEVERITY_ORDER[f.severity], f.rule, f.location),
    )


def gate_count(findings: Sequence[Finding], strict: bool = True) -> int:
    """Number of findings that fail the gate (errors; + warns when strict)."""
    bad = {ERROR, WARN} if strict else {ERROR}
    return sum(1 for f in findings if f.severity in bad)


def render_text(findings: Sequence[Finding]) -> str:
    if not findings:
        return "no findings"
    lines = []
    for f in sort_findings(findings):
        lines.append(f"[{f.severity:<5}] {f.rule} {f.location}")
        lines.append(f"        {f.message}")
        if f.hint:
            lines.append(f"        fix: {f.hint}")
    counts = {}
    for f in findings:
        counts[f.severity] = counts.get(f.severity, 0) + 1
    tally = ", ".join(f"{counts.get(s, 0)} {s}" for s in (ERROR, WARN, INFO))
    lines.append(f"-- {len(findings)} finding(s): {tally}")
    return "\n".join(lines)


def render_json(findings: Sequence[Finding]) -> str:
    return json.dumps([f.as_dict() for f in sort_findings(findings)], indent=1)
