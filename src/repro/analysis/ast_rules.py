"""Layer 2: lightweight AST rules over the ``repro`` source tree.

Where Layer 1 proves properties of the compiled artifacts, these rules
enforce the *access-path discipline* that keeps those artifacts the only
way state flows through the system:

A001  ``.synopses`` access only through the owner (``core/store.py``) and
      the deprecated ``VerdictEngine.synopses`` shim (``core/engine.py``) —
      every other caller must go through the ``SynopsisStore`` API so
      placement/quarantine bookkeeping cannot be bypassed.
A002  ``Synopsis`` state is mutated only via ``_guarded_apply`` (and its
      ``heal`` replay): a direct ``_apply_add`` call skips the quarantine
      fence and lets a failed covariance build corrupt serving state.
A003  fault-seam registry/call-site coherence: every string passed to
      ``faults.fire`` is a registered point in ``repro.ft.faults.POINTS``,
      and every registered point is actually wrapped at >= 1 call site.
A004  determinism inside ``repro.kernels``: no wall-clock, no RNG — kernel
      outputs must be pure functions of their operands (bitwise parity
      depends on it).
A005  dead-code inventory: every module is imported somewhere (src, tests
      or benchmarks), registered dynamically, a known entry point, or
      carries an explicit keep-reason in the allowlist.
A006  epsilon discipline: no local epsilon literal in the half-open band
      (1e-15, 1e-5] inside the kernels or the executor — the shared
      ``RANGE_EPS`` is the single source of truth (the pre-PR-6 parity
      drift was exactly a kernel-local ``1e-7`` vs the oracle's ``1e-12``).
A007  determinism inside ``repro.intel``: no wall-clock and no RNG in the
      workload-intelligence plane — cache keys and router features must be
      pure functions of the plan IR and engine state, or keys stop
      persisting across processes and route decisions stop replaying.
A008  clock-free serving-front decision modules: admission control and
      metrics bucketing (``serving/front/{admission,metrics}.py``) take
      timestamps/durations as arguments — the transport layer owns the
      clock — so admission decisions are seedable and replay exactly.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
from typing import Callable, Dict, List, Optional, Sequence, Set

from repro.analysis.findings import ERROR, INFO, WARN, Finding

SRC_ROOT = pathlib.Path(__file__).resolve().parents[1]  # src/repro
REPO_ROOT = SRC_ROOT.parents[1]

# --------------------------------------------------------------- allowlists

# A001: the only files allowed to touch `.synopses` / `._synopses`.
SYNOPSES_ALLOW = ("core/store.py", "core/engine.py")
# A002: the only (file, enclosing function) pairs allowed to call _apply_add.
GUARDED_APPLY_ALLOW = ("_guarded_apply", "heal")
# A006: where the shared epsilon is *defined* (literals allowed there).
EPSILON_DEF_SITE = ("kernels/__init__.py",)

# A005: modules with no static importer that are kept on purpose.
# Dynamic registry: configs/* are loaded via importlib from the ARCHS table.
DYNAMIC_IMPORT_PREFIXES = {
    "repro.configs.": "registered in repro.configs.ARCHS, "
                      "loaded via importlib.import_module",
}
# Entry points: roots of the import graph by design.
ENTRY_POINTS = {
    "repro.launch.train": "CLI trainer (python -m repro.launch.train)",
    "repro.analysis.__main__": "CLI (python -m repro.analysis)",
    "repro.analysis.cli": "CLI implementation module",
}
# Idle-but-kept: reachable only from tests/benchmarks today; each entry
# records WHY it stays (the dead-code satellite's explicit allowlist).
IDLE_KEEP = {
    "repro.aqp.online": "online-aggregation comparison baseline for the "
                        "paper's §7 accuracy study",
    "repro.aqp.workload": "query/workload generator shared by the test "
                          "suite and every benchmark driver",
    "repro.launch.cells": "assigned-architecture launch cells; exercised "
                          "by tests/test_launch_units.py",
    "repro.launch.roofline": "roofline model behind "
                             "benchmarks/roofline_report.py",
    "repro.launch.hlo_analysis": "HLO cost extraction behind "
                                 "benchmarks/roofline_report.py",
    "repro.launch.mesh": "mesh topology helpers for the launch cells",
    "repro.distributed.compression": "gradient/state compression for the "
                                     "elastic trainer; tests/test_ft.py",
    "repro.ft.elastic": "elastic re-sharding restore path; "
                        "tests/test_ft.py",
    "repro.kernels.fused_masked_scan.ref": "reference oracle for kernel "
                                           "parity tests and benchmarks",
    "repro.kernels.gp_batch_infer.ref": "reference oracle for kernel "
                                        "parity tests and benchmarks",
    "repro.kernels.range_mask_agg.ref": "reference oracle for kernel "
                                        "parity tests and benchmarks",
    "repro.kernels.se_covariance.ref": "reference oracle for kernel "
                                       "parity tests and benchmarks",
}


# ------------------------------------------------------------- file parsing


@dataclasses.dataclass
class ParsedFile:
    path: pathlib.Path
    rel: str  # posix path relative to the scanned root, e.g. "core/store.py"
    tree: ast.AST


def parse_tree(root: pathlib.Path) -> List[ParsedFile]:
    root = pathlib.Path(root)
    out = []
    for p in sorted(root.rglob("*.py")):
        rel = p.relative_to(root).as_posix()
        out.append(ParsedFile(p, rel, ast.parse(p.read_text(), str(p))))
    return out


def _loc(pf: ParsedFile, node: ast.AST) -> str:
    return f"{pf.rel}:{getattr(node, 'lineno', 0)}"


# ------------------------------------------------------------------- A001


def check_synopses_access(
    files: Sequence[ParsedFile],
    allow: Sequence[str] = SYNOPSES_ALLOW,
) -> List[Finding]:
    out: List[Finding] = []
    for pf in files:
        if pf.rel in allow:
            continue
        for node in ast.walk(pf.tree):
            if isinstance(node, ast.Attribute) \
                    and node.attr in ("synopses", "_synopses"):
                out.append(Finding(
                    "A001", ERROR, _loc(pf, node),
                    f"direct `.{node.attr}` access outside the store and "
                    "the deprecated engine shim",
                    "go through the SynopsisStore API (get/ensure/items/"
                    "state_dict); the dict is an implementation detail and "
                    "bypassing it skips placement + quarantine bookkeeping",
                ))
    return out


# ------------------------------------------------------------------- A002


def check_guarded_apply(
    files: Sequence[ParsedFile],
    owner_file: str = "core/synopsis.py",
    allow_fns: Sequence[str] = GUARDED_APPLY_ALLOW,
) -> List[Finding]:
    out: List[Finding] = []

    class V(ast.NodeVisitor):
        def __init__(self, pf: ParsedFile):
            self.pf = pf
            self.stack: List[str] = []

        def visit_FunctionDef(self, node):
            self.stack.append(node.name)
            self.generic_visit(node)
            self.stack.pop()

        visit_AsyncFunctionDef = visit_FunctionDef

        def visit_Call(self, node):
            fn = node.func
            name = fn.attr if isinstance(fn, ast.Attribute) else \
                fn.id if isinstance(fn, ast.Name) else None
            if name == "_apply_add":
                ok = (self.pf.rel == owner_file
                      and any(s in allow_fns for s in self.stack))
                if not ok:
                    out.append(Finding(
                        "A002", ERROR, _loc(self.pf, node),
                        "`_apply_add` called outside "
                        f"{owner_file}:{'/'.join(allow_fns)} — Synopsis "
                        "state mutated without the quarantine fence",
                        "route the batch through Synopsis._guarded_apply "
                        "(add/drain do); a raising _apply_add must park the "
                        "batch and quarantine, never propagate",
                    ))
            self.generic_visit(node)

    for pf in files:
        V(pf).visit(pf.tree)
    return out


# ------------------------------------------------------------------- A003


def check_fault_seams(
    files: Sequence[ParsedFile],
    points: Optional[Sequence[str]] = None,
) -> List[Finding]:
    if points is None:
        from repro.ft.faults import POINTS as points  # registry of record
    out: List[Finding] = []
    seen: Set[str] = set()
    for pf in files:
        for node in ast.walk(pf.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            name = fn.attr if isinstance(fn, ast.Attribute) else \
                fn.id if isinstance(fn, ast.Name) else None
            if name != "fire" or not node.args:
                continue
            arg = node.args[0]
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                if arg.value in points:
                    seen.add(arg.value)
                else:
                    out.append(Finding(
                        "A003", ERROR, _loc(pf, node),
                        f"fire({arg.value!r}) names a fault seam that is "
                        "not registered in repro.ft.faults.POINTS",
                        "add the point to POINTS (with a docstring line "
                        "describing the seam) or fix the typo; unregistered "
                        "seams are invisible to FaultPlan and chaos tests",
                    ))
            else:
                out.append(Finding(
                    "A003", WARN, _loc(pf, node),
                    "fire() called with a non-literal point name — the "
                    "registry check cannot verify it statically",
                    "pass the seam name as a string literal",
                ))
    for point in points:
        if point not in seen:
            out.append(Finding(
                "A003", ERROR, f"registry:{point}",
                f"fault seam {point!r} is registered in POINTS but never "
                "wrapped at any call site",
                "call faults.fire({!r}) at the seam it documents, or drop "
                "the registration".format(point),
            ))
    return out


# ----------------------------------------------------- A004 / A007 / A008

_CLOCK_RNG_MODULES = {"time", "random", "secrets", "datetime"}
_RNG_ATTR_BASES = {"np", "numpy", "jax"}


def _clock_rng_uses(tree: ast.AST):
    """Yield ``(node, description)`` for every wall-clock/RNG use: imports
    of the clock/RNG stdlib modules, ``jax.random`` imports, and
    ``np/numpy/jax .random`` attribute access. The shared detector behind
    the determinism rules (A004 kernels, A007 intel, A008 serving front)."""
    for node in ast.walk(tree):
        bad = None
        if isinstance(node, ast.Import):
            mods = [a.name.split(".")[0] for a in node.names]
            hit = sorted(set(mods) & _CLOCK_RNG_MODULES)
            if hit:
                bad = f"imports {', '.join(hit)}"
        elif isinstance(node, ast.ImportFrom) and node.module:
            top = node.module.split(".")[0]
            if top in _CLOCK_RNG_MODULES:
                bad = f"imports from {node.module}"
            elif node.module == "jax" and any(
                    a.name == "random" for a in node.names):
                bad = "imports jax.random"
        elif isinstance(node, ast.Attribute) and node.attr == "random" \
                and isinstance(node.value, ast.Name) \
                and node.value.id in _RNG_ATTR_BASES:
            bad = f"uses {node.value.id}.random"
        if bad:
            yield node, bad


def _in_kernels(rel: str) -> bool:
    return rel.startswith("kernels/")


def check_kernel_determinism(
    files: Sequence[ParsedFile],
    scope: Optional[Callable[[str], bool]] = _in_kernels,
) -> List[Finding]:
    out: List[Finding] = []
    for pf in files:
        if scope is not None and not scope(pf.rel):
            continue
        for node, bad in _clock_rng_uses(pf.tree):
            out.append(Finding(
                "A004", ERROR, _loc(pf, node),
                f"kernel module {bad} — wall-clock/RNG inside "
                "repro.kernels breaks determinism",
                "kernel outputs must be pure functions of their "
                "operands (bitwise parity depends on it); thread keys/"
                "timestamps in from the caller if truly needed",
            ))
    return out


def _in_intel(rel: str) -> bool:
    return rel.startswith("intel/")


def check_intel_determinism(
    files: Sequence[ParsedFile],
    scope: Optional[Callable[[str], bool]] = _in_intel,
) -> List[Finding]:
    """A004's discipline applied to the workload-intelligence plane.

    Cache-key derivation (``QuerySignature``) and router features must be
    pure functions of the plan IR and engine state: a wall-clock read makes
    staleness decisions replay-dependent, an RNG draw makes two processes
    derive different keys for the same query (and ``hash()`` randomization
    is why keys go through blake2b, never ``hash()``).
    """
    out: List[Finding] = []
    for pf in files:
        if scope is not None and not scope(pf.rel):
            continue
        for node, bad in _clock_rng_uses(pf.tree):
            out.append(Finding(
                "A007", ERROR, _loc(pf, node),
                f"intel module {bad} — wall-clock/RNG inside "
                "repro.intel breaks cache-key/router determinism",
                "cache keys and router features must be pure functions "
                "of the plan IR and engine state (generation counters, "
                "fill buckets); measure latency in benchmarks, never in "
                "the serving plane",
            ))
    return out


# A008: clock-free serving-front decision modules. The transport/composition
# layer (front.py, http.py) legitimately measures time; the DECISION modules
# (admission, metrics bucketing) must stay pure functions of injected
# timestamps so admission traces replay deterministically.
FRONT_DECISION_MODULES = (
    "serving/front/admission.py",
    "serving/front/metrics.py",
)


def _in_front_decisions(rel: str) -> bool:
    return rel in FRONT_DECISION_MODULES


def check_front_determinism(
    files: Sequence[ParsedFile],
    scope: Optional[Callable[[str], bool]] = _in_front_decisions,
) -> List[Finding]:
    """The determinism discipline applied to the serving front's decision
    modules: admission (token bucket, queue bound) and metrics (latency
    bucketing) take ``now``/durations as ARGUMENTS — a direct clock read or
    RNG draw there makes admission decisions unreplayable and rate-limit
    tests flaky. The transport layer owns the clock and injects it.
    """
    out: List[Finding] = []
    for pf in files:
        if scope is not None and not scope(pf.rel):
            continue
        for node, bad in _clock_rng_uses(pf.tree):
            out.append(Finding(
                "A008", ERROR, _loc(pf, node),
                f"serving-front decision module {bad} — admission/metrics "
                "must be pure functions of injected timestamps",
                "take `now` (or the duration) as an argument and let the "
                "transport layer (front.py/http.py) read the clock; "
                "seedable decisions are what make admission traces replay",
            ))
    return out


# ------------------------------------------------------------------- A005


def _module_name(rel: str) -> str:
    parts = rel[:-3].split("/")  # strip .py
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(["repro"] + parts) if parts else "repro"


def _imports_of(tree: ast.AST, self_mod: str) -> Set[str]:
    """Absolute dotted names this module imports (repro.* only)."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "repro" or a.name.startswith("repro."):
                    out.add(a.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                base = self_mod.split(".")
                # from the module's package, go up (level - 1) more
                base = base[: len(base) - node.level]
                mod = ".".join(base + ([node.module] if node.module else []))
            else:
                mod = node.module or ""
            if not (mod == "repro" or mod.startswith("repro.")):
                continue
            out.add(mod)
            for a in node.names:
                out.add(f"{mod}.{a.name}")  # may be a symbol; filtered later
    return out


def check_dead_code(
    src_root: pathlib.Path = SRC_ROOT,
    importer_roots: Sequence[pathlib.Path] = (),
    idle_keep: Dict[str, str] = IDLE_KEEP,
    entry_points: Dict[str, str] = ENTRY_POINTS,
) -> List[Finding]:
    files = parse_tree(src_root)
    modules = {_module_name(pf.rel): pf for pf in files}
    importers: Dict[str, Set[str]] = {m: set() for m in modules}

    def credit(targets: Set[str], importer: str, external: bool):
        for t in targets:
            if t not in modules:
                continue
            tag = f"{'ext:' if external else ''}{importer}"
            # importing repro.x.y also executes every ancestor __init__
            parts = t.split(".")
            for i in range(2, len(parts) + 1):
                anc = ".".join(parts[:i])
                if anc in importers and anc != importer:
                    importers[anc].add(tag)

    for pf in files:
        mod = _module_name(pf.rel)
        credit(_imports_of(pf.tree, mod), mod, external=False)
    for root in importer_roots:
        root = pathlib.Path(root)
        if not root.exists():
            continue
        for ext in parse_tree(root):
            credit(_imports_of(ext.tree, "external"),
                   f"{root.name}/{ext.rel}", external=True)

    out: List[Finding] = []
    for mod in sorted(modules):
        pf = modules[mod]
        dyn = next((r for p, r in DYNAMIC_IMPORT_PREFIXES.items()
                    if mod.startswith(p)), None)
        if dyn is not None:
            out.append(Finding("A005", INFO, pf.rel,
                               f"{mod}: no static importer ({dyn})", ""))
            continue
        if mod in entry_points:
            continue
        who = importers[mod]
        src_importers = {w for w in who if not w.startswith("ext:")}
        if src_importers:
            continue
        if mod in idle_keep:
            out.append(Finding(
                "A005", INFO, pf.rel,
                f"{mod}: idle (no src importer); kept: {idle_keep[mod]}",
                "",
            ))
        elif who:
            out.append(Finding(
                "A005", WARN, pf.rel,
                f"{mod}: reachable only from "
                f"{', '.join(sorted(w[4:] for w in who))} — idle in src",
                "add an IDLE_KEEP entry in repro/analysis/ast_rules.py "
                "with the reason it stays, or delete it",
            ))
        else:
            out.append(Finding(
                "A005", ERROR, pf.rel,
                f"{mod}: dead module — nothing in src, tests or benchmarks "
                "imports it",
                "delete it (git history keeps it), or register the dynamic "
                "import / entry point that reaches it",
            ))
    return out


# ------------------------------------------------------------------- A006

EPS_BAND_LO = 1e-15
EPS_BAND_HI = 1e-5


def _in_epsilon_scope(rel: str) -> bool:
    return rel.startswith("kernels/") or rel == "aqp/executor.py"


def check_epsilon_discipline(
    files: Sequence[ParsedFile],
    scope: Optional[Callable[[str], bool]] = _in_epsilon_scope,
    def_sites: Sequence[str] = EPSILON_DEF_SITE,
) -> List[Finding]:
    out: List[Finding] = []
    for pf in files:
        if pf.rel in def_sites:
            continue
        if scope is not None and not scope(pf.rel):
            continue
        for node in ast.walk(pf.tree):
            if isinstance(node, ast.Constant) \
                    and isinstance(node.value, float) \
                    and EPS_BAND_LO < abs(node.value) <= EPS_BAND_HI:
                out.append(Finding(
                    "A006", ERROR, _loc(pf, node),
                    f"local epsilon literal {node.value!r} in the scan "
                    "plane — epsilon drift between kernel and oracle",
                    "import RANGE_EPS from repro.kernels (the single "
                    "epsilon of record; the pre-PR-6 parity drift was a "
                    "kernel-local 1e-7 vs the oracle's 1e-12)",
                ))
    return out


# ------------------------------------------------------------------- driver

AST_RULES = ("A001", "A002", "A003", "A004", "A005", "A006", "A007", "A008")


def run_ast_rules(
    src_root: pathlib.Path = SRC_ROOT,
    repo_root: pathlib.Path = REPO_ROOT,
    rules: Optional[Sequence[str]] = None,
) -> List[Finding]:
    rules = set(AST_RULES if rules is None else rules)
    files = parse_tree(src_root)
    out: List[Finding] = []
    if "A001" in rules:
        out.extend(check_synopses_access(files))
    if "A002" in rules:
        out.extend(check_guarded_apply(files))
    if "A003" in rules:
        out.extend(check_fault_seams(files))
    if "A004" in rules:
        out.extend(check_kernel_determinism(files))
    if "A005" in rules:
        out.extend(check_dead_code(
            src_root,
            importer_roots=(repo_root / "tests", repo_root / "benchmarks"),
        ))
    if "A006" in rules:
        out.extend(check_epsilon_discipline(files))
    if "A007" in rules:
        out.extend(check_intel_determinism(files))
    if "A008" in rules:
        out.extend(check_front_determinism(files))
    return out
