"""Layer 1: jaxpr / StableHLO rules over the engine's lowered programs.

Each rule proves one clause of the scan plane's parity or performance
contract *statically* — by walking the traced jaxpr or the lowered StableHLO
of the programs in ``repro.analysis.programs`` — instead of hoping a parity
test happens to trip it at runtime:

T001  canonical fold-dot shape: every tuple-axis contraction is a FIXED
      (512, 128) x (512, P) dot. This is the PR-6 invariant: XLA's CPU
      matmul picks its contraction order by operand shape, so a single
      variable-width dot (the pre-PR-6 form) breaks Q-pad invariance — the
      bug that surfaced as a 1-ulp parity flake.
T002  ascending left-fold: per snippet tile, tuple-tile partials accumulate
      strictly left-to-right in ascending tile order (``acc + part``, never
      a tree or a descending fold — fp addition is not associative).
T003  collective-free mask build: the shard_map'd predicate-mask program
      contains ZERO collective ops (the design gathers the mask and replays
      the oracle reduction; any collective here re-partitions the compare
      work and breaks bitwise parity with the oracle).
T004  bounded aggregation collectives: aggregation programs carry at most
      ``PSUM_BOUND`` all-reduces (today: zero — a psum tree rounds
      differently than the oracle fold).
T005  no (T, Q) buffer in HBM: the fused-kernel path must never materialize
      an intermediate as large as the (tuples x snippets) mask — that is
      the entire point of the fusion (~554x modeled traffic reduction).
T006  f64 policy: programs feeding ``Partials`` run f64 end to end in
      interpret mode — no f64->f32 ``convert_element_type``, no f32 output
      produced from f64 inputs (weak-type promotion), f64 outputs only.
T007  compile-cache cardinality: driving the power-of-two (Q, fill) improve
      ladder yields EXACTLY one jit cache entry per (Q-bucket, fill-bucket)
      pair — catching unhashable static args and cache-key leaks (a key
      that varies with the unpadded size compiles one program per query).
"""
from __future__ import annotations

import itertools
import re
from typing import Iterable, Iterator, List, Optional, Sequence

from repro.analysis.findings import ERROR, INFO, Finding
from repro.analysis.programs import PSUM_BOUND, Program, engine_programs

# ------------------------------------------------------------- jaxpr walking

FOLD_DIMS = (((0,), (0,)), ((), ()))  # contract the leading (tuple) axis


def _subjaxprs(eqn) -> Iterator:
    """Every (Closed)Jaxpr hiding in an eqn's params (pjit, scan, while,
    cond branches, custom_* call jaxprs, pallas interpret bodies...)."""
    import jax.core as jcore

    def visit(val):
        if isinstance(val, jcore.ClosedJaxpr):
            yield val.jaxpr
        elif isinstance(val, jcore.Jaxpr):
            yield val
        elif isinstance(val, (tuple, list)):
            for v in val:
                yield from visit(v)

    for val in eqn.params.values():
        yield from visit(val)


def iter_jaxprs(jaxpr) -> Iterator:
    """The jaxpr and, recursively, every sub-jaxpr it calls."""
    yield jaxpr
    for eqn in jaxpr.eqns:
        for sub in _subjaxprs(eqn):
            yield from iter_jaxprs(sub)


def iter_eqns(closed_jaxpr) -> Iterator:
    for j in iter_jaxprs(closed_jaxpr.jaxpr):
        yield from j.eqns


def _is_fold_dot(eqn) -> bool:
    """A tuple-axis contraction: 2-D x 2-D dot_general contracting dim 0 of
    both operands with no batch dims — the shape class of the canonical
    ``masked_tile_fold`` dot (other dots — one-hot membership, GP solves —
    contract differently and are not fold dots)."""
    if eqn.primitive.name != "dot_general":
        return False
    if tuple(map(tuple, eqn.params["dimension_numbers"][0])) != ((0,), (0,)):
        return False
    if any(eqn.params["dimension_numbers"][1]):
        return False
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    return lhs.ndim == 2 and rhs.ndim == 2


# ----------------------------------------------------------------- T001/T002


def check_fold_dot_shapes(program: Program, tile_t: Optional[int] = None,
                          tile_q: Optional[int] = None) -> List[Finding]:
    """T001: every fold dot is exactly (tile_t, tile_q) x (tile_t, P)."""
    from repro.kernels import SCAN_TILE_Q, SCAN_TILE_T

    tile_t = SCAN_TILE_T if tile_t is None else tile_t
    tile_q = SCAN_TILE_Q if tile_q is None else tile_q
    out: List[Finding] = []
    n_fold = 0
    for eqn in iter_eqns(program.jaxpr()):
        if not _is_fold_dot(eqn):
            continue
        n_fold += 1
        lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
        if lhs.shape != (tile_t, tile_q) or rhs.shape[0] != tile_t:
            out.append(Finding(
                "T001", ERROR, f"program:{program.name}",
                f"tuple-axis fold dot has shape {lhs.shape} x {rhs.shape}; "
                f"the canonical fold requires ({tile_t}, {tile_q}) x "
                f"({tile_t}, P) for every dot",
                "route the reduction through repro.aqp.executor."
                "masked_tile_fold (fixed SCAN_TILE_T x SCAN_TILE_Q tiles); "
                "variable-shape dots change XLA's contraction order and "
                "break Q-pad/block-size bitwise invariance (the PR-6 1-ulp "
                "bug)",
            ))
    if n_fold == 0:
        out.append(Finding(
            "T001", ERROR, f"program:{program.name}",
            "no tuple-axis fold dot found — the program no longer performs "
            "the canonical masked_tile_fold reduction",
            "aggregate mask x payload through masked_tile_fold so all scan "
            "paths share one bitwise reduction order",
        ))
    return out


def _lookup(mapping, var):
    """dict lookup tolerating jaxpr Literals (unhashable)."""
    try:
        return mapping.get(var)
    except TypeError:
        return None


def check_fold_order(program: Program) -> List[Finding]:
    """T002: fold partials accumulate as an ascending left-fold."""
    out: List[Finding] = []
    for jaxpr in iter_jaxprs(program.jaxpr().jaxpr):
        produced = {}
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                produced[v] = eqn
        # var -> (min tuple-tile start, max tuple-tile start, is_single_dot)
        info = {}
        for eqn in jaxpr.eqns:
            if _is_fold_dot(eqn):
                src = _lookup(produced, eqn.invars[0])
                t0 = 0
                if src is not None and src.primitive.name == "slice":
                    t0 = int(src.params["start_indices"][0])
                info[eqn.outvars[0]] = (t0, t0, True)
            elif eqn.primitive.name == "add":
                a, b = eqn.invars
                ia, ib = _lookup(info, a), _lookup(info, b)
                if ia is None or ib is None:
                    continue
                if not ib[2]:
                    out.append(Finding(
                        "T002", ERROR, f"program:{program.name}",
                        "fold add combines two accumulated subtrees — a "
                        "tree reduction, not the canonical left-fold",
                        "accumulate per-tile dot partials strictly "
                        "left-to-right (acc = acc + part), as "
                        "masked_tile_fold does",
                    ))
                elif ia[1] >= ib[0]:
                    out.append(Finding(
                        "T002", ERROR, f"program:{program.name}",
                        f"fold accumulates tuple tile t={ib[0]} after tile "
                        f"t={ia[1]} — not an ascending left-fold",
                        "fold tuple tiles in ascending start order; fp "
                        "addition is not associative, so any other order "
                        "breaks bitwise parity with the oracle",
                    ))
                info[eqn.outvars[0]] = (
                    min(ia[0], ib[0]), max(ia[1], ib[1]), False)
    return out


# ------------------------------------------------------------ T003/T004 HLO

COLLECTIVE_OPS = (
    "all_reduce", "all_gather", "all_to_all", "collective_permute",
    "collective_broadcast", "reduce_scatter",
)
_STABLEHLO_OP_RE = re.compile(r"stablehlo\.([a-z0-9_]+)")


def collective_counts(stablehlo_text: str) -> dict:
    """Occurrences of each collective op mnemonic in a StableHLO module."""
    counts: dict = {}
    for m in _STABLEHLO_OP_RE.finditer(stablehlo_text):
        op = m.group(1)
        if op in COLLECTIVE_OPS:
            counts[op] = counts.get(op, 0) + 1
    return counts


def check_mask_build_collectives(program: Program) -> List[Finding]:
    """T003: the sharded mask build lowers with ZERO collectives."""
    counts = collective_counts(program.stablehlo())
    if not counts:
        return []
    detail = ", ".join(f"{k} x{v}" for k, v in sorted(counts.items()))
    return [Finding(
        "T003", ERROR, f"program:{program.name}",
        f"sharded mask build contains collective ops ({detail}); the "
        "mask-build stage must be embarrassingly parallel over the tuple "
        "axis",
        "keep the shard_map stage to per-shard predicate compares "
        "(out_specs=P(axis)); gather the mask and replay the oracle "
        "reduction instead of reducing across shards",
    )]


def check_agg_collectives(program: Program,
                          bound: int = PSUM_BOUND) -> List[Finding]:
    """T004: aggregation programs carry a bounded collective count."""
    counts = collective_counts(program.stablehlo())
    total = sum(counts.values())
    if total <= bound:
        return []
    detail = ", ".join(f"{k} x{v}" for k, v in sorted(counts.items()))
    return [Finding(
        "T004", ERROR, f"program:{program.name}",
        f"aggregation program lowers {total} collective op(s) ({detail}), "
        f"above the bound of {bound}",
        "a per-shard psum tree rounds differently than the oracle fold; "
        "reduce on one device in canonical tile order",
    )]


# ------------------------------------------------------------------ T005 HLO

_TENSOR_RE = re.compile(r"tensor<(\d+)x(\d+)(?:x\d+)*x(?:f64|f32|i1|i8)>")


def check_no_tq_buffer(program: Program) -> List[Finding]:
    """T005: no intermediate >= (T, Q) in the fused path's lowered module."""
    t, q = program.t, program.q
    bad = set()
    for m in _TENSOR_RE.finditer(program.stablehlo()):
        a, b = int(m.group(1)), int(m.group(2))
        if (a >= t and b >= q) or (a >= q and b >= t):
            bad.add((a, b))
    if not bad:
        return []
    shapes = ", ".join(f"({a}, {b})" for a, b in sorted(bad))
    return [Finding(
        "T005", ERROR, f"program:{program.name}",
        f"fused-kernel path materializes buffer(s) of shape {shapes} — at "
        f"least the full ({t}, {q}) predicate mask escaped to HBM",
        "the mask must live tile-by-tile in VMEM only "
        "(SCAN_TILE_T x SCAN_TILE_Q blocks inside the Pallas grid); a "
        "full-mask intermediate un-fuses the scan and collapses "
        "scan/bytes_per_sec_frac_of_peak",
    )]


# ---------------------------------------------------------------- T006 dtype


def check_partials_f64(program: Program) -> List[Finding]:
    """T006: interpret-mode f64 end to end on every path feeding Partials."""
    import numpy as np

    out: List[Finding] = []
    for eqn in iter_eqns(program.jaxpr()):
        name = eqn.primitive.name
        if name == "convert_element_type":
            src = eqn.invars[0].aval
            dst = eqn.params.get("new_dtype")
            if (getattr(src, "dtype", None) == np.float64
                    and dst == np.float32):
                out.append(Finding(
                    "T006", ERROR, f"program:{program.name}",
                    "f64 -> f32 convert_element_type on a path feeding "
                    "Partials (precision truncation)",
                    "interpret mode runs f64 end to end (see "
                    "repro/kernels/fused_masked_scan/ops.py dtype policy); "
                    "only the interpret=False TPU path may cast to f32",
                ))
            continue
        out_f32 = any(
            getattr(v.aval, "dtype", None) == np.float32
            for v in eqn.outvars)
        in_f64 = any(
            getattr(v.aval, "dtype", None) == np.float64
            for v in eqn.invars if hasattr(v, "aval"))
        if out_f32 and in_f64:
            out.append(Finding(
                "T006", ERROR, f"program:{program.name}",
                f"op '{name}' produces f32 from f64 input(s) — silent "
                "precision drop (weak-type promotion or dtype drift)",
                "keep the scan plane's arithmetic in f64; check for f32 "
                "literals / weak-typed constants contaminating the path",
            ))
    for aval in program.jaxpr().out_avals:
        dt = getattr(aval, "dtype", None)
        if dt is not None and np.issubdtype(dt, np.floating) \
                and dt != np.float64:
            out.append(Finding(
                "T006", ERROR, f"program:{program.name}",
                f"program output has dtype {dt}, expected float64",
                "Partials fields are f64 by contract; cast at the epilogue "
                "only on the interpret=False TPU path",
            ))
    return out


# ---------------------------------------------------------------- T007 cache


def _snips(q: int, l: int = 2, c: int = 1, v: int = 3):
    import jax.numpy as jnp

    from repro.core.types import SnippetBatch

    return SnippetBatch(
        lo=jnp.zeros((q, l)), hi=jnp.ones((q, l)),
        cat=jnp.ones((q, c, v), bool),
        agg=jnp.ones((q,), jnp.int32),
        measure=jnp.zeros((q,), jnp.int32),
    )


def check_improve_cache_cardinality(
    jitted=None,
    q_values: Sequence[int] = (3, 8, 12, 20),
    fill_values: Sequence[int] = (5, 8, 13, 27),
) -> List[Finding]:
    """T007: one compiled improve program per (Q-bucket, fill-bucket) pair.

    Drives ``_improve_padded`` (or ``jitted``, for fixtures) exactly the way
    ``Synopsis.improve`` does — shapes padded to the power-of-two ladder —
    and counts jit cache entries. More entries than distinct bucket pairs
    means the cache key leaks the unpadded size (one compile per query, the
    regression ``improve/mixed_q_programs`` gates dynamically); a TypeError
    means an unhashable static argument.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core.synopsis import (MIN_FILL_BUCKET, MIN_Q_BUCKET,
                                     _improve_padded)
    from repro.core.types import GPParams, Schema, bucket_size, pad_snippets

    fn = _improve_padded if jitted is None else jitted
    where = "program:improve_ladder"
    if not (hasattr(fn, "_clear_cache") and hasattr(fn, "_cache_size")):
        return [Finding(
            "T007", INFO, where,
            "jit cache introspection unavailable on this JAX version; "
            "cache-cardinality rule skipped", "",
        )]
    schema = Schema(num_lo=(0.0, 0.0), num_hi=(1.0, 1.0), cat_sizes=(3,),
                    n_measures=1)
    params = GPParams.init(schema)
    buckets = sorted({
        (bucket_size(q, MIN_Q_BUCKET), bucket_size(f, MIN_FILL_BUCKET))
        for q, f in itertools.product(q_values, fill_values)
    })
    fn._clear_cache()
    findings: List[Finding] = []
    for q, fill in itertools.product(q_values, fill_values):
        qb = bucket_size(q, MIN_Q_BUCKET)
        fb = bucket_size(fill, MIN_FILL_BUCKET)
        past = pad_snippets(_snips(fill), fb)
        new = pad_snippets(_snips(q), qb)
        valid = jnp.asarray(np.arange(fb) < fill, jnp.float64)
        sinv = jnp.eye(fb)
        alpha = jnp.zeros((fb,))
        raw_theta = jnp.zeros((qb,))
        raw_beta2 = jnp.ones((qb,))
        try:
            fn(past, valid, sinv, alpha, params, new,
               raw_theta, raw_beta2, 0.99)
        except (TypeError, ValueError) as e:
            findings.append(Finding(
                "T007", ERROR, where,
                f"improve dispatch rejected a call (unhashable static "
                f"argument?): {e}",
                "jit static args must be hashable; shape-only cache keys "
                "come from padding, not from static args",
            ))
            return findings
    size = int(fn._cache_size())
    if size != len(buckets):
        findings.append(Finding(
            "T007", ERROR, where,
            f"(Q, fill) ladder over {len(q_values)}x{len(fill_values)} "
            f"calls compiled {size} program(s); expected exactly "
            f"{len(buckets)} (one per bucket pair {buckets})",
            "the jit cache key must depend only on the PADDED shapes; a "
            "leaked unpadded size or a value-dependent static arg compiles "
            "per call instead of per bucket",
        ))
    return findings


def check_scan_jit_cache() -> List[Finding]:
    """T007 (scan leg): ``eval_partials`` is a plain shape-keyed jit — same
    shape twice is ONE cache entry, a second shape is a second entry. Pins
    that dropping the historical no-op ``static_argnames=()`` wrappers
    changed nothing about caching."""
    import jax.numpy as jnp

    from repro.aqp.executor import eval_partials

    fn = eval_partials
    where = "program:eval_partials"
    if not (hasattr(fn, "_clear_cache") and hasattr(fn, "_cache_size")):
        return [Finding("T007", INFO, where,
                        "jit cache introspection unavailable; skipped", "")]
    fn._clear_cache()
    num = jnp.zeros((4, 2))
    cat = jnp.zeros((4, 1), jnp.int32)
    meas = jnp.zeros((4, 1))
    snips = _snips(2)
    eval_partials(num, cat, meas, snips)
    eval_partials(num, cat, meas, snips)
    after_same = int(fn._cache_size())
    eval_partials(num[:3], cat[:3], meas[:3], snips)
    after_new = int(fn._cache_size())
    out: List[Finding] = []
    if after_same != 1 or after_new != 2:
        out.append(Finding(
            "T007", ERROR, where,
            f"eval_partials cache cardinality drifted: {after_same} "
            "entr(ies) after two same-shape calls (expected 1), "
            f"{after_new} after one new shape (expected 2)",
            "eval_partials must stay a plain shape-keyed jax.jit",
        ))
    return out


# ------------------------------------------------------------------- driver

TRACE_RULES = ("T001", "T002", "T003", "T004", "T005", "T006", "T007")


def run_trace_rules(programs: Optional[Iterable[Program]] = None,
                    rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """All Layer-1 findings over the engine's programs (or a custom set)."""
    rules = set(TRACE_RULES if rules is None else rules)
    progs = list(engine_programs() if programs is None else programs)
    out: List[Finding] = []
    for p in progs:
        if "fold-dot" in p.tags and "T001" in rules:
            out.extend(check_fold_dot_shapes(p))
        if "fold-order" in p.tags and "T002" in rules:
            out.extend(check_fold_order(p))
        if "mask-build" in p.tags and "T003" in rules:
            out.extend(check_mask_build_collectives(p))
        if "agg" in p.tags and "T004" in rules:
            out.extend(check_agg_collectives(p))
        if "fused" in p.tags and "T005" in rules:
            out.extend(check_no_tq_buffer(p))
        if "partials-f64" in p.tags and "T006" in rules:
            out.extend(check_partials_f64(p))
    if "T007" in rules and programs is None:
        out.extend(check_improve_cache_cardinality())
        out.extend(check_scan_jit_cache())
    return out
