"""``python -m repro.analysis`` — run the invariant checker and gate on it.

Exit status is the number of gating findings (0 = contracts hold), so CI
can use the process status directly. ``--strict`` (the CI mode) also gates
on warnings, forcing every idle module / unverifiable seam into an explicit
allowlist entry rather than a lingering warning.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional, Sequence

from repro.analysis.findings import (Finding, gate_count, render_json,
                                     render_text)

LAYERS = ("trace", "ast")


def force_topology() -> None:
    """Force the fake multi-device host topology (mirrors conftest.py).

    Must run BEFORE jax initializes its backend — the sharded-mask-build
    rule (T003) wants a real multi-shard mesh. If jax is already imported
    (e.g. the checker is called from a test process) this is a no-op and
    the mesh degenerates to however many devices exist; the rules still
    apply.
    """
    if "jax" in sys.modules:
        return
    forced = int(os.environ.get("REPRO_FORCE_HOST_DEVICES", "8"))
    flags = os.environ.get("XLA_FLAGS", "")
    if forced > 1 and "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={forced}"
        ).strip()


def run_repo_analysis(
    layers: Sequence[str] = LAYERS,
    rules: Optional[Sequence[str]] = None,
) -> List[Finding]:
    """All findings for the repository (both layers by default)."""
    force_topology()
    out: List[Finding] = []
    if "trace" in layers:
        from repro.analysis.trace_rules import run_trace_rules
        out.extend(run_trace_rules(rules=rules))
    if "ast" in layers:
        from repro.analysis.ast_rules import run_ast_rules
        out.extend(run_ast_rules(rules=rules))
    if rules is not None:
        out = [f for f in out if f.rule in set(rules)]
    return out


def violation_count(strict: bool = True) -> int:
    """The ``analysis/violations`` benchmark metric: gating finding count."""
    return gate_count(run_repo_analysis(), strict=strict)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static invariant checker for the scan plane's parity "
                    "and performance contracts",
    )
    ap.add_argument("--strict", action="store_true",
                    help="gate on warnings too (CI mode)")
    ap.add_argument("--layer", choices=("all",) + LAYERS, default="all",
                    help="run only the trace/HLO layer or only the AST "
                         "layer (default: all)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (e.g. T001,A005)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    args = ap.parse_args(argv)

    layers = LAYERS if args.layer == "all" else (args.layer,)
    rules = args.rules.split(",") if args.rules else None
    findings = run_repo_analysis(layers=layers, rules=rules)
    render = render_json if args.format == "json" else render_text
    print(render(findings))
    return gate_count(findings, strict=args.strict)
