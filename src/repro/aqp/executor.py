"""Snippet evaluation over tuple blocks + CLT error bounds.

The TPU-idiomatic form of a multi-snippet scan: build a (tuples × snippets)
predicate mask with vectorized compares, then aggregate with mask^T @ values on
the MXU (see ``repro.kernels.range_mask_agg`` for the Pallas kernel; this module
is the pure-jnp oracle and the host-side accumulation / estimate logic).

Distribution: relations are sharded over the ``data`` mesh axis; each device
computes local partial (sum, count, sumsq) vectors and a single ``psum``
finishes the aggregation — the collective *is* the aggregation tree.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import AVG, FREQ, RawAnswer, SnippetBatch

BIG_BETA2 = 1e12  # raw error for snippets with no support in the scanned sample


@dataclasses.dataclass(frozen=True)
class Partials:
    """Sufficient statistics accumulated over scanned tuples."""

    sums: jnp.ndarray  # (n,) sum of measure over matching tuples
    sumsq: jnp.ndarray  # (n,)
    count: jnp.ndarray  # (n,) matching tuples
    scanned: jnp.ndarray  # () total tuples scanned

    @staticmethod
    def zeros(n: int) -> "Partials":
        z = jnp.zeros((n,))
        return Partials(z, z, z, jnp.zeros(()))

    def __add__(self, other: "Partials") -> "Partials":
        return Partials(
            self.sums + other.sums,
            self.sumsq + other.sumsq,
            self.count + other.count,
            self.scanned + other.scanned,
        )


def predicate_mask(num_normalized, cat, snippets: SnippetBatch):
    """(T, n) float mask of tuples satisfying each snippet's predicates."""
    x = num_normalized  # (T, l), normalized units — same as snippet lo/hi
    m_num = jnp.all(
        (x[:, None, :] >= snippets.lo[None, :, :] - 1e-12)
        & (x[:, None, :] <= snippets.hi[None, :, :] + 1e-12),
        axis=-1,
    )
    mask = m_num
    c = cat.shape[1] if cat.ndim == 2 else 0
    for k in range(c):
        # snippets.cat[:, k, :]: (n, V); cat[:, k]: (T,) codes
        mk = jnp.take(snippets.cat[:, k, :], cat[:, k], axis=1)  # (n, T)
        mask = mask & mk.T
    return mask.astype(jnp.float64)


@partial(jax.jit, static_argnames=())
def eval_partials(num_normalized, cat, measures, snippets: SnippetBatch) -> Partials:
    """Partial statistics for one tuple block (pure-jnp oracle path)."""
    mask = predicate_mask(num_normalized, cat, snippets)  # (T, n)
    vals = measures[:, jnp.arange(measures.shape[1])]  # (T, m)
    per_measure_sum = mask.T @ measures  # (n, m)
    per_measure_sq = mask.T @ (measures * measures)  # (n, m)
    idx = snippets.measure[:, None]
    sums = jnp.take_along_axis(per_measure_sum, idx, axis=1)[:, 0]
    sumsq = jnp.take_along_axis(per_measure_sq, idx, axis=1)[:, 0]
    count = jnp.sum(mask, axis=0)
    return Partials(sums, sumsq, count, jnp.asarray(float(num_normalized.shape[0])))


jax.tree_util.register_dataclass(
    Partials, data_fields=("sums", "sumsq", "count", "scanned"), meta_fields=()
)


def eval_partials_sharded(mesh, axis: str, num_normalized, cat, measures, snippets):
    """Distributed partials over a relation sharded on ``axis`` (shard_map+psum)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def local(x, c, m, s):
        p = eval_partials(x, c, m, s)
        return jax.tree.map(lambda v: jax.lax.psum(v, axis), p)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P()),
        out_specs=P(),
    )
    return fn(num_normalized, cat, measures, snippets)


@partial(jax.jit, static_argnames=("exact",))
def estimates_from_partials(parts: Partials, snippets: SnippetBatch, exact: bool = False):
    """CLT raw answers (theta_i, beta_i^2) from accumulated partials.

    FREQ: p_hat = count/scanned, beta^2 = p(1-p)/scanned.
    AVG:  x_bar = sum/count,     beta^2 = sample_var/count.
    ``exact=True`` zeroes the errors (used for ground-truth evaluation).
    """
    scanned = jnp.maximum(parts.scanned, 1.0)
    cnt = parts.count
    p_hat = cnt / scanned
    freq_beta2 = p_hat * (1.0 - p_hat) / scanned

    safe_cnt = jnp.maximum(cnt, 1.0)
    mean = parts.sums / safe_cnt
    var = jnp.maximum(parts.sumsq / safe_cnt - mean * mean, 0.0)
    avg_beta2 = var / safe_cnt

    is_avg = snippets.agg == AVG
    theta = jnp.where(is_avg, mean, p_hat)
    beta2 = jnp.where(is_avg, avg_beta2, freq_beta2)
    no_support = is_avg & (cnt < 2)
    theta = jnp.where(no_support, 0.0, theta)
    beta2 = jnp.where(no_support, BIG_BETA2, beta2)
    if exact:
        beta2 = jnp.zeros_like(beta2)
    valid = ~no_support
    return theta, beta2, valid
