"""Snippet evaluation over tuple blocks + CLT error bounds.

The TPU-idiomatic form of a multi-snippet scan: build a (tuples × snippets)
predicate mask with vectorized compares, then aggregate with mask^T @ values on
the MXU (see ``repro.kernels.fused_masked_scan`` for the fused Pallas kernel;
this module is the pure-jnp oracle and the host-side accumulation / estimate
logic). The canonical reduction is ``masked_tile_fold`` — a fixed
ascending-tile-order fold shared by the oracle, the gathered sharded mask, and
the kernel's sequential-grid accumulator, which is what makes all three paths
bitwise-identical by construction.

Distribution: the scan is shape-agnostic. A tuple block of ANY size runs over
a mesh of ANY size: the tuple axis is padded to a power-of-two tile divisible
by the mesh, padding rows carry an explicit per-tuple *validity mask* (so
``Partials.scanned`` is the mask sum — a real tuple count, never the padded
shape), and the predicate-mask build — the O(T·n·(l+c)) compare work — runs
sharded via ``shard_map``. The masked mask is then gathered and the final
(2m+1)-column aggregation replays the unsharded oracle's exact reduction
order, so sharded partials are BITWISE equal to ``eval_partials`` for every
(relation size, mesh size) combination (pinned by
``tests/test_sharded_scan.py``; a per-shard matmul + psum tree would be
deterministic but NOT oracle-bitwise — fp addition is not associative).

``ScanPlacement`` is the placement seam of the scan plane (the data-plane
mirror of ``repro.core.store.SynopsisStore``): it owns where tuple blocks
live (``NamedSharding(mesh, P(axis))`` + ``jax.device_put``) and how a block
is evaluated. The ROADMAP multi-host item extends exactly this seam to
``jax.process_count() > 1`` (per-process addressable shards + a cross-host
gather of the mask blocks).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.types import AVG, SnippetBatch
from repro.ft import faults
from repro.kernels import RANGE_EPS, SCAN_TILE_Q, SCAN_TILE_T

BIG_BETA2 = 1e12  # raw error for snippets with no support in the scanned sample


@dataclasses.dataclass(frozen=True)
class Partials:
    """Sufficient statistics accumulated over scanned tuples."""

    sums: jnp.ndarray  # (n,) sum of measure over matching tuples
    sumsq: jnp.ndarray  # (n,)
    count: jnp.ndarray  # (n,) matching tuples
    scanned: jnp.ndarray  # () total VALID tuples scanned (mask sum, a real
    # count — zero-padded tuples never inflate it)

    @staticmethod
    def zeros(n: int) -> "Partials":
        z = jnp.zeros((n,))
        return Partials(z, z, z, jnp.zeros(()))

    def __add__(self, other: "Partials") -> "Partials":
        return Partials(
            self.sums + other.sums,
            self.sumsq + other.sumsq,
            self.count + other.count,
            self.scanned + other.scanned,
        )


def predicate_mask(num_normalized, cat, snippets: SnippetBatch, valid=None):
    """(T, n) float mask of tuples satisfying each snippet's predicates.

    ``valid``: optional (T,) 0/1 per-tuple validity mask; invalid (padding)
    rows are forced to exactly 0.0 in every column, valid rows are untouched
    bitwise (multiplication by 1.0 is exact).
    """
    x = num_normalized  # (T, l), normalized units — same as snippet lo/hi
    m_num = jnp.all(
        (x[:, None, :] >= snippets.lo[None, :, :] - RANGE_EPS)
        & (x[:, None, :] <= snippets.hi[None, :, :] + RANGE_EPS),
        axis=-1,
    )
    mask = m_num
    c = cat.shape[1] if cat.ndim == 2 else 0
    for k in range(c):
        # snippets.cat[:, k, :]: (n, V); cat[:, k]: (T,) codes
        mk = jnp.take(snippets.cat[:, k, :], cat[:, k], axis=1)  # (n, T)
        mask = mask & mk.T
    mask = mask.astype(jnp.float64)
    if valid is not None:
        mask = mask * valid[:, None]
    return mask


def masked_tile_fold(mask, payload, tile_t: int = SCAN_TILE_T,
                     tile_q: int = SCAN_TILE_Q):
    """out[q, p] = sum_t mask[t, q] * payload[t, p] — the canonical
    fixed-tile-order reduction of the scan plane.

    Zero-pads BOTH axes to tile multiples and, per snippet tile, left-folds
    the per-tile (tile_t, tile_q) x (tile_t, P) dot partials in ascending
    tuple-tile order — EXACTLY the accumulation the fused Pallas kernel's
    grid performs (``repro.kernels.fused_masked_scan``), so the jnp oracle
    and the kernel agree bit for bit by construction instead of by rounding
    luck.  Every dot has the same FIXED shape: XLA's CPU matmul picks its
    contraction order by operand shape, so fixed-shape tiles are what makes
    per-snippet partials bitwise independent of block size AND of how many
    snippets ride along (Q-padding invariance).  Padding rows/columns are
    zeros and sliced away — they contribute exact-zero partials.  (A single
    big matmul would round differently — fp addition is not associative.)
    """
    t, q = mask.shape
    p = payload.shape[1]
    pad_t = (-t) % tile_t
    pad_q = (-q) % tile_q
    if pad_t:
        mask = jnp.concatenate([mask, jnp.zeros((pad_t, q), mask.dtype)])
        payload = jnp.concatenate(
            [payload, jnp.zeros((pad_t, p), payload.dtype)])
    if pad_q:
        mask = jnp.concatenate(
            [mask, jnp.zeros((mask.shape[0], pad_q), mask.dtype)], axis=1)
    dn = (((0,), (0,)), ((), ()))
    cols = []
    for j in range(mask.shape[1] // tile_q):
        sq = slice(j * tile_q, (j + 1) * tile_q)
        acc = None
        for i in range(mask.shape[0] // tile_t):
            st = slice(i * tile_t, (i + 1) * tile_t)
            part = jax.lax.dot_general(mask[st, sq], payload[st], dn,
                                       preferred_element_type=payload.dtype)
            acc = part if acc is None else acc + part
        if acc is None:  # zero-row block
            acc = jnp.zeros((tile_q, p), payload.dtype)
        cols.append(acc)
    out = jnp.concatenate(cols) if cols else jnp.zeros((0, p), payload.dtype)
    return out[:q]


@jax.jit
def _partials_from_mask(mask, measures, snippets: SnippetBatch,
                        scanned) -> Partials:
    """The mask → sufficient-statistics aggregation, factored out so every
    path (local oracle, gathered sharded mask, fused kernel) performs the
    SAME reduction: the payload packs [measures, measures², 1] and the
    contraction is the canonical ``masked_tile_fold`` — the fused kernel's
    own accumulation order — so all paths are bitwise-identical."""
    t, m = measures.shape
    payload = jnp.concatenate(
        [measures, measures * measures, jnp.ones((t, 1), measures.dtype)],
        axis=1)  # (T, 2m+1)
    out = masked_tile_fold(mask, payload)  # (n, 2m+1)
    idx = snippets.measure[:, None]
    sums = jnp.take_along_axis(out[:, :m], idx, axis=1)[:, 0]
    sumsq = jnp.take_along_axis(out[:, m:2 * m], idx, axis=1)[:, 0]
    return Partials(sums, sumsq, out[:, 2 * m], scanned)


@jax.jit
def eval_partials(num_normalized, cat, measures, snippets: SnippetBatch,
                  valid=None) -> Partials:
    """Partial statistics for one tuple block (pure-jnp oracle path).

    ``valid``: optional (T,) validity mask for zero-padded tuple blocks.
    Padding rows contribute exactly nothing to sums/sumsq/count (their mask
    row is exactly 0.0 and their payload is zeros), and ``scanned`` is the
    mask sum — the true number of tuples scanned, not the padded shape.
    """
    mask = predicate_mask(num_normalized, cat, snippets, valid)  # (T, n)
    scanned = (jnp.asarray(float(num_normalized.shape[0]))
               if valid is None else jnp.sum(valid))
    return _partials_from_mask(mask, measures, snippets, scanned)


jax.tree_util.register_dataclass(
    Partials, data_fields=("sums", "sumsq", "count", "scanned"), meta_fields=()
)


def padded_tuple_count(t: int, n_shards: int) -> int:
    """Tuple-axis tile for a ``t``-row block over ``n_shards`` devices.

    Smallest power of two >= t, rounded up to a multiple of the shard count
    (the round-up is a no-op for power-of-two meshes). Power-of-two tiling
    keeps the number of compiled scan programs logarithmic in the largest
    block seen; mesh divisibility lets ``shard_map`` split the tuple axis
    evenly with NO precondition on the relation/mesh combination.
    """
    n_shards = max(int(n_shards), 1)
    b = 1
    while b < t:
        b *= 2
    return -(-b // n_shards) * n_shards


def pad_tuple_axis(n_shards: int, num_normalized, cat, measures, valid=None):
    """Zero-pad the tuple axis to ``padded_tuple_count`` rows.

    Returns ``(num, cat, measures, valid)`` where ``valid`` marks the
    original rows with 1.0 and the padding with 0.0 (an existing ``valid``
    is extended). Padding payloads are zeros; categorical codes pad with 0,
    which is always an in-domain index — the validity mask, not the padded
    values, is what guarantees they contribute nothing. ``measures`` may be
    None (the sharded mask stage has no use for the payload — the
    oracle-order reduction reads the original, unpadded measures).
    """
    t = num_normalized.shape[0]
    if valid is None:
        valid = jnp.ones((t,))
    k = padded_tuple_count(t, n_shards) - t
    if k == 0:
        return num_normalized, cat, measures, valid
    return (
        jnp.concatenate([num_normalized,
                         jnp.zeros((k, num_normalized.shape[1]))]),
        jnp.concatenate([cat, jnp.zeros((k, cat.shape[1]), cat.dtype)]),
        None if measures is None else
        jnp.concatenate([measures, jnp.zeros((k, measures.shape[1]))]),
        jnp.concatenate([valid, jnp.zeros((k,))]),
    )


@jax.jit
def _mask_rows(num_normalized, cat, valid, snippets):
    return predicate_mask(num_normalized, cat, snippets, valid=valid)


_SHARDED_MASK_FNS = {}


def _sharded_mask_fn(mesh, axis: str):
    """Jitted shard_map mask builder, cached per (mesh, axis) so repeated
    block evals reuse one compiled program per shape bucket instead of
    re-tracing the shard_map every call."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    key = (mesh, axis)
    fn = _SHARDED_MASK_FNS.get(key)
    if fn is None:
        fn = jax.jit(shard_map(
            _mask_rows,
            mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P()),
            out_specs=P(axis),
        ))
        _SHARDED_MASK_FNS[key] = fn
    return fn


def eval_partials_sharded(mesh, axis: str, num_normalized, cat, measures,
                          snippets, valid=None, place_fn=None, agg_fn=None):
    """Distributed partials over the ``axis`` mesh axis — shape-agnostic.

    Accepts ANY (tuple count, mesh size) combination: the tuple axis is
    padded to the next mesh-divisible power-of-two tile with a validity mask
    (``pad_tuple_axis``), and the padded block is placed over the mesh
    (``place_fn``, normally ``ScanPlacement.place``). The sharded stage is
    the predicate-mask build — the O(T·n·(l+c)) compare work; the masked
    mask is then gathered and the final aggregation replays the unsharded
    oracle's exact reduction over the TRUE rows, so the result is BITWISE
    equal to ``eval_partials`` (a per-shard matmul + psum tree would round
    differently). ``scanned`` is the validity-mask sum: an all-padding shard
    contributes exactly nothing.

    ``agg_fn``: optional replacement for the gathered-mask aggregation,
    called as ``agg_fn(mask, measures, snippets, scanned)``. The kernel path
    passes ``repro.kernels.fused_masked_scan.masked_partials_fused`` here —
    the same canonical tile fold run inside a Pallas kernel, so the result
    stays bitwise-identical while the aggregation exercises the kernel
    (``use_kernels=True`` composing with a mesh).
    """
    t = num_normalized.shape[0]
    # Only what the sharded mask stage consumes is padded/placed; the
    # payload never crosses devices — the reduction reads the original
    # ``measures``.
    num_p, cat_p, _, valid_p = pad_tuple_axis(
        mesh.shape[axis], num_normalized, cat, None, valid)
    # The true scanned count, computed BEFORE placement so the scalar stays
    # on the default device (mesh-wide scalars can't join the single-device
    # reduction program below).
    scanned = jnp.sum(valid_p)
    if place_fn is not None:
        num_p, cat_p, valid_p = place_fn(num_p, cat_p, valid_p)
    mask = _sharded_mask_fn(mesh, axis)(num_p, cat_p, valid_p, snippets)
    # Gather the masked rows of the ORIGINAL block onto one device and
    # replay the oracle's reduction bit for bit. (The [: t] slice drops
    # whole padding rows; rows invalidated by a caller-supplied mask are
    # already exactly 0.0 columns inside ``mask``. A single-device mask
    # keeps GSPMD from re-partitioning the reduction.)
    mask = jax.device_put(mask[:t], jax.devices()[0])
    if agg_fn is not None:
        return agg_fn(mask, measures, snippets, scanned)
    return _partials_from_mask(mask, measures, snippets, scanned)


def _kernel_agg_for(local_eval):
    """Map the engine's per-block evaluator to the matching gathered-mask
    aggregation (None -> the jnp oracle ``_partials_from_mask``).

    This is how ``use_kernels=True`` composes with a mesh: the sharded mask
    build stays shard_map'd, and the post-gather fold runs through the
    aggregation-only Pallas kernel instead of silently falling back to jnp.
    """
    if local_eval is None or local_eval is eval_partials:
        return None
    try:
        from repro.kernels.fused_masked_scan import ops as fms_ops
    except Exception:  # pragma: no cover - pallas unavailable
        return None
    if local_eval is fms_ops.eval_partials_fused:
        return fms_ops.masked_partials_fused
    return None


def _evaluator_name(local_eval) -> str:
    """Stable name of a per-block evaluator for placement telemetry."""
    if local_eval is None or local_eval is eval_partials:
        return "oracle"
    try:
        from repro.kernels.fused_masked_scan import ops as fms_ops
        if local_eval is fms_ops.eval_partials_fused:
            return "fused_masked_scan"
    except Exception:  # pragma: no cover - pallas unavailable
        pass
    try:
        from repro.kernels.range_mask_agg import ops as rma_ops
        if local_eval is rma_ops.eval_partials_kernel:
            return "range_mask_agg"
    except Exception:  # pragma: no cover - pallas unavailable
        pass
    return getattr(local_eval, "__name__", "custom")


class ScanPlacement:
    """Placement seam of the scan plane (data-plane mirror of
    ``repro.core.store.SynopsisStore``).

    Owns WHERE tuple blocks live and HOW a block is evaluated; the query
    lifecycle (``PhysicalPlan``/``BatchExecutor``/``VerdictEngine``) only
    ever calls ``eval_block`` and stays layout-oblivious — block placement
    is a non-observable implementation detail, proven bitwise by
    ``tests/test_sharded_scan.py`` rather than by convention.

    The base class is local placement: blocks stay where they are and the
    engine's per-block evaluator (pure-jnp oracle or Pallas kernel) runs
    unpadded — bit-identical to the historical direct call.
    ``ShardedScanPlacement`` pads/masks/places over a mesh. The ROADMAP
    multi-host item extends exactly this seam to
    ``jax.process_count() > 1`` (per-process addressable shards, cross-host
    mask gather).
    """

    kind = "local"
    mesh = None
    axis = "data"

    def __init__(self):
        self.blocks_evaluated = 0
        self.pad_rows = 0  # padding rows appended across all blocks
        self.tuples_placed = 0  # true (valid) tuples routed through eval
        self.last_evaluator = None  # evaluator actually used by eval_block

    @property
    def n_shards(self) -> int:
        return 1

    def describe(self) -> str:
        """Human-readable placement (``Session.explain``/``stats``)."""
        return "local"

    def place(self, num_normalized, cat, valid):
        """Place one (padded) block's mask-stage arrays; local placement is
        the identity. (The measure payload is never placed: the
        oracle-order reduction always reads it where it already lives.)"""
        return num_normalized, cat, valid

    def evaluator_for(self, local_eval) -> str:
        """Name of the evaluator ``eval_block`` WILL use for ``local_eval``
        — what ``Session.explain`` reports before any block runs."""
        return _evaluator_name(local_eval)

    def eval_block(self, block, snippets: SnippetBatch,
                   local_eval=None) -> Partials:
        """Partials for one tuple block through this placement."""
        faults.fire("scan.eval")  # seam: before dispatch, state untouched
        self.blocks_evaluated += 1
        self.tuples_placed += int(block.num_normalized.shape[0])
        self.last_evaluator = self.evaluator_for(local_eval)
        fn = local_eval if local_eval is not None else eval_partials
        return fn(block.num_normalized, block.cat, block.measures, snippets)

    def stats(self) -> dict:
        """Operator-facing snapshot of the scan plane's placement."""
        return {
            "kind": self.kind,
            "n_shards": self.n_shards,
            "axis": self.axis,
            "blocks_evaluated": self.blocks_evaluated,
            "tuples_scanned": self.tuples_placed,
            "pad_rows": self.pad_rows,
            "evaluator": self.last_evaluator,
        }


class ShardedScanPlacement(ScanPlacement):
    """Tuple blocks sharded over a mesh axis via ``NamedSharding`` +
    ``jax.device_put``; evaluation through the masked, shape-agnostic
    ``eval_partials_sharded`` — any block size over any mesh size, bitwise
    equal to the local oracle."""

    kind = "sharded"

    def __init__(self, mesh, axis: str = "data"):
        super().__init__()
        self.mesh = mesh
        self.axis = axis

    @property
    def n_shards(self) -> int:
        return int(self.mesh.shape[self.axis])

    def describe(self) -> str:
        return f"sharded:{self.n_shards}x{self.axis}"

    def place(self, num_normalized, cat, valid):
        """Shard the (padded) tuple axis over the mesh devices.

        The single ``device_put`` call the multi-host extension will widen:
        with ``jax.process_count() > 1`` the same ``NamedSharding`` places
        per-process addressable shards from globally-consistent specs.
        """
        from jax.sharding import NamedSharding, PartitionSpec as P

        sharding = NamedSharding(self.mesh, P(self.axis))
        return tuple(jax.device_put(x, sharding)
                     for x in (num_normalized, cat, valid))

    def evaluator_for(self, local_eval) -> str:
        """Sharded blocks always build the mask via shard_map; the kernel,
        when requested AND supported, runs the post-gather aggregation —
        never silently dropped without the name saying so."""
        if _kernel_agg_for(local_eval) is not None:
            return "sharded_mask+kernel_agg"
        return "sharded_mask+oracle_agg"

    def eval_block(self, block, snippets: SnippetBatch,
                   local_eval=None) -> Partials:
        faults.fire("scan.eval")  # same seam as the local placement
        t = int(block.num_normalized.shape[0])
        self.blocks_evaluated += 1
        self.tuples_placed += t
        self.pad_rows += padded_tuple_count(t, self.n_shards) - t
        self.last_evaluator = self.evaluator_for(local_eval)
        return eval_partials_sharded(
            self.mesh, self.axis,
            block.num_normalized, block.cat, block.measures, snippets,
            place_fn=self.place,
            agg_fn=_kernel_agg_for(local_eval),
        )


def scan_placement(mesh=None, axis: str = "data") -> ScanPlacement:
    """Build the placement for an optional mesh (the ``connect`` wiring)."""
    if mesh is None:
        return ScanPlacement()
    return ShardedScanPlacement(mesh, axis)


@partial(jax.jit, static_argnames=("exact",))
def estimates_from_partials(parts: Partials, snippets: SnippetBatch, exact: bool = False):
    """CLT raw answers (theta_i, beta_i^2) from accumulated partials.

    FREQ: p_hat = count/scanned, beta^2 = p(1-p)/scanned.
    AVG:  x_bar = sum/count,     beta^2 = sample_var/count.
    ``exact=True`` zeroes the errors (used for ground-truth evaluation).
    """
    scanned = jnp.maximum(parts.scanned, 1.0)
    cnt = parts.count
    p_hat = cnt / scanned
    freq_beta2 = p_hat * (1.0 - p_hat) / scanned

    safe_cnt = jnp.maximum(cnt, 1.0)
    mean = parts.sums / safe_cnt
    var = jnp.maximum(parts.sumsq / safe_cnt - mean * mean, 0.0)
    avg_beta2 = var / safe_cnt

    is_avg = snippets.agg == AVG
    theta = jnp.where(is_avg, mean, p_hat)
    beta2 = jnp.where(is_avg, avg_beta2, freq_beta2)
    no_support = is_avg & (cnt < 2)
    theta = jnp.where(no_support, 0.0, theta)
    beta2 = jnp.where(no_support, BIG_BETA2, beta2)
    if exact:
        beta2 = jnp.zeros_like(beta2)
    valid = ~no_support
    return theta, beta2, valid
