"""Offline sample creation and online-aggregation batch streams (paper §8.1).

NoLearn-style: a uniform random sample of the fact relation is built offline,
split into batches of tuples; online aggregation refines answers batch by
batch. Batch order is a seeded permutation so runs are reproducible and each
prefix is itself a uniform sample.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List

import numpy as np

from repro.aqp.relation import Relation


@dataclasses.dataclass
class SampleBatches:
    relation: Relation  # the sample, permuted
    batch_rows: List[np.ndarray]
    source_cardinality: int

    def __iter__(self) -> Iterator[Relation]:
        for rows in self.batch_rows:
            yield self.relation.take(rows)

    @property
    def n_batches(self) -> int:
        return len(self.batch_rows)


def build_sample(
    relation: Relation,
    rate: float = 0.1,
    n_batches: int = 10,
    seed: int = 0,
) -> SampleBatches:
    rng = np.random.default_rng(seed)
    n = relation.cardinality
    k = max(int(round(n * rate)), 1)
    rows = rng.choice(n, size=k, replace=False)
    sample = relation.take(rows)
    order = rng.permutation(k)
    batch_rows = [order[i::n_batches] for i in range(n_batches)]
    batch_rows = [b for b in batch_rows if len(b)]
    return SampleBatches(sample, batch_rows, source_cardinality=n)
