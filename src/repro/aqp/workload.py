"""Synthetic relations and query workloads (paper §8.1 / §8.6).

- ``make_relation``: data whose measures follow a smooth random field over the
  numeric dimensions (random Fourier features ≈ a GP draw with a known SE
  lengthscale — giving non-zero inter-tuple covariance, Appendix E) plus
  per-category offsets and iid noise. Distribution families: uniform /
  gaussian / lognormal (Figure 6(b)).
- ``make_workload``: range/equality aggregate queries whose predicate columns
  follow the §8.6 power-law "frequently accessed columns" scheme.
- ``tpch_like``: a lineitem-flavoured star-schema fact table (denormalized) and
  templates mimicking the supported TPC-H aggregates (Q1/Q6-style).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.aqp.queries import AggQuery, AggSpec, CatEq, NumRange
from repro.aqp.relation import Relation
from repro.core.types import Schema


def _smooth_field(rng, x_norm, lengthscale: float, n_features: int = 64):
    """Random Fourier features approximating a zero-mean SE-kernel GP draw."""
    l = x_norm.shape[1]
    omega = rng.normal(0.0, 1.0 / lengthscale, size=(n_features, l))
    phase = rng.uniform(0, 2 * np.pi, size=(n_features,))
    proj = x_norm @ omega.T + phase
    return np.sqrt(2.0 / n_features) * np.cos(proj).sum(axis=1) / np.sqrt(n_features) * n_features ** 0.5


def make_relation(
    seed: int,
    n_rows: int,
    n_num: int = 3,
    cat_sizes: Tuple[int, ...] = (8,),
    n_measures: int = 2,
    lengthscale: float = 0.3,
    noise: float = 0.3,
    distribution: str = "uniform",
    cat_effect: float = 0.5,
) -> Relation:
    rng = np.random.default_rng(seed)
    if distribution == "uniform":
        x = rng.uniform(0, 10, size=(n_rows, n_num))
    elif distribution == "gaussian":
        x = np.clip(rng.normal(5, 2, size=(n_rows, n_num)), 0, 10)
    elif distribution == "lognormal":
        x = np.clip(rng.lognormal(1.0, 0.6, size=(n_rows, n_num)), 0, 10)
    else:
        raise ValueError(distribution)
    if cat_sizes:
        cats = np.stack(
            [rng.integers(0, s, size=(n_rows,)) for s in cat_sizes], axis=1
        ).astype(np.int32)
    else:
        cats = np.zeros((n_rows, 0), np.int32)
    x_norm = x / 10.0
    measures = np.zeros((n_rows, n_measures))
    for m in range(n_measures):
        field = _smooth_field(rng, x_norm, lengthscale)
        if cat_sizes:
            offsets = rng.normal(0, cat_effect, size=(len(cat_sizes), max(cat_sizes)))
            cat_shift = sum(offsets[k, cats[:, k]] for k in range(len(cat_sizes)))
        else:
            cat_shift = 0.0
        measures[:, m] = 10.0 + 2.0 * field + cat_shift + rng.normal(0, noise, n_rows)
    schema = Schema(
        num_lo=tuple([0.0] * n_num),
        num_hi=tuple([10.0] * n_num),
        cat_sizes=tuple(cat_sizes),
        n_measures=n_measures,
        num_names=tuple(f"x{i}" for i in range(n_num)),
        cat_names=tuple(f"c{i}" for i in range(len(cat_sizes))),
        measure_names=tuple(f"v{i}" for i in range(n_measures)),
    )
    return Relation.from_columns(schema, x, cats, measures)


def power_law_probs(n_cols: int, frac_frequent: float) -> np.ndarray:
    """§8.6 column-access distribution: the first ``ceil(n_cols * frac)``
    "frequently accessed" columns are equally likely; every tail column is
    half as likely as its predecessor, starting from half the per-frequent-
    column mass.

    The halving chains off the head instead of a hardcoded ``0.5`` — with
    the all-ones head the old constant was numerically identical (so seeded
    workloads are unchanged), but it silently encoded the head mass; this
    form states the scheme structurally and is pinned by distribution tests.
    """
    k = max(int(np.ceil(n_cols * frac_frequent)), 1)
    probs = np.ones(n_cols)
    for i in range(k, n_cols):
        probs[i] = probs[i - 1] / 2.0
    return probs / probs.sum()


def _power_law_column(rng, n_cols: int, frac_frequent: float):
    """Draw one column index from the §8.6 power-law scheme."""
    return int(rng.choice(n_cols, p=power_law_probs(n_cols, frac_frequent)))


def make_workload(
    seed: int,
    schema: Schema,
    n_queries: int,
    *,
    n_predicates: Tuple[int, int] = (1, 3),
    frac_frequent: float = 1.0,
    width_range: Tuple[float, float] = (0.1, 0.5),
    agg_kinds: Tuple[str, ...] = ("AVG", "COUNT", "SUM"),
    cat_pred_prob: float = 0.3,
) -> List[AggQuery]:
    rng = np.random.default_rng(seed)
    queries = []
    for _ in range(n_queries):
        n_preds = rng.integers(n_predicates[0], n_predicates[1] + 1)
        preds = []
        used = set()
        for _ in range(n_preds):
            if schema.n_cat and rng.random() < cat_pred_prob:
                dim = _power_law_column(rng, schema.n_cat, frac_frequent)
                if ("c", dim) in used:
                    continue
                used.add(("c", dim))
                preds.append(CatEq(dim, int(rng.integers(0, schema.cat_sizes[dim]))))
            else:
                dim = _power_law_column(rng, schema.n_num, frac_frequent)
                if ("n", dim) in used:
                    continue
                used.add(("n", dim))
                span = schema.num_hi[dim] - schema.num_lo[dim]
                width = rng.uniform(*width_range) * span
                start = rng.uniform(schema.num_lo[dim], schema.num_hi[dim] - width)
                preds.append(NumRange(dim, start, start + width))
        kind = str(rng.choice(list(agg_kinds)))
        measure = int(rng.integers(0, schema.n_measures)) if kind != "COUNT" else None
        queries.append(AggQuery(aggs=(AggSpec(kind, measure),), predicates=tuple(preds)))
    return queries


# --------------------------------------------------------------------- TPC-H
def tpch_like(seed: int, n_rows: int = 200_000) -> Relation:
    """Denormalized lineitem-ish fact table with seasonal structure.

    numeric dims: ship_date (days), quantity, discount
    categorical:  returnflag(3), linestatus(2), nation(25)
    measures:     extendedprice, revenue = price*(1-discount)   (derived attr)
    """
    rng = np.random.default_rng(seed)
    date = rng.uniform(0, 2557, n_rows)  # 7 years of days
    qty = rng.uniform(1, 50, n_rows)
    disc = rng.uniform(0.0, 0.1, n_rows)
    rf = rng.integers(0, 3, n_rows)
    ls = rng.integers(0, 2, n_rows)
    nation = rng.integers(0, 25, n_rows)
    season = 1.0 + 0.3 * np.sin(2 * np.pi * date / 365.0) + 0.1 * (date / 2557.0)
    nation_mult = rng.uniform(0.7, 1.3, 25)
    price = (
        900.0 * season * nation_mult[nation] * (qty / 25.0)
        + rng.normal(0, 40.0, n_rows)
    )
    revenue = price * (1 - disc)
    schema = Schema(
        num_lo=(0.0, 1.0, 0.0),
        num_hi=(2557.0, 50.0, 0.1),
        cat_sizes=(3, 2, 25),
        n_measures=2,
        num_names=("ship_date", "quantity", "discount"),
        cat_names=("returnflag", "linestatus", "nation"),
        measure_names=("extendedprice", "revenue"),
    )
    num = np.stack([date, qty, disc], axis=1)
    cat = np.stack([rf, ls, nation], axis=1).astype(np.int32)
    meas = np.stack([price, revenue], axis=1)
    return Relation.from_columns(schema, num, cat, meas)


def tpch_workload(seed: int, schema: Schema, n_queries: int = 60) -> List[AggQuery]:
    """Q1/Q6-flavoured supported aggregates over the tpch_like relation."""
    rng = np.random.default_rng(seed)
    queries: List[AggQuery] = []
    for _ in range(n_queries):
        template = rng.integers(0, 3)
        start = rng.uniform(0, 2557 - 400)
        span = rng.uniform(90, 400)
        date_pred = NumRange(0, start, start + span)
        if template == 0:  # Q6-ish: revenue SUM in date+discount+qty window
            d0 = rng.uniform(0.0, 0.06)
            preds = (date_pred, NumRange(2, d0, d0 + 0.02), NumRange(1, 1, 24))
            queries.append(AggQuery(aggs=(AggSpec("SUM", 1),), predicates=preds))
        elif template == 1:  # Q1-ish: AVG price grouped by returnflag
            queries.append(
                AggQuery(
                    aggs=(AggSpec("AVG", 0), AggSpec("COUNT", None)),
                    predicates=(date_pred,),
                    groupby=(0,),
                )
            )
        else:  # nation revenue AVG
            queries.append(
                AggQuery(
                    aggs=(AggSpec("AVG", 1),),
                    predicates=(date_pred, CatEq(2, int(rng.integers(0, 25)))),
                )
            )
    return queries
