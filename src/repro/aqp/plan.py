"""Unified query-plan IR: ONE lifecycle shared by every execution path.

The paper's Figure 2 pipeline — decompose, scan, improve, validate, learn —
used to be implemented three times (``VerdictEngine.execute``, its raw-only
branch, and ``BatchExecutor.execute_many`` phase 3), kept bit-identical only
by hand-mirrored code. This module is the single home of that pipeline,
split VerdictDB-style into a logical and a physical layer:

- ``LogicalPlan``: per-query planning output — the support verdict (§2.2),
  the probe actually evaluated (raw-only queries scan their supported
  subset), the ``SnippetPlan`` decomposition (§2.3), and the query's row ids
  into the workload's *fused* snippet set (cross-query dedup by content
  hash, ``snippet_key``).
- ``plan_workload``: queries → ``WorkloadPlan`` (logical plans + the two
  fused snippet sets + fusion accounting). Group-by values for the whole
  workload are discovered with ONE first-batch probe.
- ``PhysicalPlan``: a tile-padded fused snippet set bound to a sample-batch
  stream, scanned lazily with cumulative partials snapshots — each sample
  batch is evaluated at most once no matter how many queries replay over it.
- ``replay_query``: the improve → validate → early-stop → record lifecycle
  for one logical plan against a physical plan. ``VerdictEngine.execute``,
  its raw-only path and ``BatchExecutor`` all call this one function, so the
  bitwise-parity guarantees pinned by ``tests/test_batch_executor.py`` hold
  by construction instead of by mirroring. Learned state is reached ONLY
  through ``engine.store`` (the ``SynopsisStore`` protocol,
  ``repro.core.store``): the lifecycle is placement-oblivious, so a local
  and a mesh-sharded store replay identically.

Because the scan pads the snippet axis to fixed tiles (``pad_snippets``),
per-snippet partials are bitwise identical between any two fused sets that
contain the snippet, which is what makes "one query" literally "a workload
of one" (``execute(q) == execute_many([q])[0]``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.aqp import queries as Q
from repro.aqp.executor import Partials, estimates_from_partials, eval_partials
from repro.aqp.sampler import SampleBatches
from repro.core.types import (
    ImprovedAnswer,
    RawAnswer,
    SnippetBatch,
    pad_snippets,
    snippet_key,
)
from repro.utils.stats import confidence_multiplier


@dataclasses.dataclass
class QueryResult:
    """Engine-level answer for one query (dict cells; bitwise-stable).

    ``truncated_groups``: group-by cells silently dropped by the ``n_max``
    cap in ``Q.decompose`` — surfaced so callers (and ``Session.explain``)
    can see that the result is a prefix of the full group set.

    ``degraded``/``degraded_reasons``: honest-but-weaker-than-possible
    serving. A quarantined synopsis leaves its groups on the raw sample
    estimate (the paper's Theorem-1 floor) with
    ``{state_key: quarantine reason}`` entries; a deadline expiry returns
    the best-so-far answer with a ``"deadline"`` entry. Either way the
    (estimate, CI) pair is valid — degraded flags the missed improvement,
    not a wrong answer.

    ``served_from``: None for executed answers; ``"cache:exact"`` /
    ``"cache:subsumed"`` when the workload-intelligence plane
    (``repro.intel``) served this answer from its semantic cache without
    scanning.
    """

    cells: List[dict]
    batches_used: int
    tuples_scanned: int
    supported: bool
    unsupported_reason: Optional[str] = None
    snippet_answer: Optional[ImprovedAnswer] = None
    plan: Optional[Q.SnippetPlan] = None
    truncated_groups: int = 0
    degraded: bool = False
    degraded_reasons: Dict[str, str] = dataclasses.field(default_factory=dict)
    served_from: Optional[str] = None

    def max_rel_error(self, delta: float = 0.95) -> float:
        alpha = float(confidence_multiplier(delta))
        worst = 0.0
        for c in self.cells:
            denom = max(abs(c["estimate"]), 1e-9)
            worst = max(worst, alpha * np.sqrt(c["beta2"]) / denom)
        return worst


@dataclasses.dataclass
class BatchStats:
    """Fusion accounting for one planned workload."""

    n_queries: int = 0
    n_snippets_total: int = 0  # sum of per-query plan sizes
    n_snippets_fused: int = 0  # after cross-query dedup
    eval_calls: int = 0  # one per (fused set, scanned sample batch)
    batches_scanned: int = 0
    tuples_scanned: int = 0  # TRUE tuples evaluated (never counts padding)

    @property
    def dedup_ratio(self) -> float:
        return self.n_snippets_total / max(self.n_snippets_fused, 1)


@dataclasses.dataclass
class LogicalPlan:
    """Planning output for one query within a workload.

    ``plan is None`` ⇔ the query is supported but its group-by probe found
    no groups (empty result set, nothing to scan). ``rows`` are this query's
    snippet row ids into the workload's fused set (supported queries index
    the main set, raw-only probes the plain-eval set).
    """

    index: int
    query: Q.AggQuery
    probe: Q.AggQuery
    reason: Optional[str]
    plan: Optional[Q.SnippetPlan]
    rows: Optional[np.ndarray]

    @property
    def supported(self) -> bool:
        return self.reason is None

    @property
    def truncated_groups(self) -> int:
        return self.plan.truncated_groups if self.plan is not None else 0


class SnippetInterner:
    """Accumulates unique snippets across plans, hash-keyed like Synopsis."""

    def __init__(self, schema):
        self.schema = schema
        self._keys: Dict[int, int] = {}
        self.lo: List[np.ndarray] = []
        self.hi: List[np.ndarray] = []
        self.cat: List[np.ndarray] = []
        self.agg: List[int] = []
        self.measure: List[int] = []

    def intern(self, snippets: SnippetBatch) -> np.ndarray:
        lo = np.asarray(snippets.lo)
        hi = np.asarray(snippets.hi)
        cat = np.asarray(snippets.cat)
        agg = np.asarray(snippets.agg)
        mea = np.asarray(snippets.measure)
        rows = np.empty((lo.shape[0],), np.int64)
        for i in range(lo.shape[0]):
            key = snippet_key(lo[i], hi[i], cat[i], agg[i], mea[i])
            r = self._keys.get(key)
            if r is None:
                r = len(self.agg)
                self._keys[key] = r
                self.lo.append(lo[i])
                self.hi.append(hi[i])
                self.cat.append(cat[i])
                self.agg.append(int(agg[i]))
                self.measure.append(int(mea[i]))
            rows[i] = r
        return rows

    @property
    def n(self) -> int:
        return len(self.agg)

    def fused(self) -> SnippetBatch:
        if not self.agg:  # all interned plans were empty
            return SnippetBatch.empty(self.schema)
        return SnippetBatch(
            lo=jnp.asarray(np.stack(self.lo)),
            hi=jnp.asarray(np.stack(self.hi)),
            cat=jnp.asarray(np.stack(self.cat)),
            agg=jnp.asarray(np.asarray(self.agg, np.int32)),
            measure=jnp.asarray(np.asarray(self.measure, np.int32)),
        )


@dataclasses.dataclass
class WorkloadPlan:
    """Logical plans for a workload plus its two fused snippet sets.

    Supported queries scan through the engine's eval fn (kernel / mesh
    capable); raw-only probes scan through pure ``eval_partials`` in a
    second fused set — mirroring the sequential raw-only path exactly.
    """

    logical: List[LogicalPlan]
    fused: SnippetBatch
    fused_raw: SnippetBatch
    stats: BatchStats


def plan_workload(engine, queries: Sequence[Q.AggQuery]) -> WorkloadPlan:
    """Plan + dedup a whole workload (one fused group-discovery probe)."""
    cfg = engine.config
    stats = BatchStats(n_queries=len(queries))
    intern_main = SnippetInterner(engine.schema)
    intern_raw = SnippetInterner(engine.schema)
    logical: List[LogicalPlan] = []
    reasons = [Q.unsupported_reason(q) for q in queries]
    probes = [q if r is None else engine.raw_only_probe(q)
              for q, r in zip(queries, reasons)]
    groups_all = engine._discover_groups_many(probes)
    for qi, q in enumerate(queries):
        reason, probe, groups = reasons[qi], probes[qi], groups_all[qi]
        if reason is None and not groups:
            logical.append(LogicalPlan(qi, q, probe, reason, None, None))
            continue
        plan = Q.decompose(engine.schema, probe, groups, n_max=cfg.n_max)
        interner = intern_main if reason is None else intern_raw
        rows = interner.intern(plan.snippets)
        stats.n_snippets_total += plan.snippets.n
        logical.append(LogicalPlan(qi, q, probe, reason, plan, rows))
    stats.n_snippets_fused = intern_main.n + intern_raw.n
    return WorkloadPlan(
        logical=logical,
        fused=intern_main.fused(),
        fused_raw=intern_raw.fused(),
        stats=stats,
    )


class PhysicalPlan:
    """A padded fused snippet set + the lazy cumulative-partials scan.

    ``eval_fn(block, padded) -> Partials`` is the per-batch evaluator —
    normally ``BatchExecutor._eval``, i.e. a ``ScanPlacement.eval_block``
    (pure jnp oracle, Pallas kernel, or the masked shape-agnostic sharded
    scan), so the physical plan is placement-oblivious. Sample batches are
    pulled on demand; snapshot ``b`` holds the cumulative partials of
    batches ``0..b``, and per-batch estimates are cached so replaying many
    queries against the same prefix costs one ``estimates_from_partials``.
    """

    def __init__(
        self,
        batches: SampleBatches,
        snippets: SnippetBatch,
        eval_fn: Callable[[object, SnippetBatch], Partials],
        stats: Optional[BatchStats] = None,
    ):
        self.batches = batches
        self.n = snippets.n
        self.padded = pad_snippets(snippets)
        self.eval_fn = eval_fn
        self.stats = stats
        self._snapshots: List[Partials] = []
        self._estimates: Dict[int, Tuple] = {}

    def partials_at(self, b: int) -> Partials:
        """Cumulative partials of batches ``0..b``, sliced to the
        non-padding snippets (scans lazily like ``raw_at``)."""
        self.raw_at(b)
        return jax.tree.map(
            lambda v: v[: self.n] if getattr(v, "ndim", 0) else v,
            self._snapshots[b],
        )

    def raw_at(self, b: int, rows: Optional[np.ndarray] = None) -> RawAnswer:
        """Raw answers after batches ``0..b`` for ``rows`` of the fused set
        (``None``: every non-padding snippet, in interning order)."""
        while len(self._snapshots) <= b:
            i = len(self._snapshots)
            block = self.batches.relation.take(self.batches.batch_rows[i])
            part = self.eval_fn(block, self.padded)
            self._snapshots.append(
                part if not self._snapshots else self._snapshots[-1] + part
            )
            if self.stats is not None:
                self.stats.eval_calls += 1
                self.stats.batches_scanned += 1
                self.stats.tuples_scanned += len(self.batches.batch_rows[i])
        if b not in self._estimates:
            theta, beta2, _ = estimates_from_partials(
                self._snapshots[b], self.padded
            )
            self._estimates[b] = (theta, beta2)
        theta, beta2 = self._estimates[b]
        if rows is None:
            return RawAnswer(theta[: self.n], beta2[: self.n])
        idx = jnp.asarray(rows)
        return RawAnswer(theta[idx], beta2[idx])


def plain_eval(block, padded: SnippetBatch) -> Partials:
    """The kernel-free evaluator raw-only probes always scan through."""
    return eval_partials(
        block.num_normalized, block.cat, block.measures, padded
    )


def replay_rounds(
    engine,
    lp: LogicalPlan,
    physical: PhysicalPlan,
    target_rel_error: Optional[float] = None,
    max_batches: Optional[int] = None,
    stop_delta: Optional[float] = None,
    every_batch: bool = False,
    deadline: Optional[float] = None,
):
    """The single query lifecycle, one round per evaluated sample batch.

    Yields ``(QueryResult, final)`` pairs: improve via the synopsis,
    validate, check the early-stop target (at confidence ``stop_delta``,
    default the engine's ``report_delta``), and — only on the final round —
    record the raw answers for learning. ``replay_query`` consumes this for
    one-shot execution; ``Session.stream`` surfaces every round. Both are
    therefore the same state transitions in the same order by construction.

    ``every_batch=False`` evaluates only the rounds the one-shot result
    needs (all of them under a target, just the last one otherwise, since
    intermediate improvements are side-effect-free); ``every_batch=True``
    evaluates and yields after every sample batch. Raw-only (unsupported)
    queries never early-stop and never record (paper §2.2).

    ``deadline``: absolute ``time.monotonic()`` budget (BlinkDB's "bounded
    response time" half of the contract). Checked AFTER each round: on
    expiry the round just computed becomes final — the best-so-far answer
    with its honest (wider) CI returns instead of blocking, flagged
    ``degraded`` with a ``"deadline"`` reason. At least one round always
    runs, so every query resolves to a valid estimate.

    Degradation never invalidates an answer: quarantined synopses leave
    their rows on the raw sample estimate (``improve_groups`` health
    telemetry → ``degraded_reasons``), which Theorem 1 guarantees is an
    honest unbiased fallback.
    """
    cfg = engine.config
    max_batches = min(
        max_batches or engine.batches.n_batches, engine.batches.n_batches
    )
    stop_delta = cfg.report_delta if stop_delta is None else float(stop_delta)
    if lp.plan is None:  # supported, but no group-by values discovered
        yield QueryResult([], 0, 0, True, plan=None), True
        return
    card = engine.batches.source_cardinality
    # Serve-path routing (repro.intel): under a target the router may pick
    # "scan" — skip the per-round improve/validate checks and evaluate the
    # full budget in one final round — when the learned E[batches] says the
    # improve path was not going to stop early anyway. The full-budget
    # answer is the most refined one the budget admits, so "scan" never
    # violates the caller's contract; without an intel plane the route is
    # always "improve" under a target (the historical behavior).
    intel = getattr(engine, "intel", None)
    route = "scan"
    if target_rel_error is not None:
        route = "improve"
        if (intel is not None and lp.supported and not every_batch
                and deadline is None):
            route = intel.choose_route(engine, lp, target_rel_error,
                                       max_batches)
    all_rounds = (every_batch or deadline is not None
                  or (target_rel_error is not None and route == "improve"))
    if not lp.supported:
        # Raw AQP answers over the full budget, no learning (paper §2.2).
        rounds = (range(max_batches)
                  if every_batch or deadline is not None
                  else (max_batches - 1,))
        for b in rounds:
            raw = physical.raw_at(b, lp.rows)
            cells = Q.assemble_results(lp.plan, raw.theta, raw.beta2, card)
            used = b + 1
            res = QueryResult(
                cells, used, engine._tuples(used), False, lp.reason,
                plan=lp.plan, truncated_groups=lp.truncated_groups,
            )
            expired = deadline is not None and time.monotonic() >= deadline
            final = expired or b == max_batches - 1
            if expired and b < max_batches - 1:
                res.degraded = True
                res.degraded_reasons["deadline"] = (
                    f"deadline expired after {used} of {max_batches} batches"
                )
            yield res, final
            if final:
                return
        return
    n = lp.plan.snippets.n
    rounds = range(max_batches) if all_rounds else (max_batches - 1,)
    for b in rounds:
        raw = physical.raw_at(b, lp.rows)
        used = b + 1
        health: Dict[str, str] = {}
        if cfg.learning:
            improved = engine.store.improve_groups(
                lp.plan.snippets, raw, use_kernels=cfg.use_kernels,
                health=health)
        else:
            improved = ImprovedAnswer(
                raw.theta, raw.beta2, raw.theta, raw.beta2,
                jnp.zeros((n,), bool),
            )
        cells = Q.assemble_results(lp.plan, improved.theta, improved.beta2,
                                   card)
        res = QueryResult(
            cells, used, engine._tuples(used), True,
            snippet_answer=improved, plan=lp.plan,
            truncated_groups=lp.truncated_groups,
            degraded=bool(health), degraded_reasons=health,
        )
        met = (target_rel_error is not None
               and res.max_rel_error(stop_delta) <= target_rel_error)
        expired = deadline is not None and time.monotonic() >= deadline
        final = met or expired or b == max_batches - 1
        if expired and not met and b < max_batches - 1:
            res.degraded = True
            res.degraded_reasons["deadline"] = (
                f"deadline expired after {used} of {max_batches} batches"
            )
        if final:
            if cfg.learning:
                engine.store.record(lp.plan.snippets, raw)
            if intel is not None:
                # After record: Synopsis.add bumps its generation at
                # enqueue time, so the cached entry's generation snapshot
                # includes this answer's own ingest — an exact repeat is
                # fresh, not self-stale.
                intel.observe(engine, lp, res, target_rel_error,
                              max_batches, route)
        yield res, final
        if final:
            return


def replay_query(
    engine,
    lp: LogicalPlan,
    physical: PhysicalPlan,
    target_rel_error: Optional[float] = None,
    max_batches: Optional[int] = None,
    stop_delta: Optional[float] = None,
    deadline: Optional[float] = None,
) -> QueryResult:
    """One-shot lifecycle: the final round of ``replay_rounds``."""
    result = None
    for result, _ in replay_rounds(
        engine, lp, physical, target_rel_error=target_rel_error,
        max_batches=max_batches, stop_delta=stop_delta, deadline=deadline,
    ):
        pass
    return result
