from repro.aqp.relation import Relation
from repro.aqp.queries import AggQuery, AggSpec, CatEq, CatIn, NumEq, NumRange
from repro.aqp.plan import (
    BatchStats,
    LogicalPlan,
    PhysicalPlan,
    QueryResult,
    WorkloadPlan,
    plan_workload,
    replay_query,
    replay_rounds,
)
from repro.aqp.batch import BatchExecutor
