from repro.aqp.relation import Relation
from repro.aqp.queries import AggQuery, AggSpec, CatEq, CatIn, NumEq, NumRange
from repro.aqp.batch import BatchExecutor, BatchStats
