"""Batched multi-query execution with cross-query snippet dedup.

The paper's core observation — every query's answer "reveals some degree of
knowledge about the answer to another query" — also holds for the *scan*:
snippets from different queries in a workload overlap heavily (repeated
dashboards, shared group-by cells, popular predicate columns), so evaluating
each query's plan separately re-reads the same sample batches over and over.

``BatchExecutor`` fuses a whole workload into one scan. All the machinery
lives in the shared plan IR (``repro.aqp.plan``); this class just wires it
to one engine:

1. ``plan_workload`` decomposes every query into its ``LogicalPlan``
   (unsupported queries get their raw-only probe plan) and dedups identical
   snippets across queries into two fused ``SnippetBatch``es, keyed by the
   same content hash ``Synopsis`` uses (``snippet_key``);
2. two ``PhysicalPlan``s scan sample batches lazily, evaluating each batch
   EXACTLY ONCE for the union of snippets — supported queries through the
   executor's ``ScanPlacement`` (pure-jnp oracle, Pallas kernel, or the
   masked shape-agnostic sharded scan when a mesh is given), raw-only
   probes through pure ``eval_partials``;
3. ``replay_query`` replays queries in submission order against cumulative
   per-batch partials: improve via the synopsis, early-stop per query once
   its improved bound meets the target, and record raw answers — the same
   state transitions, in the same order, as query-at-a-time execution
   (which since the plan-IR refactor is literally a workload of one).

Learning is asynchronous and placement-aware: ``replay_query`` records raw
answers through the engine's ``SynopsisStore`` (``store.record``), which
enqueues them on each synopsis' background ingest thread — per shard when the
store is sharded — and ``execute_many`` returns without waiting for the
covariance builds. Each replayed ``store.improve_groups`` drains only the
involved synopses' pending batches (so the state transitions stay
deterministic and identical to the sequential engine); a full barrier
(``VerdictEngine.drain``) is only needed at snapshot/refit boundaries.

Because the scan path pads the snippet axis to fixed tiles
(``pad_snippets``), per-snippet partials are bitwise identical between the
fused scan and the single-query scan; the replay then performs the exact
per-query improvement/validation calls every path performs, so batched
answers equal sequential answers bit for bit while the number of
``eval_partials`` calls drops from sum(batches_used per query) to
max(batches_used over queries).
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence

from repro.aqp import queries as Q
from repro.aqp.executor import ScanPlacement, scan_placement
from repro.aqp.plan import (
    BatchStats,
    PhysicalPlan,
    QueryResult,
    plain_eval,
    plan_workload,
    replay_query,
)
from repro.core.types import SnippetBatch

__all__ = ["BatchExecutor", "BatchStats"]


class BatchExecutor:
    """Fused executor over one ``VerdictEngine`` (see module docstring).

    The scan routes through a ``ScanPlacement`` (``repro.aqp.executor``):
    pass ``placement=`` directly, or ``mesh=`` to build a
    ``ShardedScanPlacement`` over ``mesh_axis`` (shape-agnostic masked
    sharding — no divisibility precondition); with neither, the engine's
    own placement (local by default) is used. Stats of the latest call are
    kept in ``self.stats``.
    """

    def __init__(self, engine, mesh=None, mesh_axis: str = "data",
                 placement: ScanPlacement = None):
        self.engine = engine
        if placement is None:
            placement = (scan_placement(mesh, mesh_axis) if mesh is not None
                         else getattr(engine, "scan", None) or ScanPlacement())
        self.placement = placement
        self.mesh = placement.mesh  # back-compat aliases
        self.mesh_axis = placement.axis
        self.stats = BatchStats()

    # ---------------------------------------------------------------- scan
    def _eval(self, block, padded: SnippetBatch):
        return self.placement.eval_block(
            block, padded, local_eval=self.engine._eval_fn
        )

    # ------------------------------------------------------------- execute
    def execute_many(
        self,
        queries: Sequence[Q.AggQuery],
        target_rel_error: Optional[float] = None,
        max_batches: Optional[int] = None,
        stop_delta: Optional[float] = None,
        deadline_s: Optional[float] = None,
        tenant: Optional[str] = None,
    ) -> List[QueryResult]:
        """``deadline_s``: per-query wall-clock budget, measured from each
        query's replay start (the shared scan amortizes across queries, so
        a query replaying over already-evaluated batches is nearly free; the
        deadline bounds the batches IT forces to be scanned). On expiry the
        best-so-far answer returns, ``degraded`` with a ``"deadline"``
        reason — every query resolves. ``tenant``: optional label threaded
        into the workload-intel per-tenant lookup/hit counters."""
        eng = self.engine
        max_batches = min(
            max_batches or eng.batches.n_batches, eng.batches.n_batches
        )
        results: List[Optional[QueryResult]] = [None] * len(queries)
        # Workload-intelligence pre-screen (repro.intel): queries served
        # from the semantic answer cache drop out of the fused batch BEFORE
        # planning/snippet dedup — they cost no probe, no scan, no improve
        # and no record. Miss queries flow through the unchanged lifecycle,
        # so their answers are bitwise-identical to a cache-disabled engine.
        intel = getattr(eng, "intel", None)
        live_idx = list(range(len(queries)))
        if intel is not None:
            live_idx = []
            for i, q in enumerate(queries):
                served = intel.lookup(
                    eng, q, target_rel_error=target_rel_error,
                    stop_delta=stop_delta, max_batches=max_batches,
                    tenant=tenant)
                if served is not None:
                    results[i] = served
                else:
                    live_idx.append(i)
        wp = plan_workload(eng, [queries[i] for i in live_idx])
        self.stats = wp.stats
        phys_main = PhysicalPlan(eng.batches, wp.fused, self._eval,
                                 stats=wp.stats)
        phys_raw = PhysicalPlan(eng.batches, wp.fused_raw, plain_eval,
                                stats=wp.stats)
        for lp in wp.logical:
            deadline = (None if deadline_s is None
                        else time.monotonic() + float(deadline_s))
            results[live_idx[lp.index]] = replay_query(
                eng, lp, phys_main if lp.supported else phys_raw,
                target_rel_error=target_rel_error, max_batches=max_batches,
                stop_delta=stop_delta, deadline=deadline,
            )
        return results
