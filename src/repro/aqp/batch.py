"""Batched multi-query execution with cross-query snippet dedup.

The paper's core observation — every query's answer "reveals some degree of
knowledge about the answer to another query" — also holds for the *scan*:
snippets from different queries in a workload overlap heavily (repeated
dashboards, shared group-by cells, popular predicate columns), so evaluating
each query's plan separately re-reads the same sample batches over and over.

``BatchExecutor`` fuses a whole workload into one scan:

1. decompose every query into its ``SnippetPlan`` (unsupported queries get
   their raw-only probe plan, mirroring ``VerdictEngine._execute_raw_only``);
2. dedup identical snippets across queries into one fused ``SnippetBatch``,
   keyed by the same content hash ``Synopsis`` uses (``snippet_key``);
3. scan sample batches lazily, evaluating each batch EXACTLY ONCE for the
   union of snippets through the engine's eval path (pure-jnp oracle, Pallas
   kernel, or ``shard_map``+psum when a mesh is given) — one fused
   ``mask^T @ payload`` MXU pass per batch instead of one per query; raw-only
   probes of unsupported queries scan in a second fused set through pure
   ``eval_partials``, exactly as ``_execute_raw_only`` does;
4. replay queries in submission order against cumulative per-batch partials:
   improve via the synopsis, early-stop per query once its improved bound
   meets the target, and record raw answers — the same state transitions, in
   the same order, as query-at-a-time execution.

Learning is asynchronous: ``_record`` enqueues raw answers on the synopsis'
background ingest thread and ``execute_many`` returns without waiting for the
covariance builds. Each replayed ``_improve`` drains only its own synopsis'
pending batches (so the state transitions stay deterministic and identical to
the sequential engine); a full barrier (``VerdictEngine.drain``) is only
needed at snapshot/refit boundaries.

Because the scan path pads the snippet axis to fixed tiles
(``pad_snippets``), per-snippet partials are bitwise identical between the
fused scan and the single-query scan; the replay then performs the exact
per-query improvement/validation calls ``VerdictEngine.execute`` performs, so
batched answers equal sequential answers bit for bit while the number of
``eval_partials`` calls drops from sum(batches_used per query) to
max(batches_used over queries).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.aqp import queries as Q
from repro.aqp.executor import (
    Partials,
    estimates_from_partials,
    eval_partials,
    eval_partials_sharded,
)
from repro.core.types import (
    ImprovedAnswer,
    RawAnswer,
    SnippetBatch,
    pad_snippets,
    snippet_key,
)


@dataclasses.dataclass
class BatchStats:
    """Fusion accounting for one ``execute_many`` call."""

    n_queries: int = 0
    n_snippets_total: int = 0  # sum of per-query plan sizes
    n_snippets_fused: int = 0  # after cross-query dedup
    eval_calls: int = 0  # one per (fused set, scanned sample batch)
    batches_scanned: int = 0

    @property
    def dedup_ratio(self) -> float:
        return self.n_snippets_total / max(self.n_snippets_fused, 1)


@dataclasses.dataclass
class _Pending:
    """Per-query bookkeeping inside one fused execution."""

    index: int
    plan: Q.SnippetPlan
    rows: np.ndarray  # fused row id per plan snippet
    supported: bool
    reason: Optional[str] = None


class _Deduper:
    """Accumulates unique snippets across plans, hash-keyed like Synopsis."""

    def __init__(self, schema):
        self.schema = schema
        self._keys: Dict[int, int] = {}
        self.lo: List[np.ndarray] = []
        self.hi: List[np.ndarray] = []
        self.cat: List[np.ndarray] = []
        self.agg: List[int] = []
        self.measure: List[int] = []

    def intern(self, snippets: SnippetBatch) -> np.ndarray:
        lo = np.asarray(snippets.lo)
        hi = np.asarray(snippets.hi)
        cat = np.asarray(snippets.cat)
        agg = np.asarray(snippets.agg)
        mea = np.asarray(snippets.measure)
        rows = np.empty((lo.shape[0],), np.int64)
        for i in range(lo.shape[0]):
            key = snippet_key(lo[i], hi[i], cat[i], agg[i], mea[i])
            r = self._keys.get(key)
            if r is None:
                r = len(self.agg)
                self._keys[key] = r
                self.lo.append(lo[i])
                self.hi.append(hi[i])
                self.cat.append(cat[i])
                self.agg.append(int(agg[i]))
                self.measure.append(int(mea[i]))
            rows[i] = r
        return rows

    @property
    def n(self) -> int:
        return len(self.agg)

    def fused(self) -> SnippetBatch:
        if not self.agg:  # all interned plans were empty
            return SnippetBatch.empty(self.schema)
        return SnippetBatch(
            lo=jnp.asarray(np.stack(self.lo)),
            hi=jnp.asarray(np.stack(self.hi)),
            cat=jnp.asarray(np.stack(self.cat)),
            agg=jnp.asarray(np.asarray(self.agg, np.int32)),
            measure=jnp.asarray(np.asarray(self.measure, np.int32)),
        )


class BatchExecutor:
    """Fused executor over one ``VerdictEngine`` (see module docstring).

    ``mesh``: optional JAX mesh; the fused scan then runs through
    ``eval_partials_sharded`` over ``mesh_axis`` (the collective is the
    aggregation tree). Stats of the latest call are kept in ``self.stats``.
    """

    def __init__(self, engine, mesh=None, mesh_axis: str = "data"):
        self.engine = engine
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.stats = BatchStats()

    # ---------------------------------------------------------------- scan
    def _eval(self, block, padded: SnippetBatch) -> Partials:
        if self.mesh is not None:
            return eval_partials_sharded(
                self.mesh, self.mesh_axis,
                block.num_normalized, block.cat, block.measures, padded,
            )
        return self.engine._eval_fn(
            block.num_normalized, block.cat, block.measures, padded
        )

    # ------------------------------------------------------------- execute
    def execute_many(
        self,
        queries: Sequence[Q.AggQuery],
        target_rel_error: Optional[float] = None,
        max_batches: Optional[int] = None,
    ):
        from repro.core.engine import QueryResult

        eng = self.engine
        cfg = eng.config
        max_batches = min(
            max_batches or eng.batches.n_batches, eng.batches.n_batches
        )
        self.stats = BatchStats(n_queries=len(queries))
        results: List[Optional[QueryResult]] = [None] * len(queries)

        # ---- phase 1: plan + dedup across the whole workload
        # Two fused sets, mirroring the sequential engine exactly: supported
        # queries scan through the engine's eval fn (kernel / mesh capable),
        # raw-only probes through pure eval_partials (engine.py does the same).
        # Group discovery is fused too: ONE first-batch predicate_mask eval
        # covers every query's probe (identical booleans to per-query probes).
        dedup = _Deduper(eng.schema)
        dedup_raw = _Deduper(eng.schema)
        pend: List[_Pending] = []
        reasons = [Q.unsupported_reason(q) for q in queries]
        probes = [q if r is None else eng.raw_only_probe(q)
                  for q, r in zip(queries, reasons)]
        groups_all = eng._discover_groups_many(probes)
        for qi, q in enumerate(queries):
            reason = reasons[qi]
            probe = probes[qi]
            groups = groups_all[qi]
            if reason is None and not groups:
                results[qi] = QueryResult([], 0, 0, True, plan=None)
                continue
            plan = Q.decompose(eng.schema, probe, groups, n_max=cfg.n_max)
            rows = (dedup if reason is None else dedup_raw).intern(plan.snippets)
            self.stats.n_snippets_total += plan.snippets.n
            pend.append(_Pending(qi, plan, rows, reason is None, reason))
        self.stats.n_snippets_fused = dedup.n + dedup_raw.n
        if not pend:
            return results

        # ---- phase 2: lazy fused scans with cumulative snapshots
        def make_scan(padded: SnippetBatch, evalfn):
            snapshots: List[Partials] = []
            estimates: Dict[int, tuple] = {}

            def raw_at(b: int, rows: np.ndarray) -> RawAnswer:
                while len(snapshots) <= b:
                    i = len(snapshots)
                    block = eng.batches.relation.take(eng.batches.batch_rows[i])
                    part = evalfn(block, padded)
                    snapshots.append(
                        part if not snapshots else snapshots[-1] + part
                    )
                    self.stats.eval_calls += 1
                    self.stats.batches_scanned += 1
                if b not in estimates:
                    theta, beta2, _ = estimates_from_partials(
                        snapshots[b], padded
                    )
                    estimates[b] = (theta, beta2)
                theta, beta2 = estimates[b]
                idx = jnp.asarray(rows)
                return RawAnswer(theta[idx], beta2[idx])

            return raw_at

        raw_at = make_scan(pad_snippets(dedup.fused()), self._eval)
        raw_at_plain = make_scan(
            pad_snippets(dedup_raw.fused()),
            lambda block, padded: eval_partials(
                block.num_normalized, block.cat, block.measures, padded
            ),
        )

        # ---- phase 3: per-query replay in submission order
        for p in pend:
            if not p.supported:
                raw = raw_at_plain(max_batches - 1, p.rows)
                cells = Q.assemble_results(
                    p.plan, raw.theta, raw.beta2, eng.batches.source_cardinality
                )
                results[p.index] = QueryResult(
                    cells, max_batches, eng._tuples(max_batches), False,
                    p.reason, plan=p.plan,
                )
                continue
            n = p.plan.snippets.n
            improved = raw = result = None
            used = 0
            # Without a target, intermediate improvements are side-effect-free
            # no-ops in the sequential path too — jump straight to the final
            # batch.
            rounds = range(max_batches) if target_rel_error is not None else (
                max_batches - 1,
            )
            for b in rounds:
                raw = raw_at(b, p.rows)
                used = b + 1
                if cfg.learning:
                    improved = eng._improve(p.plan.snippets, raw)
                else:
                    improved = ImprovedAnswer(
                        raw.theta, raw.beta2, raw.theta, raw.beta2,
                        jnp.zeros((n,), bool),
                    )
                if target_rel_error is not None:
                    cells = Q.assemble_results(
                        p.plan, improved.theta, improved.beta2,
                        eng.batches.source_cardinality,
                    )
                    res = QueryResult(
                        cells, used, eng._tuples(used), True,
                        snippet_answer=improved, plan=p.plan,
                    )
                    if res.max_rel_error(cfg.report_delta) <= target_rel_error:
                        if cfg.learning:
                            eng._record(p.plan.snippets, raw)
                        result = res
                        break
            if result is None:
                cells = Q.assemble_results(
                    p.plan, improved.theta, improved.beta2,
                    eng.batches.source_cardinality,
                )
                if cfg.learning and raw is not None:
                    eng._record(p.plan.snippets, raw)
                result = QueryResult(
                    cells, used, eng._tuples(used), True,
                    snippet_answer=improved, plan=p.plan,
                )
            results[p.index] = result
        return results
