"""Online aggregation driver (paper §7, deployment scenario 1).

Processes sample batches one at a time, maintaining accumulated partials and
emitting (raw theta, raw beta^2) after each batch. The Verdict engine wraps
each emission with model-based improvement and stops as soon as the *improved*
error meets the target — that early stop is exactly where the paper's speedup
comes from.

Since the plan-IR refactor this is a thin generator over
``repro.aqp.plan.PhysicalPlan`` — the same lazy cumulative-partials scan
every execution path uses; the public ``Session.stream`` facade adds the
improve/validate/record lifecycle on top.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Optional, Tuple

from repro.aqp.executor import Partials, eval_partials
from repro.aqp.plan import PhysicalPlan
from repro.aqp.sampler import SampleBatches
from repro.core.types import RawAnswer, SnippetBatch


@dataclasses.dataclass
class OnlineState:
    partials: Partials
    batches_used: int = 0


def online_answers(
    batches: SampleBatches,
    snippets: SnippetBatch,
    eval_fn: Optional[Callable] = None,
) -> Iterator[Tuple[RawAnswer, OnlineState]]:
    """Yields increasingly accurate raw answers after each sample batch.

    ``eval_fn(num_normalized, cat, measures, snippets)`` is invoked on the
    TILE-PADDED snippet batch (``pad_snippets``); per-snippet partials are
    bitwise independent of padding, and the yielded answers/partials are
    sliced back to ``snippets.n``.
    """
    eval_fn = eval_fn or eval_partials
    phys = PhysicalPlan(
        batches,
        snippets,
        lambda block, padded: eval_fn(
            block.num_normalized, block.cat, block.measures, padded
        ),
    )
    for b in range(batches.n_batches):
        raw = phys.raw_at(b)
        yield raw, OnlineState(phys.partials_at(b), b + 1)
