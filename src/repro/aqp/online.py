"""Online aggregation driver (paper §7, deployment scenario 1).

Processes sample batches one at a time, maintaining accumulated partials and
emitting (raw theta, raw beta^2) after each batch. The Verdict engine wraps
each emission with model-based improvement and stops as soon as the *improved*
error meets the target — that early stop is exactly where the paper's speedup
comes from.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Optional, Tuple

import jax.numpy as jnp

from repro.aqp.executor import Partials, estimates_from_partials, eval_partials
from repro.aqp.sampler import SampleBatches
from repro.core.types import RawAnswer, SnippetBatch


@dataclasses.dataclass
class OnlineState:
    partials: Partials
    batches_used: int = 0


def online_answers(
    batches: SampleBatches,
    snippets: SnippetBatch,
    eval_fn: Optional[Callable] = None,
) -> Iterator[Tuple[RawAnswer, OnlineState]]:
    """Yields increasingly accurate raw answers after each sample batch."""
    eval_fn = eval_fn or eval_partials
    acc = Partials.zeros(snippets.n)
    used = 0
    for block in batches:
        acc = acc + eval_fn(
            block.num_normalized, block.cat, block.measures, snippets
        )
        used += 1
        theta, beta2, _ = estimates_from_partials(acc, snippets)
        yield RawAnswer(theta=theta, beta2=beta2), OnlineState(acc, used)
