"""Typed aggregate queries, the support checker, and snippet decomposition.

Mirrors paper §2.2/§2.3 without a SQL parser: a query is SUM/COUNT/AVG
aggregates over a (denormalized) relation with conjunctive range / equality /
IN predicates and an optional group-by on categorical attributes. Unsupported
constructs (disjunctions, LIKE, MIN/MAX) are representable but flagged so the
engine can bypass learning for them — "the class of queries that can be
improved is equivalent to the class that can improve others".

Decomposition (§2.3): every (aggregate × group value) pair becomes one snippet;
group-by values are materialized as equality predicates; at most N_max group
snippets per query get improved answers. Internally only AVG and FREQ exist:
COUNT(*) = FREQ × cardinality, SUM = AVG × COUNT (§2.3 "Aggregate Computation").
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.types import AVG, FREQ, Schema, SnippetBatch, make_snippets

N_MAX_DEFAULT = 1000


@dataclasses.dataclass(frozen=True)
class NumRange:
    dim: int
    lo: float
    hi: float


@dataclasses.dataclass(frozen=True)
class NumEq:
    dim: int
    value: float


@dataclasses.dataclass(frozen=True)
class CatIn:
    dim: int
    values: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class CatEq:
    dim: int
    value: int


@dataclasses.dataclass(frozen=True)
class Disjunction:
    """Unsupported marker (paper §2.2: no disjunctions)."""

    terms: Tuple


@dataclasses.dataclass(frozen=True)
class TextLike:
    """Unsupported marker (paper §2.2: no textual filters)."""

    pattern: str


@dataclasses.dataclass(frozen=True)
class AggSpec:
    kind: str  # 'AVG' | 'SUM' | 'COUNT' | 'MIN' | 'MAX'
    measure: Optional[int] = None  # None for COUNT(*)


@dataclasses.dataclass(frozen=True)
class AggQuery:
    aggs: Tuple[AggSpec, ...]
    predicates: Tuple = ()
    groupby: Tuple[int, ...] = ()  # categorical dims


SUPPORTED_KINDS = {"AVG", "SUM", "COUNT"}


def unsupported_reason(q: AggQuery) -> Optional[str]:
    """Paper §2.2 support checker; None means supported."""
    for a in q.aggs:
        if a.kind not in SUPPORTED_KINDS:
            return f"aggregate {a.kind} not supported"
    for p in q.predicates:
        if isinstance(p, Disjunction):
            return "disjunctive predicates not supported"
        if isinstance(p, TextLike):
            return "textual filters not supported"
    return None


def predicates_to_arrays(schema: Schema, predicates) -> Tuple[dict, dict]:
    num_ranges, cat_sets = {}, {}
    for p in predicates:
        if isinstance(p, NumRange):
            lo, hi = num_ranges.get(p.dim, (schema.num_lo[p.dim], schema.num_hi[p.dim]))
            num_ranges[p.dim] = (max(lo, p.lo), min(hi, p.hi))
        elif isinstance(p, NumEq):
            # Intersect like NumRange does: overwriting here made the
            # canonical form order-dependent ([NumRange, NumEq] vs
            # [NumEq, NumRange] produced different boxes for the same
            # conjunction), which broke snippet dedup and cache keys for
            # commutative spellings of one query.
            lo, hi = num_ranges.get(p.dim, (schema.num_lo[p.dim], schema.num_hi[p.dim]))
            num_ranges[p.dim] = (max(lo, p.value), min(hi, p.value))
        elif isinstance(p, CatIn):
            prev = cat_sets.get(p.dim)
            vals = set(p.values) if prev is None else set(prev) & set(p.values)
            cat_sets[p.dim] = tuple(sorted(vals))
        elif isinstance(p, CatEq):
            prev = cat_sets.get(p.dim)
            vals = {p.value} if prev is None else set(prev) & {p.value}
            cat_sets[p.dim] = tuple(sorted(vals))
        else:
            raise ValueError(f"unsupported predicate {p}")
    return num_ranges, cat_sets


@dataclasses.dataclass(frozen=True)
class SnippetPlan:
    """How a query's output cells map onto internal AVG/FREQ snippets.

    snippets: one SnippetBatch covering all (group × needed-internal-agg) cells.
    cells: list of (group_index, agg_index, kind, avg_row, freq_row); avg_row /
    freq_row are row ids into ``snippets`` or -1.
    groups: list of group-value tuples (empty tuple when no group-by).
    truncated_groups: discovered group-by values dropped by the ``n_max`` cap
    — recorded so callers (``QueryResult``, ``Session.explain``) can see that
    the result covers a prefix of the full group set instead of silently
    missing cells.
    """

    snippets: SnippetBatch
    cells: Tuple
    groups: Tuple
    truncated_groups: int = 0


def decompose(
    schema: Schema,
    q: AggQuery,
    group_values: Sequence[Tuple[int, ...]] = ((),),
    n_max: int = N_MAX_DEFAULT,
) -> SnippetPlan:
    """Decompose a supported query into snippets (paper Figure 3).

    ``group_values``: the distinct group-by value tuples present in the result
    set (obtained from the AQP engine's sample scan), capped at n_max groups.
    """
    num_ranges, cat_sets = predicates_to_arrays(schema, q.predicates)
    all_groups = tuple(group_values)
    groups = all_groups[:n_max]
    truncated = len(all_groups) - len(groups)

    need_avg = [a.kind in ("AVG", "SUM") and a.measure is not None for a in q.aggs]
    need_freq = [a.kind in ("SUM", "COUNT") for a in q.aggs]

    rows_num, rows_cat, rows_agg, rows_measure = [], [], [], []
    cells = []

    def add_row(nr, cs, agg, measure):
        rows_num.append(dict(nr))
        rows_cat.append(dict(cs))
        rows_agg.append(agg)
        rows_measure.append(measure)
        return len(rows_agg) - 1

    for gi, gv in enumerate(groups):
        cs = dict(cat_sets)
        for dim, val in zip(q.groupby, gv):
            cs[dim] = (int(val),)
        freq_row_cache = None
        avg_row_cache = {}
        for ai, a in enumerate(q.aggs):
            avg_row = -1
            freq_row = -1
            if need_avg[ai]:
                if a.measure not in avg_row_cache:
                    avg_row_cache[a.measure] = add_row(num_ranges, cs, AVG, a.measure)
                avg_row = avg_row_cache[a.measure]
            if need_freq[ai]:
                if freq_row_cache is None:
                    freq_row_cache = add_row(num_ranges, cs, FREQ, 0)
                freq_row = freq_row_cache
            cells.append((gi, ai, a.kind, avg_row, freq_row))

    snippets = make_snippets(
        schema,
        agg=rows_agg,
        measure=rows_measure,
        num_ranges=rows_num,
        cat_sets=rows_cat,
    )
    return SnippetPlan(snippets=snippets, cells=tuple(cells), groups=groups,
                       truncated_groups=truncated)


def assemble_results(plan: SnippetPlan, theta, beta2, cardinality: int):
    """Combine snippet answers into query-cell answers.

    SUM = AVG × COUNT with first-order (delta-method) error propagation;
    COUNT = FREQ × |r| (paper §2.3).
    Returns list of dicts per output cell.
    """
    theta = np.asarray(theta)
    beta2 = np.asarray(beta2)
    out = []
    for gi, ai, kind, avg_row, freq_row in plan.cells:
        if kind == "AVG":
            est, var = theta[avg_row], beta2[avg_row]
        elif kind == "COUNT":
            est = theta[freq_row] * cardinality
            var = beta2[freq_row] * cardinality**2
        else:  # SUM
            avg, freq = theta[avg_row], theta[freq_row]
            est = avg * freq * cardinality
            var = (
                beta2[avg_row] * (freq * cardinality) ** 2
                + beta2[freq_row] * (avg * cardinality) ** 2
            )
        out.append(
            {
                "group": plan.groups[gi],
                "agg": ai,
                "kind": kind,
                "estimate": float(est),
                "beta2": float(max(var, 0.0)),
            }
        )
    return out
