"""Columnar relation store.

A (denormalized) relation r with l numeric dimension attributes, c categorical
dimension attributes and m measure attributes (paper §3.1). Numeric dimensions
are additionally stored domain-normalized to [0, 1] — the same units snippets,
lengthscales and the Pallas kernels use.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.types import Schema


@dataclasses.dataclass
class Relation:
    schema: Schema
    num: jnp.ndarray  # (N, l) raw units
    cat: jnp.ndarray  # (N, c) int32 codes
    measures: jnp.ndarray  # (N, m) f64
    num_normalized: jnp.ndarray = None  # (N, l) in [0,1]

    def __post_init__(self):
        if self.num_normalized is None:
            lo = jnp.asarray(self.schema.num_lo)
            hi = jnp.asarray(self.schema.num_hi)
            self.num_normalized = (self.num - lo) / jnp.maximum(hi - lo, 1e-300)

    @property
    def cardinality(self) -> int:
        return int(self.num.shape[0])

    def take(self, rows) -> "Relation":
        return Relation(
            schema=self.schema,
            num=self.num[rows],
            cat=self.cat[rows],
            measures=self.measures[rows],
            num_normalized=self.num_normalized[rows],
        )

    @staticmethod
    def from_columns(schema: Schema, num, cat, measures) -> "Relation":
        return Relation(
            schema=schema,
            num=jnp.asarray(num, jnp.float64),
            cat=jnp.asarray(cat, jnp.int32),
            measures=jnp.asarray(measures, jnp.float64),
        )

    def concat(self, other: "Relation") -> "Relation":
        return Relation(
            schema=self.schema,
            num=jnp.concatenate([self.num, other.num]),
            cat=jnp.concatenate([self.cat, other.cat]),
            measures=jnp.concatenate([self.measures, other.measures]),
        )

    def exact_answer(self, snippets):
        """Ground-truth answers for a SnippetBatch (testing/benchmarks only)."""
        from repro.aqp.executor import eval_partials, estimates_from_partials

        parts = eval_partials(
            self.num_normalized, self.cat, self.measures, snippets
        )
        theta, beta2, _ = estimates_from_partials(parts, snippets, exact=True)
        return theta
