"""Selective SSM (Mamba-2/SSD-style scalar-decay-per-channel) for Hymba.

State: h (B, D_inner, N_state); per step
    h_t = exp(dt_t * A)[d] * h_{t-1} + dt_t * x_t ⊗ B_t,   y_t = h_t · C_t
Chunked like rwkv.py: log-decays accumulated from the chunk start so all
pairwise ratios are <= 1; the intra-chunk term is a (C, C) matmul over the
state dim plus a per-channel decay-ratio weighting.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

CHUNK = 16
LW_MIN = -8.0


def _proj(cfg, p, x):
    xf = x.astype(jnp.float32)
    xi = jnp.einsum("bsd,de->bse", xf, p["w_in"].astype(jnp.float32))
    z = jnp.einsum("bsd,de->bse", xf, p["w_z"].astype(jnp.float32))
    bmat = jnp.einsum("bsd,dn->bsn", xf, p["w_b"].astype(jnp.float32))
    cmat = jnp.einsum("bsd,dn->bsn", xf, p["w_c"].astype(jnp.float32))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,de->bse", xf, p["w_dt"].astype(jnp.float32))
        + p["dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (D,) scalar decay per channel
    lw = jnp.clip(dt * a, LW_MIN, -1e-6)  # (B,S,D)
    return xi, z, bmat, cmat, dt, lw


def _chunk_step(h, inp):
    """One chunk of the SSD-style scan. h: (B,D,N); inp: (uu,bb,cc,ll)."""
    uu, bb, cc, ll = inp  # (B,C,D), (B,C,N), (B,C,N), (B,C,D)
    cum = jnp.cumsum(ll, axis=1)  # (B,C,D)
    # inter-chunk: y_t = C_t · (exp(cum_t) ⊙_D h)
    y_inter = jnp.einsum("bcn,bcd,bdn->bcd", cc, jnp.exp(cum), h)
    # intra-chunk: y[t,d] = sum_{tau<=t} (C_t·B_tau) exp(cum_t-cum_tau)[d] u[tau,d]
    cb = jnp.einsum("bcn,btn->bct", cc, bb)  # (B,C,C)
    c_len = uu.shape[1]
    tri = jnp.tril(jnp.ones((c_len, c_len), bool))
    cb = jnp.where(tri[None], cb, 0.0)
    ratio = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,C,C,D) <=1
    ratio = jnp.where(tri[None, :, :, None], ratio, 0.0)
    y_intra = jnp.einsum("bct,bctd,btd->bcd", cb, ratio, uu)
    # state update
    decay_end = jnp.exp(cum[:, -1])  # (B,D)
    tail = jnp.exp(cum[:, -1:, :] - cum)  # (B,C,D)
    h = decay_end[..., None] * h + jnp.einsum("bcd,bcn->bdn", uu * tail, bb)
    return h, y_inter + y_intra


def _conv_mix(p, xi, conv_state=None):
    """Depthwise causal conv over time. xi: (B,S,D). Returns (out, new_state)."""
    w = p["conv"].astype(jnp.float32)  # (K, D)
    k = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((xi.shape[0], k - 1, xi.shape[2]), jnp.float32)
    ext = jnp.concatenate([conv_state, xi], axis=1)
    out = sum(ext[:, i : i + xi.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out), ext[:, -(k - 1) :, :]


def mamba_mix(cfg, p, x, conv_state=None, h=None):
    """Full-sequence selective SSM. x: (B,S,d). Returns (out, (conv_state, h))."""
    b, s, d = x.shape
    di = cfg.ssm.d_inner or d
    n = cfg.ssm.state
    xi, z, bmat, cmat, dt, lw = _proj(cfg, p, x)
    xi, conv_state = _conv_mix(p, xi, conv_state)
    if h is None:
        h = jnp.zeros((b, di, n), jnp.float32)

    pad = (-s) % CHUNK
    def pad_t(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
    u = pad_t(xi * dt)  # (B,S',D) input scaled by dt
    bm, cm, lwp = pad_t(bmat), pad_t(cmat), pad_t(lw)
    nc = (s + pad) // CHUNK
    u = u.reshape(b, nc, CHUNK, di)
    bm = bm.reshape(b, nc, CHUNK, n)
    cm = cm.reshape(b, nc, CHUNK, n)
    lwp = lwp.reshape(b, nc, CHUNK, di)

    h, ys = jax.lax.scan(
        _chunk_step, h,
        (jnp.moveaxis(u, 1, 0), jnp.moveaxis(bm, 1, 0),
         jnp.moveaxis(cm, 1, 0), jnp.moveaxis(lwp, 1, 0)),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, -1, di)[:, :s]
    y = y * jax.nn.silu(z)
    y = y * p["norm_b"].astype(jnp.float32)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(jnp.float32))
    return out.astype(x.dtype), (conv_state, h)


def mamba_decode(cfg, p, x, conv_state, h):
    """One-token recurrence. x: (B,1,d)."""
    b, _, d = x.shape
    xi, z, bmat, cmat, dt, lw = _proj(cfg, p, x)
    xi, conv_state = _conv_mix(p, xi, conv_state)
    u1 = (xi * dt)[:, 0]  # (B,D)
    h = jnp.exp(lw[:, 0])[..., None] * h + jnp.einsum("bd,bn->bdn", u1, bmat[:, 0])
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0])[:, None]
    y = y * jax.nn.silu(z) * p["norm_b"].astype(jnp.float32)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(jnp.float32))
    return out.astype(x.dtype), (conv_state, h)


def mamba_mix_ref(cfg, p, x):
    """Sequential oracle for tests."""
    b, s, d = x.shape
    di = cfg.ssm.d_inner or d
    conv_state = jnp.zeros((b, cfg.ssm.conv - 1, di), jnp.float32)
    h = jnp.zeros((b, di, cfg.ssm.state), jnp.float32)
    outs = []
    for t in range(s):
        o, (conv_state, h) = mamba_decode(cfg, p, x[:, t : t + 1], conv_state, h)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)
