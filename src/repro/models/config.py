"""Architecture configuration and layer plans.

An ``ArchConfig`` describes one of the assigned architectures exactly
(dimensions from the public sources cited in the per-arch config modules).
``layer_plan()`` lowers it to a list of homogeneous *groups*: each group is a
tuple of per-layer ``LayerSpec``s (the scan-step body) plus a repeat count —
alternating-pattern archs (gemma2 local/global, llama4 dense/MoE) scan over
pattern *units* so every scanned body is shape-homogeneous.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    period: int = 1  # MoE every `period`-th layer (llama4: 2)
    shared_expert: bool = False  # llama4-style always-on expert
    dense_residual: bool = False  # arctic-style parallel dense FFN
    d_ff_expert: int = 0  # defaults to d_ff
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    kind: str  # 'rwkv6' | 'mamba'
    state: int = 16
    d_inner: int = 0  # defaults to d_model
    conv: int = 4  # mamba depthwise conv width
    dec_lora: int = 64  # rwkv6 data-dependent-decay LoRA width


@dataclasses.dataclass(frozen=True)
class CrossAttnCfg:
    period: int  # one cross-attn layer inserted per `period` layers
    n_ctx: int  # context (image / encoder) tokens


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    attn: str = "global"  # 'global' | 'local' | 'none'
    ssm: bool = False  # parallel (hymba) or sole (rwkv) sequence mixer
    moe: bool = False
    cross: bool = False  # cross-attention layer (vlm / decoder)
    causal: bool = True


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "silu"  # silu -> SwiGLU; gelu -> GeGLU; gelu_mlp -> plain MLP
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    window: int = 0  # local-attention window
    layer_pattern: str = "G"  # tiled over layers: 'G' global,'L' local
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    cross_attn: Optional[CrossAttnCfg] = None
    enc_dec: bool = False
    enc_layers: int = 0
    meta_tokens: int = 0  # hymba learnable prefix tokens
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    post_norm: bool = False  # gemma2: extra post-norms around blocks
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # mesh-dependent padding (set via with_tp)
    tp: int = 1

    # ---------------------------------------------------------------- derived
    def with_tp(self, tp: int) -> "ArchConfig":
        return dataclasses.replace(self, tp=tp)

    @property
    def heads_padded(self) -> int:
        return -(-self.n_heads // self.tp) * self.tp

    @property
    def kv_padded(self) -> int:
        """KV heads padded up to the smallest divisor of heads_padded >= n_kv
        (GQA needs heads_padded % kv == 0; e.g. hymba 25H/5kv -> 32H/8kv).
        KV projections shard over 'model' only when divisible by tp, else
        they are replicated — standard GQA practice."""
        hp = self.heads_padded
        for k in range(self.n_kv, hp + 1):
            if hp % k == 0:
                return k
        return hp

    @property
    def kv_sharded(self) -> bool:
        return self.kv_padded % self.tp == 0

    @property
    def d_ff_e(self) -> int:
        return (self.moe.d_ff_expert or self.d_ff) if self.moe else self.d_ff

    @property
    def n_params(self) -> float:
        """Total parameter count (for 6ND MODEL_FLOPS; unpadded, logical)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        att = d * self.n_heads * self.head_dim + 2 * d * self.n_kv * self.head_dim \
            + self.n_heads * self.head_dim * d
        gated = self.act in ("silu", "gelu")
        ffn_dense = (3 if gated else 2) * d * f
        total = v * d
        plans = (
            self.encoder_plan() + self.decoder_plan()
            if self.enc_dec
            else self.layer_plan()
        )
        for group, repeat in plans:
            for spec in group:
                per = 0.0
                if spec.attn != "none":
                    per += att
                if spec.cross:
                    per += att
                if spec.ssm:
                    s = self.ssm
                    di = s.d_inner or d
                    if s.kind == "rwkv6":
                        per += 4 * d * di + d * s.dec_lora + s.dec_lora * di + di * d
                    else:  # mamba
                        per += 2 * d * di + 2 * d * s.state + d * di + di * d
                if spec.moe:
                    m = self.moe
                    per += d * m.n_experts
                    per += m.n_experts * (3 if gated else 2) * d * self.d_ff_e
                    if m.shared_expert:
                        per += ffn_dense
                    if m.dense_residual:
                        per += ffn_dense
                elif spec.attn != "none" or spec.ssm:
                    per += ffn_dense
                total += per * repeat
        return float(total)

    @property
    def n_active_params(self) -> float:
        """Active parameters per token (MoE top-k instead of all experts)."""
        if not self.moe:
            return self.n_params
        m = self.moe
        inactive_frac = (m.n_experts - m.top_k) / m.n_experts
        gated = self.act in ("silu", "gelu")
        expert_params = 0.0
        for group, repeat in self.layer_plan():
            for spec in group:
                if spec.moe:
                    expert_params += repeat * m.n_experts * (3 if gated else 2) \
                        * self.d_model * self.d_ff_e
        return self.n_params - expert_params * inactive_frac

    # ------------------------------------------------------------------ plans
    def layer_plan(self) -> Tuple[Tuple[Tuple[LayerSpec, ...], int], ...]:
        """Homogeneous (unit, repeat) groups covering the decoder stack."""
        if self.name.startswith("hymba"):
            # 3 full-attention layers (first/middle/last), rest sliding-window,
            # every layer with a parallel mamba branch [arXiv:2411.13676].
            n = self.n_layers
            mid = n // 2
            loc = lambda: LayerSpec(attn="local", ssm=True)
            glob = lambda: LayerSpec(attn="global", ssm=True)
            return (
                ((glob(),), 1),
                ((loc(),), mid - 1),
                ((glob(),), 1),
                ((loc(),), n - mid - 2),
                ((glob(),), 1),
            )
        if self.ssm and self.ssm.kind == "rwkv6":
            return (((LayerSpec(attn="none", ssm=True),), self.n_layers),)
        if self.cross_attn:
            p = self.cross_attn.period
            unit = tuple(
                [LayerSpec(attn="global", cross=True)]
                + [LayerSpec(attn="global")] * (p - 1)
            )
            assert self.n_layers % p == 0
            return ((unit, self.n_layers // p),)
        if self.moe and self.moe.period > 1:
            p = self.moe.period
            unit = tuple(
                [LayerSpec(attn="global")] * (p - 1) + [LayerSpec(attn="global", moe=True)]
            )
            assert self.n_layers % p == 0
            return ((unit, self.n_layers // p),)
        if self.moe:
            return (((LayerSpec(attn="global", moe=True),), self.n_layers),)
        pattern = self.layer_pattern
        if pattern != "G":
            unit = tuple(
                LayerSpec(attn="local" if ch == "L" else "global") for ch in pattern
            )
            assert self.n_layers % len(pattern) == 0
            return ((unit, self.n_layers // len(pattern)),)
        return (((LayerSpec(attn="global"),), self.n_layers),)

    def encoder_plan(self):
        assert self.enc_dec
        return (((LayerSpec(attn="global", causal=False),), self.enc_layers),)

    def decoder_plan(self):
        """Enc-dec decoder: self-attention + cross-attention per layer."""
        assert self.enc_dec
        return (((LayerSpec(attn="global", cross=True),), self.n_layers),)
