"""Parameter-spec system: one source of truth for shapes, logical axes, init.

``param_specs(cfg)`` returns a pytree of ``P`` leaves (shape + logical axis
names + init scale). The same tree materializes three ways:
  - ``init_params``      -> real arrays (smoke tests, examples)
  - ``abstract_params``  -> ShapeDtypeStruct with NamedSharding (dry-run)
  - ``shardings``        -> NamedSharding tree (pjit in/out_shardings)

Logical axes are resolved to mesh axes by a rules dict (see
repro.distributed.sharding). Scanned layer groups get a leading 'layers' axis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig, LayerSpec


@dataclasses.dataclass(frozen=True)
class P:
    """A parameter leaf spec."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | decay
    scale: float = 1.0

    def stacked(self, n: int) -> "P":
        return P((n,) + self.shape, ("layers",) + self.axes, self.init, self.scale)


def _attn_specs(cfg: ArchConfig, cross: bool = False):
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = cfg.heads_padded, cfg.kv_padded
    kv_ax = "heads" if cfg.kv_sharded else None
    s = {
        "wq": P((d, h, hd), ("embed", "heads", None), scale=d**-0.5),
        "wk": P((d, kv, hd), ("embed", kv_ax, None), scale=d**-0.5),
        "wv": P((d, kv, hd), ("embed", kv_ax, None), scale=d**-0.5),
        "wo": P((h, hd, d), ("heads", None, "embed"), scale=(h * hd) ** -0.5),
    }
    if cfg.qkv_bias and not cross:
        s["bq"] = P((h, hd), ("heads", None), init="zeros")
        s["bk"] = P((kv, hd), (kv_ax, None), init="zeros")
        s["bv"] = P((kv, hd), (kv_ax, None), init="zeros")
    if cross:
        s["gate"] = P((), (), init="zeros")  # gated cross-attn (llama-3.2-v)
    return s


def _ffn_specs(cfg: ArchConfig, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.act in ("silu", "gelu"):
        return {
            "wg": P((d, f), ("embed", "ffn"), scale=d**-0.5),
            "wu": P((d, f), ("embed", "ffn"), scale=d**-0.5),
            "wd": P((f, d), ("ffn", "embed"), scale=f**-0.5),
        }
    return {
        "wi": P((d, f), ("embed", "ffn"), scale=d**-0.5),
        "wd": P((f, d), ("ffn", "embed"), scale=f**-0.5),
    }


def _moe_specs(cfg: ArchConfig):
    m = cfg.moe
    d, fe = cfg.d_model, cfg.d_ff_e
    e = m.n_experts
    s = {
        "router": P((d, e), ("embed", None), scale=d**-0.5),
        "we_g": P((e, d, fe), ("experts", "embed", None), scale=d**-0.5),
        "we_u": P((e, d, fe), ("experts", "embed", None), scale=d**-0.5),
        "we_d": P((e, fe, d), ("experts", None, "embed"), scale=fe**-0.5),
    }
    if m.shared_expert:
        s["shared"] = _ffn_specs(cfg)
    if m.dense_residual:
        s["dense"] = _ffn_specs(cfg)
    return s


def _rwkv_specs(cfg: ArchConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner or d
    h = di // cfg.head_dim
    # RWKV head count (di/head_dim = 40) does not divide tp=16, so time-mix
    # projections are *row-parallel* on the contraction dim ('ffn'->model:
    # psum after each projection); wo shards its head_dim contraction.
    return {
        "mu": P((5, d), (None, None), init="ones", scale=0.5),  # token-shift mix
        "wr": P((d, h, cfg.head_dim), ("ffn", None, None), scale=d**-0.5),
        "wk": P((d, h, cfg.head_dim), ("ffn", None, None), scale=d**-0.5),
        "wv": P((d, h, cfg.head_dim), ("ffn", None, None), scale=d**-0.5),
        "wg": P((d, h, cfg.head_dim), ("ffn", None, None), scale=d**-0.5),
        "dec_a": P((d, s.dec_lora), ("ffn", None), scale=d**-0.5),
        "dec_b": P((s.dec_lora, h, cfg.head_dim), (None, None, None), scale=0.1),
        "dec_lambda": P((h, cfg.head_dim), (None, None), init="decay"),
        "bonus": P((h, cfg.head_dim), (None, None), scale=0.1),
        "wo": P((h, cfg.head_dim, d), (None, "ffn", None), scale=di**-0.5),
        # channel-mix (rwkv FFN) lives in the regular ffn slot
    }


def _mamba_specs(cfg: ArchConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner or d
    return {
        "w_in": P((d, di), ("embed", "ffn"), scale=d**-0.5),
        "w_z": P((d, di), ("embed", "ffn"), scale=d**-0.5),
        "w_b": P((d, s.state), ("embed", None), scale=d**-0.5),
        "w_c": P((d, s.state), ("embed", None), scale=d**-0.5),
        "w_dt": P((d, di), ("embed", "ffn"), scale=d**-0.5),
        "dt_bias": P((di,), ("ffn",), init="ones", scale=0.01),
        "a_log": P((di,), ("ffn",), init="decay"),
        "conv": P((s.conv, di), (None, "ffn"), scale=s.conv**-0.5),
        "w_out": P((di, d), ("ffn", "embed"), scale=di**-0.5),
        "norm_b": P((di,), ("ffn",), init="ones"),
    }


def _layer_specs(cfg: ArchConfig, spec: LayerSpec):
    s = {"ln1": P((cfg.d_model,), ("embed",), init="ones")}
    if spec.attn != "none":
        s["attn"] = _attn_specs(cfg)
    if spec.cross:
        s["xattn"] = _attn_specs(cfg, cross=True)
        s["ln_x"] = P((cfg.d_model,), ("embed",), init="ones")
    if spec.ssm:
        kind = cfg.ssm.kind
        s["ssm"] = _rwkv_specs(cfg) if kind == "rwkv6" else _mamba_specs(cfg)
        if spec.attn != "none":  # hymba: fusion scalars for the two branches
            s["fuse_a"] = P((), (), init="ones")
            s["fuse_s"] = P((), (), init="ones")
    s["ln2"] = P((cfg.d_model,), ("embed",), init="ones")
    s["ffn" if not spec.moe else "moe"] = (
        _moe_specs(cfg) if spec.moe else _ffn_specs(cfg)
    )
    if cfg.post_norm:
        s["ln1b"] = P((cfg.d_model,), ("embed",), init="ones")
        s["ln2b"] = P((cfg.d_model,), ("embed",), init="ones")
    return s


def _stack_group(cfg: ArchConfig, unit, repeat: int):
    unit_specs = {f"sub{i}": _layer_specs(cfg, sp) for i, sp in enumerate(unit)}
    if repeat == 1:
        return unit_specs
    return jax.tree.map(
        lambda p: p.stacked(repeat), unit_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def vocab_padded(cfg: ArchConfig) -> int:
    """Vocab rounded up to a tp multiple (seamless 256206, hymba 32001 need
    padding at tp=16); padded logits are masked in transformer.unembed."""
    return -(-cfg.vocab // cfg.tp) * cfg.tp


def param_specs(cfg: ArchConfig):
    d, v = cfg.d_model, vocab_padded(cfg)
    tree = {
        "embed": P((v, d), ("vocab", "embed"), scale=1.0),
        "ln_f": P((d,), ("embed",), init="ones"),
        "groups": [
            _stack_group(cfg, unit, r) for unit, r in cfg.layer_plan()
        ],
    }
    if not cfg.tie_embeddings:
        tree["unembed"] = P((d, v), ("embed", "vocab"), scale=d**-0.5)
    if cfg.meta_tokens:
        tree["meta"] = P((cfg.meta_tokens, d), (None, "embed"), scale=0.02)
    if cfg.cross_attn:  # vlm: projection stub for precomputed patch embeddings
        tree["ctx_proj"] = P((d, d), (None, "embed"), scale=d**-0.5)
    if cfg.enc_dec:
        tree["enc_groups"] = [
            _stack_group(cfg, unit, r) for unit, r in cfg.encoder_plan()
        ]
        tree["dec_groups"] = [
            _stack_group(cfg, unit, r) for unit, r in cfg.decoder_plan()
        ]
        tree["ln_enc"] = P((d,), ("embed",), init="ones")
        tree.pop("groups")
    return tree


def _is_p(x):
    return isinstance(x, P)


def init_params(cfg: ArchConfig, key):
    """Materialize real (small) parameters — smoke tests and examples."""
    dtype = jnp.dtype(cfg.param_dtype)
    specs = param_specs(cfg)
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_p)
    keys = jax.random.split(key, len(leaves))

    def make(p: P, k):
        if p.init == "zeros":
            return jnp.zeros(p.shape, dtype)
        if p.init == "ones":
            return (jnp.ones(p.shape) * p.scale).astype(dtype)
        if p.init == "decay":
            span = np.linspace(-6.0, -1.0, int(np.prod(p.shape)) or 1)
            return jnp.asarray(span.reshape(p.shape), dtype)
        return (jax.random.normal(k, p.shape) * p.scale).astype(dtype)

    return jax.tree.unflatten(treedef, [make(p, k) for p, k in zip(leaves, keys)])


def shardings(cfg: ArchConfig, mesh, rules: dict):
    """NamedSharding tree resolved through the logical-axis rules."""
    from jax.sharding import NamedSharding, PartitionSpec

    def resolve(p: P):
        spec = tuple(rules.get(a) if a else None for a in p.axes)
        return NamedSharding(mesh, PartitionSpec(*spec))

    return jax.tree.map(resolve, param_specs(cfg), is_leaf=_is_p)


def abstract_params(cfg: ArchConfig, mesh=None, rules: Optional[dict] = None):
    """ShapeDtypeStruct tree (optionally sharded) — the dry-run path."""
    dtype = jnp.dtype(cfg.param_dtype)
    shard_tree = shardings(cfg, mesh, rules) if mesh is not None else None

    def make(p: P, s=None):
        return jax.ShapeDtypeStruct(p.shape, dtype, sharding=s)

    if shard_tree is None:
        return jax.tree.map(make, param_specs(cfg), is_leaf=_is_p)
    return jax.tree.map(make, param_specs(cfg), shard_tree, is_leaf=_is_p)


def count_params(cfg: ArchConfig) -> int:
    total = 0
    for p in jax.tree.leaves(param_specs(cfg), is_leaf=_is_p):
        total += int(np.prod(p.shape)) if p.shape else 1
    return total
