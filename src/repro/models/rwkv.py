"""RWKV-6 ("Finch") time-mix: linear attention with data-dependent decay.

Chunked formulation (TPU adaptation): within a chunk of length C the
per-channel decays are accumulated in log space from the chunk start, so every
pairwise decay ratio exp(cum[t-1] - cum[tau]) with tau <= t-1 is <= 1 — no
overflow; the intra-chunk term is a (C, C) masked matmul on the MXU and the
inter-chunk state is carried as (B, H, K, V). Log-decays are clipped at
LW_MIN; contributions beyond the clip are < e^{-CHUNK·|LW_MIN|} ≈ 0.

Simplification vs. the full paper config (documented in DESIGN.md): the
data-dependent *decay* (the Finch contribution) is kept; the data-dependent
token-shift LoRA is replaced by static learned mix coefficients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

CHUNK = 16
LW_MIN = -8.0


def _projections(cfg, p, x, x_prev):
    """Token-shift mix + r/k/v/g/w projections. x: (B,S,d) f32."""
    b, s, d = x.shape
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    mu = p["mu"].astype(jnp.float32)  # (5, d)
    xs = [x + mu[i] * (shifted - x) for i in range(5)]  # r,k,v,g,w views
    r = jnp.einsum("bsd,dhk->bshk", xs[0], p["wr"].astype(jnp.float32))
    k = jnp.einsum("bsd,dhk->bshk", xs[1], p["wk"].astype(jnp.float32))
    v = jnp.einsum("bsd,dhk->bshk", xs[2], p["wv"].astype(jnp.float32))
    g = jnp.einsum("bsd,dhk->bshk", xs[3], p["wg"].astype(jnp.float32))
    # data-dependent decay (LoRA): lw = -exp(lambda + tanh(x A) B)
    lora = jnp.einsum(
        "bsr,rhk->bshk",
        jnp.tanh(jnp.einsum("bsd,dr->bsr", xs[4], p["dec_a"].astype(jnp.float32))),
        p["dec_b"].astype(jnp.float32),
    )
    lw = -jnp.exp(p["dec_lambda"].astype(jnp.float32) + lora)
    lw = jnp.clip(lw, LW_MIN, -1e-6)  # log decay per (B,S,H,K)
    return r, k, v, g, lw


def _chunk_step(carry, inp, u):
    """One chunk. carry: state (B,H,K,V). inp: r,k,v,lw each (B,C,H,K)."""
    state = carry
    r, k, v, lw = inp
    cum = jnp.cumsum(lw, axis=1)  # (B,C,H,K) log decay from chunk start
    # inter-chunk: y_t += (r_t ⊙ exp(cum_{t-1})) @ state
    q_dec = r * jnp.exp(cum - lw)  # exp(cum_{t-1}) = exp(cum_t - lw_t)
    y_inter = jnp.einsum("bchk,bhkv->bchv", q_dec, state)
    # intra-chunk: A[t,tau] = sum_k r_t exp(cum_{t-1}) * k_tau exp(-cum_tau), tau < t
    k_dec = k * jnp.exp(-cum)
    att = jnp.einsum("bchk,bdhk->bhcd", q_dec, k_dec)  # (B,H,C,C)
    c = r.shape[1]
    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
    att = jnp.where(tri[None, None], att, 0.0)
    y_intra = jnp.einsum("bhcd,bdhv->bchv", att, v)
    # bonus (current token) term: u ⊙ k_t
    y_bonus = jnp.einsum("bchk,bchv->bchv", r * (u * k), v)
    # state update: S' = diag(exp(cum_C)) S + sum_tau exp(cum_C - cum_tau) k_tau v_tau^T
    decay_all = jnp.exp(cum[:, -1:])  # (B,1,H,K)
    k_tail = k * jnp.exp(cum[:, -1:] - cum)
    state = decay_all[:, 0][..., None] * state + jnp.einsum(
        "bchk,bchv->bhkv", k_tail, v
    )
    return state, y_inter + y_intra + y_bonus


def rwkv6_mix(cfg, p, x, x_prev=None, state=None):
    """Full-sequence RWKV6 time-mix. x: (B,S,d). Returns (out, (x_last, state))."""
    b, s, d = x.shape
    h = (cfg.ssm.d_inner or d) // cfg.head_dim
    hd = cfg.head_dim
    xf = x.astype(jnp.float32)
    if x_prev is None:
        x_prev = jnp.zeros((b, d), jnp.float32)
    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)
    r, k, v, g, lw = _projections(cfg, p, xf, x_prev.astype(jnp.float32))
    u = p["bonus"].astype(jnp.float32)

    pad = (-s) % CHUNK

    def pad_t(t):
        return jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))

    # Padded steps get lw=0 (decay=1) and k=v=0, leaving the state intact.
    rc, kc, vc, lwc = (
        t.reshape(b, -1, CHUNK, h, hd)
        for t in (pad_t(r), pad_t(k), pad_t(v), pad_t(lw))
    )

    def step(carry, inp):
        return _chunk_step(carry, inp, u)

    state, ys = jax.lax.scan(
        step, state,
        (jnp.moveaxis(rc, 1, 0), jnp.moveaxis(kc, 1, 0),
         jnp.moveaxis(vc, 1, 0), jnp.moveaxis(lwc, 1, 0)),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, -1, h, hd)[:, :s]
    y = y * jax.nn.silu(g)  # output gate
    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"].astype(jnp.float32))
    return out.astype(x.dtype), (xf[:, -1, :], state)


def rwkv6_decode(cfg, p, x, x_prev, state):
    """One-token recurrence. x: (B,1,d). Returns (out, (x_last, state))."""
    b, _, d = x.shape
    h = (cfg.ssm.d_inner or d) // cfg.head_dim
    hd = cfg.head_dim
    xf = x.astype(jnp.float32)
    r, k, v, g, lw = _projections(cfg, p, xf, x_prev.astype(jnp.float32))
    u = p["bonus"].astype(jnp.float32)
    r1, k1, v1, lw1 = (t[:, 0] for t in (r, k, v, lw))  # (B,H,K)
    y = jnp.einsum("bhk,bhkv->bhv", r1, state) + jnp.einsum(
        "bhk,bhv->bhv", r1 * (u * k1), v1
    )
    state = jnp.exp(lw1)[..., None] * state + jnp.einsum("bhk,bhv->bhkv", k1, v1)
    y = (y * jax.nn.silu(g[:, 0]))[:, None]
    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"].astype(jnp.float32))
    return out.astype(x.dtype), (xf[:, -1, :], state)


def rwkv6_mix_ref(cfg, p, x):
    """Sequential-scan oracle for tests: step-by-step decode over the sequence."""
    b, s, d = x.shape
    h = (cfg.ssm.d_inner or d) // cfg.head_dim
    x_prev = jnp.zeros((b, d), jnp.float32)
    state = jnp.zeros((b, h, cfg.head_dim, cfg.head_dim), jnp.float32)
    outs = []
    for t in range(s):
        o, (x_prev, state) = rwkv6_decode(cfg, p, x[:, t : t + 1], x_prev, state)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)
