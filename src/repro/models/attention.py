"""GQA attention with RoPE, logit softcap, sliding windows, cross-attention,
and a ring-buffered KV cache for decode.

Head layout: projections carry (heads, head_dim) explicitly so tensor-parallel
sharding acts on the heads axis; configs whose head counts don't divide the TP
degree are padded at spec-build time (see ArchConfig.heads_padded) — padding
heads produce garbage that wo simply projects with zero-initialized rows, and
their FLOPs are charged to the MODEL/HLO ratio in the roofline table.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.common import rope, softcap

NEG = -2.0e38


def _project_qkv(cfg, p, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _scores_to_out(cfg, q, k, v, mask):
    """q: (B,S,H,hd); k/v: (B,T,KV,hd); mask: (B,1,1,S,T) or broadcastable."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    logits = logits / (hd**0.5)
    logits = softcap(logits, cfg.attn_softcap)
    logits = jnp.where(mask, logits, NEG)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(b, s, h, hd)


def self_attention(cfg, p, x, positions, *, causal=True, window=0):
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    q, k, v = _project_qkv(cfg, p, x, positions)
    s = x.shape[1]
    qp = positions[:, :, None]  # (B,S,1)
    kp = positions[:, None, :]  # (B,1,T)
    mask = jnp.ones((1, s, s), bool)
    if causal:
        mask = mask & (kp <= qp)
    if window:
        mask = mask & (qp - kp < window)
    mask = mask[:, None, None]  # (B,1,1,S,T)
    out = _scores_to_out(cfg, q, k, v, mask)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, (k, v)


def cross_attention(cfg, p, x, ctx_kv, *, gated=True):
    """x: (B,S,d); ctx_kv: precomputed (k, v) of ctx tokens (B,N,KV,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])  # no RoPE on cross-attn
    k, v = ctx_kv
    mask = jnp.ones((1, 1, 1, 1, 1), bool)
    out = _scores_to_out(cfg, q, k, v, mask)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if gated and "gate" in p:
        out = jnp.tanh(p["gate"]).astype(out.dtype) * out
    return out


def ctx_kv(cfg, p, ctx):
    """Project context tokens to (k, v) once (prefill-time)."""
    k = jnp.einsum("bnd,dhk->bnhk", ctx, p["wk"])
    v = jnp.einsum("bnd,dhk->bnhk", ctx, p["wv"])
    return k, v


def init_attn_cache(cfg, batch: int, max_len: int, window: int = 0, dtype=None):
    """Ring KV cache; local layers bound the ring at ``window`` slots."""
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    slots = min(window, max_len) if window else max_len
    kv = cfg.kv_padded
    return {
        "k": jnp.zeros((batch, slots, kv, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, slots, kv, cfg.head_dim), dtype),
        "slot_pos": jnp.full((slots,), -1, jnp.int32),
    }


def prefill_attn_cache(cache, k, v, positions):
    """Write a full prefix into the cache (assumes prefix <= slots)."""
    slots = cache["k"].shape[1]
    s = k.shape[1]
    start = jnp.maximum(s - slots, 0)
    take = min(slots, s)
    kk = jax.lax.dynamic_slice_in_dim(k, start, take, axis=1)
    vv = jax.lax.dynamic_slice_in_dim(v, start, take, axis=1)
    pp = jax.lax.dynamic_slice_in_dim(positions[0], start, take, axis=0)
    idx = pp % slots  # ring placement consistent with decode
    ck = cache["k"].at[:, idx].set(kk)
    cv = cache["v"].at[:, idx].set(vv)
    sp = cache["slot_pos"].at[idx].set(pp.astype(jnp.int32))
    return {"k": ck, "v": cv, "slot_pos": sp}


def decode_attention(cfg, p, x, cache, pos, *, window=0):
    """One-token decode: x (B,1,d), pos () int32. Returns (out, new_cache)."""
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q, k, v = _project_qkv(cfg, p, x, positions)
    slots = cache["k"].shape[1]
    slot = (pos % slots).astype(jnp.int32)
    zero = jnp.int32(0)  # match slot dtype regardless of the x64 flag
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (zero, slot, zero, zero))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (zero, slot, zero, zero))
    sp = jax.lax.dynamic_update_slice(cache["slot_pos"], pos[None].astype(jnp.int32), (slot,))
    valid = (sp >= 0) & (sp <= pos)
    if window:
        valid = valid & (pos - sp < window)
    mask = valid[None, None, None, None, :]  # (1,1,1,1,T)
    out = _scores_to_out(cfg, q, ck, cv, mask)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, {"k": ck, "v": cv, "slot_pos": sp}
