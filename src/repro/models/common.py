"""Shared model building blocks: norms, activations, RoPE, softcap, context."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Runtime distribution context threaded through model forward passes.

    When ``mesh`` is set, MoE layers run expert-parallel via shard_map over
    ``model_axis`` and activation sharding constraints are applied. When None
    (smoke tests / single device), everything is plain jnp.
    """

    mesh: object = None
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    # ZeRO-3 semantics: layer weights are *stored* fully sharded and
    # all-gathered at use via an explicit replication constraint (XLA's
    # transpose turns the gather into a grad reduce-scatter). Without this,
    # contraction-dim-sharded weights make GSPMD all-reduce partial-sum
    # activations instead — 60x worse on the wire (EXPERIMENTS.md §Perf).
    gather_weights: bool = False

    @property
    def enabled(self) -> bool:
        return self.mesh is not None

    def use_weights(self, p):
        if not (self.enabled and self.gather_weights):
            return p
        import jax

        return jax.tree.map(lambda w: self.constrain(w), p)

    def constrain(self, x, *spec):
        if not self.enabled:
            return x
        from jax.sharding import NamedSharding, PartitionSpec

        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, PartitionSpec(*spec))
        )

    def batch_spec(self):
        return self.batch_axes if self.enabled else None


def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def activate(act: str, g, u=None):
    if act == "silu":
        return jax.nn.silu(g) * u
    if act == "gelu":
        return jax.nn.gelu(g) * u
    return jax.nn.gelu(g)  # gelu_mlp (non-gated)


def rope(x, positions, theta: float = 10_000.0):
    """Rotary embedding over the last dim; x: (..., S, H, hd), positions (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.arange(half, dtype=jnp.float32) / half
    inv = theta**-freqs  # (half,)
    ang = positions.astype(jnp.float32)[..., None, None] * inv  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
