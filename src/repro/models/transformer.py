"""Model assembly: layer dispatch, scanned layer groups, train/prefill/decode.

One generic stack serves all 10 assigned architectures; the per-layer
``LayerSpec`` chooses the sequence mixer (global/local attention, RWKV6,
Mamba branch, cross-attention) and FFN (dense / MoE). Layer groups are
``lax.scan``-ed over stacked parameters with per-layer rematerialization.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import ffn as F
from repro.models import mamba as M
from repro.models import rwkv as R
from repro.models.common import ShardCtx, rms_norm, softcap
from repro.models.config import ArchConfig, LayerSpec


def _branch_norm(x, eps=1e-6):
    return x * jax.lax.rsqrt(jnp.mean(jnp.square(x.astype(jnp.float32)), -1,
                                      keepdims=True) + eps).astype(x.dtype)


def init_layer_cache(cfg: ArchConfig, spec: LayerSpec, batch: int, max_len: int,
                     n_ctx: int = 0):
    """Decode cache slots for one layer."""
    dt = jnp.dtype(cfg.compute_dtype)
    c = {}
    if spec.attn != "none":
        window = cfg.window if spec.attn == "local" else 0
        c["attn"] = A.init_attn_cache(cfg, batch, max_len, window, dt)
    if spec.cross:
        kv = cfg.kv_padded
        c["xk"] = jnp.zeros((batch, n_ctx, kv, cfg.head_dim), dt)
        c["xv"] = jnp.zeros((batch, n_ctx, kv, cfg.head_dim), dt)
    if spec.ssm:
        d = cfg.d_model
        di = cfg.ssm.d_inner or d
        if cfg.ssm.kind == "rwkv6":
            h = di // cfg.head_dim
            c["ssm"] = {
                "x_prev": jnp.zeros((batch, d), jnp.float32),
                "state": jnp.zeros((batch, h, cfg.head_dim, cfg.head_dim), jnp.float32),
            }
        else:
            c["ssm"] = {
                "conv": jnp.zeros((batch, cfg.ssm.conv - 1, di), jnp.float32),
                "h": jnp.zeros((batch, di, cfg.ssm.state), jnp.float32),
            }
    return c


def layer_fwd(cfg: ArchConfig, spec: LayerSpec, p, x, positions, sctx: ShardCtx,
              *, mode: str = "train", cache=None, pos=None, ctx_tokens=None):
    """One transformer layer. Returns (x, new_cache)."""
    new_cache = {}
    p = sctx.use_weights(p)  # ZeRO-3: all-gather stored shards at use
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    mixed = None

    if spec.attn != "none":
        window = cfg.window if spec.attn == "local" else 0
        if mode == "decode":
            attn_out, new_cache["attn"] = A.decode_attention(
                cfg, p["attn"], h, cache["attn"], pos, window=window
            )
        else:
            attn_out, (k, v) = A.self_attention(
                cfg, p["attn"], h, positions, causal=spec.causal, window=window
            )
            if mode == "prefill":
                new_cache["attn"] = A.prefill_attn_cache(cache["attn"], k, v, positions)
        mixed = attn_out

    if spec.ssm:
        if cfg.ssm.kind == "rwkv6":
            if mode == "decode":
                ssm_out, (xp, st) = R.rwkv6_decode(
                    cfg, p["ssm"], h, cache["ssm"]["x_prev"], cache["ssm"]["state"]
                )
            else:
                ssm_out, (xp, st) = R.rwkv6_mix(cfg, p["ssm"], h)
            if mode in ("decode", "prefill"):
                new_cache["ssm"] = {"x_prev": xp, "state": st}
        else:
            if mode == "decode":
                ssm_out, (cs, hh) = M.mamba_decode(
                    cfg, p["ssm"], h, cache["ssm"]["conv"], cache["ssm"]["h"]
                )
            else:
                ssm_out, (cs, hh) = M.mamba_mix(cfg, p["ssm"], h)
            if mode in ("decode", "prefill"):
                new_cache["ssm"] = {"conv": cs, "h": hh}
        if mixed is None:
            mixed = ssm_out
        else:  # hymba: normalized fusion of the two branches
            mixed = (
                p["fuse_a"].astype(mixed.dtype) * _branch_norm(mixed)
                + p["fuse_s"].astype(mixed.dtype) * _branch_norm(ssm_out)
            ) * 0.5

    if cfg.post_norm:
        mixed = rms_norm(mixed, p["ln1b"], cfg.norm_eps)
    x = x + mixed

    if spec.cross:
        hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
        if mode == "decode":
            kv = (cache["xk"], cache["xv"])
            new_cache["xk"], new_cache["xv"] = kv
        else:
            kv = A.ctx_kv(cfg, p["xattn"], ctx_tokens)
            if mode == "prefill":
                new_cache["xk"], new_cache["xv"] = kv
        x = x + A.cross_attention(cfg, p["xattn"], hx, kv)

    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if spec.moe:
        ffn_out = F.moe_ffn(cfg, p["moe"], h2, sctx)
    else:
        ffn_out = F.dense_ffn(cfg, p["ffn"], h2)
    if cfg.post_norm:
        ffn_out = rms_norm(ffn_out, p["ln2b"], cfg.norm_eps)
    x = x + ffn_out
    return x, new_cache


def _unit_fwd(cfg, unit, p_unit, x, positions, sctx, *, mode, cache=None,
              pos=None, ctx_tokens=None, remat=True):
    def run(x, p_unit, cache_in):
        new_caches = {}
        for i, spec in enumerate(unit):
            c = cache_in.get(f"sub{i}") if cache_in else None
            x, nc = layer_fwd(cfg, spec, p_unit[f"sub{i}"], x, positions, sctx,
                              mode=mode, cache=c, pos=pos, ctx_tokens=ctx_tokens)
            new_caches[f"sub{i}"] = nc
        return x, new_caches

    if remat and mode == "train":
        run = jax.checkpoint(run)
    return run(x, p_unit, cache or {})


def groups_fwd(cfg, groups_params, plan, x, positions, sctx, *, mode="train",
               caches=None, pos=None, ctx_tokens=None):
    """Run all layer groups; scanned when repeat > 1. Returns (x, new_caches)."""
    new_caches = []
    for gi, ((unit, repeat), gp) in enumerate(zip(plan, groups_params)):
        cache_g = caches[gi] if caches is not None else None
        if repeat == 1:
            x, nc = _unit_fwd(cfg, unit, gp, x, positions, sctx, mode=mode,
                              cache=cache_g, pos=pos, ctx_tokens=ctx_tokens)
            new_caches.append(nc)
        elif cache_g is None:
            def body_nc(x, lp):
                x, _ = _unit_fwd(cfg, unit, lp, x, positions, sctx, mode=mode,
                                 ctx_tokens=ctx_tokens)
                return x, None

            x, _ = jax.lax.scan(body_nc, x, gp)
            new_caches.append(None)
        else:
            def body(x, scanned):
                lp, lc = scanned
                x, nc = _unit_fwd(cfg, unit, lp, x, positions, sctx, mode=mode,
                                  cache=lc, pos=pos, ctx_tokens=ctx_tokens)
                return x, nc

            x, ncs = jax.lax.scan(body, x, (gp, cache_g))
            new_caches.append(ncs)
    return x, new_caches


def init_cache(cfg: ArchConfig, plan, batch: int, max_len: int, n_ctx: int = 0):
    caches = []
    for unit, repeat in plan:
        unit_c = {
            f"sub{i}": init_layer_cache(cfg, spec, batch, max_len, n_ctx)
            for i, spec in enumerate(unit)
        }
        if repeat > 1:
            unit_c = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (repeat,) + a.shape), unit_c
            )
        caches.append(unit_c)
    return caches


# ---------------------------------------------------------------- full model
def embed_tokens(cfg, params, tokens):
    dt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(dt)
    return x * jnp.asarray(cfg.d_model**0.5, dt)


def unembed(cfg, params, x):
    table = params.get("unembed")
    if table is None:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, table)
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    v_pad = logits.shape[-1]
    if v_pad != cfg.vocab:  # mask tp-padding columns (see params.vocab_padded)
        keep = jnp.arange(v_pad) < cfg.vocab
        logits = jnp.where(keep, logits, -1e30)
    return logits


def forward(cfg: ArchConfig, params, tokens, sctx: ShardCtx = ShardCtx(), *,
            ctx_tokens=None, enc_embeds=None, mode="train", caches=None,
            pos=None):
    """Decoder forward. tokens: (B,S) int32 (decode: (B,1)).

    ctx_tokens: VLM patch embeddings (B,N,d) or enc-dec encoder output.
    Returns (logits, new_caches).
    """
    if sctx.gather_weights:  # ZeRO-3: embed/head shards gathered at use too
        top = {k: v for k, v in params.items()
               if k not in ("groups", "enc_groups", "dec_groups")}
        params = {**params, **sctx.use_weights(top)}
    b, s = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    if cfg.meta_tokens and mode != "decode":
        meta = jnp.broadcast_to(
            params["meta"].astype(x.dtype)[None], (b, cfg.meta_tokens, x.shape[-1])
        )
        x = jnp.concatenate([meta, x], axis=1)
        s = s + cfg.meta_tokens
    if ctx_tokens is not None and "ctx_proj" in params:
        ctx_tokens = jnp.einsum(
            "bnd,de->bne", ctx_tokens.astype(x.dtype), params["ctx_proj"]
        )
    if mode == "decode":
        positions = None
        plan = cfg.decoder_plan() if cfg.enc_dec else cfg.layer_plan()
        groups = params["dec_groups"] if cfg.enc_dec else params["groups"]
        x, new_caches = groups_fwd(cfg, groups, plan, x, positions, sctx,
                                   mode="decode", caches=caches, pos=pos,
                                   ctx_tokens=ctx_tokens)
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        plan = cfg.decoder_plan() if cfg.enc_dec else cfg.layer_plan()
        groups = params["dec_groups"] if cfg.enc_dec else params["groups"]
        x, new_caches = groups_fwd(cfg, groups, plan, x, positions, sctx,
                                   mode=mode, caches=caches,
                                   ctx_tokens=ctx_tokens)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if cfg.meta_tokens and mode != "decode":
        x = x[:, cfg.meta_tokens :]
    logits = unembed(cfg, params, x)
    return logits, new_caches


def encode(cfg: ArchConfig, params, enc_embeds, sctx: ShardCtx = ShardCtx()):
    """Enc-dec encoder over precomputed frame embeddings (B,S,d)."""
    b, s, _ = enc_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = enc_embeds.astype(jnp.dtype(cfg.compute_dtype))
    x, _ = groups_fwd(cfg, params["enc_groups"], cfg.encoder_plan(), x,
                      positions, sctx, mode="train")
    return rms_norm(x, params["ln_enc"], cfg.norm_eps)
