"""Dense FFN and expert-parallel MoE.

MoE dispatch (TPU/GSPMD adaptation, see DESIGN.md): activations entering the
FFN block are replicated across the ``model`` mesh axis (standard Megatron TP
layout), so expert parallelism needs **no token all-to-all**: a shard_map over
``model`` gives each device its E/tp local experts; tokens route locally into
an (E_local, capacity, d) buffer via scatter-add (O(N·d) data movement — not
the O(N·E·C·d) one-hot einsum of GShard, which would dwarf the expert matmuls
at E=128), batched expert matmuls run on the MXU, and a single psum over
``model`` combines expert outputs — the same collective a dense TP FFN needs.

Capacity: ceil(top_k·N/E · capacity_factor); overflow tokens are dropped
(standard GShard/Switch semantics), underflow slots are zero.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activate


def dense_ffn(cfg, p, x):
    if "wg" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        u = jnp.einsum("bsd,df->bsf", x, p["wu"])
        h = activate(cfg.act, g, u)
    else:
        h = activate("gelu_mlp", jnp.einsum("bsd,df->bsf", x, p["wi"]))
    return jnp.einsum("bsf,fd->bsd", h, p["wd"])


def _route(cfg, router_logits):
    """Top-k routing with renormalized softmax gates."""
    k = cfg.moe.top_k
    gates, idx = jax.lax.top_k(router_logits, k)  # (N, k)
    gates = jax.nn.softmax(gates.astype(jnp.float32), axis=-1)
    return gates, idx


def _moe_experts(cfg, p, x_flat, e_lo, e_local, capacity):
    """Scatter-dispatch -> batched expert matmuls -> gather-combine.

    ``p`` holds the *local* expert weight slices (E_local, ...); the (full,
    replicated) router produces global expert ids and tokens routed to
    [e_lo, e_lo + e_local) are processed here. Returns this shard's partial
    output (N, d) — psum over the model axis completes the MoE.
    """
    gates, idx = _route(cfg, jnp.einsum("nd,de->ne", x_flat, p["router"]))
    n, d = x_flat.shape
    k = cfg.moe.top_k

    flat_idx = idx.reshape(-1)  # (N*k,) global expert ids
    flat_gate = gates.reshape(-1)
    local = (flat_idx >= e_lo) & (flat_idx < e_lo + e_local)
    local_e = jnp.where(local, flat_idx - e_lo, e_local)  # sentinel e_local
    # Rank of each (token, choice) within its expert queue (1-based cumsum).
    onehot = jax.nn.one_hot(local_e, e_local + 1, dtype=jnp.int32)
    slot = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1
    keep = local & (slot < capacity)
    slot = jnp.where(keep, slot, capacity)  # overflow -> spill slot

    tok = jnp.repeat(jnp.arange(n), k)
    buf = jnp.zeros((e_local, capacity + 1, d), x_flat.dtype)
    buf = buf.at[local_e, slot].add(
        jnp.where(keep[:, None], x_flat[tok], 0.0), mode="drop"
    )[:, :capacity]  # (E_local, C, d)

    g = jnp.einsum("ecd,edf->ecf", buf, p["we_g"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["we_u"])
    h = activate(cfg.act, g, u)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["we_d"])  # (E_local, C, d)

    gathered = out_buf[local_e.clip(0, e_local - 1), slot.clip(0, capacity - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    contrib = gathered * flat_gate[:, None].astype(gathered.dtype)
    return jnp.zeros_like(x_flat).at[tok].add(contrib)


def moe_ffn(cfg, p, x, sctx):
    """x: (B,S,d) -> (B,S,d). Expert-parallel over sctx.model_axis."""
    b, s, d = x.shape
    m = cfg.moe
    x_flat = x.reshape(b * s, d)
    n = b * s

    if sctx.enabled:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as Pspec

        tp = sctx.mesh.shape[sctx.model_axis]
        e_per = m.n_experts // tp

        def local_fn(xf, router, we_g, we_u, we_d):
            # Capacity sized from the *local* token count (xf is the
            # per-device shard): sizing from global N would give every
            # device data_shards-times-oversized expert buffers — a 16x
            # MoE overcompute found via the roofline useful-FLOPs ratio
            # (EXPERIMENTS.md §Perf, arctic iteration 1).
            cap = max(int(xf.shape[0] * m.top_k / m.n_experts
                          * m.capacity_factor), 4)
            e_lo = jax.lax.axis_index(sctx.model_axis) * e_per
            pp = {"router": router, "we_g": we_g, "we_u": we_u, "we_d": we_d}
            out = _moe_experts(cfg, pp, xf, e_lo, e_per, cap)
            return jax.lax.psum(out, sctx.model_axis)

        out_flat = shard_map(
            local_fn,
            mesh=sctx.mesh,
            in_specs=(
                Pspec(sctx.batch_axes, None),
                Pspec(None, None),
                Pspec(sctx.model_axis, None, None),
                Pspec(sctx.model_axis, None, None),
                Pspec(sctx.model_axis, None, None),
            ),
            out_specs=Pspec(sctx.batch_axes, None),
            check_rep=False,
        )(x_flat, p["router"], p["we_g"], p["we_u"], p["we_d"])
    else:
        capacity = max(int(n * m.top_k / m.n_experts * m.capacity_factor), 4)
        out_flat = _moe_experts(cfg, p, x_flat, 0, m.n_experts, capacity)

    out = out_flat.reshape(b, s, d)
    if m.shared_expert:
        out = out + dense_ffn(cfg, p["shared"], x)
    if m.dense_residual:
        out = out + dense_ffn(cfg, p["dense"], x)
    return out
