"""Offline correlation-parameter learning (paper Appendix A).

Maximizes the log marginal likelihood of past raw answers (Eq. 13):

    log Pr(theta_past | Sigma_n) =
        -1/2 r^T Sigma_n^{-1} r - 1/2 log|Sigma_n| - n/2 log 2pi,
    r = theta_past - mu,   Sigma_n = sigma^2 K(ls) + diag(beta^2)

The paper uses Matlab's gradient-free fminunc; we differentiate the Cholesky
NLL exactly with jax.grad and run Adam on log-lengthscales — faster and exact
(beyond-paper). sigma_g^2 defaults to the analytic estimate of Appendix F.3
(paper-faithful; joint learning available with ``learn_sigma=True``).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import covariance
from repro.core.types import GPParams, SnippetBatch
from repro.utils.optim import adam_minimize

LOG2PI = 1.8378770664093453


def nll(params: GPParams, snippets: SnippetBatch, theta, beta2, jitter=1e-10):
    """Negative Eq. (13); differentiable w.r.t. params."""
    n = theta.shape[0]
    sigma = covariance.cov_matrix(snippets, snippets, params) + jnp.diag(beta2)
    sigma = sigma + jitter * jnp.eye(n, dtype=sigma.dtype)
    chol = jnp.linalg.cholesky(sigma)
    resid = theta - covariance.prior_mean(snippets, params)
    w = jax.scipy.linalg.solve_triangular(chol, resid, lower=True)
    return 0.5 * jnp.sum(w * w) + jnp.sum(jnp.log(jnp.diagonal(chol))) + 0.5 * n * LOG2PI


def fit(
    snippets: SnippetBatch,
    theta,
    beta2,
    schema,
    *,
    steps: int = 150,
    lr: float = 0.1,
    learn_sigma: bool = False,
    init: GPParams | None = None,
) -> Tuple[GPParams, jax.Array]:
    """Learn lengthscales (and optionally sigma^2) from the synopsis content."""
    sigma2, mu = covariance.analytic_sigma2_mu(snippets, theta)
    if init is None:
        init = GPParams.init(schema)
    base = GPParams(log_ls=init.log_ls, log_sigma2=jnp.log(sigma2), mu=mu)

    if learn_sigma:
        free0 = {"log_ls": base.log_ls, "log_sigma2": base.log_sigma2}
    else:
        free0 = {"log_ls": base.log_ls}

    def loss(free):
        p = GPParams(
            log_ls=free["log_ls"],
            log_sigma2=free.get("log_sigma2", base.log_sigma2),
            mu=base.mu,
        )
        # Soft prior keeping lengthscales in a sane band (normalized units).
        reg = 1e-3 * jnp.sum(free["log_ls"] ** 2)
        return nll(p, snippets, theta, beta2) + reg

    free, hist = adam_minimize(loss, free0, steps=steps, lr=lr)
    fitted = GPParams(
        log_ls=free["log_ls"],
        log_sigma2=free.get("log_sigma2", base.log_sigma2),
        mu=base.mu,
    )
    return fitted, hist
