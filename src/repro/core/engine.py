"""VerdictEngine: the end-to-end DBL query engine (paper Figure 2, Algorithm 2).

Workflow per query:
  1. support check (§2.2) — unsupported queries bypass inference entirely;
  2. decompose into snippets (§2.3), discovering group-by values from the
     first sample batch;
  3. online aggregation over sample batches; after each batch the raw
     answers are improved via the per-aggregate-function synopsis model and
     validated (Appendix B); stop early once the improved error bound meets
     the target — the source of the paper's speedups;
  4. insert the final raw answers into the synopsis (the model learns from
     *raw* answers, never from its own outputs).

``learning=False`` turns the engine into the NoLearn baseline of §8.1.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.aqp import queries as Q
from repro.aqp.executor import estimates_from_partials, eval_partials, Partials
from repro.aqp.relation import Relation
from repro.aqp.sampler import SampleBatches, build_sample
from repro.core.synopsis import Synopsis
from repro.core.types import (
    AVG,
    FREQ,
    ImprovedAnswer,
    RawAnswer,
    Schema,
    SnippetBatch,
    pad_snippets,
)
from repro.utils.stats import confidence_multiplier


@dataclasses.dataclass
class EngineConfig:
    sample_rate: float = 0.1
    n_batches: int = 10
    capacity: int = 2000  # C_g
    n_max: int = 1000  # N^max
    delta_v: float = 0.99
    report_delta: float = 0.95
    learning: bool = True
    seed: int = 0
    use_kernels: bool = False  # route hot paths through the Pallas kernels


@dataclasses.dataclass
class QueryResult:
    cells: List[dict]
    batches_used: int
    tuples_scanned: int
    supported: bool
    unsupported_reason: Optional[str] = None
    snippet_answer: Optional[ImprovedAnswer] = None
    plan: Optional[Q.SnippetPlan] = None

    def max_rel_error(self, delta: float = 0.95) -> float:
        alpha = float(confidence_multiplier(delta))
        worst = 0.0
        for c in self.cells:
            denom = max(abs(c["estimate"]), 1e-9)
            worst = max(worst, alpha * np.sqrt(c["beta2"]) / denom)
        return worst


class VerdictEngine:
    def __init__(self, relation: Relation, config: Optional[EngineConfig] = None):
        self.relation = relation
        self.schema: Schema = relation.schema
        self.config = config or EngineConfig()
        self.batches: SampleBatches = build_sample(
            relation,
            rate=self.config.sample_rate,
            n_batches=self.config.n_batches,
            seed=self.config.seed,
        )
        self.synopses: Dict[Tuple[int, int], Synopsis] = {}
        self._eval_fn = eval_partials
        if self.config.use_kernels:
            from repro.kernels.range_mask_agg import ops as rma_ops

            self._eval_fn = rma_ops.eval_partials_kernel

    # ------------------------------------------------------------- synopses
    def synopsis_for(self, agg: int, measure: int) -> Synopsis:
        key = (int(agg), int(measure) if agg == AVG else 0)
        if key not in self.synopses:
            self.synopses[key] = Synopsis(
                self.schema, capacity=self.config.capacity, delta_v=self.config.delta_v
            )
        return self.synopses[key]

    def refit(self, steps: int = 150, lr: float = 0.1, learn_sigma: bool = False):
        """Offline learning pass (paper Algorithm 1)."""
        for syn in self.synopses.values():
            syn.refit(steps=steps, lr=lr, learn_sigma=learn_sigma)

    # ------------------------------------------------------------ improve
    def _improve(self, snippets: SnippetBatch, raw: RawAnswer) -> ImprovedAnswer:
        """Per-aggregate-function improvement, scattered back to query order."""
        agg = np.asarray(snippets.agg)
        mea = np.asarray(snippets.measure)
        theta = np.array(np.asarray(raw.theta))
        beta2 = np.array(np.asarray(raw.beta2))
        out_theta = theta.copy()
        out_beta2 = beta2.copy()
        accepted = np.zeros(len(agg), dtype=bool)
        for key in {(int(a), int(m) if a == AVG else 0) for a, m in zip(agg, mea)}:
            rows = np.where(
                (agg == key[0]) & ((mea == key[1]) if key[0] == AVG else True)
            )[0]
            syn = self.synopsis_for(*key)
            sub = snippets[jnp.asarray(rows)]
            imp = syn.improve(
                sub, RawAnswer(jnp.asarray(theta[rows]), jnp.asarray(beta2[rows]))
            )
            out_theta[rows] = np.asarray(imp.theta)
            out_beta2[rows] = np.asarray(imp.beta2)
            accepted[rows] = np.asarray(imp.accepted)
        return ImprovedAnswer(
            theta=jnp.asarray(out_theta),
            beta2=jnp.asarray(out_beta2),
            raw_theta=raw.theta,
            raw_beta2=raw.beta2,
            accepted=jnp.asarray(accepted),
        )

    def _record(self, snippets: SnippetBatch, raw: RawAnswer):
        agg = np.asarray(snippets.agg)
        mea = np.asarray(snippets.measure)
        for key in {(int(a), int(m) if a == AVG else 0) for a, m in zip(agg, mea)}:
            rows = np.where(
                (agg == key[0]) & ((mea == key[1]) if key[0] == AVG else True)
            )[0]
            syn = self.synopsis_for(*key)
            sub = snippets[jnp.asarray(rows)]
            syn.add(sub, np.asarray(raw.theta)[rows], np.asarray(raw.beta2)[rows])

    # ------------------------------------------------------------- groups
    def _discover_groups(self, q: Q.AggQuery):
        if not q.groupby:
            return ((),)
        first = self.batches.relation.take(self.batches.batch_rows[0])
        plan_probe = Q.decompose(self.schema, Q.AggQuery(aggs=(Q.AggSpec("COUNT"),), predicates=q.predicates))
        from repro.aqp.executor import predicate_mask

        mask = np.asarray(
            predicate_mask(first.num_normalized, first.cat, plan_probe.snippets)
        )[:, 0].astype(bool)
        cats = np.asarray(first.cat)[mask][:, list(q.groupby)]
        if cats.size == 0:
            return ((),) if not q.groupby else tuple()
        uniq = np.unique(cats, axis=0)
        return tuple(tuple(int(v) for v in row) for row in uniq)

    # ------------------------------------------------------------- execute
    def execute(
        self,
        q: Q.AggQuery,
        target_rel_error: Optional[float] = None,
        max_batches: Optional[int] = None,
    ) -> QueryResult:
        reason = Q.unsupported_reason(q)
        max_batches = max_batches or self.batches.n_batches
        if reason is not None:
            return self._execute_raw_only(q, reason, max_batches)

        groups = self._discover_groups(q)
        if not groups:
            return QueryResult([], 0, 0, True, plan=None)
        plan = Q.decompose(self.schema, q, groups, n_max=self.config.n_max)
        # Scan over a tile-padded batch: shape-stable across plans (one
        # compiled program per size bucket) and bitwise-reproducible per row,
        # so the fused BatchExecutor path can match this one exactly.
        padded = pad_snippets(plan.snippets)
        n = plan.snippets.n
        acc = Partials.zeros(padded.n)
        used = 0
        improved = None
        raw = None
        for rows in self.batches.batch_rows[:max_batches]:
            block = self.batches.relation.take(rows)
            acc = acc + self._eval_fn(
                block.num_normalized, block.cat, block.measures, padded
            )
            used += 1
            theta, beta2, _ = estimates_from_partials(acc, padded)
            raw = RawAnswer(theta[:n], beta2[:n])
            if self.config.learning:
                improved = self._improve(plan.snippets, raw)
            else:
                improved = ImprovedAnswer(
                    raw.theta, raw.beta2, raw.theta, raw.beta2,
                    jnp.zeros((n,), bool),
                )
            if target_rel_error is not None:
                cells = Q.assemble_results(
                    plan, improved.theta, improved.beta2, self.batches.source_cardinality
                )
                res = QueryResult(cells, used, self._tuples(used), True,
                                  snippet_answer=improved, plan=plan)
                if res.max_rel_error(self.config.report_delta) <= target_rel_error:
                    if self.config.learning:
                        self._record(plan.snippets, raw)
                    return res
        cells = Q.assemble_results(
            plan, improved.theta, improved.beta2, self.batches.source_cardinality
        )
        if self.config.learning and raw is not None:
            self._record(plan.snippets, raw)
        return QueryResult(cells, used, self._tuples(used), True,
                           snippet_answer=improved, plan=plan)

    def _tuples(self, used_batches: int) -> int:
        return int(sum(len(b) for b in self.batches.batch_rows[:used_batches]))

    def _execute_raw_only(self, q, reason, max_batches):
        """Unsupported queries: raw AQP answers, no learning (paper §2.2)."""
        probe = self.raw_only_probe(q)
        groups = self._discover_groups(probe)
        plan = Q.decompose(self.schema, probe, groups, n_max=self.config.n_max)
        padded = pad_snippets(plan.snippets)
        acc = Partials.zeros(padded.n)
        used = 0
        for rows in self.batches.batch_rows[:max_batches]:
            block = self.batches.relation.take(rows)
            acc = acc + eval_partials(
                block.num_normalized, block.cat, block.measures, padded
            )
            used += 1
        theta, beta2, _ = estimates_from_partials(acc, padded)
        n = plan.snippets.n
        cells = Q.assemble_results(
            plan, theta[:n], beta2[:n], self.batches.source_cardinality
        )
        return QueryResult(cells, used, self._tuples(used), False, reason, plan=plan)

    def raw_only_probe(self, q: Q.AggQuery) -> Q.AggQuery:
        """The supported-subset probe the raw-only path evaluates (§2.2)."""
        supported_aggs = tuple(
            a for a in q.aggs if a.kind in Q.SUPPORTED_KINDS
        ) or (Q.AggSpec("COUNT", None),)
        clean_preds = tuple(
            p for p in q.predicates
            if not isinstance(p, (Q.Disjunction, Q.TextLike))
        )
        return Q.AggQuery(aggs=supported_aggs, predicates=clean_preds,
                          groupby=q.groupby)

    # -------------------------------------------------------------- batched
    def execute_many(
        self,
        queries,
        target_rel_error: Optional[float] = None,
        max_batches: Optional[int] = None,
        mesh=None,
    ) -> List[QueryResult]:
        """Execute a workload through the fused ``BatchExecutor`` path.

        Every sample batch is scanned exactly once for the whole workload
        (identical snippets deduped across queries); answers match ``execute``
        run query-by-query bit for bit. See ``repro.aqp.batch``.
        """
        from repro.aqp.batch import BatchExecutor

        return BatchExecutor(self, mesh=mesh).execute_many(
            queries, target_rel_error=target_rel_error, max_batches=max_batches
        )
