"""VerdictEngine: the end-to-end DBL query engine (paper Figure 2, Algorithm 2).

Workflow per query:
  1. support check (§2.2) — unsupported queries bypass inference entirely;
  2. decompose into snippets (§2.3), discovering group-by values from the
     first sample batch;
  3. online aggregation over sample batches; after each batch the raw
     answers are improved via the per-aggregate-function synopsis model and
     validated (Appendix B); stop early once the improved error bound meets
     the target — the source of the paper's speedups;
  4. insert the final raw answers into the synopsis (the model learns from
     *raw* answers, never from its own outputs).

The lifecycle itself lives in the shared plan IR (``repro.aqp.plan``), ALL
learned state lives behind the ``SynopsisStore`` protocol
(``repro.core.store``), and the scan routes through a ``ScanPlacement``
(``repro.aqp.executor``): ``execute(q)`` is literally
``execute_many([q])[0]``, so the engine holds only the store, the scan
placement, the engine-level config, and the sample-batch stream. Pass
``store=`` (an instance or a ``(schema, config) -> SynopsisStore``
factory) and/or ``scan=`` to choose placement per plane —
``LocalSynopsisStore`` + local ``ScanPlacement`` by default,
``ShardedSynopsisStore`` + ``ShardedScanPlacement`` for mesh placement
(``repro.verdict.connect`` wires both from its ``mesh=`` argument; the
sharded scan accepts any relation/mesh combination via masked tuple
padding).

``learning=False`` turns the engine into the NoLearn baseline of §8.1.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.aqp import queries as Q
from repro.aqp.executor import ScanPlacement, eval_partials
from repro.aqp.plan import QueryResult  # noqa: F401 — canonical home is the plan IR
from repro.aqp.relation import Relation
from repro.aqp.sampler import SampleBatches, build_sample
from repro.core.store import (
    LocalSynopsisStore,
    SynopsisStore,
    agg_key,
    group_rows,
)
from repro.core.synopsis import MIN_FILL_BUCKET, MIN_Q_BUCKET, Synopsis
from repro.core.types import (
    ImprovedAnswer,
    RawAnswer,
    Schema,
    SnippetBatch,
    pad_snippets,
)


@dataclasses.dataclass
class EngineConfig:
    sample_rate: float = 0.1
    n_batches: int = 10
    capacity: int = 2000  # C_g
    n_max: int = 1000  # N^max
    delta_v: float = 0.99
    report_delta: float = 0.95
    learning: bool = True
    seed: int = 0
    use_kernels: bool = False  # route hot paths through the Pallas kernels
    async_ingest: bool = True  # learn on the background ingest thread
    ingest_max_pending: int = 64  # back-pressure bound on pending ingest batches
    # Smallest serve-path tiles (power-of-two ladder floors): fills/batches
    # below these share one compiled program. Per-deployment knobs — the
    # first step of the adaptive bucket policy (ROADMAP).
    min_fill_bucket: int = MIN_FILL_BUCKET
    min_q_bucket: int = MIN_Q_BUCKET


class VerdictEngine:
    def __init__(
        self,
        relation: Relation,
        config: Optional[EngineConfig] = None,
        store=None,
        scan: Optional[ScanPlacement] = None,
        intel=None,
    ):
        self.relation = relation
        self.schema: Schema = relation.schema
        self.config = config or EngineConfig()
        # Optional workload-intelligence plane (repro.intel.WorkloadIntel):
        # semantic answer cache + serve-path router. None (the default)
        # keeps every path bit-for-bit the historical engine; the plan
        # lifecycle and the batch executor consult it via getattr, so the
        # core never imports the intel package.
        self.intel = intel
        # The scan plane's placement seam (repro.aqp.executor.ScanPlacement):
        # every block evaluation routes through it, mirroring how all
        # learned state routes through `store`. Local by default;
        # `repro.verdict.connect(..., mesh=...)` passes a sharded one.
        self.scan: ScanPlacement = scan or ScanPlacement()
        self.batches: SampleBatches = build_sample(
            relation,
            rate=self.config.sample_rate,
            n_batches=self.config.n_batches,
            seed=self.config.seed,
        )
        if store is None:
            self.store: SynopsisStore = LocalSynopsisStore(
                self.schema, self.config)
        elif callable(store) and not isinstance(store, SynopsisStore):
            # a (schema, config) -> SynopsisStore factory
            self.store = store(self.schema, self.config)
        else:
            # an instance — SynopsisStore subclass or any duck-typed
            # implementation of the store protocol
            self.store = store
        self._eval_fn = eval_partials
        if self.config.use_kernels:
            # The fused masked-scan kernel: predicate compare, categorical
            # membership, validity masking and partials accumulation in one
            # VMEM pass — bitwise-equal to ``eval_partials`` in interpret
            # mode (the canonical ``masked_tile_fold`` reduction).
            from repro.kernels.fused_masked_scan import ops as fms_ops

            self._eval_fn = fms_ops.eval_partials_fused

    # ------------------------------------------------------------- synopses
    @property
    def synopses(self) -> Dict[tuple, Synopsis]:
        """Deprecated: the raw key → ``Synopsis`` mapping.

        The store is the only supported access path to learned state; this
        shim survives for external callers and returns the store's live
        mapping (reads and in-place synopsis mutation keep working).
        """
        warnings.warn(
            "VerdictEngine.synopses is deprecated; go through "
            "VerdictEngine.store (repro.core.store.SynopsisStore)",
            DeprecationWarning, stacklevel=2,
        )
        return self.store.synopses

    def synopsis_for(self, agg: int, measure: int) -> Synopsis:
        return self.store.for_key(agg_key(agg, measure))

    def drain(self):
        """Barrier over the store's async ingest (snapshot/refit boundary)."""
        self.store.drain()

    def refit(self, steps: int = 150, lr: float = 0.1, learn_sigma: bool = False):
        """Offline learning pass (paper Algorithm 1). Drains async ingest."""
        self.store.refit(steps=steps, lr=lr, learn_sigma=learn_sigma)

    def ingest_stats(self) -> Dict[str, dict]:
        """Per-synopsis async-ingest back-pressure telemetry (structured
        ``"agg<k>-measure<m>"`` keys; see ``repro.core.store.state_key``)."""
        return self.store.ingest_stats()

    def heal(self, manager=None, step: Optional[int] = None) -> Dict[str, bool]:
        """Heal every quarantined synopsis and rejoin it to serving.

        With a ``CheckpointManager``, quarantined keys restore from the
        last good committed checkpoint (``restore_blind``) and replay their
        parked batches on top; without one (or for keys absent from the
        checkpoint) they rebuild from their own row arrays. Returns
        ``{state_key: healed}`` for the keys that were quarantined.
        """
        states = None
        if manager is not None:
            try:
                states, _ = manager.restore_blind(step)
            except Exception as e:  # noqa: BLE001 — degrade to rebuild
                # No committed checkpoint (or none intact): heal from the
                # synopses' own row arrays instead of failing the heal.
                warnings.warn(
                    f"heal(): checkpoint restore unavailable ({e!r}); "
                    "rebuilding quarantined synopses from row arrays",
                    RuntimeWarning, stacklevel=2,
                )
                states = None
        return self.store.heal(states)

    # ------------------------------------------------------------ improve
    _group_rows = staticmethod(group_rows)  # back-compat alias

    def _improve(self, snippets: SnippetBatch, raw: RawAnswer) -> ImprovedAnswer:
        """Back-compat hook: the improvement lives in the store now."""
        return self.store.improve_groups(
            snippets, raw, use_kernels=self.config.use_kernels)

    def _record(self, snippets: SnippetBatch, raw: RawAnswer):
        """Back-compat hook: recording lives in the store now."""
        self.store.record(snippets, raw)

    # ------------------------------------------------------------- groups
    def _discover_groups(self, q: Q.AggQuery):
        return self._discover_groups_many([q])[0]

    def _discover_groups_many(self, queries: Sequence[Q.AggQuery]):
        """Group-by value discovery for a whole workload in ONE probe.

        Every query's COUNT-probe snippets are fused into a single padded
        batch and evaluated with one ``predicate_mask`` pass over the first
        sample batch, instead of one eval (and one ``relation.take``) per
        query. Mask columns are computed independently per snippet, so the
        per-query booleans — and hence the discovered groups — are identical
        to the one-probe-at-a-time path.
        """
        out: List[Optional[tuple]] = [None] * len(queries)
        need = []
        for i, q in enumerate(queries):
            if not q.groupby:
                out[i] = ((),)
            else:
                need.append(i)
        if not need:
            return out
        from repro.aqp.executor import predicate_mask

        first = self.batches.relation.take(self.batches.batch_rows[0])
        plans = [
            Q.decompose(
                self.schema,
                Q.AggQuery(aggs=(Q.AggSpec("COUNT"),),
                           predicates=queries[i].predicates),
            )
            for i in need
        ]
        fused = SnippetBatch.concat([p.snippets for p in plans])
        mask_all = np.asarray(
            predicate_mask(first.num_normalized, first.cat, pad_snippets(fused))
        ).astype(bool)
        cat_first = np.asarray(first.cat)
        off = 0
        for i, plan in zip(need, plans):
            q = queries[i]
            mask = mask_all[:, off]
            off += plan.snippets.n
            cats = cat_first[mask][:, list(q.groupby)]
            if cats.size == 0:
                out[i] = tuple()
                continue
            uniq = np.unique(cats, axis=0)
            out[i] = tuple(tuple(int(v) for v in row) for row in uniq)
        return out

    # ------------------------------------------------------------- execute
    def execute(
        self,
        q: Q.AggQuery,
        target_rel_error: Optional[float] = None,
        max_batches: Optional[int] = None,
    ) -> QueryResult:
        """One query is a workload of one: the entire lifecycle (plan, fused
        scan, improve, validate, early-stop, record) lives in
        ``repro.aqp.plan.replay_query`` — there is no second copy here."""
        return self.execute_many(
            [q], target_rel_error=target_rel_error, max_batches=max_batches
        )[0]

    def _tuples(self, used_batches: int) -> int:
        return int(sum(len(b) for b in self.batches.batch_rows[:used_batches]))

    def _execute_raw_only(self, q, reason, max_batches):
        """Forced raw-only execution: raw AQP answers over the supported
        subset probe, no learning, whatever ``q``'s own supportedness
        (paper §2.2). The lifecycle is the ``supported=False`` branch of the
        shared ``replay_query`` — no scan loop lives here.
        """
        from repro.aqp.plan import (LogicalPlan, PhysicalPlan,
                                    SnippetInterner, plain_eval, replay_query)

        probe = self.raw_only_probe(q)
        groups = self._discover_groups(probe)
        plan = Q.decompose(self.schema, probe, groups, n_max=self.config.n_max)
        interner = SnippetInterner(self.schema)
        rows = interner.intern(plan.snippets)
        lp = LogicalPlan(0, q, probe, reason or "forced raw-only", plan, rows)
        phys = PhysicalPlan(self.batches, interner.fused(), plain_eval)
        return replay_query(self, lp, phys, max_batches=max_batches)

    def raw_only_probe(self, q: Q.AggQuery) -> Q.AggQuery:
        """The supported-subset probe the raw-only path evaluates (§2.2)."""
        supported_aggs = tuple(
            a for a in q.aggs if a.kind in Q.SUPPORTED_KINDS
        ) or (Q.AggSpec("COUNT", None),)
        clean_preds = tuple(
            p for p in q.predicates
            if not isinstance(p, (Q.Disjunction, Q.TextLike))
        )
        return Q.AggQuery(aggs=supported_aggs, predicates=clean_preds,
                          groupby=q.groupby)

    # -------------------------------------------------------------- persist
    def synopses_state_dict(self) -> Dict[str, dict]:
        """Host snapshot of the store, keyed ``"agg<k>-measure<m>"`` with a
        ``shard`` tag per entry (see ``SynopsisStore.state_dict``)."""
        return self.store.state_dict()

    def load_synopses_state_dict(self, state: Dict[str, dict]):
        """Restore a store snapshot (accepts legacy ``"<agg>_<measure>"``
        keys from pre-store checkpoints; placement is re-derived by the
        current store's policy, so the snapshot re-places onto any mesh).

        A reserved ``"intel"`` payload (present when the saving engine had
        a workload-intelligence plane) restores the answer cache + learned
        router state when this engine has one too — AFTER the store, so
        cache-entry generations re-license against the restored synopses.
        """
        state = dict(state)
        intel_state = state.pop("intel", None)
        self.store.load_state_dict(state)
        if self.intel is not None and intel_state is not None:
            self.intel.load_state_dict(intel_state, self.store)

    def save_synopses(self, manager, step: int):
        """Checkpoint the learned synopses (plus, when a workload-
        intelligence plane is attached, its answer cache and learned router
        state under the reserved ``"intel"`` key) through a
        ``CheckpointManager`` — one payload, one CRC-verified commit."""
        payload = self.store.state_dict()
        if self.intel is not None:
            payload["intel"] = self.intel.state_dict(self.store)
        manager.save(step, payload, extra={"kind": "verdict-synopses"})

    def load_synopses(self, manager, step: Optional[int] = None):
        """Restore synopses from a ``CheckpointManager`` checkpoint.

        This is what makes the engine smarter across process restarts: a new
        process pays zero queries to recover everything past sessions learned
        — including re-placing a sharded checkpoint onto whatever devices
        this process' store spans, and (when both sides carry a workload-
        intelligence plane) the semantic answer cache and router state.
        """
        state, extra = manager.restore_blind(step)
        self.load_synopses_state_dict(state)
        return extra

    # -------------------------------------------------------------- batched
    def execute_many(
        self,
        queries,
        target_rel_error: Optional[float] = None,
        max_batches: Optional[int] = None,
        mesh=None,
        stop_delta: Optional[float] = None,
        deadline_s: Optional[float] = None,
    ) -> List[QueryResult]:
        """Execute a workload through the fused ``BatchExecutor`` path.

        Every sample batch is scanned exactly once for the whole workload
        (identical snippets deduped across queries); answers match ``execute``
        run query-by-query bit for bit. ``stop_delta`` overrides the
        confidence level of the early-stop check (default
        ``config.report_delta``); ``deadline_s`` bounds each query's wall
        clock — on expiry the best-so-far answer returns with its honest
        (wider) CI, flagged ``degraded``. See ``repro.aqp.batch``.
        """
        from repro.aqp.batch import BatchExecutor

        return BatchExecutor(self, mesh=mesh).execute_many(
            queries, target_rel_error=target_rel_error,
            max_batches=max_batches, stop_delta=stop_delta,
            deadline_s=deadline_s,
        )
