"""VerdictEngine: the end-to-end DBL query engine (paper Figure 2, Algorithm 2).

Workflow per query:
  1. support check (§2.2) — unsupported queries bypass inference entirely;
  2. decompose into snippets (§2.3), discovering group-by values from the
     first sample batch;
  3. online aggregation over sample batches; after each batch the raw
     answers are improved via the per-aggregate-function synopsis model and
     validated (Appendix B); stop early once the improved error bound meets
     the target — the source of the paper's speedups;
  4. insert the final raw answers into the synopsis (the model learns from
     *raw* answers, never from its own outputs).

The lifecycle itself lives in the shared plan IR (``repro.aqp.plan``):
``execute(q)`` is literally ``execute_many([q])[0]``, so the engine holds
only the synopsis state, the improvement/record hooks the replay calls into,
and the sample-batch stream.

``learning=False`` turns the engine into the NoLearn baseline of §8.1.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.aqp import queries as Q
from repro.aqp.executor import eval_partials
from repro.aqp.plan import QueryResult  # noqa: F401 — canonical home is the plan IR
from repro.aqp.relation import Relation
from repro.aqp.sampler import SampleBatches, build_sample
from repro.core.synopsis import (
    MIN_Q_BUCKET,
    Synopsis,
    _improve_stacked,
    _pad_raw,
)
from repro.core.types import (
    AVG,
    ImprovedAnswer,
    RawAnswer,
    Schema,
    SnippetBatch,
    bucket_size,
    pad_snippets,
)


@dataclasses.dataclass
class EngineConfig:
    sample_rate: float = 0.1
    n_batches: int = 10
    capacity: int = 2000  # C_g
    n_max: int = 1000  # N^max
    delta_v: float = 0.99
    report_delta: float = 0.95
    learning: bool = True
    seed: int = 0
    use_kernels: bool = False  # route hot paths through the Pallas kernels
    async_ingest: bool = True  # learn on the background ingest thread
    ingest_max_pending: int = 64  # back-pressure bound on pending ingest batches


class VerdictEngine:
    def __init__(self, relation: Relation, config: Optional[EngineConfig] = None):
        self.relation = relation
        self.schema: Schema = relation.schema
        self.config = config or EngineConfig()
        self.batches: SampleBatches = build_sample(
            relation,
            rate=self.config.sample_rate,
            n_batches=self.config.n_batches,
            seed=self.config.seed,
        )
        self.synopses: Dict[Tuple[int, int], Synopsis] = {}
        self._eval_fn = eval_partials
        if self.config.use_kernels:
            from repro.kernels.range_mask_agg import ops as rma_ops

            self._eval_fn = rma_ops.eval_partials_kernel

    # ------------------------------------------------------------- synopses
    def synopsis_for(self, agg: int, measure: int) -> Synopsis:
        key = (int(agg), int(measure) if agg == AVG else 0)
        if key not in self.synopses:
            self.synopses[key] = Synopsis(
                self.schema,
                capacity=self.config.capacity,
                delta_v=self.config.delta_v,
                async_ingest=self.config.async_ingest,
                max_pending=self.config.ingest_max_pending,
            )
        return self.synopses[key]

    def drain(self):
        """Barrier over every synopsis' async ingest queue.

        Call at snapshot/refit boundaries; serving itself drains lazily (each
        ``improve`` waits only for its own synopsis' pending batches).
        """
        for syn in self.synopses.values():
            syn.drain()

    def refit(self, steps: int = 150, lr: float = 0.1, learn_sigma: bool = False):
        """Offline learning pass (paper Algorithm 1). Drains async ingest."""
        for syn in self.synopses.values():
            syn.refit(steps=steps, lr=lr, learn_sigma=learn_sigma)

    def ingest_stats(self) -> Dict[str, dict]:
        """Per-synopsis async-ingest back-pressure telemetry."""
        return {
            f"{agg}_{mea}": self.synopses[(agg, mea)].ingest_stats()
            for (agg, mea) in sorted(self.synopses)
        }

    # ------------------------------------------------------------ improve
    def _group_rows(self, snippets: SnippetBatch):
        """(key, row-index array) per aggregate-function group, in key order."""
        agg = np.asarray(snippets.agg)
        mea = np.asarray(snippets.measure)
        keys = sorted({(int(a), int(m) if a == AVG else 0)
                       for a, m in zip(agg, mea)})
        out = []
        for key in keys:
            rows = np.where(
                (agg == key[0]) & ((mea == key[1]) if key[0] == AVG else True)
            )[0]
            out.append((key, rows))
        return out

    def _improve(self, snippets: SnippetBatch, raw: RawAnswer) -> ImprovedAnswer:
        """Per-aggregate-function improvement, scattered back to query order.

        The per-key Python loop is fused into ONE stacked jitted dispatch:
        every group's (state, new-snippets, raw answers) is padded to a shared
        (Q-bucket, fill-bucket) tile and improved by a single vmapped program
        (bitwise equal per group to the single-synopsis path). With
        ``use_kernels=True`` each group instead routes through the
        ``gp_batch_infer`` Pallas kernel, whose 128-wide MXU tiling is the
        TPU-side equivalent of the stacking.
        """
        theta = np.asarray(raw.theta)
        beta2 = np.asarray(raw.beta2)
        out_theta = np.array(theta)
        out_beta2 = np.array(beta2)
        accepted = np.zeros(theta.shape[0], dtype=bool)
        groups = []
        for key, rows in self._group_rows(snippets):
            syn = self.synopsis_for(*key)
            syn.drain()
            if syn.n == 0:
                continue  # Theorem 1 equality case: raw passes through
            groups.append((syn, rows))
        if groups and (self.config.use_kernels or len(groups) == 1):
            for syn, rows in groups:
                sub = snippets[jnp.asarray(rows)]
                imp = syn.improve(
                    sub,
                    RawAnswer(jnp.asarray(theta[rows]), jnp.asarray(beta2[rows])),
                    use_kernel=self.config.use_kernels,
                )
                out_theta[rows] = np.asarray(imp.theta)
                out_beta2[rows] = np.asarray(imp.beta2)
                accepted[rows] = np.asarray(imp.accepted)
        elif groups:
            qb = bucket_size(max(len(rows) for _, rows in groups), MIN_Q_BUCKET)
            fb = max(syn._fill_bucket() for syn, _ in groups)
            states = [syn._padded_state(fb) for syn, _ in groups]
            news, raw_ts, raw_bs = [], [], []
            for syn, rows in groups:
                news.append(pad_snippets(snippets[jnp.asarray(rows)], qb))
                raw_ts.append(_pad_raw(jnp.asarray(theta[rows]), qb, 0.0))
                raw_bs.append(_pad_raw(jnp.asarray(beta2[rows]), qb, 1.0))
            stack = lambda *xs: jnp.stack(xs)  # noqa: E731
            th_s, b2_s, acc_s = _improve_stacked(
                jax.tree.map(stack, *[s[0] for s in states]),
                jnp.stack([s[1] for s in states]),
                jnp.stack([s[2] for s in states]),
                jnp.stack([s[3] for s in states]),
                jax.tree.map(stack, *[syn.params for syn, _ in groups]),
                jax.tree.map(stack, *news),
                jnp.stack(raw_ts),
                jnp.stack(raw_bs),
                groups[0][0].delta_v,
            )
            for g, (syn, rows) in enumerate(groups):
                k = len(rows)
                out_theta[rows] = np.asarray(th_s[g, :k])
                out_beta2[rows] = np.asarray(b2_s[g, :k])
                accepted[rows] = np.asarray(acc_s[g, :k])
        return ImprovedAnswer(
            theta=jnp.asarray(out_theta),
            beta2=jnp.asarray(out_beta2),
            raw_theta=raw.theta,
            raw_beta2=raw.beta2,
            accepted=jnp.asarray(accepted),
        )

    def _record(self, snippets: SnippetBatch, raw: RawAnswer):
        """Enqueue the final raw answers for learning (async per synopsis)."""
        theta = np.asarray(raw.theta)
        beta2 = np.asarray(raw.beta2)
        for key, rows in self._group_rows(snippets):
            syn = self.synopsis_for(*key)
            sub = snippets[jnp.asarray(rows)]
            syn.add(sub, theta[rows], beta2[rows])

    # ------------------------------------------------------------- groups
    def _discover_groups(self, q: Q.AggQuery):
        return self._discover_groups_many([q])[0]

    def _discover_groups_many(self, queries: Sequence[Q.AggQuery]):
        """Group-by value discovery for a whole workload in ONE probe.

        Every query's COUNT-probe snippets are fused into a single padded
        batch and evaluated with one ``predicate_mask`` pass over the first
        sample batch, instead of one eval (and one ``relation.take``) per
        query. Mask columns are computed independently per snippet, so the
        per-query booleans — and hence the discovered groups — are identical
        to the one-probe-at-a-time path.
        """
        out: List[Optional[tuple]] = [None] * len(queries)
        need = []
        for i, q in enumerate(queries):
            if not q.groupby:
                out[i] = ((),)
            else:
                need.append(i)
        if not need:
            return out
        from repro.aqp.executor import predicate_mask

        first = self.batches.relation.take(self.batches.batch_rows[0])
        plans = [
            Q.decompose(
                self.schema,
                Q.AggQuery(aggs=(Q.AggSpec("COUNT"),),
                           predicates=queries[i].predicates),
            )
            for i in need
        ]
        fused = SnippetBatch.concat([p.snippets for p in plans])
        mask_all = np.asarray(
            predicate_mask(first.num_normalized, first.cat, pad_snippets(fused))
        ).astype(bool)
        cat_first = np.asarray(first.cat)
        off = 0
        for i, plan in zip(need, plans):
            q = queries[i]
            mask = mask_all[:, off]
            off += plan.snippets.n
            cats = cat_first[mask][:, list(q.groupby)]
            if cats.size == 0:
                out[i] = tuple()
                continue
            uniq = np.unique(cats, axis=0)
            out[i] = tuple(tuple(int(v) for v in row) for row in uniq)
        return out

    # ------------------------------------------------------------- execute
    def execute(
        self,
        q: Q.AggQuery,
        target_rel_error: Optional[float] = None,
        max_batches: Optional[int] = None,
    ) -> QueryResult:
        """One query is a workload of one: the entire lifecycle (plan, fused
        scan, improve, validate, early-stop, record) lives in
        ``repro.aqp.plan.replay_query`` — there is no second copy here."""
        return self.execute_many(
            [q], target_rel_error=target_rel_error, max_batches=max_batches
        )[0]

    def _tuples(self, used_batches: int) -> int:
        return int(sum(len(b) for b in self.batches.batch_rows[:used_batches]))

    def _execute_raw_only(self, q, reason, max_batches):
        """Forced raw-only execution: raw AQP answers over the supported
        subset probe, no learning, whatever ``q``'s own supportedness
        (paper §2.2). The lifecycle is the ``supported=False`` branch of the
        shared ``replay_query`` — no scan loop lives here.
        """
        from repro.aqp.plan import (LogicalPlan, PhysicalPlan,
                                    SnippetInterner, plain_eval, replay_query)

        probe = self.raw_only_probe(q)
        groups = self._discover_groups(probe)
        plan = Q.decompose(self.schema, probe, groups, n_max=self.config.n_max)
        interner = SnippetInterner(self.schema)
        rows = interner.intern(plan.snippets)
        lp = LogicalPlan(0, q, probe, reason or "forced raw-only", plan, rows)
        phys = PhysicalPlan(self.batches, interner.fused(), plain_eval)
        return replay_query(self, lp, phys, max_batches=max_batches)

    def raw_only_probe(self, q: Q.AggQuery) -> Q.AggQuery:
        """The supported-subset probe the raw-only path evaluates (§2.2)."""
        supported_aggs = tuple(
            a for a in q.aggs if a.kind in Q.SUPPORTED_KINDS
        ) or (Q.AggSpec("COUNT", None),)
        clean_preds = tuple(
            p for p in q.predicates
            if not isinstance(p, (Q.Disjunction, Q.TextLike))
        )
        return Q.AggQuery(aggs=supported_aggs, predicates=clean_preds,
                          groupby=q.groupby)

    # -------------------------------------------------------------- persist
    def synopses_state_dict(self) -> Dict[str, dict]:
        """Host snapshot of every synopsis, keyed ``"<agg>_<measure>"``.

        Drains async ingest first (via ``Synopsis.state_dict``) and returns
        copies, so the snapshot is stable across later queries — the pytree
        ``repro.ft.checkpoint`` persists across process restarts.
        """
        return {
            f"{agg}_{mea}": self.synopses[(agg, mea)].state_dict()
            for (agg, mea) in sorted(self.synopses)
        }

    def load_synopses_state_dict(self, state: Dict[str, dict]):
        """Restore synopses saved by ``synopses_state_dict`` (rebuilds models)."""
        for key, sd in state.items():
            agg, mea = (int(x) for x in key.split("_"))
            self.synopsis_for(agg, mea).load_state_dict(sd)

    def save_synopses(self, manager, step: int):
        """Checkpoint the learned synopses through a ``CheckpointManager``."""
        manager.save(step, self.synopses_state_dict(),
                     extra={"kind": "verdict-synopses"})

    def load_synopses(self, manager, step: Optional[int] = None):
        """Restore synopses from a ``CheckpointManager`` checkpoint.

        This is what makes the engine smarter across process restarts: a new
        process pays zero queries to recover everything past sessions learned.
        """
        state, extra = manager.restore_blind(step)
        self.load_synopses_state_dict(state)
        return extra

    # -------------------------------------------------------------- batched
    def execute_many(
        self,
        queries,
        target_rel_error: Optional[float] = None,
        max_batches: Optional[int] = None,
        mesh=None,
        stop_delta: Optional[float] = None,
    ) -> List[QueryResult]:
        """Execute a workload through the fused ``BatchExecutor`` path.

        Every sample batch is scanned exactly once for the whole workload
        (identical snippets deduped across queries); answers match ``execute``
        run query-by-query bit for bit. ``stop_delta`` overrides the
        confidence level of the early-stop check (default
        ``config.report_delta``). See ``repro.aqp.batch``.
        """
        from repro.aqp.batch import BatchExecutor

        return BatchExecutor(self, mesh=mesh).execute_many(
            queries, target_rel_error=target_rel_error,
            max_batches=max_batches, stop_delta=stop_delta,
        )
