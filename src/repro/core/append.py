"""Data-append generalization (paper Appendix D).

When r^a is appended to r, every past snippet answer computed on r is adjusted
(Lemma 3):

    theta_i'  = theta_i + f * mu_k          f = |r^a| / (|r| + |r^a|)
    beta_i'^2 = beta_i^2 + (f * eta_k)^2

where s_k ~ (mu_k, eta_k^2) models the drift of A_k between r and r^a, estimated
from small samples of both.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class AppendStats:
    """Per-measure drift statistics plus the append fraction f."""

    mu: np.ndarray  # (m,)
    eta2: np.ndarray  # (m,)
    frac: float


def estimate_append_stats(old_sample, new_sample, n_old: int, n_new: int) -> AppendStats:
    """old_sample/new_sample: (k, m) measure samples of r and r^a."""
    mu_old = np.asarray(old_sample).mean(axis=0)
    mu_new = np.asarray(new_sample).mean(axis=0)
    var_old = np.asarray(old_sample).var(axis=0)
    var_new = np.asarray(new_sample).var(axis=0)
    k_old = max(len(old_sample), 1)
    k_new = max(len(new_sample), 1)
    mu = mu_new - mu_old
    # Variance of the drift estimate: sampling noise of both means plus the
    # spread of the appended values themselves (they replace a deterministic
    # aggregate with a random one).
    eta2 = var_new + var_old / k_old + var_new / k_new
    frac = n_new / max(n_old + n_new, 1)
    return AppendStats(mu=mu, eta2=eta2, frac=frac)


def adjust_answers(theta, beta2, measure_idx, agg, stats: AppendStats):
    """Apply Lemma 3 to past AVG answers (FREQ fractions are unaffected by
    value drift; COUNT rescaling is handled by cardinality bookkeeping)."""
    from repro.core.types import AVG

    mu_k = jnp.asarray(stats.mu)[measure_idx]
    eta2_k = jnp.asarray(stats.eta2)[measure_idx]
    f = stats.frac
    is_avg = agg == AVG
    theta_new = jnp.where(is_avg, theta + f * mu_k, theta)
    beta2_new = jnp.where(is_avg, beta2 + (f**2) * eta2_k, beta2)
    return theta_new, beta2_new
