"""Core types for Database Learning (Verdict).

The unit of inference is the *query snippet* (paper Definition 1): a supported
aggregate query whose answer is a single scalar.  Snippets are stored as a
struct-of-arrays ``SnippetBatch`` so that covariance construction, inference and
aggregation are all vectorized / JIT-able.

Numeric predicate ranges are normalized to the attribute domain ([0, 1] per
dimension) at ingestion: lengthscales, volumes and the SE double integrals then
operate in well-conditioned units (a beyond-paper numerical hardening; the paper
works in raw attribute units inside Matlab's f64).
"""
from __future__ import annotations

import jax

# Verdict's core math runs in float64: the closed-form double integral of the SE
# kernel is an inclusion-exclusion of 4 antiderivative terms whose difference is
# O(width^2) — catastrophic cancellation in f32 for narrow predicates.
jax.config.update("jax_enable_x64", True)

import dataclasses  # noqa: E402
from typing import Optional, Tuple  # noqa: E402

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.utils.pytree import pytree_dataclass  # noqa: E402

AVG = 0
FREQ = 1


@dataclasses.dataclass(frozen=True)
class Schema:
    """Static description of the (denormalized) relation the engine serves.

    ``num_lo/num_hi``: domain bounds of the ``l`` numeric dimension attributes.
    ``cat_sizes``: domain cardinality of each of the ``c`` categorical dimension
    attributes; ``cat_vmax`` is the padded one-hot width (>= max(cat_sizes)).
    ``n_measures``: number of measure attributes (AVG targets).
    """

    num_lo: Tuple[float, ...]
    num_hi: Tuple[float, ...]
    cat_sizes: Tuple[int, ...]
    n_measures: int
    cat_vmax: int = 0
    num_names: Tuple[str, ...] = ()
    cat_names: Tuple[str, ...] = ()
    measure_names: Tuple[str, ...] = ()

    def __post_init__(self):
        if self.cat_vmax == 0 and self.cat_sizes:
            object.__setattr__(self, "cat_vmax", int(max(self.cat_sizes)))

    @property
    def n_num(self) -> int:
        return len(self.num_lo)

    @property
    def n_cat(self) -> int:
        return len(self.cat_sizes)

    def normalize(self, dim: int, value):
        lo, hi = self.num_lo[dim], self.num_hi[dim]
        return (value - lo) / max(hi - lo, 1e-300)

    def denormalize(self, dim: int, value):
        lo, hi = self.num_lo[dim], self.num_hi[dim]
        return value * (hi - lo) + lo


@pytree_dataclass
class SnippetBatch:
    """A batch of query snippets, vectorized (struct of arrays).

    lo, hi    : (n, l) f64 — normalized numeric range constraints (defaults 0/1)
    cat       : (n, c, V) bool — categorical membership masks (all-True = free)
    agg       : (n,) i32 — AVG / FREQ
    measure   : (n,) i32 — measure attribute index (0 for FREQ)
    """

    lo: jax.Array
    hi: jax.Array
    cat: jax.Array
    agg: jax.Array
    measure: jax.Array

    @property
    def n(self) -> int:
        return self.lo.shape[0]

    def __getitem__(self, idx) -> "SnippetBatch":
        if isinstance(idx, int):
            idx = slice(idx, idx + 1)
        return SnippetBatch(
            lo=self.lo[idx],
            hi=self.hi[idx],
            cat=self.cat[idx],
            agg=self.agg[idx],
            measure=self.measure[idx],
        )

    @staticmethod
    def concat(batches) -> "SnippetBatch":
        return SnippetBatch(
            lo=jnp.concatenate([b.lo for b in batches]),
            hi=jnp.concatenate([b.hi for b in batches]),
            cat=jnp.concatenate([b.cat for b in batches]),
            agg=jnp.concatenate([b.agg for b in batches]),
            measure=jnp.concatenate([b.measure for b in batches]),
        )

    @staticmethod
    def empty(schema: Schema) -> "SnippetBatch":
        l, c, v = schema.n_num, schema.n_cat, schema.cat_vmax
        return SnippetBatch(
            lo=jnp.zeros((0, l)),
            hi=jnp.ones((0, l)),
            cat=jnp.ones((0, c, max(v, 1)), dtype=bool),
            agg=jnp.zeros((0,), jnp.int32),
            measure=jnp.zeros((0,), jnp.int32),
        )


SNIPPET_TILE = 128


def bucket_size(n: int, minimum: int = 8, cap: Optional[int] = None) -> int:
    """Smallest power-of-two tile >= max(n, minimum), optionally clamped to cap.

    The shape-bucketing rule shared by the serve path: padding device buffers
    to the next power of two (instead of a fixed capacity) keeps the number of
    compiled programs logarithmic in the largest size seen while letting cost
    scale with actual fill. ``cap`` (the synopsis capacity) bounds the largest
    bucket; since n <= cap always, the clamped bucket still covers n.
    """
    b = max(int(minimum), 1)
    n = int(n)
    while b < n:
        b *= 2
    if cap is not None:
        b = min(b, int(cap))
    return b


def snippet_key(lo, hi, cat, agg, measure) -> int:
    """Content hash of one snippet (host-side numpy rows).

    The shared dedup key: ``Synopsis`` uses it for LRU/replacement bookkeeping
    and ``BatchExecutor`` uses it to fuse identical snippets across queries.
    """
    return hash(
        (lo.tobytes(), hi.tobytes(), cat.tobytes(), int(agg), int(measure))
    )


def pad_snippets(snippets: "SnippetBatch", multiple: int = SNIPPET_TILE) -> "SnippetBatch":
    """Pad the snippet axis up to the next multiple of ``multiple``.

    Scanning a shape-stable (T, n_pad) mask keeps one compiled program per
    size bucket instead of one per distinct plan, and — because each output
    element's reduction over tuples is independent of its sibling columns —
    makes per-snippet partials bitwise reproducible across different plans
    (the property the batched executor's answer-parity guarantee rests on).
    Padding rows are full-domain FREQ snippets; callers slice them away.
    """
    n = snippets.n
    target = max(((n + multiple - 1) // multiple) * multiple, multiple)
    if target == n:
        return snippets
    k = target - n
    l = snippets.lo.shape[1]
    c, v = snippets.cat.shape[1], snippets.cat.shape[2]
    return SnippetBatch(
        lo=jnp.concatenate([snippets.lo, jnp.zeros((k, l))]),
        hi=jnp.concatenate([snippets.hi, jnp.ones((k, l))]),
        cat=jnp.concatenate([snippets.cat, jnp.ones((k, c, v), dtype=bool)]),
        agg=jnp.concatenate([snippets.agg, jnp.full((k,), FREQ, jnp.int32)]),
        measure=jnp.concatenate([snippets.measure, jnp.zeros((k,), jnp.int32)]),
    )


def make_snippets(
    schema: Schema,
    *,
    agg,
    measure=None,
    num_ranges=None,
    cat_sets=None,
) -> SnippetBatch:
    """Build a SnippetBatch from python-level predicate descriptions.

    num_ranges: list (len n) of dict {dim: (lo, hi)} in RAW attribute units.
    cat_sets:   list (len n) of dict {dim: iterable of category ids}.
    agg:        int or list of ints; measure likewise.
    """
    # An explicitly-empty list is a valid 0-snippet batch (e.g. decompose()
    # over zero groups); only None means "one unconstrained snippet".
    if num_ranges is None:
        num_ranges = [{}]
    n = len(num_ranges)
    if cat_sets is None:
        cat_sets = [{} for _ in range(n)]
    if len(cat_sets) != n:
        raise ValueError("num_ranges and cat_sets length mismatch")
    l, c, v = schema.n_num, schema.n_cat, max(schema.cat_vmax, 1)
    lo = np.zeros((n, l))
    hi = np.ones((n, l))
    cat = np.zeros((n, c, v), dtype=bool)
    for k, size in enumerate(schema.cat_sizes):
        cat[:, k, :size] = True
    for i, ranges in enumerate(num_ranges):
        for dim, (a, b) in ranges.items():
            lo[i, dim] = schema.normalize(dim, a)
            hi[i, dim] = schema.normalize(dim, b)
    for i, sets in enumerate(cat_sets):
        for dim, values in sets.items():
            cat[i, dim, :] = False
            for val in values:
                cat[i, dim, int(val)] = True
    agg_arr = np.full((n,), agg, np.int32) if np.isscalar(agg) else np.asarray(agg, np.int32)
    if measure is None:
        measure = 0
    mea_arr = (
        np.full((n,), measure, np.int32)
        if np.isscalar(measure)
        else np.asarray(measure, np.int32)
    )
    return SnippetBatch(
        lo=jnp.asarray(lo),
        hi=jnp.asarray(hi),
        cat=jnp.asarray(cat),
        agg=jnp.asarray(agg_arr),
        measure=jnp.asarray(mea_arr),
    )


@pytree_dataclass
class GPParams:
    """Correlation parameters of one aggregate function g (paper §4.2, App. A/F.3).

    log_ls     : (l,) log lengthscales (normalized units)
    log_sigma2 : () log of sigma_g^2
    mu         : () prior mean (AVG: answer units; FREQ: density units)
    """

    log_ls: jax.Array
    log_sigma2: jax.Array
    mu: jax.Array

    @property
    def ls(self):
        return jnp.exp(self.log_ls)

    @property
    def sigma2(self):
        return jnp.exp(self.log_sigma2)

    @staticmethod
    def init(schema: Schema, sigma2=1.0, mu=0.0) -> "GPParams":
        # Paper App. A: starting lengthscale = attribute range (=1.0 normalized).
        return GPParams(
            log_ls=jnp.zeros((schema.n_num,)),
            log_sigma2=jnp.log(jnp.asarray(float(sigma2))),
            mu=jnp.asarray(float(mu)),
        )


@pytree_dataclass
class RawAnswer:
    """AQP engine output for a batch of snippets: theta_i and beta_i^2."""

    theta: jax.Array  # (n,)
    beta2: jax.Array  # (n,)


@pytree_dataclass
class ImprovedAnswer:
    """Verdict output: improved answer/error plus bookkeeping.

    accepted: bool per snippet — whether the model-based answer passed validation
    (False ⇒ theta/beta2 are the raw values, paper §3.2 / Appendix B).
    """

    theta: jax.Array
    beta2: jax.Array
    raw_theta: jax.Array
    raw_beta2: jax.Array
    accepted: jax.Array

    def error_bound(self, delta: float = 0.95):
        from repro.utils.stats import confidence_multiplier

        return confidence_multiplier(delta) * jnp.sqrt(self.beta2)
