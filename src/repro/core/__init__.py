"""Database Learning (Verdict) core: the paper's primary contribution."""
from repro.core.types import (
    AVG,
    FREQ,
    GPParams,
    ImprovedAnswer,
    RawAnswer,
    Schema,
    SnippetBatch,
    make_snippets,
)
from repro.core.synopsis import Synopsis

# NOTE: ``repro.core.engine`` (VerdictEngine) is imported lazily by users to
# avoid a circular import with ``repro.aqp`` (which depends on core.types).
