"""Model validation (paper Appendix B).

The model-based answer is accepted only if the AQP raw answer lands inside the
"likely region" the model predicts for it; otherwise Verdict returns the raw
answer unchanged (this is what makes Theorem 1 hold unconditionally).

likely region: |theta_raw - theta_dd| < t with t = alpha_{delta_v} * beta_raw
(the AQP answer is ~N(exact, beta^2) by the engine's own CLT bound; under the
model's hypothesis exact = theta_dd).

FREQ(*) additionally rejects negative model-based answers and clamps CI lower
bounds at zero.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import FREQ
from repro.utils.stats import confidence_multiplier


@jax.jit
def validate(agg, model_theta, model_beta2, raw_theta, raw_beta2, delta_v=0.99):
    """Returns (theta_hat, beta2_hat, accepted) per snippet (batched)."""
    t = confidence_multiplier(delta_v) * jnp.sqrt(jnp.maximum(raw_beta2, 0.0))
    in_region = jnp.abs(raw_theta - model_theta) <= t
    nonneg_ok = jnp.where(agg == FREQ, model_theta >= 0.0, True)
    accepted = in_region & nonneg_ok
    theta = jnp.where(accepted, model_theta, raw_theta)
    beta2 = jnp.where(accepted, model_beta2, raw_beta2)
    return theta, beta2, accepted
