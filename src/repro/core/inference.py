"""Verdict inference: the most-likely answer to a new snippet (paper §3, §5).

We use the O(n^2) block forms of Eq. (11)/(12):

    gamma^2   = kappa_bar^2 - k_n^T Sigma_n^{-1} k_n
    theta_pri = mu_new + k_n^T Sigma_n^{-1} (theta_n - mu_n)
    theta_dd  = (beta^2 * theta_pri + gamma^2 * theta_raw) / (beta^2 + gamma^2)
    beta_dd^2 = (beta^2 * gamma^2) / (beta^2 + gamma^2)

Sigma_n carries past raw-answer covariances (exact-answer cov + beta_i^2 on the
diagonal, Eq. 6). All functions are batched over Q new snippets and padded to a
fixed synopsis capacity so the serving path compiles exactly once:
padding rows have k = 0, Sigma^{-1} = I and alpha = 0, which leaves every
product untouched (verified by a padding-invariance property test).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

GAMMA_FLOOR = 1e-30


def factorize(sigma_n, jitter: float = 1e-10):
    """Cholesky of the past-answer covariance (adds jitter on the diagonal)."""
    n = sigma_n.shape[0]
    return jnp.linalg.cholesky(sigma_n + jitter * jnp.eye(n, dtype=sigma_n.dtype))


def chol_append_row(chol, new_col, new_diag, jitter: float = 1e-10):
    """O(n^2) Cholesky update appending one row/col to Sigma_n.

    chol: (n, n) lower factor; new_col: (n,) cov vs existing; new_diag: scalar.
    Returns (n+1, n+1) factor.
    """
    n = chol.shape[0]
    w = solve_triangular(chol, new_col, lower=True) if n else jnp.zeros((0,), chol.dtype)
    d = jnp.sqrt(jnp.maximum(new_diag + jitter - jnp.sum(w * w), jitter))
    out = jnp.zeros((n + 1, n + 1), chol.dtype)
    out = out.at[:n, :n].set(chol)
    out = out.at[n, :n].set(w)
    out = out.at[n, n].set(d)
    return out


def inverse_from_chol(chol):
    eye = jnp.eye(chol.shape[0], dtype=chol.dtype)
    inv_l = solve_triangular(chol, eye, lower=True)
    return inv_l.T @ inv_l


def gp_posterior(k_mat, kappa2, sigma_inv, alpha, mu_new):
    """Model prior predictive for Q new snippets given n past raw answers.

    k_mat: (Q, n); kappa2: (Q,); sigma_inv: (n, n); alpha = Sigma^{-1} resid (n,).
    Returns (theta_prior (Q,), gamma2 (Q,)).
    """
    t = k_mat @ sigma_inv  # (Q, n)
    gamma2 = kappa2 - jnp.sum(t * k_mat, axis=-1)
    gamma2 = jnp.maximum(gamma2, GAMMA_FLOOR)
    theta_prior = mu_new + k_mat @ alpha
    return theta_prior, gamma2


def combine(theta_prior, gamma2, raw_theta, raw_beta2):
    """Product-of-Gaussians blend (Eq. 12). Handles beta^2 = 0 (exact raw)."""
    denom = raw_beta2 + gamma2
    theta = (raw_beta2 * theta_prior + gamma2 * raw_theta) / denom
    beta2 = raw_beta2 * gamma2 / denom
    exact = raw_beta2 <= 0.0
    theta = jnp.where(exact, raw_theta, theta)
    beta2 = jnp.where(exact, 0.0, beta2)
    return theta, beta2


@jax.jit
def model_based_answer(k_mat, kappa2, sigma_inv, alpha, mu_new, raw_theta, raw_beta2):
    """Full Eq. 11+12 pipeline, batched; returns (theta_dd, beta2_dd, gamma2)."""
    theta_prior, gamma2 = gp_posterior(k_mat, kappa2, sigma_inv, alpha, mu_new)
    theta, beta2 = combine(theta_prior, gamma2, raw_theta, raw_beta2)
    return theta, beta2, gamma2
