"""Analytic inter-tuple covariance aggregation (paper §4, Appendix F).

The covariance between two snippet answers decomposes into a product over
dimension attributes (Eq. 10 / Eq. 16):

  cov(th_i, th_j) = sigma_g^2
      * prod_{k in numeric}  II_k(i, j)          (double integral of SE kernel)
      * prod_{k in categorical} |F_ik ∩ F_jk|    (membership overlap)

with AVG answers normalized by the predicate-region size |F_i||F_j| (the paper
"omits normalization terms"; Appendix F.3's mu estimators imply exactly this
normalization, which makes the model unit-consistent across range sizes).

Everything here is pure jnp (the oracle); ``repro.kernels.se_covariance`` is the
Pallas TPU kernel for the numeric-factor hot loop, validated against this module.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import erf

from repro.core.types import AVG, GPParams, SnippetBatch

# Widening for degenerate (equality) numeric ranges, in normalized units.
EPS_WIDTH = 1e-6
SQRT_PI = 1.7724538509055159


def _antideriv(u, z):
    """F(u) with u = x - y: d^2F/dxdy = exp(-u^2/z^2) (Appendix F.1)."""
    return -0.5 * z * z * jnp.exp(-((u / z) ** 2)) - 0.5 * SQRT_PI * z * u * erf(u / z)


def se_double_integral(a, b, c, d, z):
    """∫_a^b ∫_c^d exp(-(x-y)^2/z^2) dy dx, elementwise/broadcast.

    Closed form by inclusion-exclusion of the antiderivative (Appendix F.1).
    """
    return _antideriv(b - d, z) - _antideriv(b - c, z) - _antideriv(a - d, z) + _antideriv(a - c, z)


def widened(lo, hi):
    """Equality predicates arrive as zero-width ranges; widen to EPS_WIDTH."""
    w = jnp.maximum(hi - lo, EPS_WIDTH)
    return lo, lo + w, w


def numeric_factors(bi: SnippetBatch, bj: SnippetBatch, params: GPParams):
    """(n_i, n_j) product over numeric dims of the SE double integrals.

    Returns (raw_product, vol_i, vol_j): ``raw_product`` is the unnormalized
    ∏_k II_k; volumes are ∏_k width for AVG normalization.
    """
    lo_i, hi_i, w_i = widened(bi.lo, bi.hi)  # (n_i, l)
    lo_j, hi_j, w_j = widened(bj.lo, bj.hi)  # (n_j, l)
    z = params.ls  # (l,)
    g = se_double_integral(
        lo_i[:, None, :], hi_i[:, None, :], lo_j[None, :, :], hi_j[None, :, :], z
    )  # (n_i, n_j, l)
    # The SE integral is mathematically positive; clamp fp rounding.
    g = jnp.maximum(g, 0.0)
    return jnp.prod(g, axis=-1), jnp.prod(w_i, axis=-1), jnp.prod(w_j, axis=-1)


def categorical_factors(bi: SnippetBatch, bj: SnippetBatch):
    """(n_i, n_j) ∏_k |F_ik ∩ F_jk| and the per-snippet counts ∏_k |F_ik|."""
    if bi.cat.shape[1] == 0:
        n_i, n_j = bi.lo.shape[0], bj.lo.shape[0]
        return jnp.ones((n_i, n_j)), jnp.ones((n_i,)), jnp.ones((n_j,))
    ci = bi.cat.astype(jnp.float64)
    cj = bj.cat.astype(jnp.float64)
    overlap = jnp.einsum("ikv,jkv->ijk", ci, cj)  # (n_i, n_j, c)
    counts_i = jnp.prod(jnp.sum(ci, axis=-1), axis=-1)
    counts_j = jnp.prod(jnp.sum(cj, axis=-1), axis=-1)
    return jnp.prod(overlap, axis=-1), counts_i, counts_j


def region_size(b: SnippetBatch):
    """|F_i| = numeric volume × categorical count (normalized units)."""
    _, _, w = widened(b.lo, b.hi)
    vol = jnp.prod(w, axis=-1)
    if b.cat.shape[1] > 0:
        vol = vol * jnp.prod(jnp.sum(b.cat.astype(jnp.float64), axis=-1), axis=-1)
    return vol


def cov_matrix(bi: SnippetBatch, bj: SnippetBatch, params: GPParams):
    """cov(exact answers) between two snippet batches: (n_i, n_j).

    Assumes both batches share one aggregate function g (Section 3.1 WLOG).
    """
    num, vol_i, vol_j = numeric_factors(bi, bj, params)
    cat, cnt_i, cnt_j = categorical_factors(bi, bj)
    raw = params.sigma2 * num * cat
    # AVG: normalize by |F_i| |F_j| (integral -> mean); FREQ: leave as integral.
    is_avg_i = (bi.agg == AVG).astype(jnp.float64)
    is_avg_j = (bj.agg == AVG).astype(jnp.float64)
    norm_i = jnp.where(is_avg_i > 0, vol_i * cnt_i, 1.0)
    norm_j = jnp.where(is_avg_j > 0, vol_j * cnt_j, 1.0)
    return raw / (norm_i[:, None] * norm_j[None, :])


def cov_diag(b: SnippetBatch, params: GPParams):
    """Prior variance kappa_bar^2 of each snippet's exact answer: (n,)."""
    lo, hi, w = widened(b.lo, b.hi)
    z = params.ls
    g = jnp.maximum(se_double_integral(lo, hi, lo, hi, z), 0.0)  # (n, l)
    num = jnp.prod(g, axis=-1)
    vol = jnp.prod(w, axis=-1)
    if b.cat.shape[1] > 0:
        counts = jnp.prod(jnp.sum(b.cat.astype(jnp.float64), axis=-1), axis=-1)
    else:
        counts = jnp.ones_like(vol)
    raw = params.sigma2 * num * counts
    is_avg = (b.agg == AVG).astype(jnp.float64)
    norm = jnp.where(is_avg > 0, (vol * counts) ** 2, 1.0)
    return raw / norm


def prior_mean(b: SnippetBatch, params: GPParams):
    """Prior mean per snippet (Appendix F.3): AVG -> mu; FREQ -> mu * |F_i|."""
    size = region_size(b)
    is_avg = (b.agg == AVG).astype(jnp.float64)
    return jnp.where(is_avg > 0, params.mu, params.mu * size)


def analytic_sigma2_mu(b: SnippetBatch, theta):
    """Analytic estimates of (sigma_g^2, mu) from past answers (Appendix F.3)."""
    size = region_size(b)
    is_avg = b.agg == AVG
    dens = jnp.where(is_avg, theta, theta / size)
    mu = jnp.mean(dens)
    sigma2 = jnp.maximum(jnp.var(dens), 1e-12)
    return sigma2, mu


def cross_cov_with_raw(bi, bj, params, beta2_j):
    """cov(theta_bar_i, raw theta_j) == cov of exact answers (Eq. 6, off-diag)."""
    return cov_matrix(bi, bj, params)


cov_matrix_jit = jax.jit(cov_matrix)
cov_diag_jit = jax.jit(cov_diag)
