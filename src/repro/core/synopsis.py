"""Query synopsis: bounded store of past snippets + incremental model state.

Paper §2.3: per aggregate function g the synopsis retains at most C_g snippets
(LRU replacement). The covariance matrix Sigma_n (raw-answer covariances) and
its inverse are maintained *incrementally* in O(n^2 k) per blocked insert/evict
using the block matrix-inversion lemma — the same identity the paper's
Theorem 1 proof uses — with a periodic full refactor to bound numerical drift.

Serving (``improve``) runs against device-resident buffers padded to
**fill-level buckets** (powers of two, clamped to capacity) rather than to
capacity: one compiled program per bucket, and inference cost scales with the
actual synopsis fill instead of C_g^2. The new-snippet axis Q is bucketed the
same way, so a mixed-Q workload compiles one program per (Q-bucket,
fill-bucket) pair. Power-of-two buckets are mutually bitwise-consistent on the
XLA CPU/TPU dot paths (padding columns carry k=0 / Sigma^{-1}=I / alpha=0 and
contribute exact zeros), which is what the padding-invariance parity tests
pin down.

Learning never blocks serving: ``add`` snapshots the raw answers to host
memory and enqueues them on a background ingest thread (``_IngestQueue``)
which runs the covariance builds and blocked inverse updates off the critical
path. ``drain()`` is the explicit barrier; every reader of model state
(``improve``, ``state_dict``, ``refit``…) drains first, so the post-drain
state is bitwise identical to synchronous ingestion regardless of thread
timing — async ingest is deterministic by construction.

Failure never blocks serving either: a failed apply **quarantines** this
synopsis — the failed batch and everything after it are parked unapplied
(FIFO), ``drain()`` stays a plain barrier (it NEVER raises), ``improve``
returns the raw sample estimate (the paper's Theorem-1 floor — degraded but
honest), and ``state_dict`` refuses with a typed
``SynopsisQuarantinedError`` so a half-applied model never checkpoints.
``heal()`` restores a consistent model (from a last-good checkpoint state,
or a fresh ``rebuild()`` from the row arrays), replays the parked batches in
order, and rejoins serving — for failures injected at the apply seam
(``repro.ft.faults``) the healed state is bitwise-identical to a
never-failed store.
"""
from __future__ import annotations

import atexit
import collections
import threading
import weakref
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import covariance, inference, learning, validation
from repro.ft import faults
from repro.core.types import (
    FREQ,
    GPParams,
    ImprovedAnswer,
    RawAnswer,
    Schema,
    SnippetBatch,
    bucket_size,
    pad_snippets,
    snippet_key,
)

REFACTOR_EVERY = 128  # full O(n^3) rebuild cadence (numerical hygiene)
JITTER = 1e-10

# Smallest serve-path tiles: fills/batches below these share one program.
MIN_FILL_BUCKET = 8
MIN_Q_BUCKET = 8


def inv_append_block(ainv, cols, block, jitter=JITTER):
    """O(m^2 k + k^3) inverse update appending k rows/cols at once.

    Blocked matrix-inversion lemma (rank-k): given A^{-1} for the current
    (m, m) covariance, the inverse of [[A, Bᵀ], [B, D]] is assembled from the
    Schur complement S = D - B A^{-1} Bᵀ.

    cols:  (k, m) covariance of the new rows against the existing ones (B).
    block: (k, k) covariance among the new rows, noise included on the
           diagonal (D).
    """
    k = block.shape[0]
    m = ainv.shape[0]
    u = cols @ ainv  # (k, m) = B A^{-1}
    s = block - u @ cols.T  # Schur complement
    s = 0.5 * (s + s.T)
    # Clamp to PSD via eigenvalues — the rank-k generalization of the scalar
    # max(s, jitter): near-duplicate snippets can make S numerically
    # indefinite, and jnp's Cholesky would silently emit NaNs.
    w, v = jnp.linalg.eigh(s)
    w = jnp.maximum(w + jitter, jitter)
    sinv = (v / w) @ v.T
    ust = u.T @ sinv  # (m, k) = A^{-1} Bᵀ S^{-1}
    out = jnp.zeros((m + k, m + k), ainv.dtype)
    out = out.at[:m, :m].set(ainv + ust @ u)
    out = out.at[:m, m:].set(-ust)
    out = out.at[m:, :m].set(-ust.T)
    out = out.at[m:, m:].set(sinv)
    return out


def inv_delete_block(ainv, positions):
    """O(m^2 k + k^3) inverse update deleting k rows/cols at once.

    Partitioned-inverse identity: with the inverse partitioned over
    keep/delete index sets as [[P, Q], [Qᵀ, R]], the inverse of the kept
    block of the original matrix is P - Q R^{-1} Qᵀ.
    """
    n = ainv.shape[0]
    pos = np.asarray(positions, np.int64)
    keep = np.setdiff1d(np.arange(n), pos)
    a = ainv[np.ix_(keep, keep)]
    b = ainv[np.ix_(keep, pos)]
    d = ainv[np.ix_(pos, pos)]
    return a - b @ jnp.linalg.solve(d, b.T)


def _improve_inputs(past: SnippetBatch, valid, params: GPParams, new: SnippetBatch):
    """Covariance inputs of the improve step: (k_mat, kappa2, mu_new)."""
    k_mat = covariance.cov_matrix(new, past, params) * valid[None, :]
    kappa2 = covariance.cov_diag(new, params)
    mu_new = covariance.prior_mean(new, params)
    return k_mat, kappa2, mu_new


def _improve_core(
    past: SnippetBatch,
    valid,
    sigma_inv,
    alpha,
    params: GPParams,
    new: SnippetBatch,
    raw_theta,
    raw_beta2,
    delta_v,
):
    """Improve Q new snippets against one padded synopsis state (Eq. 11/12 + App. B)."""
    k_mat, kappa2, mu_new = _improve_inputs(past, valid, params, new)
    model_theta, model_beta2, gamma2 = inference.model_based_answer(
        k_mat, kappa2, sigma_inv, alpha, mu_new, raw_theta, raw_beta2
    )
    theta, beta2, accepted = validation.validate(
        new.agg, model_theta, model_beta2, raw_theta, raw_beta2, delta_v
    )
    return theta, beta2, accepted


# One compiled program per (Q-bucket, fill-bucket) shape pair.
_improve_padded = jax.jit(_improve_core)
# Stacked variant: one dispatch improves G aggregate-function groups at once
# (leading axis over synopses). Bitwise equal per slice to the single-group
# program — batched dots reduce in the same order as unbatched ones.
_improve_stacked = jax.jit(
    jax.vmap(_improve_core, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, None))
)
_improve_inputs_jit = jax.jit(_improve_inputs)


def _pad_raw(x, target: int, fill: float):
    """Pad a 1-D raw-answer vector up to the Q bucket (host-side, f64)."""
    x = jnp.asarray(x)
    k = target - x.shape[0]
    if k <= 0:
        return x
    return jnp.concatenate([x, jnp.full((k,), fill, x.dtype)])


# Ingest threads must be quiescent when the interpreter tears down: a worker
# still inside an XLA dispatch at exit aborts the C++ runtime. atexit runs
# before teardown, so draining here leaves the daemon threads parked in plain
# condition waits.
_LIVE_QUEUES: "weakref.WeakSet" = weakref.WeakSet()


@atexit.register
def _drain_live_queues():
    for q in list(_LIVE_QUEUES):
        try:
            q.drain()
        except Exception:
            pass


MAX_PENDING_DEFAULT = 64  # ingest back-pressure bound (pending batches)


class SynopsisQuarantinedError(RuntimeError):
    """Raised by ``state_dict`` on a quarantined synopsis: a model built on a
    half-applied batch must never checkpoint. Serving paths never raise this —
    they degrade to the raw sample estimate instead (Theorem 1's floor)."""

    def __init__(self, name: Optional[str], cause: BaseException):
        super().__init__(
            f"synopsis {name or '<unnamed>'} is quarantined "
            f"(heal() to rejoin): {cause!r}"
        )
        self.name = name
        self.cause = cause


class _IngestQueue:
    """Background applier for ``Synopsis.add`` batches.

    Batches are applied strictly in submission order, one at a time, so the
    post-``drain()`` state is bitwise identical to synchronous ingestion no
    matter how worker progress interleaves with serving. Wakeups coalesce:
    one lock round hands the worker every batch queued since the last one.
    The worker thread is daemonic, starts lazily, and exits after an idle
    period (``submit`` restarts it on demand).

    The queue is BOUNDED (``max_pending`` batches): ``try_submit`` refuses
    new work while the worker is that far behind, and the caller sheds to
    synchronous ingestion (drain, then apply inline — FIFO order and hence
    bitwise determinism are preserved). ``high_water`` records the deepest
    backlog observed, so operators can see how close serving runs to the
    bound.

    Failure handling lives in the apply fn, not here: the queue's applier is
    ``Synopsis._guarded_apply``, which never raises — a failed apply
    quarantines the owning synopsis and parks the failed batch (and every
    later one) for ``heal()`` replay. ``drain()`` is therefore ALWAYS a
    plain barrier: it waits for the backlog and never re-raises, so one bad
    batch can no longer poison every subsequent barrier globally.
    """

    IDLE_TIMEOUT = 5.0

    def __init__(self, apply_fn, max_pending: int = MAX_PENDING_DEFAULT):
        self._apply = apply_fn
        self.max_pending = int(max_pending)
        self.high_water = 0
        self._pending: collections.deque = collections.deque()
        self._cv = threading.Condition()
        self._outstanding = 0
        self._thread: Optional[threading.Thread] = None
        _LIVE_QUEUES.add(self)

    def try_submit(self, item) -> bool:
        """Enqueue unless the backlog is at the bound; False means shed."""
        with self._cv:
            if self._outstanding >= self.max_pending:
                return False
            self._pending.append(item)
            self._outstanding += 1
            self.high_water = max(self.high_water, self._outstanding)
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._run, name="synopsis-ingest", daemon=True
                )
                self._thread.start()
            self._cv.notify_all()
            return True

    def _run(self):
        while True:
            with self._cv:
                while not self._pending:
                    woke = self._cv.wait(timeout=self.IDLE_TIMEOUT)
                    if not woke and not self._pending:
                        self._thread = None  # idle exit; submit() restarts
                        return
                batch = list(self._pending)
                self._pending.clear()
            for item in batch:
                try:
                    self._apply(*item)
                except BaseException:  # noqa: BLE001 — guarded appliers
                    pass  # never raise out of the worker; the guarded apply
                    # already quarantined the synopsis
                with self._cv:
                    self._outstanding -= 1
                    self._cv.notify_all()

    def drain(self):
        """Barrier only: wait until the backlog is fully handed to the
        applier. Never raises (see class docstring)."""
        with self._cv:
            while self._outstanding:
                self._cv.wait()


class Synopsis:
    """Bounded per-aggregate-function snippet store + incremental GP state.

    ``device``: optional JAX device the model state (serve buffers and the
    incremental Sigma^{-1} chain) is committed to — the placement hook the
    ``ShardedSynopsisStore`` uses to spread aggregate keys over a mesh.
    ``min_fill_bucket``/``min_q_bucket``: smallest serve-path tiles
    (``EngineConfig`` lifts these per deployment; defaults unchanged).
    """

    def __init__(
        self,
        schema: Schema,
        capacity: int = 2000,
        delta_v: float = 0.99,
        params: Optional[GPParams] = None,
        async_ingest: bool = True,
        max_pending: int = MAX_PENDING_DEFAULT,
        device=None,
        min_fill_bucket: int = MIN_FILL_BUCKET,
        min_q_bucket: int = MIN_Q_BUCKET,
    ):
        self.schema = schema
        self.capacity = int(capacity)
        self.delta_v = float(delta_v)
        self.async_ingest = bool(async_ingest)
        self.max_pending = int(max_pending)
        self.device = device
        self.min_fill_bucket = int(min_fill_bucket)
        self.min_q_bucket = int(min_q_bucket)
        self.name: Optional[str] = None  # store-assigned state_key (fault key)
        # Monotone state-generation counter for cache invalidation
        # (repro.intel): bumped SYNCHRONOUSLY on the caller thread at every
        # state transition that can change served answers — add() at enqueue
        # time (before the async apply, so staleness is deterministic even
        # under async ingest), quarantine, heal, refit, append adjustment and
        # state restore. A cached answer records the generations it was
        # derived under; any mismatch marks it stale.
        self.generation = 0
        self._shed_count = 0
        self._restored_high_water = 0
        self._qlock = threading.Lock()
        self._quarantine_exc: Optional[BaseException] = None
        self._unapplied: list = []  # parked (FIFO) batches awaiting heal()
        self._quarantine_count = 0  # quarantine episodes over this lifetime
        l, c, v = schema.n_num, schema.n_cat, max(schema.cat_vmax, 1)
        C = self.capacity
        self._lo = np.zeros((C, l))
        self._hi = np.ones((C, l))
        self._cat = np.ones((C, c, v), dtype=bool)
        self._agg = np.full((C,), FREQ, np.int32)
        self._measure = np.zeros((C,), np.int32)
        self._theta = np.zeros((C,))
        self._beta2 = np.ones((C,))
        self._stamp = np.full((C,), -1, np.int64)
        self.n = 0
        self._clock = 0
        self._keys: dict = {}
        self.params = params or GPParams.init(schema)
        self._sigma = np.zeros((C, C))
        self._sigma_inv = self._put(jnp.zeros((0, 0)))
        self._alpha = self._put(jnp.zeros((0,)))
        self._updates_since_refactor = 0
        self._order: list = []  # row ids in Sigma^{-1} ordering
        self._device_states: dict = {}  # fill bucket -> padded serve buffers
        self._ingest: Optional[_IngestQueue] = None

    # -------------------------------------------------------------- placement
    def _put(self, x):
        """Commit an array (or pytree) to this synopsis' device.

        With ``device=None`` this is a plain ``jnp`` conversion on the
        default device — the historical behavior. All CPU host devices run
        the same compiled programs, so placement never changes answers
        bitwise; it only changes where the FLOPs land.
        """
        if self.device is None:
            return jax.tree.map(jnp.asarray, x)
        return jax.device_put(x, self.device)

    # ---------------------------------------------------------------- storage
    def _row_batch(self, rows) -> SnippetBatch:
        return SnippetBatch(
            lo=jnp.asarray(self._lo[rows]),
            hi=jnp.asarray(self._hi[rows]),
            cat=jnp.asarray(self._cat[rows]),
            agg=jnp.asarray(self._agg[rows]),
            measure=jnp.asarray(self._measure[rows]),
        )

    def active(self) -> SnippetBatch:
        self.drain()
        return self._row_batch(np.arange(self.n))

    def theta(self):
        self.drain()
        return jnp.asarray(self._theta[: self.n])

    def beta2(self):
        self.drain()
        return jnp.asarray(self._beta2[: self.n])

    @staticmethod
    def _key(lo, hi, cat, agg, measure):
        return snippet_key(lo, hi, cat, agg, measure)

    # ----------------------------------------------------------------- ingest
    def add(self, snippets: SnippetBatch, theta, beta2):
        """Insert raw answers; duplicates refresh LRU stamps and keep the more
        accurate answer.

        The host snapshot happens here (cheap copies); the covariance builds
        and blocked inverse updates run on the background ingest thread so
        callers return as soon as the answers are enqueued. ``drain()`` is
        the barrier; batches apply strictly in FIFO order, so the post-drain
        state is bitwise identical to synchronous ingestion
        (``async_ingest=False`` applies inline instead).

        Back-pressure: the ingest queue holds at most ``max_pending``
        batches. Under overload the caller sheds to synchronous ingestion —
        drain the backlog, then apply this batch inline — which bounds host
        memory and keeps FIFO order (determinism) intact.
        """
        # Bump BEFORE the (possibly async) apply: callers observe the new
        # generation at enqueue time, so an answer cached right after this
        # add() records the post-ingest generation deterministically, and a
        # failing apply (→ quarantine) can never serve a pre-failure cached
        # answer as fresh — the entry was already staleness-bumped here.
        self.generation += 1
        item = (
            np.array(np.asarray(snippets.lo), dtype=np.float64),
            np.array(np.asarray(snippets.hi), dtype=np.float64),
            np.array(np.asarray(snippets.cat), dtype=bool),
            np.array(np.asarray(snippets.agg), dtype=np.int32),
            np.array(np.asarray(snippets.measure), dtype=np.int32),
            np.array(np.asarray(theta), dtype=np.float64),
            np.array(np.asarray(beta2), dtype=np.float64),
        )
        if not self.async_ingest:
            self._guarded_apply(*item)
            return
        if self._ingest is None:
            self._ingest = _IngestQueue(self._guarded_apply,
                                        max_pending=self.max_pending)
        if not self._ingest.try_submit(item):
            self._shed_count += 1
            self._ingest.drain()  # preserve FIFO before applying inline
            self._guarded_apply(*item)

    def _guarded_apply(self, *item):
        """Apply one batch, quarantining on failure instead of raising.

        This is the ONLY applier the ingest queue (and the sync/shed paths)
        run, so a failed covariance build / inverse update can never
        propagate out of ``add``/``drain``: the synopsis quarantines, the
        failed batch and everything after it park in FIFO order for
        ``heal()`` replay, and serving continues on the raw-answer floor.
        """
        with self._qlock:
            if self._quarantine_exc is not None:
                self._unapplied.append(item)
                return
        try:
            self._apply_add(*item)
        except BaseException as e:  # noqa: BLE001 — quarantine, never raise
            self._mark_quarantined(e, item)

    def _mark_quarantined(self, exc: BaseException, item=None):
        with self._qlock:
            if self._quarantine_exc is None:
                self._quarantine_exc = exc
                self._quarantine_count += 1
                self.generation += 1  # degraded: cached answers go stale
            if item is not None:
                self._unapplied.append(item)

    @property
    def quarantined(self) -> bool:
        """Whether this synopsis is serving degraded (raw answers only)."""
        return self._quarantine_exc is not None

    @property
    def quarantine_reason(self) -> Optional[str]:
        exc = self._quarantine_exc
        return None if exc is None else repr(exc)

    def heal(self, state: Optional[dict] = None) -> bool:
        """Rebuild a consistent model and rejoin serving.

        ``state``: a last-good ``state_dict`` snapshot (e.g. from
        ``CheckpointManager.restore_blind``) to restore from; ``None``
        rebuilds Sigma / Sigma^{-1} / alpha from this synopsis' own row
        arrays (``rebuild()``), which is exact when the failure struck at
        the apply seam *before* any mutation (all ``repro.ft.faults``
        injections do). Parked batches then replay in their original FIFO
        order, so a healed synopsis is bitwise-identical to one that never
        failed. Returns True iff the synopsis is healthy afterwards; a
        replay failure re-quarantines (remaining batches stay parked) and
        returns False. Call from a quiesced serving thread — concurrent
        ``add`` during heal can reorder replay.
        """
        if not self.quarantined:
            return True
        if self._ingest is not None:
            # Flush in-flight adds into the parked list while the flag is
            # still set (the guarded applier parks rather than applies).
            self._ingest.drain()
        with self._qlock:
            parked = list(self._unapplied)
            self._unapplied.clear()
            self._quarantine_exc = None
        try:
            if state is not None:
                self.load_state_dict(state)
            else:
                self.rebuild()
        except BaseException as e:  # noqa: BLE001 — re-quarantine
            with self._qlock:
                self._quarantine_exc = e
                self._quarantine_count += 1
                self._unapplied = parked + self._unapplied
            return False
        for i, item in enumerate(parked):
            try:
                self._apply_add(*item)
            except BaseException as e:  # noqa: BLE001 — re-quarantine
                with self._qlock:
                    self._quarantine_exc = e
                    self._quarantine_count += 1
                    self._unapplied = parked[i:] + self._unapplied
                return False
        self.generation += 1  # healed state ≠ the state cached answers saw
        return True

    def drain(self):
        """Barrier: block until every enqueued ``add`` batch has been handed
        to the (never-raising) guarded applier. NEVER raises — an ingest
        failure quarantines this synopsis instead of poisoning the barrier.
        Idempotent and cheap when idle."""
        try:
            faults.fire("store.drain", key=self.name)
        except BaseException as e:  # noqa: BLE001 — injected barrier fault
            self._mark_quarantined(e)  # still quiesce the worker below
        if self._ingest is not None:
            self._ingest.drain()

    @property
    def ingest_high_water(self) -> int:
        """Deepest async-ingest backlog observed (batches), incl. restored."""
        live = self._ingest.high_water if self._ingest is not None else 0
        return max(live, self._restored_high_water)

    def ingest_stats(self) -> dict:
        """Back-pressure + quarantine telemetry for the ingest path."""
        with self._qlock:
            return {
                "max_pending": self.max_pending,
                "high_water": self.ingest_high_water,
                "shed_count": self._shed_count,
                "quarantined": self._quarantine_exc is not None,
                "quarantine_reason": (
                    None if self._quarantine_exc is None
                    else repr(self._quarantine_exc)
                ),
                "unapplied": len(self._unapplied),
                "quarantine_count": self._quarantine_count,
            }

    def _apply_add(self, lo, hi, cat, agg, mea, theta, beta2):
        """Synchronous ingest of one host-side batch (runs on the worker).

        Vectorized: covariance columns for every genuinely-new row are built
        in one ``cov_matrix_jit`` call and applied with one blocked rank-k
        inverse update (``inv_append_block``); capacity evictions for the
        whole batch are applied with one blocked delete. Dedup/LRU semantics
        match the historical per-snippet path, except that eviction victims
        are chosen after the whole incoming batch has refreshed its duplicate
        stamps.
        """
        faults.fire("ingest.apply", key=self.name)  # seam: before any mutation
        pending: dict = {}  # key -> [incoming index of best beta2, LRU stamp]
        for i in range(lo.shape[0]):
            if not (np.isfinite(theta[i]) and np.isfinite(beta2[i])):
                continue
            key = self._key(lo[i], hi[i], cat[i], agg[i], mea[i])
            self._clock += 1
            if key in self._keys:
                r = self._keys[key]
                self._stamp[r] = self._clock
                if beta2[i] < self._beta2[r]:
                    self._theta[r] = theta[i]
                    self._replace_beta(r, beta2[i])
                continue
            entry = pending.get(key)
            if entry is None:
                pending[key] = [i, self._clock]
            else:
                entry[1] = self._clock
                if beta2[i] < beta2[entry[0]]:
                    entry[0] = i
        # If one call brings more new snippets than the whole store holds,
        # only the most recently used ``capacity`` survive (LRU: a snippet
        # re-occurring late in the batch carries its refreshed stamp).
        new = list(pending.items())
        if len(new) > self.capacity:
            new.sort(key=lambda kv: kv[1][1])
            new = new[-self.capacity :]
        if new:
            n_evict = max(0, self.n + len(new) - self.capacity)
            free: list = []
            if n_evict:
                victims = np.argsort(self._stamp[: self.n], kind="stable")[:n_evict]
                for r in victims:
                    old_key = self._key(
                        self._lo[r], self._hi[r], self._cat[r],
                        self._agg[r], self._measure[r],
                    )
                    self._keys.pop(old_key, None)
                self._delete_block_from_model(victims)
                free = [int(r) for r in victims]
            grow = len(new) - len(free)
            slots = list(range(self.n, self.n + grow)) + free
            self.n += grow
            for (key, (i, stamp)), r in zip(new, slots):
                self._lo[r] = lo[i]
                self._hi[r] = hi[i]
                self._cat[r] = cat[i]
                self._agg[r] = agg[i]
                self._measure[r] = mea[i]
                self._theta[r] = theta[i]
                self._beta2[r] = beta2[i]
                self._stamp[r] = stamp
                self._keys[key] = r
            self._insert_block_into_model(slots)
        self._refresh_alpha()
        self._device_states.clear()

    def _replace_beta(self, r, new_beta2):
        """Diagonal-only change: redo row r in the model (delete+insert)."""
        self._delete_block_from_model([r])
        self._beta2[r] = new_beta2
        self._insert_block_into_model([r])

    # ------------------------------------------------------ incremental model
    def _cov_blocks(self, rows, prev):
        """Covariance of ``rows`` against ``prev`` and among themselves.

        Inputs are padded to shape buckets so ``cov_matrix_jit`` compiles a
        bounded number of programs instead of one per synopsis fill level.
        """
        k = len(rows)
        batch = self._row_batch(np.asarray(rows, np.int64))
        padded = pad_snippets(batch, 8)
        if len(prev):
            prev_b = pad_snippets(self._row_batch(np.asarray(prev, np.int64)), 64)
            cols = np.asarray(
                covariance.cov_matrix_jit(padded, prev_b, self.params)
            )[:k, : len(prev)]
        else:
            cols = np.zeros((k, 0))
        block = np.array(
            covariance.cov_matrix_jit(padded, padded, self.params)
        )[:k, :k]
        block[np.diag_indices(k)] = (
            np.asarray(covariance.cov_diag_jit(padded, self.params))[:k]
            + self._beta2[np.asarray(rows, np.int64)]
        )
        return cols, block

    def _insert_block_into_model(self, rows):
        """Rows were just written into free/evicted slots; append them to the
        model in one blocked update.

        The inverse is maintained over the *ordering* [active rows]; we keep a
        permutation-free scheme by always appending logically: position in the
        inverse == position in ``self._order``.
        """
        rows = [int(r) for r in rows]
        prev = list(self._order)
        cols, block = self._cov_blocks(rows, prev)
        self._sigma[np.ix_(rows, prev)] = cols
        self._sigma[np.ix_(prev, rows)] = cols.T
        self._sigma[np.ix_(rows, rows)] = block
        self._updates_since_refactor += len(rows)
        self._order.extend(rows)
        if self._updates_since_refactor >= REFACTOR_EVERY:
            self._refactor()
            return
        self._sigma_inv = inv_append_block(
            self._sigma_inv, jnp.asarray(cols), jnp.asarray(block)
        )

    def _delete_block_from_model(self, rows):
        members = set(self._order)
        rows = [int(r) for r in rows if int(r) in members]
        if not rows:
            return
        pos = sorted(self._order.index(r) for r in rows)
        self._sigma_inv = inv_delete_block(self._sigma_inv, pos)
        for p in reversed(pos):
            self._order.pop(p)
        self._updates_since_refactor += len(pos)

    def _refactor(self):
        """Full O(n^3) rebuild of Sigma^{-1} from Sigma (numerical hygiene)."""
        rows = np.asarray(self._order, dtype=np.int64)
        if len(rows) == 0:
            self._sigma_inv = self._put(jnp.zeros((0, 0)))
            self._updates_since_refactor = 0
            return
        sig = self._put(self._sigma[np.ix_(rows, rows)])
        chol = inference.factorize(sig, JITTER)
        self._sigma_inv = inference.inverse_from_chol(chol)
        self._updates_since_refactor = 0

    def _refresh_alpha(self):
        rows = np.asarray(self._order, dtype=np.int64)
        if len(rows) == 0:
            self._alpha = self._put(jnp.zeros((0,)))
            return
        batch = self._row_batch(rows)
        resid = jnp.asarray(self._theta[rows]) - covariance.prior_mean(batch, self.params)
        self._alpha = self._sigma_inv @ resid

    # ------------------------------------------------------------------ refit
    def refit(self, steps: int = 150, lr: float = 0.1, learn_sigma: bool = False):
        """Offline learning (Appendix A): relearn params, rebuild the model.

        A quarantined synopsis skips refit (no-op): the row arrays may hold a
        half-applied batch, so learning waits for ``heal()``.
        """
        self.drain()
        if self.quarantined or self.n < 3:
            return self.params
        rows = np.asarray(self._order, dtype=np.int64)
        batch = self._row_batch(rows)
        theta = jnp.asarray(self._theta[rows])
        beta2 = jnp.asarray(self._beta2[rows])
        self.params, _ = learning.fit(
            batch, theta, beta2, self.schema, steps=steps, lr=lr, learn_sigma=learn_sigma
        )
        self.rebuild()
        self.generation += 1  # relearned params change improved answers
        return self.params

    def rebuild(self):
        """Recompute Sigma for the current params, refactor, refresh alpha."""
        self.drain()
        rows = np.asarray(self._order, dtype=np.int64)
        if len(rows):
            batch = self._row_batch(rows)
            sig = np.array(covariance.cov_matrix_jit(batch, batch, self.params))
            sig[np.diag_indices(len(rows))] = np.asarray(
                covariance.cov_diag_jit(batch, self.params)
            ) + self._beta2[rows]
            self._sigma[np.ix_(rows, rows)] = sig
        self._refactor()
        self._refresh_alpha()
        self._device_states.clear()

    # ------------------------------------------------------------------ serve
    def _fill_bucket(self) -> int:
        """Power-of-two serve tile covering the current fill (<= capacity)."""
        return bucket_size(self.n, self.min_fill_bucket, cap=self.capacity)

    def _padded_state(self, bucket: Optional[int] = None):
        """Device-resident buffers padded to a fill bucket, cached per bucket.

        Padding rows carry k = 0 (valid mask), Sigma^{-1} = I and alpha = 0,
        leaving every product untouched; the jitted serve path therefore
        compiles one program per bucket and its cost scales with fill, not
        capacity. Callers may request a larger bucket than the current fill
        (the stacked multi-synopsis dispatch aligns groups on one bucket).
        """
        bucket = self._fill_bucket() if bucket is None else int(bucket)
        state = self._device_states.get(bucket)
        if state is not None:
            return state
        rows = np.asarray(self._order, dtype=np.int64)
        n = len(rows)
        idx = np.concatenate([rows, np.zeros((bucket - n,), np.int64)])
        past = self._put(self._row_batch(idx))
        valid = self._put(np.asarray(np.arange(bucket) < n, np.float64))
        sinv = np.eye(bucket)
        if n:
            sinv[:n, :n] = np.asarray(self._sigma_inv)
        alpha = np.zeros((bucket,))
        alpha[:n] = np.asarray(self._alpha)
        state = (past, valid, self._put(sinv), self._put(alpha))
        self._device_states[bucket] = state
        return state

    def improve(self, new: SnippetBatch, raw: RawAnswer,
                use_kernel: bool = False) -> ImprovedAnswer:
        """Improved answers for a batch of new snippets (Algorithm 2 lines 3-7).

        Drains pending ingest first (the model the paper conditions on is the
        one containing every recorded answer), then serves from the bucketed
        device state. ``use_kernel=True`` routes the fused inference through
        the ``gp_batch_infer`` Pallas kernel (f32 MXU path) instead of the
        jnp f64 program; validation (Appendix B) applies either way.
        """
        self.drain()
        if self.n == 0 or self.quarantined:
            # Empty synopsis (Theorem 1's equality case) or quarantined
            # (degraded mode): return raw unchanged — always a valid,
            # honest answer.
            acc = jnp.zeros((new.n,), bool)
            return ImprovedAnswer(raw.theta, raw.beta2, raw.theta, raw.beta2, acc)
        q = new.n
        qb = bucket_size(q, self.min_q_bucket)
        padded_new = pad_snippets(new, qb)
        raw_theta = _pad_raw(raw.theta, qb, 0.0)
        raw_beta2 = _pad_raw(raw.beta2, qb, 1.0)
        past, valid, sinv, alpha = self._padded_state()
        if use_kernel:
            from repro.kernels.gp_batch_infer import ops as gp_ops

            k_mat, kappa2, mu_new = _improve_inputs_jit(
                past, valid, self.params, padded_new
            )
            m_theta, m_beta2, _ = gp_ops.gp_batch_infer(
                k_mat, sinv, alpha, kappa2, mu_new, raw_theta, raw_beta2
            )
            theta, beta2, accepted = validation.validate(
                padded_new.agg, m_theta, m_beta2, raw_theta, raw_beta2,
                self.delta_v,
            )
        else:
            theta, beta2, accepted = _improve_padded(
                past, valid, sinv, alpha, self.params, padded_new,
                raw_theta, raw_beta2, self.delta_v,
            )
        return ImprovedAnswer(
            theta[:q], beta2[:q], raw.theta, raw.beta2, accepted[:q]
        )

    # ------------------------------------------------------------- append (D)
    def apply_append(self, stats):
        """Adjust all stored answers for appended data (Appendix D, Lemma 3)."""
        from repro.core.append import adjust_answers

        self.drain()
        if self.n == 0:
            return
        rows = np.arange(self.n)
        theta, beta2 = adjust_answers(
            jnp.asarray(self._theta[rows]),
            jnp.asarray(self._beta2[rows]),
            jnp.asarray(self._measure[rows]),
            jnp.asarray(self._agg[rows]),
            stats,
        )
        self._theta[rows] = np.asarray(theta)
        self._beta2[rows] = np.asarray(beta2)
        self.rebuild()
        self.generation += 1  # stored answers rescaled for appended data

    # ------------------------------------------------------------ persistence
    def state_dict(self):
        """Host snapshot of the learned state (drains pending ingest first).

        Every array is a copy — never a live view into the ring buffers — so
        snapshots stay valid across later ``add`` calls (checkpointing relies
        on this).

        Raises ``SynopsisQuarantinedError`` while quarantined: a model with
        half-applied batches must never persist (``heal()`` first).
        """
        self.drain()
        if self.quarantined:
            raise SynopsisQuarantinedError(self.name, self._quarantine_exc)
        n = self.n
        return {
            "lo": np.array(self._lo[:n]),
            "hi": np.array(self._hi[:n]),
            "cat": np.array(self._cat[:n]),
            "agg": np.array(self._agg[:n]),
            "measure": np.array(self._measure[:n]),
            "theta": np.array(self._theta[:n]),
            "beta2": np.array(self._beta2[:n]),
            "stamp": np.array(self._stamp[:n]),
            "order": np.asarray(self._order, np.int64),
            "log_ls": np.array(np.asarray(self.params.log_ls)),
            "log_sigma2": np.array(np.asarray(self.params.log_sigma2)),
            "mu": np.array(np.asarray(self.params.mu)),
            "ingest_high_water": np.asarray(self.ingest_high_water, np.int64),
        }

    def load_state_dict(self, state):
        self.drain()
        if "ingest_high_water" in state:  # absent in pre-back-pressure dumps
            self._restored_high_water = int(state["ingest_high_water"])
        n = state["lo"].shape[0]
        self.n = n
        self._lo[:n] = state["lo"]
        self._hi[:n] = state["hi"]
        self._cat[:n] = state["cat"]
        self._agg[:n] = state["agg"]
        self._measure[:n] = state["measure"]
        self._theta[:n] = state["theta"]
        self._beta2[:n] = state["beta2"]
        self._stamp[:n] = state["stamp"]
        self._order = [int(x) for x in state["order"]]
        self.params = GPParams(
            log_ls=jnp.asarray(state["log_ls"]),
            log_sigma2=jnp.asarray(state["log_sigma2"]),
            mu=jnp.asarray(state["mu"]),
        )
        self._keys = {
            self._key(self._lo[i], self._hi[i], self._cat[i], self._agg[i], self._measure[i]): i
            for i in range(n)
        }
        self._clock = int(self._stamp[:n].max()) if n else 0
        self.rebuild()
        self.generation += 1  # restored state ≠ whatever answers were cached
