"""Query synopsis: bounded store of past snippets + incremental model state.

Paper §2.3: per aggregate function g the synopsis retains at most C_g snippets
(LRU replacement). The covariance matrix Sigma_n (raw-answer covariances) and
its inverse are maintained *incrementally* in O(n^2) per insert/evict using the
block matrix-inversion lemma — the same identity the paper's Theorem 1 proof
uses — with a periodic full refactor to bound numerical drift.

The serving path (``improve``) runs against device-resident buffers padded to
capacity, so one jitted program serves every synopsis fill level.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import covariance, inference, learning, validation
from repro.core.types import (
    FREQ,
    GPParams,
    ImprovedAnswer,
    RawAnswer,
    Schema,
    SnippetBatch,
)

REFACTOR_EVERY = 128  # full O(n^3) rebuild cadence (numerical hygiene)
JITTER = 1e-10


def inv_append_row(ainv, col, diag, jitter=JITTER):
    """O(n^2) inverse update appending one row/col (matrix inversion lemma)."""
    u = ainv @ col
    s = jnp.maximum(diag + jitter - col @ u, jitter)
    n = ainv.shape[0]
    out = jnp.zeros((n + 1, n + 1), ainv.dtype)
    out = out.at[:n, :n].set(ainv + jnp.outer(u, u) / s)
    out = out.at[:n, n].set(-u / s)
    out = out.at[n, :n].set(-u / s)
    out = out.at[n, n].set(1.0 / s)
    return out


def inv_delete_row(ainv, r):
    """O(n^2) inverse update deleting row/col r."""
    n = ainv.shape[0]
    keep = np.r_[0:r, r + 1 : n]
    a = ainv[np.ix_(keep, keep)]
    b = ainv[keep, r]
    d = ainv[r, r]
    return a - jnp.outer(b, b) / d


@jax.jit
def _improve_padded(
    past: SnippetBatch,
    valid,
    sigma_inv,
    alpha,
    params: GPParams,
    new: SnippetBatch,
    raw_theta,
    raw_beta2,
    delta_v,
):
    k_mat = covariance.cov_matrix(new, past, params) * valid[None, :]
    kappa2 = covariance.cov_diag(new, params)
    mu_new = covariance.prior_mean(new, params)
    model_theta, model_beta2, gamma2 = inference.model_based_answer(
        k_mat, kappa2, sigma_inv, alpha, mu_new, raw_theta, raw_beta2
    )
    theta, beta2, accepted = validation.validate(
        new.agg, model_theta, model_beta2, raw_theta, raw_beta2, delta_v
    )
    return theta, beta2, accepted


class Synopsis:
    """Bounded per-aggregate-function snippet store + incremental GP state."""

    def __init__(
        self,
        schema: Schema,
        capacity: int = 2000,
        delta_v: float = 0.99,
        params: Optional[GPParams] = None,
    ):
        self.schema = schema
        self.capacity = int(capacity)
        self.delta_v = float(delta_v)
        l, c, v = schema.n_num, schema.n_cat, max(schema.cat_vmax, 1)
        C = self.capacity
        self._lo = np.zeros((C, l))
        self._hi = np.ones((C, l))
        self._cat = np.ones((C, c, v), dtype=bool)
        self._agg = np.full((C,), FREQ, np.int32)
        self._measure = np.zeros((C,), np.int32)
        self._theta = np.zeros((C,))
        self._beta2 = np.ones((C,))
        self._stamp = np.full((C,), -1, np.int64)
        self.n = 0
        self._clock = 0
        self._keys: dict = {}
        self.params = params or GPParams.init(schema)
        self._sigma = np.zeros((C, C))
        self._sigma_inv = jnp.zeros((0, 0))
        self._alpha = jnp.zeros((0,))
        self._updates_since_refactor = 0
        self._order: list = []  # row ids in Sigma^{-1} ordering
        self._device_state = None  # padded buffers for the jitted serve path

    # ---------------------------------------------------------------- storage
    def _row_batch(self, rows) -> SnippetBatch:
        return SnippetBatch(
            lo=jnp.asarray(self._lo[rows]),
            hi=jnp.asarray(self._hi[rows]),
            cat=jnp.asarray(self._cat[rows]),
            agg=jnp.asarray(self._agg[rows]),
            measure=jnp.asarray(self._measure[rows]),
        )

    def active(self) -> SnippetBatch:
        return self._row_batch(np.arange(self.n))

    def theta(self):
        return jnp.asarray(self._theta[: self.n])

    def beta2(self):
        return jnp.asarray(self._beta2[: self.n])

    @staticmethod
    def _key(lo, hi, cat, agg, measure):
        return hash(
            (lo.tobytes(), hi.tobytes(), cat.tobytes(), int(agg), int(measure))
        )

    # ----------------------------------------------------------------- insert
    def add(self, snippets: SnippetBatch, theta, beta2):
        """Insert raw answers; duplicates refresh LRU stamps and keep the more
        accurate answer. O(n^2) per genuinely-new snippet."""
        lo = np.asarray(snippets.lo)
        hi = np.asarray(snippets.hi)
        cat = np.asarray(snippets.cat)
        agg = np.asarray(snippets.agg)
        mea = np.asarray(snippets.measure)
        theta = np.asarray(theta)
        beta2 = np.asarray(beta2)
        for i in range(lo.shape[0]):
            if not (np.isfinite(theta[i]) and np.isfinite(beta2[i])):
                continue
            key = self._key(lo[i], hi[i], cat[i], agg[i], mea[i])
            self._clock += 1
            if key in self._keys:
                r = self._keys[key]
                self._stamp[r] = self._clock
                if beta2[i] < self._beta2[r]:
                    self._theta[r] = theta[i]
                    self._replace_beta(r, beta2[i])
                continue
            if self.n < self.capacity:
                r = self.n
                self.n += 1
            else:
                r = int(np.argmin(self._stamp[: self.n]))  # LRU eviction
                old_key = self._key(
                    self._lo[r], self._hi[r], self._cat[r], self._agg[r], self._measure[r]
                )
                self._keys.pop(old_key, None)
                self._delete_from_model(r)
            self._lo[r] = lo[i]
            self._hi[r] = hi[i]
            self._cat[r] = cat[i]
            self._agg[r] = agg[i]
            self._measure[r] = mea[i]
            self._theta[r] = theta[i]
            self._beta2[r] = beta2[i]
            self._stamp[r] = self._clock
            self._keys[key] = r
            self._insert_into_model(r)
        self._refresh_alpha()
        self._device_state = None

    def _replace_beta(self, r, new_beta2):
        """Diagonal-only change: redo row r in the model (delete+insert)."""
        self._delete_from_model(r, already_removed_row=False)
        self._beta2[r] = new_beta2
        self._insert_into_model(r)

    # ------------------------------------------------------ incremental model
    def _cov_against_active(self, r, rows):
        one = self._row_batch(np.array([r]))
        if len(rows) == 0:
            col = np.zeros((0,))
        else:
            others = self._row_batch(np.asarray(rows))
            col = np.asarray(covariance.cov_matrix_jit(one, others, self.params))[0]
        diag = float(np.asarray(covariance.cov_diag_jit(one, self.params))[0]) + float(
            self._beta2[r]
        )
        return col, diag

    def _insert_into_model(self, r):
        """Row r was just written at position n-1 OR replaces an evicted slot.

        The inverse is maintained over the *ordering* [active rows]; we keep a
        permutation-free scheme by always appending logically: position in the
        inverse == position in ``self._order``.
        """
        if not hasattr(self, "_order"):
            self._order = []
        rows = [x for x in self._order]
        col, diag = self._cov_against_active(r, rows)
        self._sigma[r, rows] = col
        self._sigma[rows, r] = col
        self._sigma[r, r] = diag
        self._updates_since_refactor += 1
        if self._updates_since_refactor >= REFACTOR_EVERY:
            self._order.append(r)
            self._refactor()
            return
        self._sigma_inv = inv_append_row(
            self._sigma_inv, jnp.asarray(col), jnp.asarray(diag)
        )
        self._order.append(r)

    def _delete_from_model(self, r, already_removed_row=True):
        if r not in getattr(self, "_order", []):
            return
        pos = self._order.index(r)
        self._sigma_inv = inv_delete_row(self._sigma_inv, pos)
        self._order.pop(pos)
        self._updates_since_refactor += 1

    def _refactor(self):
        """Full O(n^3) rebuild of Sigma^{-1} from Sigma (numerical hygiene)."""
        rows = np.asarray(self._order, dtype=np.int64)
        if len(rows) == 0:
            self._sigma_inv = jnp.zeros((0, 0))
            self._updates_since_refactor = 0
            return
        sig = jnp.asarray(self._sigma[np.ix_(rows, rows)])
        chol = inference.factorize(sig, JITTER)
        self._sigma_inv = inference.inverse_from_chol(chol)
        self._updates_since_refactor = 0

    def _refresh_alpha(self):
        rows = np.asarray(getattr(self, "_order", []), dtype=np.int64)
        if len(rows) == 0:
            self._alpha = jnp.zeros((0,))
            return
        batch = self._row_batch(rows)
        resid = jnp.asarray(self._theta[rows]) - covariance.prior_mean(batch, self.params)
        self._alpha = self._sigma_inv @ resid

    # ------------------------------------------------------------------ refit
    def refit(self, steps: int = 150, lr: float = 0.1, learn_sigma: bool = False):
        """Offline learning (Appendix A): relearn params, rebuild the model."""
        if self.n < 3:
            return self.params
        rows = np.asarray(self._order, dtype=np.int64)
        batch = self._row_batch(rows)
        theta = jnp.asarray(self._theta[rows])
        beta2 = jnp.asarray(self._beta2[rows])
        self.params, _ = learning.fit(
            batch, theta, beta2, self.schema, steps=steps, lr=lr, learn_sigma=learn_sigma
        )
        self.rebuild()
        return self.params

    def rebuild(self):
        """Recompute Sigma for the current params, refactor, refresh alpha."""
        rows = np.asarray(getattr(self, "_order", []), dtype=np.int64)
        if len(rows):
            batch = self._row_batch(rows)
            sig = np.array(covariance.cov_matrix_jit(batch, batch, self.params))
            sig[np.diag_indices(len(rows))] = np.asarray(
                covariance.cov_diag_jit(batch, self.params)
            ) + self._beta2[rows]
            self._sigma[np.ix_(rows, rows)] = sig
        self._refactor()
        self._refresh_alpha()
        self._device_state = None

    # ------------------------------------------------------------------ serve
    def _padded_state(self):
        """Device-resident buffers padded to capacity for the jitted hot path."""
        if self._device_state is not None:
            return self._device_state
        C = self.capacity
        rows = np.asarray(getattr(self, "_order", []), dtype=np.int64)
        n = len(rows)
        idx = np.concatenate([rows, np.zeros((C - n,), np.int64)])
        past = self._row_batch(idx)
        valid = jnp.asarray(np.arange(C) < n, jnp.float64)
        sinv = np.eye(C)
        if n:
            sinv[:n, :n] = np.asarray(self._sigma_inv)
        alpha = np.zeros((C,))
        alpha[:n] = np.asarray(self._alpha)
        self._device_state = (past, valid, jnp.asarray(sinv), jnp.asarray(alpha))
        return self._device_state

    def improve(self, new: SnippetBatch, raw: RawAnswer) -> ImprovedAnswer:
        """Improved answers for a batch of new snippets (Algorithm 2 lines 3-7)."""
        if self.n == 0:
            # Empty synopsis: Theorem 1's equality case — return raw unchanged.
            acc = jnp.zeros((new.n,), bool)
            return ImprovedAnswer(raw.theta, raw.beta2, raw.theta, raw.beta2, acc)
        past, valid, sinv, alpha = self._padded_state()
        theta, beta2, accepted = _improve_padded(
            past, valid, sinv, alpha, self.params, new, raw.theta, raw.beta2,
            self.delta_v,
        )
        return ImprovedAnswer(theta, beta2, raw.theta, raw.beta2, accepted)

    # ------------------------------------------------------------- append (D)
    def apply_append(self, stats):
        """Adjust all stored answers for appended data (Appendix D, Lemma 3)."""
        from repro.core.append import adjust_answers

        if self.n == 0:
            return
        rows = np.arange(self.n)
        theta, beta2 = adjust_answers(
            jnp.asarray(self._theta[rows]),
            jnp.asarray(self._beta2[rows]),
            jnp.asarray(self._measure[rows]),
            jnp.asarray(self._agg[rows]),
            stats,
        )
        self._theta[rows] = np.asarray(theta)
        self._beta2[rows] = np.asarray(beta2)
        self.rebuild()

    # ------------------------------------------------------------ persistence
    def state_dict(self):
        return {
            "lo": self._lo[: self.n],
            "hi": self._hi[: self.n],
            "cat": self._cat[: self.n],
            "agg": self._agg[: self.n],
            "measure": self._measure[: self.n],
            "theta": self._theta[: self.n],
            "beta2": self._beta2[: self.n],
            "stamp": self._stamp[: self.n],
            "order": np.asarray(getattr(self, "_order", []), np.int64),
            "log_ls": np.asarray(self.params.log_ls),
            "log_sigma2": np.asarray(self.params.log_sigma2),
            "mu": np.asarray(self.params.mu),
        }

    def load_state_dict(self, state):
        n = state["lo"].shape[0]
        self.n = n
        self._lo[:n] = state["lo"]
        self._hi[:n] = state["hi"]
        self._cat[:n] = state["cat"]
        self._agg[:n] = state["agg"]
        self._measure[:n] = state["measure"]
        self._theta[:n] = state["theta"]
        self._beta2[:n] = state["beta2"]
        self._stamp[:n] = state["stamp"]
        self._order = [int(x) for x in state["order"]]
        self.params = GPParams(
            log_ls=jnp.asarray(state["log_ls"]),
            log_sigma2=jnp.asarray(state["log_sigma2"]),
            mu=jnp.asarray(state["mu"]),
        )
        self._keys = {
            self._key(self._lo[i], self._hi[i], self._cat[i], self._agg[i], self._measure[i]): i
            for i in range(n)
        }
        self._clock = int(self._stamp[:n].max()) if n else 0
        self.rebuild()
