"""SynopsisStore: the placement-aware home of ALL learned state.

The paper's premise is that the synopsis — not the raw data — is the asset
that grows ("processing more queries should continuously enhance our
knowledge of the underlying distribution"). This module makes that asset a
first-class, *placeable* component: the query lifecycle (``repro.aqp.plan``)
talks to an abstract ``SynopsisStore`` and never to raw ``Synopsis`` dicts,
mirroring the storage/optimizer split in BlinkDB and the engine-agnostic
layering of VerdictDB (PAPERS.md).

Store protocol (every access path to learned state):

- ``for_key(key)`` / ``get(key)`` — per-aggregate-key synopsis lookup,
  created on demand with the store's placement policy;
- ``improve_groups(snippets, raw)`` — the per-aggregate-key improvement of a
  mixed snippet batch, scattered back to query order (Algorithm 2 lines
  3-7), fused into one stacked jitted dispatch per *dispatch set*;
- ``record(snippets, raw)`` — enqueue final raw answers for learning
  (async per synopsis);
- ``drain`` / ``refit`` / ``ingest_stats`` — ingest barrier, offline
  learning (Algorithm 1), back-pressure telemetry;
- ``state_dict`` / ``load_state_dict`` — structured-key, shard-tagged
  checkpoint payloads (see ``state_key``); a checkpoint written by one
  placement can be re-placed onto a different one.

Two implementations ship:

- ``LocalSynopsisStore`` — everything on the default device; bitwise
  identical to the historical ``VerdictEngine``-internal dict, and the
  default.
- ``ShardedSynopsisStore`` — per-aggregate-key placement over the devices of
  a JAX mesh (``jax.device_put``): each key's serve buffers and incremental
  Sigma^{-1} chain live on its assigned device, ingest threads are per
  synopsis (hence per shard), ``drain`` waits on all shards concurrently,
  and the stacked improve dispatch partitions into one fused program per
  device. Answers are bitwise-equal to the local store on identical
  backends (all forced-host CPU devices share one backend; pinned by
  ``tests/test_synopsis_store.py``), because the stacked dispatch is itself
  bitwise-equal per group to the per-synopsis path.

Invariant enforced across the codebase (tripwire-tested): no module outside
this file constructs or indexes the raw ``Dict[AggKey, Synopsis]`` directly —
``VerdictEngine.synopses`` survives only as a deprecated property shim over
``store.synopses``.
"""
from __future__ import annotations

import re
import threading
import warnings
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.synopsis import Synopsis, _improve_stacked, _pad_raw
from repro.core.types import (
    AVG,
    ImprovedAnswer,
    RawAnswer,
    SnippetBatch,
    bucket_size,
    pad_snippets,
)

AggKey = Tuple[int, int]

_STATE_KEY_RE = re.compile(r"^agg(\d+)-measure(\d+)$")


def agg_key(agg: int, measure: int) -> AggKey:
    """Canonical aggregate-function key: (agg, measure), FREQ collapses
    measure to 0 (frequency snippets are measure-oblivious, paper §2.3)."""
    agg = int(agg)
    return (agg, int(measure) if agg == AVG else 0)


def state_key(key: AggKey) -> str:
    """Structured checkpoint key for one aggregate-function synopsis.

    Replaces the historical ``"{agg}_{measure}"`` format whose loader
    round-tripped through ``str.split("_")``; ``parse_state_key`` still
    accepts the legacy form so old checkpoints keep restoring.
    """
    return f"agg{key[0]}-measure{key[1]}"


def parse_state_key(name: str) -> AggKey:
    """Inverse of ``state_key``; accepts legacy ``"<agg>_<measure>"`` keys."""
    m = _STATE_KEY_RE.match(name)
    if m:
        return (int(m.group(1)), int(m.group(2)))
    agg, sep, mea = name.partition("_")
    if sep and agg.isdigit() and mea.isdigit():  # pre-store checkpoints
        return (int(agg), int(mea))
    raise ValueError(f"unrecognized synopsis state key: {name!r}")


def group_rows(snippets: SnippetBatch) -> List[Tuple[AggKey, np.ndarray]]:
    """(key, row-index array) per aggregate-function group, in key order."""
    agg = np.asarray(snippets.agg)
    mea = np.asarray(snippets.measure)
    keys = sorted({agg_key(a, m) for a, m in zip(agg, mea)})
    out = []
    for key in keys:
        rows = np.where(
            (agg == key[0]) & ((mea == key[1]) if key[0] == AVG else True)
        )[0]
        out.append((key, rows))
    return out


class SynopsisStore:
    """Base store: local placement plus all placement-oblivious machinery.

    Subclasses override the placement hooks (``shard_index``/``device_for``/
    ``describe_placement``), the dispatch partition (``_dispatch_sets``) and
    optionally ``drain``; everything else — lookup, improvement math,
    recording, refit, persistence — is shared, so the two implementations
    cannot drift apart semantically.
    """

    kind = "local"

    def __init__(self, schema, config):
        self.schema = schema
        self.config = config
        self._synopses: Dict[AggKey, Synopsis] = {}

    # ------------------------------------------------------------ mapping
    @property
    def synopses(self) -> Dict[AggKey, Synopsis]:
        """The live key → Synopsis mapping (read-mostly; the backing of the
        deprecated ``VerdictEngine.synopses`` shim)."""
        return self._synopses

    def keys(self):
        return self._synopses.keys()

    def values(self):
        return self._synopses.values()

    def items(self):
        return self._synopses.items()

    def get(self, key: AggKey) -> Optional[Synopsis]:
        return self._synopses.get(key)

    def __len__(self) -> int:
        return len(self._synopses)

    def __contains__(self, key: AggKey) -> bool:
        return key in self._synopses

    def __iter__(self) -> Iterator[AggKey]:
        return iter(self._synopses)

    def generation(self, key: AggKey) -> int:
        """Monotone state generation of ``key``'s synopsis (0 if absent).

        The cache-staleness primitive (``repro.intel``): a cached answer
        records the generations of every aggregate key it touched; any
        mismatch on lookup marks it stale. Bumps happen synchronously at
        every serving-visible state transition (ingest enqueue, quarantine,
        heal, refit, append, restore), so staleness is deterministic even
        with asynchronous ingest.
        """
        syn = self._synopses.get(key)
        return syn.generation if syn is not None else 0

    # ---------------------------------------------------------- placement
    def shard_index(self, key: AggKey) -> int:
        """Deterministic shard assignment for ``key`` (0 when unsharded).

        A pure function of (key, placement width) — never of insertion
        order — so a checkpoint written by any store re-places identically
        on load, and ``Session.explain`` can report assignments for keys
        that do not exist yet.
        """
        return 0

    def device_for(self, key: AggKey):
        """Device the key's synopsis lives on (None: default device)."""
        return None

    def describe_placement(self, key: AggKey) -> str:
        return "local"

    def placement(self) -> Dict[AggKey, str]:
        """Key → human-readable placement for every existing synopsis."""
        return {k: self.describe_placement(k) for k in sorted(self._synopses)}

    # -------------------------------------------------------------- lookup
    def for_key(self, key: AggKey) -> Synopsis:
        """The synopsis for one aggregate-function key, created on demand
        with the store's placement policy."""
        syn = self._synopses.get(key)
        if syn is None:
            cfg = self.config
            syn = Synopsis(
                self.schema,
                capacity=cfg.capacity,
                delta_v=cfg.delta_v,
                async_ingest=cfg.async_ingest,
                max_pending=cfg.ingest_max_pending,
                min_fill_bucket=cfg.min_fill_bucket,
                min_q_bucket=cfg.min_q_bucket,
                device=self.device_for(key),
            )
            syn.name = state_key(key)  # fault-injection / telemetry identity
            self._synopses[key] = syn
        return syn

    # ------------------------------------------------------------- improve
    def _dispatch_sets(self, groups: Sequence[tuple]) -> List[List[tuple]]:
        """Partition improvable ``(key, synopsis, rows)`` groups into
        stacked-dispatch sets.

        Local placement fuses everything into ONE stacked program; sharded
        placement yields one set per device (states on different devices
        cannot be stacked into one dispatch).
        """
        return [list(groups)] if groups else []

    def improve_groups(self, snippets: SnippetBatch, raw: RawAnswer,
                       use_kernels: bool = False,
                       health: Optional[dict] = None) -> ImprovedAnswer:
        """Per-aggregate-key improvement, scattered back to query order.

        Within each dispatch set the per-key Python loop is fused into ONE
        stacked jitted program: every group's (state, new-snippets, raw
        answers) is padded to a shared (Q-bucket, fill-bucket) tile and
        improved by a single vmapped dispatch — bitwise equal per group to
        the single-synopsis path, which is what makes local and sharded
        placements answer-equivalent. With ``use_kernels=True`` each group
        instead routes through the ``gp_batch_infer`` Pallas kernel, whose
        128-wide MXU tiling is the TPU-side equivalent of the stacking.

        Degraded mode: a QUARANTINED synopsis is skipped exactly like an
        empty one — its rows keep the raw sample estimate (the paper's
        Theorem-1 floor, still an honest unbiased answer) — and, when the
        caller passes a ``health`` dict, gains an entry
        ``{state_key: quarantine reason}`` so the query result can surface
        ``degraded=True`` telemetry.
        """
        theta = np.asarray(raw.theta)
        beta2 = np.asarray(raw.beta2)
        out_theta = np.array(theta)
        out_beta2 = np.array(beta2)
        accepted = np.zeros(theta.shape[0], dtype=bool)
        groups = []
        for key, rows in group_rows(snippets):
            syn = self.for_key(key)
            syn.drain()
            if syn.quarantined:
                if health is not None:
                    health[state_key(key)] = syn.quarantine_reason
                continue  # degrade: raw floor for this group's rows
            if syn.n == 0:
                continue  # Theorem 1 equality case: raw passes through
            groups.append((key, syn, rows))
        for dispatch in self._dispatch_sets(groups):
            if use_kernels or len(dispatch) == 1:
                for _, syn, rows in dispatch:
                    sub = snippets[jnp.asarray(rows)]
                    imp = syn.improve(
                        sub,
                        RawAnswer(jnp.asarray(theta[rows]),
                                  jnp.asarray(beta2[rows])),
                        use_kernel=use_kernels,
                    )
                    out_theta[rows] = np.asarray(imp.theta)
                    out_beta2[rows] = np.asarray(imp.beta2)
                    accepted[rows] = np.asarray(imp.accepted)
                continue
            qb = bucket_size(max(len(rows) for _, _, rows in dispatch),
                             self.config.min_q_bucket)
            fb = max(syn._fill_bucket() for _, syn, _ in dispatch)
            states = [syn._padded_state(fb) for _, syn, _ in dispatch]
            news, raw_ts, raw_bs = [], [], []
            for _, syn, rows in dispatch:
                news.append(pad_snippets(snippets[jnp.asarray(rows)], qb))
                raw_ts.append(_pad_raw(jnp.asarray(theta[rows]), qb, 0.0))
                raw_bs.append(_pad_raw(jnp.asarray(beta2[rows]), qb, 1.0))
            stack = lambda *xs: jnp.stack(xs)  # noqa: E731
            th_s, b2_s, acc_s = _improve_stacked(
                jax.tree.map(stack, *[s[0] for s in states]),
                jnp.stack([s[1] for s in states]),
                jnp.stack([s[2] for s in states]),
                jnp.stack([s[3] for s in states]),
                jax.tree.map(stack, *[syn.params for _, syn, _ in dispatch]),
                jax.tree.map(stack, *news),
                jnp.stack(raw_ts),
                jnp.stack(raw_bs),
                dispatch[0][1].delta_v,
            )
            for g, (_, syn, rows) in enumerate(dispatch):
                k = len(rows)
                out_theta[rows] = np.asarray(th_s[g, :k])
                out_beta2[rows] = np.asarray(b2_s[g, :k])
                accepted[rows] = np.asarray(acc_s[g, :k])
        return ImprovedAnswer(
            theta=jnp.asarray(out_theta),
            beta2=jnp.asarray(out_beta2),
            raw_theta=raw.theta,
            raw_beta2=raw.beta2,
            accepted=jnp.asarray(accepted),
        )

    # -------------------------------------------------------------- record
    def record(self, snippets: SnippetBatch, raw: RawAnswer):
        """Enqueue final raw answers for learning (async per synopsis)."""
        theta = np.asarray(raw.theta)
        beta2 = np.asarray(raw.beta2)
        for key, rows in group_rows(snippets):
            syn = self.for_key(key)
            sub = snippets[jnp.asarray(rows)]
            syn.add(sub, theta[rows], beta2[rows])

    # ----------------------------------------------------------- lifecycle
    def drain(self):
        """Barrier over every synopsis' async ingest queue.

        Call at snapshot/refit boundaries; serving itself drains lazily
        (each ``improve`` waits only for its own synopsis' pending batches).
        """
        for syn in self._synopses.values():
            syn.drain()

    def refit(self, steps: int = 150, lr: float = 0.1,
              learn_sigma: bool = False):
        """Offline learning pass (paper Algorithm 1). Drains async ingest."""
        for syn in self._synopses.values():
            syn.refit(steps=steps, lr=lr, learn_sigma=learn_sigma)

    def ingest_stats(self) -> Dict[str, dict]:
        """Per-synopsis async-ingest back-pressure telemetry, keyed by the
        structured ``state_key`` form."""
        return {
            state_key(key): self._synopses[key].ingest_stats()
            for key in sorted(self._synopses)
        }

    # --------------------------------------------------------------- health
    def quarantined(self) -> Dict[str, str]:
        """``{state_key: reason}`` for every quarantined synopsis ({} when
        healthy) — the store-level view behind ``Session.stats()["health"]``."""
        return {
            state_key(key): syn.quarantine_reason
            for key, syn in sorted(self._synopses.items())
            if syn.quarantined
        }

    def heal(self, states: Optional[Dict[str, dict]] = None) -> Dict[str, bool]:
        """Heal every quarantined synopsis; returns ``{state_key: healed}``.

        ``states``: an optional store-level ``state_dict`` payload (e.g.
        ``CheckpointManager.restore_blind``) — keys present there heal from
        the last-good snapshot then replay parked batches; keys absent heal
        via a fresh ``rebuild()`` from their own row arrays. Healthy
        synopses are untouched (not in the returned dict).
        """
        out: Dict[str, bool] = {}
        for key, syn in sorted(self._synopses.items()):
            if not syn.quarantined:
                continue
            name = state_key(key)
            state = states.get(name) if states is not None else None
            if state is not None:
                state = dict(state)
                state.pop("shard", None)
            out[name] = syn.heal(state)
        return out

    def stats(self) -> dict:
        """Operator-facing snapshot: placement, occupancy, back-pressure."""
        keys = {}
        for key in sorted(self._synopses):
            syn = self._synopses[key]
            keys[state_key(key)] = {
                "n": syn.n,
                "capacity": syn.capacity,
                "shard": self.shard_index(key),
                "placement": self.describe_placement(key),
                "ingest": syn.ingest_stats(),
            }
        return {"kind": self.kind, "n_shards": 1, "n_keys": len(keys),
                "keys": keys, "quarantined": self.quarantined()}

    # ------------------------------------------------------------- persist
    def state_dict(self) -> Dict[str, dict]:
        """Host snapshot of every synopsis, keyed by ``state_key``.

        Drains async ingest first (via ``Synopsis.state_dict``) and returns
        copies, so the snapshot is stable across later queries. Each entry
        carries a ``shard`` tag recording where it lived — observability
        only: ``load_state_dict`` re-places by policy, so a checkpoint
        written under one placement restores onto any other (including a
        different mesh shape).

        Quarantined synopses are SKIPPED (with a warning): a half-applied
        model never persists, and one sick key must not block checkpointing
        the healthy rest — after ``heal()`` the key rejoins the next save.
        """
        out = {}
        for key in sorted(self._synopses):
            syn = self._synopses[key]
            if syn.quarantined:
                warnings.warn(
                    f"skipping quarantined synopsis {state_key(key)} in "
                    f"state_dict (heal() to rejoin): {syn.quarantine_reason}",
                    RuntimeWarning, stacklevel=2,
                )
                continue
            sd = syn.state_dict()
            sd["shard"] = np.asarray(self.shard_index(key), np.int64)
            out[state_key(key)] = sd
        return out

    def load_state_dict(self, state: Dict[str, dict]):
        """Restore synopses saved by any store's ``state_dict``.

        Accepts both structured (``"agg0-measure1"``) and legacy
        (``"0_1"``) key forms; ``shard`` tags are ignored in favor of this
        store's own deterministic placement.
        """
        for name, sd in state.items():
            sd = dict(sd)
            sd.pop("shard", None)
            self.for_key(parse_state_key(name)).load_state_dict(sd)


class LocalSynopsisStore(SynopsisStore):
    """Default store: every synopsis on the default device, one stacked
    improve dispatch for the whole batch — bitwise-identical to the
    historical engine-internal dict."""


class ShardedSynopsisStore(SynopsisStore):
    """Per-aggregate-key synopsis placement over the devices of a mesh.

    ``mesh``: any JAX mesh — placement flattens its device grid; the same
    mesh can simultaneously drive the sharded scan (``BatchExecutor``), so
    ``repro.verdict.connect(..., mesh=...)`` shards both the data plane and
    the learned state from one object. ``devices`` overrides the device
    list directly (useful for re-placing a checkpoint onto a subset).

    Placement is ``shard_index``: a deterministic hash of the key modulo
    the device count — stable across processes, insertion orders and mesh
    shapes, which is what makes checkpoint re-placement onto a different
    mesh a pure load (no remapping table to persist).
    """

    kind = "sharded"

    def __init__(self, schema, config, mesh=None, devices=None):
        super().__init__(schema, config)
        if devices is None:
            devices = (list(np.asarray(mesh.devices).flat)
                       if mesh is not None else jax.devices())
        if not devices:
            raise ValueError("ShardedSynopsisStore needs at least one device")
        self.devices = list(devices)

    # ---------------------------------------------------------- placement
    def shard_index(self, key: AggKey) -> int:
        return (int(key[0]) * 8191 + int(key[1])) % len(self.devices)

    def device_for(self, key: AggKey):
        return self.devices[self.shard_index(key)]

    def describe_placement(self, key: AggKey) -> str:
        i = self.shard_index(key)
        return f"shard{i}:{self.devices[i]}"

    # ------------------------------------------------------------ improve
    def _dispatch_sets(self, groups: Sequence[tuple]) -> List[List[tuple]]:
        """One stacked dispatch per device: states committed to different
        devices cannot be fused into one program, and per-device fusion
        keeps every shard's compute on its own device."""
        by_dev: Dict[int, List[tuple]] = {}
        for key, syn, rows in groups:
            by_dev.setdefault(self.shard_index(key), []).append(
                (key, syn, rows))
        return [by_dev[i] for i in sorted(by_dev)]

    # ----------------------------------------------------------- lifecycle
    def drain(self):
        """Parallel barrier: one waiter thread per occupied shard drains
        that shard's synopses (total wall clock = the slowest shard, not
        the sum over shards). Never raises: an ingest failure quarantines
        the ONE affected synopsis (shard-level blast radius at most), which
        degrades to raw serving until ``heal()`` — it no longer poisons the
        whole store's barrier."""
        by_shard: Dict[int, List[Synopsis]] = {}
        for key, syn in self._synopses.items():
            by_shard.setdefault(self.shard_index(key), []).append(syn)
        if len(by_shard) <= 1:
            for syns in by_shard.values():
                for syn in syns:
                    syn.drain()
            return
        shards = sorted(by_shard)

        def wait(shard):
            for syn in by_shard[shard]:
                syn.drain()  # quarantines on failure; never raises

        threads = [threading.Thread(target=wait, args=(s,), daemon=True)
                   for s in shards]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    def stats(self) -> dict:
        out = super().stats()
        occupancy = [{"device": str(d), "n_keys": 0, "fill": 0}
                     for d in self.devices]
        for key, syn in self._synopses.items():
            shard = occupancy[self.shard_index(key)]
            shard["n_keys"] += 1
            shard["fill"] += syn.n
        out["n_shards"] = len(self.devices)
        out["shards"] = occupancy
        return out
