"""Pure-jnp oracle for the range-mask aggregation kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import RANGE_EPS


def range_mask_agg_ref(x, payload, lo, hi, extra_mask):
    """x: (T,L); payload: (T,P); lo/hi: (Q,L); extra_mask: (T,Q) -> (Q,P).

    out[q, p] = sum_t [all_k lo[q,k] <= x[t,k] <= hi[q,k]] * extra[t,q] * payload[t,p]
    """
    m = jnp.all(
        (x[:, None, :] >= lo[None, :, :] - RANGE_EPS)
        & (x[:, None, :] <= hi[None, :, :] + RANGE_EPS),
        axis=-1,
    ).astype(payload.dtype)
    m = m * extra_mask.astype(payload.dtype)
    return m.T @ payload
