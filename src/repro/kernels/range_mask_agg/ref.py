"""Pure-jnp oracle for the range-mask aggregation kernel."""
from __future__ import annotations

import jax.numpy as jnp


def range_mask_agg_ref(x, payload, lo, hi, extra_mask):
    """x: (T,L); payload: (T,P); lo/hi: (Q,L); extra_mask: (T,Q) -> (Q,P).

    out[q, p] = sum_t [all_k lo[q,k] <= x[t,k] <= hi[q,k]] * extra[t,q] * payload[t,p]
    """
    m = jnp.all(
        (x[:, None, :] >= lo[None, :, :] - 1e-7)
        & (x[:, None, :] <= hi[None, :, :] + 1e-7),
        axis=-1,
    ).astype(payload.dtype)
    m = m * extra_mask.astype(payload.dtype)
    return m.T @ payload
