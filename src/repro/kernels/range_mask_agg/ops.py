"""Public wrappers: padding, categorical pre-mask, Partials assembly."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import INTERPRET
from repro.kernels.range_mask_agg.kernel import range_mask_agg_pallas


def _pad_axis(x, axis, mult, fill=0.0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


@partial(jax.jit, static_argnames=("tile_t", "tile_q", "interpret"))
def range_mask_agg(x, payload, lo, hi, extra_mask=None,
                   *, tile_t: int = 512, tile_q: int = 128,
                   interpret: bool = INTERPRET):
    """out[q, p] = sum over tuples matching snippet q of payload[t, p]."""
    t_n, _ = x.shape
    q_n = lo.shape[0]
    dt = jnp.float32
    if extra_mask is None:
        extra_mask = jnp.ones((t_n, q_n), dt)
    # Padded tuples are masked off via extra_mask=0 (so the count column stays
    # exact); padded snippets are sliced away after the call.
    x_p = _pad_axis(x.astype(dt), 0, tile_t)
    payload_p = _pad_axis(payload.astype(dt), 0, tile_t)
    lo_p = _pad_axis(lo.astype(dt), 0, tile_q)
    hi_p = _pad_axis(hi.astype(dt), 0, tile_q, fill=1.0)
    em = _pad_axis(_pad_axis(extra_mask.astype(dt), 0, tile_t), 1, tile_q)
    out = range_mask_agg_pallas(
        x_p, payload_p, lo_p, hi_p, em,
        tile_t=tile_t, tile_q=tile_q, interpret=interpret,
    )
    return out[:q_n]


def categorical_premask(cat_codes, snip_cat):
    """(T, Q) mask of categorical-membership, one-hot matmul per cat dim.

    cat_codes: (T, c) int; snip_cat: (Q, c, V) bool.
    """
    t_n = cat_codes.shape[0]
    q_n = snip_cat.shape[0]
    mask = jnp.ones((t_n, q_n), jnp.float32)
    for k in range(cat_codes.shape[1]):
        onehot = jax.nn.one_hot(cat_codes[:, k], snip_cat.shape[2], dtype=jnp.float32)
        mask = mask * (onehot @ snip_cat[:, k, :].T.astype(jnp.float32))
    return mask


@jax.jit
def eval_partials_kernel(num_normalized, cat, measures, snippets, valid=None):
    """Kernel-backed drop-in for ``repro.aqp.executor.eval_partials``.

    ``valid``: optional (T,) 0/1 per-tuple validity mask for zero-padded
    blocks. Invalid rows are zeroed out of every snippet column and
    ``scanned`` is the mask sum — the TRUE tuple count, never the padded
    shape (reporting ``float(t_n)`` here deflated every CLT error bound on
    padded blocks).
    """
    from repro.aqp.executor import Partials

    t_n, m = measures.shape
    meas32 = measures.astype(jnp.float32)
    payload = jnp.concatenate(
        [meas32, meas32 * meas32, jnp.ones((t_n, 1), jnp.float32)], axis=1
    )  # (T, 2M+1)
    extra = categorical_premask(cat, snippets.cat) if cat.shape[1] else None
    scanned = (jnp.asarray(float(t_n)) if valid is None else jnp.sum(valid))
    if valid is not None:
        v = valid.astype(jnp.float32)[:, None]
        extra = v * jnp.ones((t_n, snippets.lo.shape[0]), jnp.float32) \
            if extra is None else extra * v
    out = range_mask_agg(
        num_normalized, payload, snippets.lo, snippets.hi, extra
    ).astype(jnp.float64)  # (Q, 2M+1)
    idx = snippets.measure[:, None]
    sums = jnp.take_along_axis(out[:, :m], idx, axis=1)[:, 0]
    sumsq = jnp.take_along_axis(out[:, m : 2 * m], idx, axis=1)[:, 0]
    count = out[:, 2 * m]
    return Partials(sums, sumsq, count, scanned)
