"""Predicate-mask aggregation kernel (TPU Pallas) — the AQP scan hot loop.

TPU adaptation of the paper's Spark tuple scan: instead of evaluating snippets
tuple-at-a-time, a (TT x TQ) 0/1 predicate mask is materialized in VMEM with
vectorized range compares (VPU), then ``mask^T @ payload`` runs on the MXU,
aggregating *all concurrent snippets* in one matmul. payload packs
[measures, measures^2, 1] so sum/sumsq/count come out of a single pass.

Grid: (Q / TQ, T / TT); the tuple axis is the sequential accumulation axis
(out block indexed by q only; initialized at t == 0). Tuples stream through
VMEM tile by tile — HBM traffic is O(T·(L+P)) and compute O(T·Q·(L+P)), so
for Q snippets in flight the scan is Q-fold work-shared vs. one-at-a-time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import RANGE_EPS


def _rma_kernel(x_ref, payload_ref, lo_ref, hi_ref, em_ref, out_ref, *, n_dims: int):
    t = pl.program_id(1)
    x = x_ref[...]  # (TT, L)
    mask = None
    for k in range(n_dims):
        xk = x[:, k][:, None]  # (TT, 1)
        mk = ((xk >= lo_ref[:, k][None, :] - RANGE_EPS)
              & (xk <= hi_ref[:, k][None, :] + RANGE_EPS))
        mask = mk if mask is None else (mask & mk)
    m = em_ref[...] if mask is None else mask.astype(x.dtype) * em_ref[...]
    acc = jax.lax.dot_general(
        m, payload_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TQ, P)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = acc.astype(out_ref.dtype)

    @pl.when(t != 0)
    def _accum():
        out_ref[...] = out_ref[...] + acc.astype(out_ref.dtype)


def range_mask_agg_pallas(x, payload, lo, hi, extra_mask,
                          *, tile_t: int = 512, tile_q: int = 128,
                          interpret: bool = True):
    """Raw pallas_call; T and Q must be pre-padded to tile multiples."""
    t_n, l = x.shape
    q_n = lo.shape[0]
    p = payload.shape[1]
    assert t_n % tile_t == 0 and q_n % tile_q == 0
    grid = (q_n // tile_q, t_n // tile_t)
    kern = functools.partial(_rma_kernel, n_dims=l)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_t, l), lambda q, t: (t, 0)),  # x
            pl.BlockSpec((tile_t, p), lambda q, t: (t, 0)),  # payload
            pl.BlockSpec((tile_q, l), lambda q, t: (q, 0)),  # lo
            pl.BlockSpec((tile_q, l), lambda q, t: (q, 0)),  # hi
            pl.BlockSpec((tile_t, tile_q), lambda q, t: (t, q)),  # extra mask
        ],
        out_specs=pl.BlockSpec((tile_q, p), lambda q, t: (q, 0)),
        out_shape=jax.ShapeDtypeStruct((q_n, p), jnp.float32),
        interpret=interpret,
    )(x, payload, lo, hi, extra_mask)
