from repro.kernels.range_mask_agg.ops import eval_partials_kernel, range_mask_agg
