"""Public wrappers: padding, dtype policy, Partials epilogue.

``eval_partials_fused`` is the kernel-backed drop-in for
``repro.aqp.executor.eval_partials`` — same signature including ``valid=``,
same ``Partials`` out, and (interpret mode, f64) the SAME bits: the kernel's
sequential tuple-tile accumulation is the scan plane's canonical fixed-order
fold (``masked_tile_fold``), which ``_partials_from_mask`` also performs.

``masked_partials_fused`` is the aggregation-only drop-in for
``_partials_from_mask`` used by the sharded placement: the mask is built
sharded over the mesh, gathered, and reduced here through the kernel — the
composition that makes ``use_kernels=True`` meaningful under a mesh.

Dtype policy: interpret mode (CPU container) runs f64 end to end — that is
the configuration the bitwise gate pins.  With ``interpret=False`` (real
TPU) inputs are cast to f32 (the MXU has no f64 path) and parity degrades
to allclose; see ``repro.kernels`` docstring.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import INTERPRET, SCAN_TILE_Q, SCAN_TILE_T
from repro.kernels.fused_masked_scan.kernel import (
    fused_masked_scan_pallas,
    masked_partials_pallas,
)

TILE_Q = SCAN_TILE_Q  # snippet-axis tile; SNIPPET_TILE batches use 1 tile


def _pad_rows(x, mult, fill=0.0):
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=fill)


def _payload(measures, dt):
    t_n = measures.shape[0]
    meas = measures.astype(dt)
    return jnp.concatenate(
        [meas, meas * meas, jnp.ones((t_n, 1), dt)], axis=1)  # (T, 2M+1)


def _epilogue(out, snippets, m, scanned):
    """(Q, 2M+1) kernel accumulator -> Partials (f64, oracle layout)."""
    from repro.aqp.executor import Partials

    out = out.astype(jnp.float64)
    idx = snippets.measure[:, None]
    sums = jnp.take_along_axis(out[:, :m], idx, axis=1)[:, 0]
    sumsq = jnp.take_along_axis(out[:, m:2 * m], idx, axis=1)[:, 0]
    return Partials(sums, sumsq, out[:, 2 * m], scanned)


@partial(jax.jit, static_argnames=("tile_t", "tile_q", "interpret"))
def eval_partials_fused(num_normalized, cat, measures, snippets, valid=None,
                        *, tile_t: int = SCAN_TILE_T, tile_q: int = TILE_Q,
                        interpret: bool = INTERPRET):
    """Fused-kernel partials for one tuple block (drop-in for
    ``eval_partials``; bitwise-equal to it in interpret mode).

    ``valid``: optional (T,) 0/1 validity mask for zero-padded blocks —
    invalid rows contribute exactly nothing and ``scanned`` is the mask sum
    (the TRUE tuple count), matching the oracle's contract exactly.
    """
    dt = jnp.float64 if interpret else jnp.float32
    t_n, m = measures.shape
    q_n = snippets.lo.shape[0]
    scanned = (jnp.asarray(float(t_n)) if valid is None
               else jnp.sum(valid))
    if valid is None:
        valid = jnp.ones((t_n,), dt)
    # Tuple-axis padding: zero rows with valid=0 — their mask rows are exact
    # 0.0, so they add exact-zero partials (the fold is padding-oblivious).
    x_p = _pad_rows(num_normalized.astype(dt), tile_t)
    valid_p = _pad_rows(valid.astype(dt), tile_t)[:, None]
    payload_p = _pad_rows(_payload(measures, dt), tile_t)
    c = cat.shape[1] if cat.ndim == 2 else 0
    if c:
        codes_p = _pad_rows(cat.astype(jnp.int32), tile_t)
        snip_cat = snippets.cat.astype(dt).reshape(q_n, -1)  # (Q, C*V)
    else:
        # Cat-free schema: one dummy all-member dim keeps the kernel
        # signature static (code 0 is always a member of the {0} set).
        codes_p = jnp.zeros((x_p.shape[0], 1), jnp.int32)
        snip_cat = jnp.ones((q_n, 1), dt)
    # Snippet-axis padding: full-domain rows, sliced away after the call.
    lo_p = _pad_rows(snippets.lo.astype(dt), tile_q)
    hi_p = _pad_rows(snippets.hi.astype(dt), tile_q, fill=1.0)
    cat_p = _pad_rows(snip_cat, tile_q, fill=1.0)
    out = fused_masked_scan_pallas(
        x_p, codes_p, valid_p, payload_p, lo_p, hi_p, cat_p,
        tile_t=tile_t, tile_q=tile_q, interpret=interpret,
    )[:q_n]
    return _epilogue(out, snippets, m, scanned)


@partial(jax.jit, static_argnames=("tile_t", "tile_q", "interpret"))
def masked_partials_fused(mask, measures, snippets, scanned,
                          *, tile_t: int = SCAN_TILE_T, tile_q: int = TILE_Q,
                          interpret: bool = INTERPRET):
    """Kernel-backed drop-in for ``_partials_from_mask``: fold a pre-built
    (T, Q) predicate mask (e.g. gathered from the sharded mask build)
    against [measures, measures^2, 1] in the canonical tile order."""
    dt = jnp.float64 if interpret else jnp.float32
    t_n, m = measures.shape
    q_n = mask.shape[1]
    mask_p = _pad_rows(mask.astype(dt), tile_t)
    mask_p = jnp.pad(mask_p, ((0, 0), (0, (-q_n) % tile_q)))
    payload_p = _pad_rows(_payload(measures, dt), tile_t)
    out = masked_partials_pallas(
        mask_p, payload_p, tile_t=tile_t, tile_q=tile_q, interpret=interpret,
    )[:q_n]
    return _epilogue(out, snippets, m, scanned)
