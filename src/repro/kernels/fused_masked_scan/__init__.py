from repro.kernels.fused_masked_scan.ops import (
    eval_partials_fused,
    masked_partials_fused,
)
from repro.kernels.fused_masked_scan.ref import (
    fused_masked_scan_ref,
    masked_tile_fold,
)

__all__ = [
    "eval_partials_fused",
    "masked_partials_fused",
    "fused_masked_scan_ref",
    "masked_tile_fold",
]
