"""Fused masked-scan kernel (TPU Pallas) — the whole scan hot path in one pass.

Replaces the three-piece hot path (jnp ``predicate_mask`` compare, separate
``mask^T @ payload`` matmul, and the partial-coverage ``range_mask_agg``
kernel) with ONE kernel that streams relation tiles through VMEM exactly
once.  Per (snippet-tile q, tuple-tile t) grid step:

  1. range compare (VPU): ``lo - RANGE_EPS <= x <= hi + RANGE_EPS`` over all
     numeric dims — the SAME shared epsilon the jnp oracle uses;
  2. categorical membership (MXU): one-hot(codes) @ snip_cat_k^T per cat dim
     — exactly 0.0/1.0, bit-identical to the oracle's ``jnp.take`` gather;
  3. per-tuple validity mask: padding rows multiply to exact 0.0;
  4. partials accumulation (MXU): ``mask^T @ [measures, measures^2, 1]``,
     accumulated over the sequential tuple-tile axis.

Bitwise parity by construction: the tuple axis is the sequential grid axis,
so the accumulator performs a FIXED ascending-tile-order fold of
(SCAN_TILE_T x tile_q) dot partials — the same fold
``repro.aqp.executor._partials_from_mask`` performs (same dot shapes, same
order, f64 in interpret mode), so kernel partials equal the jnp oracle bit
for bit.  Column (snippet) tiling is bitwise-free: each output column's
reduction over tuples is independent of its siblings.

``_mpa_kernel`` is the aggregation-only variant for the sharded placement:
the predicate mask is built sharded (``shard_map`` over the mesh), gathered,
and fed here pre-built — the same accumulation body, hence the same bits,
which is what lets ``use_kernels=True`` compose with a mesh.

Grid: (Q / TQ, T / TT); out block indexed by q only, initialized at t == 0.
HBM traffic is O(T·(L+C+P)) — each relation tile is read once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import RANGE_EPS


def _range_cat_mask(x, codes, lo_ref, hi_ref, cat_ref, valid_ref,
                    *, n_dims: int, n_cat: int, vmax: int):
    """(TT, TQ) validity-masked predicate mask, exact 0.0/1.0 entries."""
    dt = x.dtype
    mask = None
    for k in range(n_dims):
        xk = x[:, k][:, None]  # (TT, 1)
        mk = ((xk >= lo_ref[:, k][None, :] - RANGE_EPS)
              & (xk <= hi_ref[:, k][None, :] + RANGE_EPS))
        mask = mk if mask is None else (mask & mk)
    for k in range(n_cat):
        # one-hot(codes_k) @ snip_cat_k^T: exactly 1.0 iff the tuple's code
        # is a member of snippet q's category set (one 1-entry per row).
        onehot = (codes[:, k][:, None]
                  == jax.lax.broadcasted_iota(jnp.int32,
                                              (x.shape[0], vmax), 1))
        catk = cat_ref[:, k * vmax:(k + 1) * vmax]  # (TQ, V) 0/1
        member = jax.lax.dot_general(
            onehot.astype(dt), catk,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=dt,
        )  # (TT, TQ)
        mk = member > 0.5
        mask = mk if mask is None else (mask & mk)
    if mask is None:  # no predicate dims at all: every tuple matches
        m = jnp.ones((x.shape[0], lo_ref.shape[0]), dt)
    else:
        m = mask.astype(dt)
    return m * valid_ref[...]  # (TT, TQ) * (TT, 1)


def _accumulate(acc, out_ref, t):
    @pl.when(t == 0)
    def _init():
        out_ref[...] = acc.astype(out_ref.dtype)

    @pl.when(t != 0)
    def _accum():
        out_ref[...] = out_ref[...] + acc.astype(out_ref.dtype)


def _fms_kernel(x_ref, codes_ref, valid_ref, payload_ref, lo_ref, hi_ref,
                cat_ref, out_ref, *, n_dims: int, n_cat: int, vmax: int):
    t = pl.program_id(1)
    m = _range_cat_mask(x_ref[...], codes_ref[...], lo_ref, hi_ref, cat_ref,
                        valid_ref, n_dims=n_dims, n_cat=n_cat, vmax=vmax)
    acc = jax.lax.dot_general(
        m, payload_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype,
    )  # (TQ, P)
    _accumulate(acc, out_ref, t)


def fused_masked_scan_pallas(x, codes, valid, payload, lo, hi, cat,
                             *, tile_t: int, tile_q: int,
                             interpret: bool = True):
    """Raw pallas_call; T and Q must be pre-padded to tile multiples.

    x: (T, L) normalized numerics; codes: (T, C) int32 category codes
    (C >= 1 — wrappers pass a zero dummy column for cat-free schemas);
    valid: (T, 1); payload: (T, P); lo/hi: (Q, L); cat: (Q, C*V) 0/1.
    Accumulator dtype follows the payload dtype (f64 interpret / f32 TPU).
    """
    t_n, l = x.shape
    q_n = lo.shape[0]
    p = payload.shape[1]
    c = codes.shape[1]
    vmax = cat.shape[1] // max(c, 1)
    assert t_n % tile_t == 0 and q_n % tile_q == 0
    grid = (q_n // tile_q, t_n // tile_t)
    kern = functools.partial(_fms_kernel, n_dims=l, n_cat=c, vmax=vmax)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_t, l), lambda q, t: (t, 0)),  # x
            pl.BlockSpec((tile_t, c), lambda q, t: (t, 0)),  # codes
            pl.BlockSpec((tile_t, 1), lambda q, t: (t, 0)),  # valid
            pl.BlockSpec((tile_t, p), lambda q, t: (t, 0)),  # payload
            pl.BlockSpec((tile_q, l), lambda q, t: (q, 0)),  # lo
            pl.BlockSpec((tile_q, l), lambda q, t: (q, 0)),  # hi
            pl.BlockSpec((tile_q, cat.shape[1]), lambda q, t: (q, 0)),  # cat
        ],
        out_specs=pl.BlockSpec((tile_q, p), lambda q, t: (q, 0)),
        out_shape=jax.ShapeDtypeStruct((q_n, p), payload.dtype),
        interpret=interpret,
    )(x, codes, valid, payload, lo, hi, cat)


def _mpa_kernel(mask_ref, payload_ref, out_ref):
    t = pl.program_id(1)
    acc = jax.lax.dot_general(
        mask_ref[...], payload_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype,
    )
    _accumulate(acc, out_ref, t)


def masked_partials_pallas(mask, payload, *, tile_t: int, tile_q: int,
                           interpret: bool = True):
    """Aggregation-only entry: a pre-built (T, Q) mask (e.g. gathered from a
    sharded mask build) folded against the payload in the SAME fixed tile
    order as the fused kernel — the mesh-composition path of the scan."""
    t_n, q_n = mask.shape
    p = payload.shape[1]
    assert t_n % tile_t == 0 and q_n % tile_q == 0
    grid = (q_n // tile_q, t_n // tile_t)
    return pl.pallas_call(
        _mpa_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_t, tile_q), lambda q, t: (t, q)),  # mask
            pl.BlockSpec((tile_t, p), lambda q, t: (t, 0)),  # payload
        ],
        out_specs=pl.BlockSpec((tile_q, p), lambda q, t: (q, 0)),
        out_shape=jax.ShapeDtypeStruct((q_n, p), payload.dtype),
        interpret=interpret,
    )(mask, payload)
