"""Pure-jnp oracle for the fused masked-scan kernel.

Mirrors the kernel's semantics exactly — shared ``RANGE_EPS`` boundary
widening, categorical membership, validity masking — but reduces with the
SAME fixed ascending-tile-order fold over ``SCAN_TILE_T`` tuple tiles, so in
f64 the reference is bitwise-equal to the interpret-mode kernel (a single
big matmul would round differently; the fold IS the canonical reduction of
the scan plane, see ``repro.aqp.executor._partials_from_mask``).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.aqp.executor import masked_tile_fold  # the canonical fold
from repro.kernels import RANGE_EPS, SCAN_TILE_T

__all__ = ["fused_masked_scan_ref", "masked_tile_fold"]


def fused_masked_scan_ref(x, codes, valid, payload, lo, hi, cat,
                          tile_t: int = SCAN_TILE_T):
    """x: (T,L); codes: (T,C) int; valid: (T,1); payload: (T,P);
    lo/hi: (Q,L); cat: (Q, C*V) 0/1 -> (Q,P)."""
    dt = payload.dtype
    mask = jnp.all(
        (x[:, None, :] >= lo[None, :, :] - RANGE_EPS)
        & (x[:, None, :] <= hi[None, :, :] + RANGE_EPS),
        axis=-1,
    )  # (T, Q)
    c = codes.shape[1]
    vmax = cat.shape[1] // max(c, 1)
    for k in range(c):
        catk = cat[:, k * vmax:(k + 1) * vmax]  # (Q, V)
        mk = jnp.take(catk, codes[:, k], axis=1) > 0.5  # (Q, T)
        mask = mask & mk.T
    m = mask.astype(dt) * valid.astype(dt)
    return masked_tile_fold(m, payload, tile_t)
