"""Public jit'd wrapper for the fused improved-answer kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import INTERPRET
from repro.kernels.gp_batch_infer.kernel import gp_batch_infer_pallas


def _pad1(x, mult, fill=0.0):
    pad = (-x.shape[0]) % mult
    return x if pad == 0 else jnp.concatenate(
        [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])


@partial(jax.jit, static_argnames=("tile_q", "tile_c", "interpret"))
def gp_batch_infer(k_mat, sigma_inv, alpha, kappa2, mu_new, raw_theta, raw_beta2,
                   *, tile_q: int = 128, tile_c: int = 128,
                   interpret: bool = INTERPRET):
    """(theta_dd, beta2_dd, gamma2) for Q new snippets; f32 on the MXU.

    Zero-padding C is exact (zero K columns/Sinv blocks contribute nothing);
    padded Q rows are sliced away.
    """
    q_n, c_n = k_mat.shape
    dt = jnp.float32
    k_p = _pad1(k_mat.astype(dt), tile_q)
    k_p = jnp.pad(k_p, ((0, 0), (0, (-c_n) % tile_c)))
    s_p = jnp.pad(sigma_inv.astype(dt),
                  ((0, (-c_n) % tile_c), (0, (-c_n) % tile_c)))
    a_p = _pad1(alpha.astype(dt), tile_c)
    kap = _pad1(kappa2.astype(dt), tile_q, fill=1.0)
    mu = _pad1(mu_new.astype(dt), tile_q)
    rt = _pad1(raw_theta.astype(dt), tile_q)
    rb = _pad1(raw_beta2.astype(dt), tile_q, fill=1.0)
    theta, beta2, gamma2 = gp_batch_infer_pallas(
        k_p, s_p, a_p, kap, mu, rt, rb,
        tile_q=tile_q, tile_c=tile_c, interpret=interpret,
    )
    return theta[:q_n], beta2[:q_n], gamma2[:q_n]
