from repro.kernels.gp_batch_infer.ops import gp_batch_infer
