"""Pure-jnp oracle for the fused improved-answer kernel (Eq. 11/12)."""
from __future__ import annotations

import jax.numpy as jnp

GAMMA_FLOOR = 1e-30


def gp_batch_infer_ref(k_mat, sigma_inv, alpha, kappa2, mu_new, raw_theta, raw_beta2):
    t = k_mat @ sigma_inv
    gamma2 = jnp.maximum(kappa2 - jnp.sum(t * k_mat, axis=-1), GAMMA_FLOOR)
    prior = mu_new + k_mat @ alpha
    denom = raw_beta2 + gamma2
    theta = (raw_beta2 * prior + gamma2 * raw_theta) / denom
    beta2 = raw_beta2 * gamma2 / denom
    exact = raw_beta2 <= 0.0
    theta = jnp.where(exact, raw_theta, theta)
    beta2 = jnp.where(exact, 0.0, beta2)
    return theta, beta2, gamma2
