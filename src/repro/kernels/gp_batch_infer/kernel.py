"""Fused improved-answer kernel (TPU Pallas) — query-time inference, Eq. 11/12.

For Q new snippets against a synopsis of C past snippets:

    gamma2[q] = kappa2[q] - K[q,:] @ Sigma^{-1} @ K[q,:]^T
    prior[q]  = mu[q] + K[q,:] @ alpha
    theta[q]  = (beta2[q]·prior + gamma2·raw) / (beta2 + gamma2)
    beta2'[q] = beta2[q]·gamma2 / (beta2 + gamma2)

Grid: (Q/TQ, C/TC, C/TC). The quadratic form streams Sigma^{-1} tiles through
VMEM once (the dominant traffic, C^2 floats); per (c1, c2) step a
(TQ, TC)·(TC, TC) matmul runs on the MXU and a row-sum folds into a VMEM
scratch accumulator. The Eq. 12 blend is fused into the final grid step, so
improved answers never round-trip through HBM — this is how the paper's
"negligible overhead" property is kept at serving batch sizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

GAMMA_FLOOR = 1e-30


def _gp_kernel(k1_ref, k2_ref, sinv_ref, alpha_ref, kappa2_ref, mu_ref,
               rawt_ref, rawb_ref, theta_ref, beta2_ref, gamma2_ref,
               gacc, tacc):
    c1 = pl.program_id(1)
    c2 = pl.program_id(2)
    nc1 = pl.num_programs(1)
    nc2 = pl.num_programs(2)

    @pl.when((c1 == 0) & (c2 == 0))
    def _zero():
        gacc[...] = jnp.zeros_like(gacc)
        tacc[...] = jnp.zeros_like(tacc)

    p = jax.lax.dot_general(
        k1_ref[...], sinv_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TQ, TC2)
    gacc[...] = gacc[...] + jnp.sum(p * k2_ref[...], axis=1)

    @pl.when(c2 == 0)
    def _theta_acc():
        tacc[...] = tacc[...] + k1_ref[...] @ alpha_ref[...]

    @pl.when((c1 == nc1 - 1) & (c2 == nc2 - 1))
    def _finalize():
        gamma2 = jnp.maximum(kappa2_ref[...] - gacc[...], GAMMA_FLOOR)
        prior = mu_ref[...] + tacc[...]
        rawb = rawb_ref[...]
        rawt = rawt_ref[...]
        denom = rawb + gamma2
        theta = (rawb * prior + gamma2 * rawt) / denom
        beta2 = rawb * gamma2 / denom
        exact = rawb <= 0.0
        theta_ref[...] = jnp.where(exact, rawt, theta)
        beta2_ref[...] = jnp.where(exact, 0.0, beta2)
        gamma2_ref[...] = gamma2


def gp_batch_infer_pallas(k_mat, sigma_inv, alpha, kappa2, mu_new, raw_theta,
                          raw_beta2, *, tile_q: int = 128, tile_c: int = 128,
                          interpret: bool = True):
    """Raw pallas_call; Q and C must be pre-padded to tile multiples."""
    q_n, c_n = k_mat.shape
    assert q_n % tile_q == 0 and c_n % tile_c == 0
    grid = (q_n // tile_q, c_n // tile_c, c_n // tile_c)
    dt = k_mat.dtype
    return pl.pallas_call(
        _gp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, tile_c), lambda q, c1, c2: (q, c1)),  # K (c1)
            pl.BlockSpec((tile_q, tile_c), lambda q, c1, c2: (q, c2)),  # K (c2)
            pl.BlockSpec((tile_c, tile_c), lambda q, c1, c2: (c1, c2)),  # Sinv
            pl.BlockSpec((tile_c,), lambda q, c1, c2: (c1,)),  # alpha
            pl.BlockSpec((tile_q,), lambda q, c1, c2: (q,)),  # kappa2
            pl.BlockSpec((tile_q,), lambda q, c1, c2: (q,)),  # mu
            pl.BlockSpec((tile_q,), lambda q, c1, c2: (q,)),  # raw theta
            pl.BlockSpec((tile_q,), lambda q, c1, c2: (q,)),  # raw beta2
        ],
        out_specs=[
            pl.BlockSpec((tile_q,), lambda q, c1, c2: (q,)),
            pl.BlockSpec((tile_q,), lambda q, c1, c2: (q,)),
            pl.BlockSpec((tile_q,), lambda q, c1, c2: (q,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q_n,), dt),
            jax.ShapeDtypeStruct((q_n,), dt),
            jax.ShapeDtypeStruct((q_n,), dt),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_q,), jnp.float32),
            pltpu.VMEM((tile_q,), jnp.float32),
        ],
        interpret=interpret,
    )(k_mat, k_mat, sigma_inv, alpha, kappa2, mu_new, raw_theta, raw_beta2)
