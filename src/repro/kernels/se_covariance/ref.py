"""Pure-jnp oracle for the blocked SE-covariance kernel.

The numeric factor of cov(theta_i, theta_j) (paper Eq. 10): product over
numeric dims of the closed-form double integral, scaled by
sigma2 / (norm_i * norm_j).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.covariance import se_double_integral


def se_cov_matrix_ref(lo_i, hi_i, lo_j, hi_j, ls, sigma2, norm_i, norm_j):
    """lo/hi: (n, l) pre-widened ranges; ls: (l,); norm: (n,). -> (n_i, n_j)."""
    g = se_double_integral(
        lo_i[:, None, :], hi_i[:, None, :], lo_j[None, :, :], hi_j[None, :, :], ls
    )
    g = jnp.maximum(g, 0.0)
    prod = jnp.prod(g, axis=-1)
    return sigma2 * prod / (norm_i[:, None] * norm_j[None, :])
