"""Blocked SE double-integral covariance kernel (TPU Pallas).

Grid: (n_i / TI, n_j / TJ). Each program instance holds a (TI, l) and (TJ, l)
tile of pre-widened predicate ranges in VMEM plus the (l,) lengthscales, and
emits a (TI, TJ) covariance tile:

    out[a, b] = sigma2 / (norm_i[a] * norm_j[b])
                * prod_k II(lo_i[a,k], hi_i[a,k], lo_j[b,k], hi_j[b,k]; ls[k])

The per-dimension closed form needs exp and erf only — both VPU-native.
The k-loop is a static Python loop (l is small), so the whole tile stays in
registers/VMEM; arithmetic intensity is O(l) per output element, making the
kernel compute-bound for l >= 3 (see DESIGN.md roofline notes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.scipy.special import erf

SQRT_PI = 1.7724538509055159


def _antideriv(u, z):
    return -0.5 * z * z * jnp.exp(-((u / z) ** 2)) - 0.5 * SQRT_PI * z * u * erf(u / z)


def _integral(a, b, c, d, z):
    return _antideriv(b - d, z) - _antideriv(b - c, z) - _antideriv(a - d, z) + _antideriv(a - c, z)


def _se_cov_kernel(lo_i_ref, hi_i_ref, lo_j_ref, hi_j_ref, ls_ref, ni_ref, nj_ref,
                   sigma2_ref, out_ref, *, n_dims: int):
    acc = None
    for k in range(n_dims):
        a = lo_i_ref[:, k][:, None]  # (TI, 1)
        b = hi_i_ref[:, k][:, None]
        c = lo_j_ref[:, k][None, :]  # (1, TJ)
        d = hi_j_ref[:, k][None, :]
        z = ls_ref[k]
        g = jnp.maximum(_integral(a, b, c, d, z), 0.0)  # (TI, TJ)
        acc = g if acc is None else acc * g
    if acc is None:  # zero numeric dims: pure categorical schema
        acc = jnp.ones_like(out_ref[...])
    scale = sigma2_ref[0] / (ni_ref[:][:, None] * nj_ref[:][None, :])
    out_ref[...] = acc * scale


def se_cov_pallas(lo_i, hi_i, lo_j, hi_j, ls, sigma2, norm_i, norm_j,
                  *, tile_i: int = 128, tile_j: int = 128, interpret: bool = True):
    """Raw pallas_call; inputs must be pre-padded to tile multiples (see ops)."""
    n_i, l = lo_i.shape
    n_j = lo_j.shape[0]
    assert n_i % tile_i == 0 and n_j % tile_j == 0
    grid = (n_i // tile_i, n_j // tile_j)
    kern = functools.partial(_se_cov_kernel, n_dims=l)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_i, l), lambda i, j: (i, 0)),  # lo_i
            pl.BlockSpec((tile_i, l), lambda i, j: (i, 0)),  # hi_i
            pl.BlockSpec((tile_j, l), lambda i, j: (j, 0)),  # lo_j
            pl.BlockSpec((tile_j, l), lambda i, j: (j, 0)),  # hi_j
            pl.BlockSpec((l,), lambda i, j: (0,)),  # ls
            pl.BlockSpec((tile_i,), lambda i, j: (i,)),  # norm_i
            pl.BlockSpec((tile_j,), lambda i, j: (j,)),  # norm_j
            pl.BlockSpec((1,), lambda i, j: (0,)),  # sigma2
        ],
        out_specs=pl.BlockSpec((tile_i, tile_j), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_i, n_j), lo_i.dtype),
        interpret=interpret,
    )(lo_i, hi_i, lo_j, hi_j, ls, norm_i, norm_j, sigma2)
