"""Public jit'd wrapper for the SE-covariance kernel: padding + epilogue."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import INTERPRET
from repro.kernels.se_covariance.kernel import se_cov_pallas


def _pad_rows(x, mult, fill):
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    fill_arr = jnp.full((pad,) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, fill_arr], axis=0)


@partial(jax.jit, static_argnames=("tile_i", "tile_j", "interpret"))
def se_cov_matrix(
    lo_i, hi_i, lo_j, hi_j, ls, sigma2, norm_i, norm_j,
    *, tile_i: int = 128, tile_j: int = 128, interpret: bool = INTERPRET,
):
    """sigma2 * prod_k II_k / (norm_i norm_j) as an (n_i, n_j) matrix.

    Pads both snippet batches to tile multiples (padding rows use unit-width
    ranges and norm=1 so they are numerically benign), runs the Pallas kernel,
    slices the result back.
    """
    n_i, n_j = lo_i.shape[0], lo_j.shape[0]
    dt = jnp.float32 if lo_i.dtype == jnp.float32 else lo_i.dtype
    args_i = [_pad_rows(x.astype(dt), tile_i, f) for x, f in
              ((lo_i, 0.0), (hi_i, 1.0))]
    args_j = [_pad_rows(x.astype(dt), tile_j, f) for x, f in
              ((lo_j, 0.0), (hi_j, 1.0))]
    ni = _pad_rows(norm_i.astype(dt), tile_i, 1.0)
    nj = _pad_rows(norm_j.astype(dt), tile_j, 1.0)
    out = se_cov_pallas(
        args_i[0], args_i[1], args_j[0], args_j[1],
        ls.astype(dt), jnp.asarray([sigma2], dt), ni, nj,
        tile_i=tile_i, tile_j=tile_j, interpret=interpret,
    )
    return out[:n_i, :n_j]
