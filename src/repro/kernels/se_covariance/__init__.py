from repro.kernels.se_covariance.ops import se_cov_matrix
