"""Pallas TPU kernels for Verdict's compute hot spots.

Each kernel package follows the kernel.py (pl.pallas_call + BlockSpec VMEM
tiling) / ops.py (jit'd public wrapper with padding + epilogue) / ref.py
(pure-jnp oracle) convention. On this CPU container kernels execute via
``interpret=True``; on TPU the same BlockSpecs define the VMEM working set.

Kernels:
  se_covariance    -- blocked closed-form SE double-integral covariance build
                      (offline learning hot loop: O(n^2 l) erf evaluations).
  fused_masked_scan-- THE scan hot loop: one pass that streams relation tiles
                      through VMEM — predicate compare (RANGE_EPS widened),
                      categorical membership (one-hot MXU matmul), per-tuple
                      validity mask, and [measures, measures^2, 1] partials
                      accumulation, fused.  Accumulation is a FIXED tile-order
                      fold over SCAN_TILE_T tuple tiles — the SAME reduction
                      ``repro.aqp.executor._partials_from_mask`` performs —
                      so kernel partials are BITWISE equal to the
                      ``eval_partials`` oracle under interpret mode (f64),
                      for any block size and under local AND sharded
                      placement (``tests/test_fused_scan.py``).
  range_mask_agg   -- legacy partial-coverage scan kernel ((tuples x snippets)
                      mask then mask^T @ payload); superseded by
                      fused_masked_scan on the engine path but kept as a
                      stable public wrapper (now valid-mask aware and on the
                      shared RANGE_EPS).
  gp_batch_infer   -- gamma^2 = diag(K Sigma^-1 K^T) + prior blend, tiled on
                      the MXU (the query-time inference hot loop, Eq. 11/12).

Parity guarantees vs the INTERPRET flag:
  INTERPRET=True (this CPU container): kernel bodies execute as jnp ops in
  f64; the fused scan's fixed tile-order fold makes its partials bitwise
  equal to the jnp oracle — the repo-wide raw-answer-consistency discipline.
  INTERPRET=False (real TPU): the same BlockSpecs compile to Mosaic; the MXU
  has no f64 path, so the fused scan accumulates in f32 and parity degrades
  to allclose — the bitwise gate applies to interpret mode only.

Shared numeric constants (imported by kernels, the executor oracle and the
refs — ONE epsilon, ONE tile, so kernel and oracle can never drift):

  RANGE_EPS    -- predicate range-boundary widening. All range compares are
                  ``lo - RANGE_EPS <= x <= hi + RANGE_EPS``; kernel, oracle
                  and ref share this constant (a kernel-local 1e-7 once made
                  ``use_kernels=True`` change answers near snippet bounds).
  SCAN_TILE_T  -- the tuple-axis accumulation tile of the scan plane. The
                  oracle's reduction and the fused kernel's grid both fold
                  (SCAN_TILE_T x SCAN_TILE_Q) dot partials in ascending tile
                  order, so their sums agree bit for bit by construction.
  SCAN_TILE_Q  -- the snippet-axis tile. Every dot in the canonical fold has
                  the FIXED shape (SCAN_TILE_T, SCAN_TILE_Q) x (SCAN_TILE_T,
                  P): XLA's CPU matmul picks its contraction order by shape,
                  so only fixed-shape per-tile dots make per-snippet partials
                  bitwise independent of how many snippets ride along
                  (Q-padding invariance — pinned by the verdict-API tests).
"""

INTERPRET = True  # CPU container: flip to False on real TPU.

RANGE_EPS = 1e-12  # shared predicate-boundary epsilon (kernel == oracle == ref)

SCAN_TILE_T = 512  # tuple-axis tile of the scan's fixed-order accumulation fold
SCAN_TILE_Q = 128  # snippet-axis tile (= core.types.SNIPPET_TILE serve tiles)
