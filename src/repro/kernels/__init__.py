"""Pallas TPU kernels for Verdict's compute hot spots.

Each kernel package follows the kernel.py (pl.pallas_call + BlockSpec VMEM
tiling) / ops.py (jit'd public wrapper with padding + epilogue) / ref.py
(pure-jnp oracle) convention. On this CPU container kernels execute via
``interpret=True``; on TPU the same BlockSpecs define the VMEM working set.

Kernels:
  se_covariance   -- blocked closed-form SE double-integral covariance build
                     (offline learning hot loop: O(n^2 l) erf evaluations).
  range_mask_agg  -- (tuples x snippets) predicate mask built in VMEM, then
                     mask^T @ [measures, measures^2, 1] on the MXU (the AQP
                     scan hot loop).
  gp_batch_infer  -- gamma^2 = diag(K Sigma^-1 K^T) + prior blend, tiled on
                     the MXU (the query-time inference hot loop, Eq. 11/12).
"""

INTERPRET = True  # CPU container: flip to False on real TPU.
