"""Deterministic, checkpointable, straggler-tolerant token pipeline.

- Determinism: batch ``step`` is a pure function of (seed, step, assignment),
  so any host can recompute any shard — restarts and elastic re-scales replay
  exactly (state is just the step counter, stored in every checkpoint).
- Over-decomposition (straggler mitigation): each global step is split into
  ``over_factor`` x more work units than hosts; units are claimed greedily so
  a slow host hands surplus units to fast ones. Within-SPMD compute stays
  bulk-synchronous; the stealing happens at the host/unit level (as in
  production input pipelines).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    over_factor: int = 4
    step: int = 0

    def unit_count(self) -> int:
        return self.n_hosts * self.over_factor

    def _unit_batch(self, step: int, unit: int) -> np.ndarray:
        per_unit = self.global_batch // self.unit_count()
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, unit]))
        toks = rng.integers(0, self.vocab,
                            size=(per_unit, self.seq_len + 1), dtype=np.int32)
        return toks

    def assignments(self, speeds: List[float] = None) -> List[List[int]]:
        """Greedy longest-processing-time unit assignment given host speeds
        (1.0 = nominal). Slow hosts get fewer units — work stealing."""
        speeds = speeds or [1.0] * self.n_hosts
        loads = [0.0] * self.n_hosts
        buckets: List[List[int]] = [[] for _ in range(self.n_hosts)]
        for unit in range(self.unit_count()):
            h = int(np.argmin([l + 1.0 / s for l, s in zip(loads, speeds)]))
            buckets[h].append(unit)
            loads[h] += 1.0 / speeds[h]
        return buckets

    def next_batch(self, speeds: List[float] = None) -> Tuple[np.ndarray, np.ndarray]:
        """(tokens, labels) for this host's units at the current step."""
        buckets = self.assignments(speeds)
        units = buckets[self.host_id]
        toks = np.concatenate([self._unit_batch(self.step, u) for u in units])
        self.step += 1
        return toks[:, :-1], toks[:, 1:]

    def global_batch_at(self, step: int) -> np.ndarray:
        toks = np.concatenate(
            [self._unit_batch(step, u) for u in range(self.unit_count())])
        return toks

    def state_dict(self):
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, s):
        self.step = int(s["step"])
        self.seed = int(s["seed"])
