from repro.distributed.sharding import (
    DEFAULT_RULES,
    batch_axes,
    cache_shardings,
    data_shards,
    opt_state_shardings,
)
