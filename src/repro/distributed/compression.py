"""Cross-pod gradient compression: int8 quantized exchange with error feedback.

The cross-pod gradient all-reduce crosses DCN (slowest link in a multi-pod
run). This module replaces it with an int8 collective-permute exchange
(pod count 2: one partner) + local dequant-average, with per-tensor scales
and an error-feedback residual so quantization noise doesn't bias training
(1-bit/8-bit SGD lineage: Seide et al. 2014, Bernstein et al. 2018).

Integration: ``make_compressed_train_step`` wraps the standard train step in
``shard_map`` over the 'pod' axis (all other axes stay GSPMD-auto). Inside,
each pod computes grads on its half of the global batch; the exchange then
runs as s8 wire traffic — visible in the dry-run HLO as an
s8 collective-permute (vs. f32 all-reduce at 4x the bytes).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_pair_mean(x, axis: str = "pod"):
    """Mean of ``x`` across a 2-member axis via int8 ppermute exchange.

    Returns (mean, error_feedback_residual).
    """
    n = jax.lax.axis_size(axis)
    q, scale = quantize_int8(x)
    sent = dequantize(q, scale)
    residual = x - sent  # error feedback: re-injected into the next step
    perm = [(i, (i + 1) % n) for i in range(n)]
    q_peer = jax.lax.ppermute(q, axis, perm)
    scale_peer = jax.lax.ppermute(scale, axis, perm)
    mean = (sent + dequantize(q_peer, scale_peer)) / n
    return mean, residual


def tree_compressed_mean(tree, axis: str = "pod"):
    flat, treedef = jax.tree.flatten(tree)
    outs = [compressed_pair_mean(x.astype(jnp.float32), axis) for x in flat]
    mean = jax.tree.unflatten(treedef, [o[0] for o in outs])
    resid = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return mean, resid


def make_compressed_train_step(cfg, opt, mesh, *, accum: int = 1,
                               clip_norm: float = 1.0):
    """Train step with manual pod-axis DP + int8 compressed grad exchange.

    Params/opt-state are pod-replicated (P() over 'pod'); batch microbatches
    are pod-sharded; 'data'/'model' axes remain GSPMD-auto inside.
    """
    from repro.models.common import ShardCtx
    from repro.training.losses import lm_loss
    from repro.training.train_loop import clip_by_global_norm

    sctx = ShardCtx(mesh=mesh, batch_axes=("data",))

    def inner(params, opt_state, batch, lr):
        def micro_loss(p, mb):
            return lm_loss(cfg, p, mb, sctx)

        if accum == 1:
            mb = jax.tree.map(lambda x: x[0], batch)
            loss, grads = jax.value_and_grad(micro_loss)(params, mb)
        else:
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                g, l = carry
                loss, gr = jax.value_and_grad(micro_loss)(params, mb)
                return (jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                     g, gr), l + loss), None

            (grads, loss_sum), _ = jax.lax.scan(body, (zeros, 0.0), batch)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
        grads, _resid = tree_compressed_mean(grads, "pod")
        loss = jax.lax.pmean(loss, "pod")
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    def specs_like(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    def step(params, opt_state, batch, lr):
        # jax.shard_map with axis_names={'pod'}: only the pod axis is manual;
        # 'data'/'model' stay GSPMD-auto inside (standard partial-manual mode).
        return jax.shard_map(
            inner,
            mesh=mesh,
            in_specs=(specs_like(params, P()), specs_like(opt_state, P()),
                      specs_like(batch, P(None, "pod")), P()),
            out_specs=(specs_like(params, P()), specs_like(opt_state, P()),
                       {"loss": P(), "grad_norm": P()}),
            axis_names=frozenset({"pod"}),
            check_vma=False,
        )(params, opt_state, batch, lr)

    return step
