"""Logical-axis -> mesh-axis rules and sharding trees for steps and caches.

Baseline layout (Megatron-style TP x DP, + pod axis for multi-pod DP):
  batch        -> ('pod', 'data')
  vocab/heads/ffn/experts -> 'model'
  embed (d_model dims), head_dim, states -> replicated
KV heads shard over 'model' only when divisible (else replicated — standard
GQA practice); head counts are padded at spec-build time (ArchConfig).
Alternative rule sets (fsdp / sequence-parallel) are hillclimb levers.
"""
from __future__ import annotations


import jax
from jax.sharding import NamedSharding, PartitionSpec as Pspec

DEFAULT_RULES = {
    "vocab": "model",
    "heads": "model",
    "ffn": "model",
    "experts": "model",
    "embed": None,
    "layers": None,
}

# ZeRO/FSDP-flavoured: additionally shard the d_model dimension of weights
# over the data axis (parameter+optimizer state sharding; gathered per layer).
FSDP_RULES = dict(DEFAULT_RULES, embed="data")

# Pure ZeRO-3 data parallelism: the WHOLE 256-chip mesh is one data-parallel
# domain (batch over ('data','model')); weights/optimizer state fully sharded
# on their d_model dim; XLA inserts per-layer all-gather (params) and
# reduce-scatter (grads) — wire cost ~3 x params/step instead of
# O(tokens x layers) activation all-reduces. The winning layout for <=10B
# dense models at pod scale (EXPERIMENTS.md §Perf, qwen train hillclimb).
# Dense archs only (MoE expert-parallelism needs the model axis).
PURE_DP_RULES = {
    "vocab": None,
    "heads": None,
    "ffn": None,
    "experts": None,
    "embed": ("data", "model"),
    "layers": None,
    "_batch_axes": ("data", "model"),  # consumed by launch.cells
}


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def data_shards(mesh, multi_pod: bool) -> int:
    n = mesh.shape["data"]
    return n * (mesh.shape["pod"] if multi_pod else 1)


def named(mesh, *spec):
    return NamedSharding(mesh, Pspec(*spec))


def batch_sharding(mesh, multi_pod: bool, ndim: int, batch_dim: int = 0):
    spec = [None] * ndim
    spec[batch_dim] = batch_axes(multi_pod)
    return named(mesh, *spec)


def opt_state_shardings(opt_name: str, param_shardings, abstract_state):
    """Optimizer-state sharding mirroring parameter shardings.

    adamw: m/v identical to params. adafactor: vr drops the last dim's spec,
    vc drops the second-to-last.
    """
    mesh = jax.tree.leaves(param_shardings)[0].mesh

    if opt_name == "adamw":
        return {
            "m": param_shardings,
            "v": param_shardings,
            "step": named(mesh),
        }

    # adafactor: walk the param sharding tree and emit {vr, vc} or {v}.
    def state_for(shard, aparam):
        ndim = len(aparam.shape)
        spec = list(shard.spec)
        spec = spec + [None] * (ndim - len(spec))
        if ndim >= 2:
            return {
                "vr": named(mesh, *spec[:-1]),
                "vc": named(mesh, *(spec[:-2] + spec[-1:])),
            }
        return {"v": named(mesh, *spec)}

    return {
        "v": jax.tree.map(state_for, param_shardings, abstract_state_params(abstract_state)),
        "step": named(mesh),
    }


def abstract_state_params(abstract_state):
    """adafactor state['v'] mirrors params structure with {vr,vc}|{v} leaves;
    recover per-param shapes from vr/vc for spec derivation."""

    def leaf(x):
        if isinstance(x, dict) and ("vr" in x or "v" in x):
            if "v" in x:
                return jax.ShapeDtypeStruct(x["v"].shape, x["v"].dtype)
            vr, vc = x["vr"], x["vc"]
            return jax.ShapeDtypeStruct(vr.shape + vc.shape[-1:], vr.dtype)
        return x

    return jax.tree.map(
        leaf, abstract_state["v"],
        is_leaf=lambda x: isinstance(x, dict) and ("vr" in x or "v" in x),
    )


def cache_shardings(mesh, multi_pod: bool, abstract_caches, cfg, *,
                    seq_axis=None, batch_sharded: bool = True):
    """Sharding tree for decode caches.

    Convention (see transformer.init_cache): leaves are either
      attention k/v:  (R?, B, S, KV, hd)
      slot_pos:       (R?, S)
      cross xk/xv:    (R?, B, N, KV, hd)
      rwkv state:     (R?, B, H, k, v) / x_prev (R?, B, d)
      mamba conv/h:   (R?, B, K, di) / (R?, B, di, n)
    Batch shards over the data axes; KV heads over 'model' when divisible;
    mamba channel dims over 'model'. With ``shard_cache_seq`` (long-context,
    batch=1) the KV sequence dim shards over 'data' instead of batch.
    """
    # long-context (batch=1) cells shard the KV sequence dim over 'data';
    # the decode hillclimb shards it over 'model' (flash-decoding style,
    # batch stays data-sharded) — see EXPERIMENTS.md §Perf.
    b_ax = batch_axes(multi_pod) if batch_sharded else None
    kv_ax = "model" if (cfg.kv_sharded and seq_axis != "model") else None

    # Rank-based assignment: match by leaf name; any extra leading dims are
    # the scan-stacking dims of repeated layer groups (replicated).
    def spec_for(path, x):
        keys = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        name = keys[-1] if keys else ""
        base = {
            "k": (b_ax, seq_axis, kv_ax, None),
            "v": (b_ax, seq_axis, kv_ax, None),
            "xk": (b_ax, None, kv_ax, None),
            "xv": (b_ax, None, kv_ax, None),
            "slot_pos": (None,),
            "x_prev": (b_ax, None),
            "state": (b_ax, None, None, None),
            "conv": (b_ax, None, "model"),
            "h": (b_ax, "model", None),
        }.get(name)
        if base is None:
            base = (b_ax,) + (None,) * (x.ndim - 1)
        extra = x.ndim - len(base)
        spec = (None,) * extra + tuple(base)  # leading scan dim(s) replicated
        return named(mesh, *spec)

    return jax.tree_util.tree_map_with_path(spec_for, abstract_caches)
