"""Deterministic, seeded fault injection at every seam of the serving stack.

The paper's Theorem 1 gives every failure of the learning plane a principled
degrade target: the improved answer is *in expectation* at least as accurate
as the plain sample estimate, so when any part of the synopsis machinery is
unhealthy the engine can always fall back to the raw AQP answer and keep the
error bound honest. This module is the test harness for that contract — a
registry of **named injection points** at the seams where real deployments
fail, plus a seeded plan that fires faults deterministically so chaos runs
are reproducible bit for bit.

Injection points (``POINTS``):

==================  =========================================================
``ingest.apply``    top of ``Synopsis._apply_add`` — a failed covariance
                    build / inverse update on the background ingest thread
                    (quarantines the synopsis; serving degrades to raw).
``scan.eval``       ``ScanPlacement.eval_block`` — a failed block eval /
                    kernel dispatch (the ``AqpService`` bisect-retry seam).
``store.drain``     ``Synopsis.drain`` — a failed ingest barrier, per shard
                    for ``ShardedSynopsisStore`` (quarantines the synopsis,
                    never the whole store).
``checkpoint.write``  ``CheckpointManager._write`` — a torn/failed shard
                    write (async failures surface on the next ``wait``).
``checkpoint.read``   ``CheckpointManager._read_step`` — a corrupt shard
                    read (restore falls back to an earlier intact step).
==================  =========================================================

Hot-path contract: ``fire(point)`` with no active plan is ONE module-global
load and an ``is None`` check — zero allocations, no locks, no dict lookups
— so the hooks can live on the serving hot path permanently (gated by the
``faults/hooks_inactive`` metric in ``benchmarks/check_regression.py``
alongside the scan/improve regression gates).

Determinism: every spec decides from its OWN counter (per ``(point, key)``)
— an explicit ``hits`` schedule and/or a seeded per-spec Bernoulli stream —
never from wall clock or global call order across keys, so a chaos run with
a fixed seed fires the same faults at the same call indices every time, even
with per-synopsis ingest threads interleaving arbitrarily (each synopsis'
apply order is FIFO, hence its per-key counter is deterministic).

Usage::

    from repro.ft import faults

    with faults.inject(faults.FaultSpec("ingest.apply", key="agg0-measure0",
                                        hits=(1,)), seed=7):
        ...  # the 2nd apply on that synopsis raises InjectedFault

    faults.stats()  # {"ingest.apply": {"calls": 5, "fires": 1}, ...}
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

POINTS = (
    "ingest.apply",
    "scan.eval",
    "store.drain",
    "checkpoint.write",
    "checkpoint.read",
)


class InjectedFault(RuntimeError):
    """The typed failure every injection raises — callers can tell injected
    chaos from organic bugs, and the degraded-path telemetry carries the
    point name."""

    def __init__(self, point: str, key: Optional[str], hit: int):
        super().__init__(f"injected fault at {point}"
                         + (f"[{key}]" if key else "") + f" (hit {hit})")
        self.point = point
        self.key = key
        self.hit = hit


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One armed injection point.

    point:     a name from ``POINTS``.
    key:       optional key filter — ``None`` matches every call at the
               point; a string matches only calls fired with that key
               (e.g. a ``state_key`` like ``"agg0-measure0"``), which is
               what makes multi-threaded ingest chaos deterministic.
    hits:      explicit 0-based per-(point, key) call indices that fire.
    rate:      Bernoulli fire probability per call (seeded, per-spec
               stream; composes with ``hits``).
    max_fires: stop firing after this many (transient-fault modeling;
               ``None`` = unbounded).
    """

    point: str
    key: Optional[str] = None
    hits: Tuple[int, ...] = ()
    rate: float = 0.0
    max_fires: Optional[int] = None

    def __post_init__(self):
        if self.point not in POINTS:
            raise ValueError(
                f"unknown injection point {self.point!r}; known: {POINTS}")
        object.__setattr__(self, "hits", tuple(int(h) for h in self.hits))


class FaultPlan:
    """A seeded set of specs plus the mutable counters of one chaos run.

    The plan owns all bookkeeping so ``activate``/``deactivate`` swap whole
    runs atomically and ``stats()`` reads one object. Thread-safe: counters
    mutate under one lock (only reached when a plan is active).
    """

    def __init__(self, specs: Sequence[FaultSpec], seed: int = 0):
        self.specs = tuple(specs)
        self.seed = int(seed)
        self._lock = threading.Lock()
        # Per-spec seeded streams: independent of call interleaving across
        # specs, so one spec's draws never perturb another's.
        self._rngs = [
            np.random.default_rng((self.seed, i)) for i in range(len(specs))
        ]
        self._fires_per_spec = [0] * len(specs)
        self._counters: Dict[Tuple[str, Optional[str]], int] = {}
        self.calls: Dict[str, int] = {}
        self.fires: Dict[str, int] = {}

    def check(self, point: str, key: Optional[str]):
        """Count one call; raise ``InjectedFault`` if any spec fires."""
        with self._lock:
            self.calls[point] = self.calls.get(point, 0) + 1
            ck = (point, key)
            hit = self._counters.get(ck, 0)
            self._counters[ck] = hit + 1
            for i, spec in enumerate(self.specs):
                if spec.point != point:
                    continue
                if spec.key is not None and spec.key != key:
                    continue
                if (spec.max_fires is not None
                        and self._fires_per_spec[i] >= spec.max_fires):
                    continue
                fire = hit in spec.hits
                if not fire and spec.rate > 0.0:
                    fire = bool(self._rngs[i].random() < spec.rate)
                if fire:
                    self._fires_per_spec[i] += 1
                    self.fires[point] = self.fires.get(point, 0) + 1
                    raise InjectedFault(point, key, hit)


# The one module global the disabled fast path reads. ``None`` ⇔ inactive.
_PLAN: Optional[FaultPlan] = None


def fire(point: str, key: Optional[str] = None) -> None:
    """Injection hook — call at a seam; no-op unless a plan is active.

    The disabled path is intentionally the first two lines: one global load
    and an ``is None`` test, so leaving hooks on production seams costs
    nothing (see module docstring).
    """
    plan = _PLAN
    if plan is None:
        return
    plan.check(point, key)


def active() -> bool:
    """Whether a fault plan is currently armed."""
    return _PLAN is not None


def activate(plan: FaultPlan) -> FaultPlan:
    """Arm a plan (replacing any active one); returns it for chaining."""
    global _PLAN
    _PLAN = plan
    return plan


def deactivate() -> Optional[FaultPlan]:
    """Disarm; returns the plan that was active (its stats stay readable)."""
    global _PLAN
    plan, _PLAN = _PLAN, None
    return plan


@contextlib.contextmanager
def inject(*specs: FaultSpec, seed: int = 0):
    """Scoped chaos: arm a seeded plan for the ``with`` body, yield it."""
    plan = activate(FaultPlan(specs, seed=seed))
    try:
        yield plan
    finally:
        if _PLAN is plan:
            deactivate()


def stats() -> Dict[str, dict]:
    """Per-point ``{"calls": n, "fires": k}`` of the active plan (``{}``
    when disarmed — the shape ``Session.stats()["health"]`` surfaces)."""
    plan = _PLAN
    if plan is None:
        return {}
    with plan._lock:
        return {
            point: {"calls": plan.calls.get(point, 0),
                    "fires": plan.fires.get(point, 0)}
            for point in sorted(set(plan.calls) | set(plan.fires))
        }
