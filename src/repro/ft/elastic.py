"""Elastic re-scale: resume a run on a different mesh.

Checkpoints store host (global) arrays; restoring with the *new* mesh's
sharding tree re-lays the state out — no format migration. The pieces:

  - ``reshard(tree, shardings)``: device_put onto new NamedShardings.
  - ``rescale_plan(old_shape, new_shape)``: validates that the model axis is
    unchanged (TP degree is baked into padded head counts) and that the
    global batch stays divisible; data-parallel size may grow/shrink freely
    (the data pipeline re-slices by new process/topology, see repro.data).
"""
from __future__ import annotations

from typing import Dict

import jax


def reshard(tree, shardings):
    return jax.tree.map(
        lambda x, s: jax.device_put(jax.device_get(x), s), tree, shardings)


def rescale_plan(old_mesh_shape: Dict[str, int], new_mesh_shape: Dict[str, int],
                 global_batch: int) -> Dict:
    if old_mesh_shape.get("model") != new_mesh_shape.get("model"):
        raise ValueError(
            "elastic rescale keeps the model axis fixed "
            f"({old_mesh_shape.get('model')} -> {new_mesh_shape.get('model')}): "
            "head/vocab padding is TP-degree dependent")
    new_dp = new_mesh_shape.get("data", 1) * new_mesh_shape.get("pod", 1)
    if global_batch % new_dp:
        raise ValueError(f"global batch {global_batch} not divisible by new "
                         f"data parallelism {new_dp}")
    return {
        "new_data_parallel": new_dp,
        "per_replica_batch": global_batch // new_dp,
    }
