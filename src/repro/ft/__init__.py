from repro.ft.checkpoint import CheckpointManager
