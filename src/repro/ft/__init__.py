"""Fault tolerance: checkpointing + deterministic fault injection.

``CheckpointManager`` persists the learned synopses (atomic commits,
per-shard checksums, fallback to the newest intact step); ``faults`` is the
seeded fault-injection registry whose named points the degraded-mode
serving path is tested against (see ``repro.ft.faults``).
"""
from repro.ft import faults
from repro.ft.checkpoint import CheckpointManager

__all__ = ["CheckpointManager", "faults"]
