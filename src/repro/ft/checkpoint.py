"""Sharded, atomic, async-capable checkpointing (no orbax offline).

Layout:  <dir>/step_<n>/
             manifest.json          tree structure, shapes, dtypes, step
             shard_<p>.npz          arrays owned by process p (np.savez)
             COMMITTED              empty marker written last (atomic rename)

- Writes go to ``step_<n>.tmp`` then a single ``os.rename`` commits — a
  killed writer never leaves a half-readable checkpoint.
- Integrity: the manifest records a CRC32 per shard file; reads verify it
  and a corrupt (or unreadable) step FALLS BACK to the newest intact
  earlier committed step with a warning instead of crashing — restore
  degrades to older knowledge, never to no knowledge. (Checkpoints written
  before checksums existed load unverified.)
- ``save_async`` snapshots to host memory synchronously (jax.device_get) and
  does the file I/O on a daemon thread, overlapping with the next step. A
  failed async write is NOT dropped on the daemon thread: it re-raises on
  the next ``wait()``/``save``/``save_async``.
- Fault-injection seams (``repro.ft.faults``): ``checkpoint.write`` fires
  before the shard write (a torn write: tmp dir, no COMMITTED marker —
  invisible to readers), ``checkpoint.read`` per step read (exercises the
  fallback walk).
- Restore validates the manifest against the target pytree structure and
  ``device_put``s with the *target's* shardings, so restoring onto a
  different mesh (elastic re-scale) is the same code path (see
  repro.ft.elastic).
- The Verdict query synopsis (a few MB, data-size-oblivious — paper §2) rides
  along in every checkpoint under the 'synopsis' key when provided. Store
  snapshots (``SynopsisStore.state_dict``) are structured-key
  (``"agg<k>-measure<m>"``) nested dicts with a per-entry ``shard`` tag;
  ``restore_blind`` hands them back verbatim and the loading store re-places
  each key by its own policy, so a checkpoint written under one mesh shape
  restores onto any other (or onto the local store) unchanged.
"""
from __future__ import annotations

import io
import json
import os
import shutil
import threading
import warnings
import zlib
from typing import Any, Optional

import jax
import numpy as np

from repro.ft import faults

_SEP = "/"


class CheckpointCorruptError(RuntimeError):
    """A committed step failed checksum/readback verification."""


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._async_exc: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()  # serialize with any in-flight async save
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._write(step, host_tree, extra or {})

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def run():
            try:
                self._write(step, host_tree, extra or {})
            except BaseException as e:  # noqa: BLE001 — surfaced on wait()
                self._async_exc = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        """Join any in-flight async save; re-raise its failure here.

        A failed background write must never vanish on the daemon thread —
        the NEXT synchronization point (``wait``/``save``/``save_async``)
        raises it, so callers learn a step is missing before relying on it.
        """
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        exc, self._async_exc = self._async_exc, None
        if exc is not None:
            raise RuntimeError("async checkpoint save failed") from exc

    def _write(self, step: int, host_tree, extra: dict):
        flat, _ = _flatten(host_tree)
        proc = jax.process_index()
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        # Injected torn write: tmp dir exists, no COMMITTED marker, never
        # renamed — invisible to all_steps()/readers by construction.
        faults.fire("checkpoint.write", key=f"step_{step}")
        shard_name = f"shard_{proc}.npz"
        np.savez(os.path.join(tmp, shard_name),
                 **{k: v for k, v in flat.items()})
        with open(os.path.join(tmp, shard_name), "rb") as f:
            crc = zlib.crc32(f.read())
        manifest = {
            "step": step,
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                     for k, v in flat.items()},
            "extra": extra,
            "n_processes": jax.process_count(),
            "checksums": {shard_name: crc},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        open(os.path.join(tmp, "COMMITTED"), "w").close()
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, name)
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and os.path.exists(os.path.join(full, "COMMITTED")):
                out.append(int(name[5:]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_step(self, step: int):
        """Load ONE committed step, verifying per-shard checksums."""
        faults.fire("checkpoint.read", key=f"step_{step}")
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        # Absent in checkpoints written before integrity checking: load
        # unverified rather than refuse old knowledge.
        checksums = manifest.get("checksums", {})
        data = {}
        for p in range(manifest["n_processes"]):
            shard_name = f"shard_{p}.npz"
            with open(os.path.join(path, shard_name), "rb") as f:
                raw = f.read()
            want = checksums.get(shard_name)
            if want is not None and zlib.crc32(raw) != int(want):
                raise CheckpointCorruptError(
                    f"checksum mismatch in {path}/{shard_name}")
            with np.load(io.BytesIO(raw)) as z:
                for k in z.files:
                    data[k] = z[k]
        return data, manifest

    def _read_step(self, step: Optional[int]):
        """Newest intact committed step ≤ ``step`` (or newest overall).

        A corrupt/unreadable step WARNS and falls back to the next-newest
        committed step — restore degrades to older knowledge rather than
        crashing (the synopsis is an accelerator, not the source of truth).
        Raises only when no intact step remains.
        """
        steps = self.all_steps()
        candidates = [s for s in reversed(steps)
                      if step is None or s <= step]
        if not candidates:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        last_exc: Optional[BaseException] = None
        for s in candidates:
            try:
                return self._load_step(s)
            except Exception as e:  # noqa: BLE001 — walk back, warn
                last_exc = e
                warnings.warn(
                    f"checkpoint step {s} unreadable ({e!r}); falling back "
                    f"to an earlier committed step",
                    RuntimeWarning, stacklevel=2,
                )
        raise CheckpointCorruptError(
            f"no intact committed checkpoint in {self.dir} "
            f"(last error: {last_exc!r})")

    def restore_blind(self, step: Optional[int] = None):
        """Restore without a target pytree: nested dicts straight from the
        manifest key paths, leaves as host numpy arrays.

        This is how structure-bearing state whose shapes are unknown before
        restore comes back — e.g. the Verdict synopsis snapshots
        (``VerdictEngine.load_synopses``), whose per-synopsis row counts are
        a property of what past sessions learned. Returns (tree, extra).
        """
        data, manifest = self._read_step(step)
        tree: dict = {}
        for key, arr in data.items():
            node = tree
            parts = key.split(_SEP)
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = arr
        return tree, manifest["extra"]

    def restore(self, target: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of ``target``; returns (tree, extra).

        ``shardings``: optional tree of NamedShardings (defaults to the
        target leaves' shardings when they are jax Arrays) — re-sharding onto
        a different mesh happens here via device_put.
        """
        data, manifest = self._read_step(step)
        flat_t, treedef = _flatten(target)
        missing = set(flat_t) - set(data)
        if missing:
            raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
        if shardings is not None:
            flat_s, _ = _flatten(shardings)
        else:
            flat_s = {k: getattr(v, "sharding", None) for k, v in flat_t.items()}
        restored = {}
        for k, leaf in flat_t.items():
            arr = data[k]
            want_dtype = getattr(leaf, "dtype", arr.dtype)
            arr = arr.astype(want_dtype)
            s = flat_s.get(k)
            restored[k] = jax.device_put(arr, s) if s is not None else jax.numpy.asarray(arr)
        leaves = [restored[k] for k, _ in _flatten(target)[0].items()]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, manifest["extra"]
