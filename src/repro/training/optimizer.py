"""Optimizers built from scratch (optax is unavailable offline).

- ``adamw``: fp32 m/v state; the default for <=10B dense archs.
- ``adafactor``: factored second moment (row/col statistics for >=2D params),
  no momentum — state is O(rows+cols) instead of O(n). Default for the
  100B+ MoE archs where AdamW state (+8 bytes/param) would not fit a v5e pod
  (see DESIGN.md memory model).

Both return an ``Optimizer`` with pure ``init`` / ``update`` functions
suitable for pjit (state mirrors the parameter sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (new_params, new_state)
    name: str = "opt"


def adamw(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1**t
        bc2 = 1 - b2**t

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32) * (p.ndim >= 2)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "step": step}

    return Optimizer(init, update, "adamw")


def adafactor(eps=1e-30, clip_threshold=1.0, decay=0.8, weight_decay=0.0) -> Optimizer:
    """Factored RMS (Shazeer & Stern 2018), momentum-free."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def state_for(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "v": jax.tree.map(state_for, params,
                              is_leaf=lambda x: isinstance(x, (jax.Array, jax.ShapeDtypeStruct))),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        rho = 1.0 - t ** (-decay)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr = rho * s["vr"] + (1 - rho) * jnp.mean(g2, axis=-1)
                vc = rho * s["vc"] + (1 - rho) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                v_hat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
                u = g / jnp.sqrt(v_hat)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = rho * s["v"] + (1 - rho) * g2
                u = g / jnp.sqrt(v)
                new_s = {"v": v}
            # update clipping (RMS(u) <= clip_threshold)
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            u = u + weight_decay * p.astype(jnp.float32) * (p.ndim >= 2)
            new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            return new_p, new_s

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["v"])
        outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_v = jax.tree.unflatten(tdef, [o[1] for o in outs])
        return new_params, {"v": new_v, "step": step}

    return Optimizer(init, update, "adafactor")


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return lr
