"""Train step factory: microbatched gradient accumulation, mixed precision,
global-norm clipping, schedule — the pjit-able unit the launcher compiles.

The global batch arrives as (accum, micro_batch, seq): a lax.scan over the
leading axis accumulates fp32 gradients so the activation working set is one
microbatch deep (the standard memory/throughput trade at 4k-seq training),
then one optimizer step applies. With ``accum == 1`` the scan disappears.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ShardCtx
from repro.training.losses import lm_loss
from repro.training.optimizer import Optimizer


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: x * scale, tree), norm


def make_train_step(cfg, opt: Optimizer, sctx: ShardCtx = ShardCtx(), *,
                    accum: int = 1, clip_norm: float = 1.0,
                    loss_fn: Optional[Callable] = None,
                    grad_transform: Optional[Callable] = None):
    """Returns step(params, opt_state, batch, lr) -> (params, opt_state, metrics).

    batch leaves are shaped (accum, micro, ...); ``grad_transform`` hooks
    cross-pod gradient compression (repro.distributed.compression).
    """
    loss_fn = loss_fn or lm_loss

    def micro_loss(params, mb):
        return loss_fn(cfg, params, mb, sctx)

    def step(params, opt_state, batch, lr):
        if accum == 1:
            mb = jax.tree.map(lambda x: x[0], batch)
            loss, grads = jax.value_and_grad(micro_loss)(params, mb)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                g_acc, l_acc = carry
                loss, grads = jax.value_and_grad(micro_loss)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                return (g_acc, l_acc + loss), None

            (grads, loss_sum), _ = jax.lax.scan(body, (zeros, 0.0), batch)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
        if grad_transform is not None:
            grads = grad_transform(grads)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return step
