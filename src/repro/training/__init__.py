from repro.training.optimizer import adafactor, adamw
from repro.training.train_loop import make_train_step
