"""Training losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.common import ShardCtx


def lm_loss(cfg, params, batch, sctx: ShardCtx = ShardCtx()):
    """Next-token cross entropy. batch: {'tokens', 'labels', ['ctx'|'enc']}.

    labels == -1 positions are masked out.
    """
    ctx_tokens = batch.get("ctx")
    if cfg.enc_dec:
        enc_out = T.encode(cfg, params, batch["enc"], sctx)
        ctx_tokens = enc_out
    logits, _ = T.forward(cfg, params, batch["tokens"], sctx,
                          ctx_tokens=ctx_tokens, mode="train")
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels.clip(0)[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
