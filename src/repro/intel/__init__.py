"""Workload intelligence: the fourth serving plane (`repro.intel`).

The engine already learns across queries at the *model* level (synopses);
this package learns across queries at the *workload* level, closing the
"database that becomes smarter every time" loop at serving:

- ``cache``: a plan-IR-keyed semantic answer cache with subsumption lookup
  and error-budget-aware staleness (generation counters threaded from
  ``Synopsis``);
- ``router``: a per-query serve-path router (cache / synopsis improve /
  full scan) with a deterministic online cost model, plus the learned
  bucket-ladder floors replacing the static ``EngineConfig`` minimums;
- ``telemetry``: the hit/miss/subsumption/staleness/route counters behind
  ``Session.stats()["intel"]`` and ``explain()``.

``WorkloadIntel`` bundles the three and is what
``repro.verdict.connect(cache=...)`` attaches to the engine
(``VerdictEngine.intel``). Everything here is strictly additive: with no
intel plane attached (the default) the engine behaves bit-for-bit as
before, and cache-miss answers are bitwise-identical to the cache-disabled
engine (pinned by ``tests/test_intel.py``).

Determinism (analysis rule A007): no wall-clock and no RNG anywhere in
cache-key or router-feature derivation — keys persist across processes and
route decisions replay deterministically.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple

import numpy as np

from repro.intel.cache import AnswerCache, CacheEntry, QuerySignature
from repro.intel.router import RouterConfig, ServeRouter
from repro.intel.telemetry import IntelTelemetry

__all__ = [
    "AnswerCache",
    "CacheEntry",
    "IntelConfig",
    "IntelTelemetry",
    "QuerySignature",
    "RouterConfig",
    "ServeRouter",
    "WorkloadIntel",
]


@dataclasses.dataclass
class IntelConfig:
    capacity: int = 256  # answer-cache entries (LRU beyond this)
    subsumption: bool = True
    router: RouterConfig = dataclasses.field(default_factory=RouterConfig)


class WorkloadIntel:
    """The workload-intelligence plane of one engine (cache+router+counters).

    Single-threaded like the engine's serve path; attach one instance per
    engine (``repro.verdict.connect(cache=True)``).
    """

    def __init__(self, config: Optional[IntelConfig] = None):
        self.config = config or IntelConfig()
        self.telemetry = IntelTelemetry()
        self.cache = AnswerCache(capacity=self.config.capacity,
                                 subsumption=self.config.subsumption)
        self.router = ServeRouter(self.config.router)

    # ------------------------------------------------------------- serving
    @staticmethod
    def _budget(engine, stop_delta, max_batches) -> Tuple[float, int]:
        delta = (engine.config.report_delta if stop_delta is None
                 else float(stop_delta))
        eff = min(max_batches or engine.batches.n_batches,
                  engine.batches.n_batches)
        return delta, eff

    def lookup(self, engine, query, target_rel_error: Optional[float] = None,
               stop_delta: Optional[float] = None,
               max_batches: Optional[int] = None,
               tenant: Optional[str] = None):
        """Serve ``query`` from the answer cache, or None (execute it).

        ``tenant``: optional label (the serving front's per-tenant
        namespace) — counted in ``telemetry.per_tenant`` so a shared cache
        still reports per-tenant hit rates."""
        sig = QuerySignature.from_query(engine.schema, query)
        if sig is None:
            self.telemetry.lookups += 1
            self.telemetry.misses += 1
            self.telemetry.uncacheable += 1
            if tenant is not None:
                self.telemetry.record_tenant(tenant, hit=False)
            return None
        delta, eff = self._budget(engine, stop_delta, max_batches)
        res = self.cache.lookup(engine.store, sig, target_rel_error, delta,
                                eff, telemetry=self.telemetry)
        if res is not None:
            self.telemetry.record_route("cache")
        if tenant is not None:
            self.telemetry.record_tenant(tenant, hit=res is not None)
        return res

    def peek(self, engine, query, target_rel_error: Optional[float] = None,
             stop_delta: Optional[float] = None,
             max_batches: Optional[int] = None,
             lp=None) -> Tuple[str, str]:
        """Read-only (status, route) prediction for ``explain()`` — no
        counters, no LRU movement, no probe-streak mutation."""
        sig = QuerySignature.from_query(engine.schema, query)
        if sig is None:
            return "uncacheable", "scan"
        delta, eff = self._budget(engine, stop_delta, max_batches)
        res = self.cache.lookup(engine.store, sig, target_rel_error, delta,
                                eff, mutate=False)
        if res is not None:
            status = ("exact" if res.served_from == "cache:exact"
                      else "subsumed")
            return status, "cache"
        if lp is None or not lp.supported or lp.plan is None:
            return "miss", "scan" if target_rel_error is None else "improve"
        return "miss", self.router.predict_route(
            engine, lp, target_rel_error, eff)

    def choose_route(self, engine, lp, target_rel_error: Optional[float],
                     max_batches: int) -> str:
        return self.router.choose_route(engine, lp, target_rel_error,
                                        max_batches)

    def observe(self, engine, lp, res, target_rel_error: Optional[float],
                max_batches: int, route: str):
        """Final-round bookkeeping for one executed query: route counters,
        router statistics (and the periodic ladder application), and the
        answer-cache insert. Runs right after ``store.record`` in the plan
        lifecycle, so the generation snapshot includes the answer's own
        ingest bump."""
        self.telemetry.record_route(route)
        if lp.supported:
            self.router.observe(engine, lp, res, target_rel_error, route)
            sig = QuerySignature.from_query(engine.schema, lp.query)
            if sig is not None:
                self.cache.insert(engine.store, sig, lp, res,
                                  target_rel_error, max_batches,
                                  telemetry=self.telemetry)

    # -------------------------------------------------------------- persist
    def state_dict(self, store) -> dict:
        """One ``"blob"`` uint8 array (canonical JSON) — rides the same
        np.savez + CRC checkpoint payload the synopses use."""
        payload = {
            "cache": self.cache.state_dict(store),
            "router": self.router.state_dict(),
            "telemetry": self.telemetry.state_dict(),
        }
        raw = json.dumps(payload, sort_keys=True).encode()
        return {"blob": np.frombuffer(raw, dtype=np.uint8)}

    def load_state_dict(self, state: dict, store):
        blob = np.asarray(state["blob"], dtype=np.uint8)
        payload = json.loads(bytes(blob).decode())
        self.cache.load_state_dict(payload.get("cache", {}), store)
        self.router.load_state_dict(payload.get("router", {}))
        self.telemetry.load_state_dict(payload.get("telemetry", {}))

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        out = self.telemetry.as_dict()
        out["enabled"] = True
        out["entries"] = len(self.cache)
        out["capacity"] = self.cache.capacity
        out["router"] = self.router.stats()
        return out
