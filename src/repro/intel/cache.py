"""Plan-IR-keyed semantic answer cache with subsumption serving.

The paper's thesis — past answers make future queries cheaper — stops at the
synopsis in the core engine: a repeated query still pays plan → scan →
improve. This cache closes the loop at the *answer* level, VerdictDB-style:
the final ``QueryResult`` of every supported query is stored under a
canonical key derived from the same logical-plan content the snippet
interner dedups on, and later queries that are semantically equal (or
subsumed, see below) are served without scanning at all.

Cache key derivation
    ``QuerySignature.from_query`` canonicalizes a query through
    ``predicates_to_arrays`` — the SAME canonical predicate-box form the
    snippet decomposition uses — so commutative conjunctions, reordered
    ``one_of`` sets, duplicated predicates and explicit-full-range spellings
    all produce one signature. The key is a BLAKE2b digest of the
    signature's canonical JSON: deterministic across processes (never
    Python's salted ``hash()``), so persisted caches rehydrate onto the same
    keys. No wall-clock, no RNG (analysis rule A007).

Subsumption rule (servable from cached entry C for new query N)
    - identical aggregate list; C recorded no truncated groups;
    - numeric boxes equal per-dimension within ``RANGE_EPS`` (the scan
      plane's single predicate epsilon — a bound within eps of a cached
      bound selects the same tuples by construction of ``predicate_mask``);
    - on non-grouped categorical dims, identical constraint sets;
    - ``N.groupby`` is an order-preserving subsequence of ``C.groupby``;
      every dim C grouped by that N dropped must be pinned to a single
      value in N, and N's sets on grouped dims must be subsets of C's.
    The served cells are then literally C's recorded cells, filtered to N's
    member groups and projected onto N's group-by dims — "exactly
    reproducible from the recorded cached cells" is true by construction.

Staleness semantics (error-budget-aware invalidation)
    Every entry snapshots the ``Synopsis.generation`` of each aggregate key
    it touched (bumped synchronously on ingest/quarantine/heal/refit).
    A quarantined key always refuses. A fresh entry serves when the
    caller's budget is satisfied. A staleness-bumped entry serves ONLY to
    callers with an explicit ``target_rel_error`` whose recorded CI still
    meets it — the error budget licenses bounded staleness; full-accuracy
    callers (no target) get a miss and a fresh answer.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.aqp import queries as Q
from repro.aqp.plan import QueryResult
from repro.core.store import group_rows, parse_state_key, state_key
from repro.kernels import RANGE_EPS
from repro.utils.stats import confidence_multiplier

from repro.intel.telemetry import IntelTelemetry


@dataclasses.dataclass(frozen=True)
class QuerySignature:
    """Canonical content of one supported query (the cache-key basis).

    ``num_lo``/``num_hi`` carry the full per-dimension box (schema bounds
    where unconstrained), ``cat_sets`` the full sorted member set per
    categorical dim — so syntactically different spellings of one predicate
    conjunction collapse to one signature.
    """

    aggs: Tuple[Tuple[str, int], ...]  # (kind, measure; -1 when irrelevant)
    groupby: Tuple[int, ...]
    num_lo: Tuple[float, ...]
    num_hi: Tuple[float, ...]
    cat_sets: Tuple[Tuple[int, ...], ...]

    @staticmethod
    def from_query(schema, q: Q.AggQuery) -> Optional["QuerySignature"]:
        """Canonical signature, or None when the query is uncacheable
        (unsupported constructs never enter the cache — they serve raw)."""
        if Q.unsupported_reason(q) is not None:
            return None
        num_ranges, cat_sets = Q.predicates_to_arrays(schema, q.predicates)
        aggs = tuple(
            (a.kind, -1 if (a.measure is None or a.kind == "COUNT")
             else int(a.measure))
            for a in q.aggs
        )
        num_lo, num_hi = [], []
        for d in range(schema.n_num):
            lo, hi = num_ranges.get(d, (schema.num_lo[d], schema.num_hi[d]))
            num_lo.append(float(lo))
            num_hi.append(float(hi))
        cats = tuple(
            tuple(int(v) for v in cat_sets.get(d, range(schema.cat_sizes[d])))
            for d in range(schema.n_cat)
        )
        return QuerySignature(
            aggs=aggs,
            groupby=tuple(int(d) for d in q.groupby),
            num_lo=tuple(num_lo),
            num_hi=tuple(num_hi),
            cat_sets=cats,
        )

    def to_jsonable(self) -> list:
        return [list(map(list, self.aggs)), list(self.groupby),
                list(self.num_lo), list(self.num_hi),
                [list(s) for s in self.cat_sets]]

    @staticmethod
    def from_jsonable(obj) -> "QuerySignature":
        aggs, groupby, lo, hi, cats = obj
        return QuerySignature(
            aggs=tuple((str(k), int(m)) for k, m in aggs),
            groupby=tuple(int(d) for d in groupby),
            num_lo=tuple(float(v) for v in lo),
            num_hi=tuple(float(v) for v in hi),
            cat_sets=tuple(tuple(int(v) for v in s) for s in cats),
        )

    def digest(self) -> str:
        payload = json.dumps(self.to_jsonable(), separators=(",", ":"))
        return hashlib.blake2b(payload.encode(), digest_size=16).hexdigest()


@dataclasses.dataclass
class CacheEntry:
    """One recorded final answer plus the state snapshot that licensed it."""

    key: str
    sig: QuerySignature
    cells: Tuple[dict, ...]
    batches_used: int
    tuples_scanned: int
    truncated_groups: int
    gens: Tuple[Tuple[str, int], ...]  # (state_key, generation) at record
    target: Optional[float]  # error budget it was recorded under
    max_batches: int  # effective batch budget at record time
    hits: int = 0

    def to_jsonable(self) -> dict:
        return {
            "key": self.key,
            "sig": self.sig.to_jsonable(),
            "cells": [dict(c, group=list(c["group"])) for c in self.cells],
            "batches_used": self.batches_used,
            "tuples_scanned": self.tuples_scanned,
            "truncated_groups": self.truncated_groups,
            "gens": [[n, g] for n, g in self.gens],
            "target": self.target,
            "max_batches": self.max_batches,
            "hits": self.hits,
        }

    @staticmethod
    def from_jsonable(obj: dict) -> "CacheEntry":
        return CacheEntry(
            key=str(obj["key"]),
            sig=QuerySignature.from_jsonable(obj["sig"]),
            cells=tuple(
                dict(c, group=tuple(int(v) for v in c["group"]))
                for c in obj["cells"]
            ),
            batches_used=int(obj["batches_used"]),
            tuples_scanned=int(obj["tuples_scanned"]),
            truncated_groups=int(obj["truncated_groups"]),
            gens=tuple((str(n), int(g)) for n, g in obj["gens"]),
            target=None if obj["target"] is None else float(obj["target"]),
            max_batches=int(obj["max_batches"]),
            hits=int(obj["hits"]),
        )


def _max_rel_error(cells, delta: float) -> float:
    alpha = float(confidence_multiplier(delta))
    worst = 0.0
    for c in cells:
        denom = max(abs(c["estimate"]), 1e-9)
        worst = max(worst, alpha * float(np.sqrt(c["beta2"])) / denom)
    return worst


class AnswerCache:
    """LRU semantic answer cache (see module docstring for the contracts)."""

    def __init__(self, capacity: int = 256, subsumption: bool = True):
        self.capacity = int(capacity)
        self.subsumption = bool(subsumption)
        self._entries: "OrderedDict[str, CacheEntry]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    # ------------------------------------------------------------ freshness
    @staticmethod
    def _freshness(store, entry: CacheEntry) -> Tuple[bool, bool]:
        """(stale, quarantined) of an entry against the live store."""
        stale = quarantined = False
        for name, gen in entry.gens:
            key = parse_state_key(name)
            syn = store.get(key)
            if syn is not None and syn.quarantined:
                quarantined = True
            if store.generation(key) != gen:
                stale = True
        return stale, quarantined

    def _admit(self, store, entry: CacheEntry, cells,
               target: Optional[float], delta: float, max_batches: int,
               refusals: set) -> Tuple[bool, bool]:
        """Serve decision for candidate ``cells`` of ``entry``.

        Returns (serve, stale_served). Collects refusal reasons into
        ``refusals`` ("quarantine" | "stale" | "budget") for telemetry.
        """
        stale, quarantined = self._freshness(store, entry)
        if quarantined:
            refusals.add("quarantine")
            return False, False
        if target is None:
            # Full-accuracy caller: only a fresh entry recorded under the
            # same full batch budget reproduces what the engine would
            # compute now.
            if stale:
                refusals.add("stale")
                return False, False
            if entry.target is not None or entry.batches_used != max_batches:
                refusals.add("budget")
                return False, False
            return True, False
        if _max_rel_error(cells, delta) > target:
            refusals.add("budget")
            if stale:
                refusals.add("stale")
            return False, False
        return True, stale

    # --------------------------------------------------------- subsumption
    @staticmethod
    def _subsumed_cells(entry: CacheEntry,
                        sig: QuerySignature) -> Optional[List[dict]]:
        """C=entry.sig's recorded cells filtered/projected for N=sig, or
        None when N is not servable from C (see module docstring)."""
        c = entry.sig
        if sig.aggs != c.aggs or entry.truncated_groups > 0:
            return None
        for nl, nh, cl, ch in zip(sig.num_lo, sig.num_hi, c.num_lo, c.num_hi):
            if abs(nl - cl) > RANGE_EPS or abs(nh - ch) > RANGE_EPS:
                return None
        grouped = set(c.groupby)
        for d in range(len(sig.cat_sets)):
            if d in grouped:
                if not set(sig.cat_sets[d]) <= set(c.cat_sets[d]):
                    return None
            elif sig.cat_sets[d] != c.cat_sets[d]:
                return None
        # N.groupby must be an order-preserving subsequence of C.groupby,
        # with every dropped grouped dim pinned to a single value in N
        # (AVG cells cannot be merged, only selected).
        it = iter(c.groupby)
        if not all(d in it for d in sig.groupby):
            return None
        dropped = [d for d in c.groupby if d not in set(sig.groupby)]
        if any(len(sig.cat_sets[d]) != 1 for d in dropped):
            return None
        gpos = {d: i for i, d in enumerate(c.groupby)}
        members = {d: set(sig.cat_sets[d]) for d in c.groupby}
        out = []
        for cell in entry.cells:
            gv = cell["group"]
            if all(gv[gpos[d]] in members[d] for d in c.groupby):
                out.append(dict(
                    cell,
                    group=tuple(gv[gpos[d]] for d in sig.groupby),
                ))
        return out or None

    # --------------------------------------------------------------- lookup
    @staticmethod
    def _result(entry: CacheEntry, cells, served_from: str,
                truncated: int) -> QueryResult:
        return QueryResult(
            cells=[dict(c, group=tuple(c["group"])) for c in cells],
            batches_used=entry.batches_used,
            tuples_scanned=entry.tuples_scanned,
            supported=True,
            truncated_groups=truncated,
            served_from=served_from,
        )

    def lookup(self, store, sig: QuerySignature, target: Optional[float],
               delta: float, max_batches: int,
               telemetry: Optional[IntelTelemetry] = None,
               mutate: bool = True) -> Optional[QueryResult]:
        """Serve ``sig`` from the cache, or None (a miss).

        ``mutate=False`` is the ``explain()`` peek: no counters, no LRU
        movement, no hit bookkeeping.
        """
        t = telemetry if (telemetry is not None and mutate) else None
        if t is not None:
            t.lookups += 1
        refusals: set = set()
        key = sig.digest()
        entry = self._entries.get(key)
        if entry is not None and entry.sig == sig:
            ok, stale_served = self._admit(
                store, entry, entry.cells, target, delta, max_batches,
                refusals)
            if ok:
                if t is not None:
                    t.hits_exact += 1
                    t.stale_served += int(stale_served)
                if mutate:
                    entry.hits += 1
                    self._entries.move_to_end(key)
                return self._result(entry, entry.cells, "cache:exact",
                                    entry.truncated_groups)
        if self.subsumption:
            for cand in self._entries.values():
                if cand.key == key:
                    continue
                cells = self._subsumed_cells(cand, sig)
                if cells is None:
                    continue
                ok, stale_served = self._admit(
                    store, cand, cells, target, delta, max_batches, refusals)
                if not ok:
                    continue
                if t is not None:
                    t.hits_subsumed += 1
                    t.stale_served += int(stale_served)
                if mutate:
                    cand.hits += 1
                    self._entries.move_to_end(cand.key)
                return self._result(cand, cells, "cache:subsumed", 0)
        if t is not None:
            t.misses += 1
            t.stale_refused += int("stale" in refusals)
            t.quarantine_refused += int("quarantine" in refusals)
            t.budget_refused += int("budget" in refusals)
        return None

    # --------------------------------------------------------------- insert
    def insert(self, store, sig: QuerySignature, lp, res,
               target: Optional[float], max_batches: int,
               telemetry: Optional[IntelTelemetry] = None):
        """Record a final engine answer (called from the plan lifecycle
        after ``store.record``, so the generation snapshot includes the
        answer's own ingest bump — a repeat is fresh, not self-stale)."""
        if (lp.plan is None or not res.supported or res.degraded
                or res.served_from is not None):
            return
        gens = tuple(
            (state_key(k), store.generation(k))
            for k, _ in group_rows(lp.plan.snippets)
        )
        cells = tuple(
            {
                "group": tuple(int(v) for v in c["group"]),
                "agg": int(c["agg"]),
                "kind": str(c["kind"]),
                "estimate": float(c["estimate"]),
                "beta2": float(c["beta2"]),
            }
            for c in res.cells
        )
        key = sig.digest()
        self._entries[key] = CacheEntry(
            key=key, sig=sig, cells=cells,
            batches_used=int(res.batches_used),
            tuples_scanned=int(res.tuples_scanned),
            truncated_groups=int(res.truncated_groups),
            gens=gens,
            target=None if target is None else float(target),
            max_batches=int(max_batches),
        )
        self._entries.move_to_end(key)
        if telemetry is not None:
            telemetry.insertions += 1
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            if telemetry is not None:
                telemetry.evictions += 1

    # -------------------------------------------------------------- persist
    def state_dict(self, store) -> dict:
        """JSON-serializable snapshot plus the store's per-key generations
        at save time (needed to re-license entries on restore)."""
        return {
            "entries": [e.to_jsonable() for e in self._entries.values()],
            "store_gens": {state_key(k): store.generation(k)
                           for k in store.keys()},
            "capacity": self.capacity,
            "subsumption": self.subsumption,
        }

    def load_state_dict(self, state: dict, store):
        """Restore, remapping generations onto the restored store.

        Generation counters restart per process, so raw restored gens would
        mark everything stale. An entry that was FRESH at save time (its
        gens matched the saved store gens) is remapped to the restored
        store's current generations — cache and store persist in one
        payload, so they are mutually consistent. Entries stale at save
        stay permanently stale (gen -1 never matches).
        """
        self.capacity = int(state.get("capacity", self.capacity))
        self.subsumption = bool(state.get("subsumption", self.subsumption))
        saved_gens = {str(k): int(v)
                      for k, v in dict(state.get("store_gens", {})).items()}
        self._entries = OrderedDict()
        for obj in state.get("entries", []):
            entry = CacheEntry.from_jsonable(obj)
            fresh = all(gen == saved_gens.get(name, 0)
                        for name, gen in entry.gens)
            entry.gens = tuple(
                (name,
                 store.generation(parse_state_key(name)) if fresh else -1)
                for name, _ in entry.gens
            )
            self._entries[entry.key] = entry
