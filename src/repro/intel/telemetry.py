"""Workload-intelligence counters (`Session.stats()["intel"]`).

One mutable counter block shared by the semantic answer cache
(``repro.intel.cache``) and the serve-path router (``repro.intel.router``):
every lookup resolves to exactly one of hit-exact / hit-subsumed / miss,
with the refusal sub-reasons (stale / quarantined / budget / uncacheable)
counted alongside so operators can see WHY a repeat query re-scanned.
Route decisions (cache / improve / scan) accumulate per route.

Determinism note (analysis rule A007): these are pure event counters —
no wall-clock, no RNG. Latency-flavoured metrics live in the benchmarks
(``benchmarks/cache_bench.py``), never in serve-path state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass
class IntelTelemetry:
    """Hit/miss/staleness/route counters for one ``WorkloadIntel`` plane.

    ``lookups`` counts every cache consult; a lookup lands in exactly one of
    ``hits_exact`` / ``hits_subsumed`` / ``misses``. The ``*_refused``
    counters sub-classify misses by refusal reason (one miss may carry
    several: e.g. an entry both stale and quarantined). ``stale_served``
    counts hits served from a staleness-bumped entry whose recorded CI still
    met the caller's explicit error budget (error-budget-licensed serving).
    """

    lookups: int = 0
    hits_exact: int = 0
    hits_subsumed: int = 0
    misses: int = 0
    stale_served: int = 0
    stale_refused: int = 0
    quarantine_refused: int = 0
    budget_refused: int = 0
    uncacheable: int = 0
    insertions: int = 0
    evictions: int = 0
    routes: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"cache": 0, "improve": 0, "scan": 0})

    @property
    def hits(self) -> int:
        return self.hits_exact + self.hits_subsumed

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.lookups, 1)

    def record_route(self, route: str):
        self.routes[route] = self.routes.get(route, 0) + 1

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hits"] = self.hits
        d["hit_rate"] = self.hit_rate
        return d

    def state_dict(self) -> dict:
        return dataclasses.asdict(self)

    def load_state_dict(self, state: dict):
        for f in dataclasses.fields(self):
            if f.name in state:
                val = state[f.name]
                setattr(self, f.name,
                        dict(val) if f.name == "routes" else int(val))
