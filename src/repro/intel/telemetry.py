"""Workload-intelligence counters (`Session.stats()["intel"]`).

One mutable counter block shared by the semantic answer cache
(``repro.intel.cache``) and the serve-path router (``repro.intel.router``):
every lookup resolves to exactly one of hit-exact / hit-subsumed / miss,
with the refusal sub-reasons (stale / quarantined / budget / uncacheable)
counted alongside so operators can see WHY a repeat query re-scanned.
Route decisions (cache / improve / scan) accumulate per route.

Determinism note (analysis rule A007): these are pure event counters —
no wall-clock, no RNG. Latency-flavoured metrics live in the benchmarks
(``benchmarks/cache_bench.py``), never in serve-path state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass
class IntelTelemetry:
    """Hit/miss/staleness/route counters for one ``WorkloadIntel`` plane.

    ``lookups`` counts every cache consult; a lookup lands in exactly one of
    ``hits_exact`` / ``hits_subsumed`` / ``misses``. The ``*_refused``
    counters sub-classify misses by refusal reason (one miss may carry
    several: e.g. an entry both stale and quarantined). ``stale_served``
    counts hits served from a staleness-bumped entry whose recorded CI still
    met the caller's explicit error budget (error-budget-licensed serving).

    ``per_tenant`` splits lookups/hits by the tenant label the serving
    front threads through (``AqpService(tenant=)`` /
    ``Session(tenant=)``) — the per-tenant hit-rate surface of
    ``ServingFront.stats()``. Unlabeled traffic is not counted here (the
    aggregate counters above already cover it).
    """

    lookups: int = 0
    hits_exact: int = 0
    hits_subsumed: int = 0
    misses: int = 0
    stale_served: int = 0
    stale_refused: int = 0
    quarantine_refused: int = 0
    budget_refused: int = 0
    uncacheable: int = 0
    insertions: int = 0
    evictions: int = 0
    routes: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"cache": 0, "improve": 0, "scan": 0})
    per_tenant: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=dict)

    @property
    def hits(self) -> int:
        return self.hits_exact + self.hits_subsumed

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.lookups, 1)

    def record_route(self, route: str):
        self.routes[route] = self.routes.get(route, 0) + 1

    def record_tenant(self, tenant: str, hit: bool):
        t = self.per_tenant.setdefault(tenant, {"lookups": 0, "hits": 0})
        t["lookups"] += 1
        t["hits"] += int(hit)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hits"] = self.hits
        d["hit_rate"] = self.hit_rate
        d["per_tenant"] = {
            name: dict(t, hit_rate=t["hits"] / max(t["lookups"], 1))
            for name, t in self.per_tenant.items()
        }
        return d

    def state_dict(self) -> dict:
        return dataclasses.asdict(self)

    def load_state_dict(self, state: dict):
        for f in dataclasses.fields(self):
            if f.name in state:
                val = state[f.name]
                if f.name == "routes":
                    val = dict(val)
                elif f.name == "per_tenant":
                    val = {str(k): {m: int(n) for m, n in dict(v).items()}
                           for k, v in dict(val).items()}
                else:
                    val = int(val)
                setattr(self, f.name, val)
