"""Learned per-query serve-path router (cache / improve / scan).

The cache is route "cache" and is decided by lookup; this module decides,
for queries that MUST execute, between the engine's two lifecycles:

- "improve": evaluate every sample batch round, improve + validate via the
  synopsis after each, early-stop once the improved bound meets the target
  (the engine's historical behavior under an error budget);
- "scan": skip the per-round improve/validate checks and evaluate the full
  batch budget in one final round (the engine's historical behavior without
  a target). Never violates the caller's budget — the full-budget answer is
  the most refined answer the engine can produce under it; what "scan"
  saves is per-round improve dispatches that were not going to stop early.

The choice is a deterministic cost model trained online from telemetry the
engine already emits — counters only, per analysis rule A007: no wall-clock,
no RNG anywhere in route-feature derivation. Costs are in abstract
"operand units":

    batch_cost   = tuples_per_batch × padded snippet count   (scan work)
    improve_cost = Σ_keys (q_bucket × fill_bucket² + fill_bucket²)
                                              (the GP serve matvec shapes)
    E[batches | fill bucket] = running mean of observed ``batches_used``
        of improve-routed targeted queries, bucketed by the largest fill
        bucket the query touches (optimistic 1.0 when unobserved, so the
        cold-start route is "improve" — exactly the pre-intel engine).

    route "improve"  iff  E[batches]×(batch_cost+improve_cost)
                          <= max_batches×batch_cost + improve_cost

A deterministic probe keeps the model honest: after ``probe_every``
consecutive "scan" decisions in one fill bucket, the next query routes
"improve" once so E[batches] keeps tracking a synopsis that got better.

The same observation stream drives the learned bucket-ladder floors (the
PR-4 carryover): the observed Q and fill distributions are histogrammed,
and every ``ladder_every`` observations the power-of-two bucket covering
the ``ladder_quantile`` of each distribution replaces the static
``EngineConfig(min_q_bucket=, min_fill_bucket=)`` floors — bitwise-safe
because bucket padding invariance is pinned (padding rows are masked out of
every product), so ladder moves change compile/cost, never answers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.store import group_rows
from repro.core.types import SNIPPET_TILE, bucket_size


@dataclasses.dataclass
class RouterConfig:
    route_switching: bool = True  # False: always "improve" under a target
    probe_every: int = 16  # forced improve-probe cadence per fill bucket
    ladder_every: int = 32  # observations between ladder applications
    ladder_quantile: float = 0.9
    max_ladder_bucket: int = 512
    learn_ladder: bool = True


class ServeRouter:
    """Online route chooser + ladder learner (see module docstring)."""

    def __init__(self, config: Optional[RouterConfig] = None):
        self.config = config or RouterConfig()
        # fill bucket -> [count, sum of batches_used] (improve-routed only)
        self._batches: Dict[int, list] = {}
        self._scan_streak: Dict[int, int] = {}
        # histograms for the learned ladder: value -> count
        self._q_hist: Dict[int, int] = {}
        self._fill_hist: Dict[int, int] = {}
        self.observations = 0
        self.learned_floors: Optional[Tuple[int, int]] = None  # (q, fill)

    # ------------------------------------------------------------ features
    @staticmethod
    def _features(engine, lp) -> Tuple[int, float, float]:
        """(max fill bucket, batch_cost, improve_cost) — all deterministic
        functions of plan + store occupancy (A007)."""
        tuples_per_batch = (
            sum(len(b) for b in engine.batches.batch_rows)
            / max(engine.batches.n_batches, 1)
        )
        n_pad = -(-lp.plan.snippets.n // SNIPPET_TILE) * SNIPPET_TILE
        batch_cost = tuples_per_batch * n_pad
        improve_cost = 0.0
        fill_bucket = 0
        for key, rows in group_rows(lp.plan.snippets):
            syn = engine.store.get(key)
            fb = syn._fill_bucket() if syn is not None and syn.n else 0
            qb = bucket_size(len(rows), engine.config.min_q_bucket)
            improve_cost += qb * fb * fb + fb * fb
            fill_bucket = max(fill_bucket, fb)
        return fill_bucket, batch_cost, improve_cost

    def _expected_batches(self, fill_bucket: int) -> float:
        stat = self._batches.get(fill_bucket)
        if not stat or not stat[0]:
            return 1.0  # optimistic: cold-start route is "improve"
        return stat[1] / stat[0]

    def predict_route(self, engine, lp, target: Optional[float],
                      max_batches: int) -> str:
        """Pure route prediction (no probe-streak mutation) — explain()."""
        if target is None:
            return "scan"
        if not self.config.route_switching or lp.plan is None:
            return "improve"
        fb, batch_cost, improve_cost = self._features(engine, lp)
        if fb == 0:
            return "improve"  # empty synopses: improve rounds are no-ops
        est = self._expected_batches(fb)
        improve_total = est * (batch_cost + improve_cost)
        scan_total = max_batches * batch_cost + improve_cost
        return "improve" if improve_total <= scan_total else "scan"

    def choose_route(self, engine, lp, target: Optional[float],
                     max_batches: int) -> str:
        route = self.predict_route(engine, lp, target, max_batches)
        if target is None or lp.plan is None:
            return route
        fb, _, _ = self._features(engine, lp)
        if route == "scan":
            streak = self._scan_streak.get(fb, 0) + 1
            if streak >= self.config.probe_every:
                # Deterministic exploration: periodically re-measure how
                # many batches the improve path actually needs now.
                route, streak = "improve", 0
            self._scan_streak[fb] = streak
        else:
            self._scan_streak[fb] = 0
        return route

    # ------------------------------------------------------------- observe
    def observe(self, engine, lp, res, target: Optional[float], route: str):
        if lp.plan is None:
            return
        fill_bucket = 0
        for key, rows in group_rows(lp.plan.snippets):
            q = len(rows)
            self._q_hist[q] = self._q_hist.get(q, 0) + 1
            syn = engine.store.get(key)
            n = syn.n if syn is not None else 0
            self._fill_hist[n] = self._fill_hist.get(n, 0) + 1
            fb = syn._fill_bucket() if syn is not None and syn.n else 0
            fill_bucket = max(fill_bucket, fb)
        if target is not None and route == "improve":
            stat = self._batches.setdefault(fill_bucket, [0, 0.0])
            stat[0] += 1
            stat[1] += float(res.batches_used)
        self.observations += 1
        if (self.config.learn_ladder
                and self.observations % self.config.ladder_every == 0):
            self.apply_ladder(engine)

    # -------------------------------------------------------------- ladder
    @staticmethod
    def _quantile(hist: Dict[int, int], q: float) -> int:
        total = sum(hist.values())
        if total == 0:
            return 0
        need = q * total
        seen = 0
        for value in sorted(hist):
            seen += hist[value]
            if seen >= need:
                return value
        return max(hist)

    def ladder(self) -> Tuple[int, int]:
        """Learned (min_q_bucket, min_fill_bucket) floors: the power-of-two
        bucket covering ``ladder_quantile`` of the observed distributions,
        clamped to [engine default minimum, max_ladder_bucket]."""
        cfg = self.config
        q90 = self._quantile(self._q_hist, cfg.ladder_quantile)
        f90 = self._quantile(self._fill_hist, cfg.ladder_quantile)
        cap = cfg.max_ladder_bucket
        return (min(bucket_size(q90), cap), min(bucket_size(f90), cap))

    def apply_ladder(self, engine):
        """Install the learned floors on the engine config (new synopses)
        and every live synopsis (serve-path tiles). Padding invariance
        makes this answer-preserving — only compiled bucket shapes move."""
        qf, ff = self.ladder()
        self.learned_floors = (qf, ff)
        engine.config.min_q_bucket = qf
        engine.config.min_fill_bucket = min(ff, engine.config.capacity)
        for key in list(engine.store.keys()):
            syn = engine.store.get(key)
            if syn is None:
                continue
            syn.min_q_bucket = qf
            syn.min_fill_bucket = min(ff, syn.capacity)

    # -------------------------------------------------------------- persist
    def state_dict(self) -> dict:
        return {
            "batches": {str(k): [int(v[0]), float(v[1])]
                        for k, v in self._batches.items()},
            "scan_streak": {str(k): int(v)
                            for k, v in self._scan_streak.items()},
            "q_hist": {str(k): int(v) for k, v in self._q_hist.items()},
            "fill_hist": {str(k): int(v) for k, v in self._fill_hist.items()},
            "observations": int(self.observations),
            "learned_floors": (list(self.learned_floors)
                               if self.learned_floors else None),
        }

    def load_state_dict(self, state: dict):
        self._batches = {int(k): [int(v[0]), float(v[1])]
                         for k, v in dict(state.get("batches", {})).items()}
        self._scan_streak = {
            int(k): int(v)
            for k, v in dict(state.get("scan_streak", {})).items()}
        self._q_hist = {int(k): int(v)
                        for k, v in dict(state.get("q_hist", {})).items()}
        self._fill_hist = {
            int(k): int(v)
            for k, v in dict(state.get("fill_hist", {})).items()}
        self.observations = int(state.get("observations", 0))
        lf = state.get("learned_floors")
        self.learned_floors = tuple(int(v) for v in lf) if lf else None

    def stats(self) -> dict:
        return {
            "observations": self.observations,
            "expected_batches": {
                fb: round(self._expected_batches(fb), 3)
                for fb in sorted(self._batches)},
            "learned_floors": self.learned_floors,
        }
