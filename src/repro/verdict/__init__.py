"""Public driver-level API for the Database Learning engine.

    import repro.verdict as vd

    session = vd.connect(relation, vd.EngineConfig(sample_rate=0.1))
    q = session.query().avg("v0").where(vd.between("x0", 2, 8)).group_by("c0")
    print(session.explain(q))
    answer = session.execute(q, vd.ErrorBudget(target_rel_error=0.02))
    for partial in session.stream(q):           # online aggregation
        print(partial.max_rel_error(), partial.final)

See ``repro.verdict.session`` for the Session surface and the README's
"Session API" section for the migration notes from raw ``VerdictEngine``
dict cells.
"""
from repro.core.engine import EngineConfig
from repro.core.store import (
    LocalSynopsisStore,
    ShardedSynopsisStore,
    SynopsisStore,
)
from repro.intel import IntelConfig, WorkloadIntel
from repro.verdict.answer import Cell, FailedAnswer, PlanReport, QueryAnswer
from repro.verdict.query import (
    QueryBuilder,
    any_of,
    between,
    equals,
    matches,
    one_of,
)
from repro.verdict.session import ErrorBudget, Session, connect

__all__ = [
    "Cell",
    "EngineConfig",
    "ErrorBudget",
    "FailedAnswer",
    "IntelConfig",
    "LocalSynopsisStore",
    "PlanReport",
    "QueryAnswer",
    "QueryBuilder",
    "Session",
    "ShardedSynopsisStore",
    "SynopsisStore",
    "WorkloadIntel",
    "any_of",
    "between",
    "connect",
    "equals",
    "matches",
    "one_of",
]
