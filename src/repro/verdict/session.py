"""The public Session facade: connect → query → explain/stream/execute.

VerdictDB-style driver API over the engine/plan core: ``connect`` binds a
relation (plus an ``EngineConfig``, plus optionally a JAX ``mesh``) to a
``Session``; queries are built with the typed ``QueryBuilder``; per-call
accuracy/latency contracts are ``ErrorBudget``s (BlinkDB-style); ``explain``
reports the plan the engine would run (support verdict, snippet counts,
dedup, predicted shape buckets, synopsis placement); ``stream`` yields
per-batch refined answers (the online-aggregation loop with the full
improve/validate/record lifecycle); answers are typed
``QueryAnswer``/``Cell`` dataclasses. Everything routes through the same
``repro.aqp.plan`` lifecycle the raw engine uses, so facade answers are
bit-for-bit the engine's.

One ``mesh`` shards BOTH planes: the scan (a ``ShardedScanPlacement`` —
shape-agnostic masked tuple padding, so ANY relation/mesh combination
shards with answers bitwise-equal to the local session) and the learned
state (a ``ShardedSynopsisStore`` placing each aggregate key's synopsis on
a mesh device). ``Session.stats()`` surfaces the resulting scan placement,
shard occupancy and ingest back-pressure.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterator, List, Optional, Sequence, Union

from repro.aqp import queries as Q
from repro.aqp.batch import BatchExecutor, BatchStats
from repro.aqp.executor import scan_placement
from repro.aqp.plan import (
    PhysicalPlan,
    plain_eval,
    plan_workload,
    replay_rounds,
)
from repro.aqp.relation import Relation
from repro.core.engine import EngineConfig, VerdictEngine
from repro.core.store import (
    ShardedSynopsisStore,
    SynopsisStore,
    group_rows,
    state_key,
)
from repro.core.types import bucket_size
from repro.ft import faults
from repro.intel import IntelConfig, WorkloadIntel
from repro.verdict.answer import PlanReport, QueryAnswer
from repro.verdict.query import QueryBuilder

QueryLike = Union[Q.AggQuery, QueryBuilder]


@dataclasses.dataclass(frozen=True)
class ErrorBudget:
    """Per-call accuracy/latency contract (BlinkDB-style).

    target_rel_error: stop as soon as every cell's relative error bound (at
        confidence ``delta``) is below this; None scans the full budget.
    max_batches: hard cap on sample batches (None: the engine's budget).
    delta: confidence level of the stopping bound (None: the engine's
        ``report_delta``).
    deadline_s: per-query wall-clock bound (None: unbounded). On expiry the
        best-so-far answer returns with its honest (wider) CI, flagged
        ``degraded`` with a ``"deadline"`` reason — bounded response time
        without ever returning an invalid estimate. At least one sample
        batch always runs.
    """

    target_rel_error: Optional[float] = None
    max_batches: Optional[int] = None
    delta: Optional[float] = None
    deadline_s: Optional[float] = None


def connect(relation: Relation,
            config: Optional[EngineConfig] = None,
            mesh=None, cache=None, tenant: Optional[str] = None) -> "Session":
    """Open a Session over a relation (the driver-level entry point).

    ``mesh``: optional JAX mesh. One mesh shards both planes — the fused
    scan runs through a ``ShardedScanPlacement`` over its devices (tuple
    blocks of any size: padding + validity masking make divisibility a
    non-issue), and the learned state is placed per aggregate key by a
    ``ShardedSynopsisStore`` over the same devices. Without a mesh both
    stay on the default device.

    ``cache``: opt-in workload intelligence (``repro.intel``) — the
    semantic answer cache + learned serve-path router. ``True`` attaches a
    default ``WorkloadIntel``; an ``IntelConfig`` or a pre-built
    ``WorkloadIntel`` customizes it; ``None``/``False`` (default) keeps
    every path bit-for-bit the historical engine.

    ``tenant``: optional tenant label (see ``Session.attached`` for the
    shared-state flavor) — surfaces in ``stats()`` and threads into the
    workload-intel per-tenant counters.
    """
    return Session(relation, config, mesh=mesh, cache=cache, tenant=tenant)


class Session:
    """One connection's worth of query/learn state over a relation.

    Wraps a ``VerdictEngine`` plus a persistent ``BatchExecutor`` so
    workload-level fusion stats survive across calls (``last_stats``).
    A ``mesh`` (see ``connect``) shards the scan and the synopsis store
    from the same device grid.
    """

    def __init__(self, relation: Relation,
                 config: Optional[EngineConfig] = None, mesh=None,
                 cache=None, tenant: Optional[str] = None, _engine=None):
        self.tenant = tenant
        if _engine is not None:
            # Attach mode (Session.attached): wrap an EXISTING engine —
            # shared SynopsisStore/WorkloadIntel namespace, own executor
            # stats and tenant label.
            self.engine = _engine
            self._executor = BatchExecutor(self.engine)
            return
        store = None
        if mesh is not None:
            store = (lambda schema, cfg:
                     ShardedSynopsisStore(schema, cfg, mesh=mesh))
        intel = None
        if cache:  # True | IntelConfig | WorkloadIntel (None/False: off)
            if isinstance(cache, WorkloadIntel):
                intel = cache
            elif isinstance(cache, IntelConfig):
                intel = WorkloadIntel(cache)
            else:
                intel = WorkloadIntel()
        self.engine = VerdictEngine(relation, config, store=store,
                                    scan=scan_placement(mesh), intel=intel)
        # The executor picks up the engine's ScanPlacement, so every path —
        # execute/execute_many/stream/serve — scans through the same seam.
        self._executor = BatchExecutor(self.engine)

    @classmethod
    def attached(cls, engine, tenant: Optional[str] = None) -> "Session":
        """A Session over an EXISTING engine (or another Session's engine).

        This is the shared-tenancy handle the serving front hands out:
        every attached session reads and writes the SAME learned state
        (synopsis store, workload-intel cache) while keeping its own
        workload stats and tenant label. The caller is responsible for
        serializing engine access across attached sessions (the front does,
        via one engine lock per shared engine).
        """
        return cls(None, _engine=getattr(engine, "engine", engine),
                   tenant=tenant)

    # ------------------------------------------------------------ properties
    @property
    def schema(self):
        return self.engine.schema

    @property
    def config(self) -> EngineConfig:
        return self.engine.config

    @property
    def store(self) -> SynopsisStore:
        """The session's synopsis store (placement-aware learned state)."""
        return self.engine.store

    @property
    def last_stats(self) -> BatchStats:
        """Fusion accounting of the most recent execute/execute_many call."""
        return self._executor.stats

    @property
    def intel(self) -> Optional[WorkloadIntel]:
        """The workload-intelligence plane (``connect(cache=...)``), or
        None when the session runs the historical cache-less paths."""
        return self.engine.intel

    # --------------------------------------------------------------- queries
    def query(self) -> QueryBuilder:
        """Start a typed query: ``session.query().avg("v0").where(...)``."""
        return QueryBuilder(self.engine.schema)

    @staticmethod
    def _lower(q: QueryLike) -> Q.AggQuery:
        return q.build() if isinstance(q, QueryBuilder) else q

    # --------------------------------------------------------------- execute
    def execute(self, q: QueryLike,
                budget: Optional[ErrorBudget] = None) -> QueryAnswer:
        return self.execute_many([q], budget=budget)[0]

    def execute_many(self, queries: Sequence[QueryLike],
                     budget: Optional[ErrorBudget] = None
                     ) -> List[QueryAnswer]:
        """Answer a workload in one fused scan (see ``repro.aqp.batch``)."""
        budget = budget or ErrorBudget()
        results = self._executor.execute_many(
            [self._lower(q) for q in queries],
            target_rel_error=budget.target_rel_error,
            max_batches=budget.max_batches,
            stop_delta=budget.delta,
            deadline_s=budget.deadline_s,
            tenant=self.tenant,
        )
        return [QueryAnswer.from_result(r) for r in results]

    # --------------------------------------------------------------- explain
    def explain(self, q: QueryLike,
                budget: Optional[ErrorBudget] = None) -> PlanReport:
        """Plan a query without scanning past the group-discovery probe.

        Reports, per aggregate-function key, the predicted serve tiles AND
        the store's shard assignment — for keys that do not exist yet this
        is where the state *would* be placed (placement is a pure function
        of the key, never of arrival order). With workload intelligence
        attached (``connect(cache=...)``), also reports the answer-cache
        status (exact/subsumed/miss/uncacheable) and the route the serve
        router would pick under ``budget`` — read-only: explaining never
        moves LRU state, counters, or probe streaks.
        """
        eng = self.engine
        budget = budget or ErrorBudget()
        scan = self._executor.placement.describe()
        evaluator = self._executor.placement.evaluator_for(eng._eval_fn)
        wp = plan_workload(eng, [self._lower(q)])
        lp = wp.logical[0]
        cache_status, route = None, None
        if eng.intel is not None:
            cache_status, route = eng.intel.peek(
                eng, self._lower(q),
                target_rel_error=budget.target_rel_error,
                stop_delta=budget.delta,
                max_batches=budget.max_batches, lp=lp)
        if lp.plan is None:
            return PlanReport(True, None, 0, 0, 0, 0, 0, 1.0, {}, {}, {},
                              scan_placement=scan, scan_evaluator=evaluator,
                              cache=cache_status, route=route)
        n_total = lp.plan.snippets.n
        n_unique = wp.stats.n_snippets_fused
        q_buckets, fill_buckets, placement, quarantined = {}, {}, {}, {}
        for key, rows in group_rows(lp.plan.snippets):
            q_buckets[key] = bucket_size(len(rows), eng.config.min_q_bucket)
            syn = eng.store.get(key)
            fill_buckets[key] = syn._fill_bucket() if syn is not None else 0
            placement[key] = eng.store.describe_placement(key)
            if syn is not None and syn.quarantined:
                quarantined[state_key(key)] = syn.quarantine_reason
        return PlanReport(
            supported=lp.supported,
            unsupported_reason=lp.reason,
            n_cells=len(lp.plan.cells),
            n_groups=len(lp.plan.groups),
            truncated_groups=lp.truncated_groups,
            n_snippets=n_total,
            n_snippets_unique=n_unique,
            dedup_ratio=wp.stats.dedup_ratio,
            q_buckets=q_buckets,
            fill_buckets=fill_buckets,
            placement=placement,
            scan_placement=scan,
            scan_evaluator=evaluator,
            quarantined=quarantined,
            cache=cache_status,
            route=route,
        )

    # ---------------------------------------------------------------- stream
    def stream(self, q: QueryLike,
               budget: Optional[ErrorBudget] = None
               ) -> Iterator[QueryAnswer]:
        """Online aggregation: yield a refined answer after every batch.

        Each yielded ``QueryAnswer`` carries the improved (validated)
        estimates after batches ``0..b``; the last one (``final=True``) is
        bit-for-bit what ``execute`` under the same budget returns, and only
        its raw answers are recorded into the synopsis. There is no second
        lifecycle here: this is ``replay_rounds`` — the exact generator
        ``replay_query``/``execute`` consume — surfaced round by round.
        """
        eng = self.engine
        budget = budget or ErrorBudget()
        if eng.intel is not None:
            served = eng.intel.lookup(
                eng, self._lower(q),
                target_rel_error=budget.target_rel_error,
                stop_delta=budget.delta, max_batches=budget.max_batches,
                tenant=self.tenant)
            if served is not None:
                # Cache hit: the stream collapses to its (final) answer —
                # exactly what execute() under the same budget returns.
                yield QueryAnswer.from_result(served, final=True)
                return
        wp = plan_workload(eng, [self._lower(q)])
        lp = wp.logical[0]
        phys = PhysicalPlan(
            eng.batches,
            wp.fused if lp.supported else wp.fused_raw,
            self._executor._eval if lp.supported else plain_eval,
        )
        deadline = (None if budget.deadline_s is None
                    else time.monotonic() + float(budget.deadline_s))
        for res, final in replay_rounds(
            eng, lp, phys,
            target_rel_error=budget.target_rel_error,
            max_batches=budget.max_batches,
            stop_delta=budget.delta,
            every_batch=True,
            deadline=deadline,
        ):
            yield QueryAnswer.from_result(res, final=final)

    # ------------------------------------------------------------- lifecycle
    def refit(self, **kw):
        """Offline learning pass (Algorithm 1); drains async ingest."""
        self.engine.refit(**kw)

    def drain(self):
        """Barrier over async synopsis ingest (snapshot/refit boundaries)."""
        self.engine.drain()

    def ingest_stats(self) -> dict:
        """Per-synopsis async-ingest back-pressure telemetry."""
        return self.engine.ingest_stats()

    def stats(self) -> dict:
        """Operator snapshot of the learned-state plane.

        ``store``: placement kind, per-key occupancy/placement/ingest
        telemetry, and (sharded) per-shard occupancy — back-pressure and
        shard skew at a glance. ``scan``: the scan plane's placement plus
        its true scanned-tuple accounting (``tuples_scanned`` counts valid
        tuples only; ``pad_rows`` is the masking overhead). ``workload``:
        fusion accounting of the most recent execute/execute_many call —
        its ``tuples_scanned`` likewise never counts padding.
        ``health``: quarantined synopses (``{state_key: reason}`` — those
        keys serve raw sample estimates until ``heal()``) and, during a
        chaos run, the active fault plan's per-point call/fire counters.
        ``intel``: the workload-intelligence plane's hit/miss/subsumption/
        staleness/route counters (``{"enabled": False}`` without one).
        ``tenant``: this session's tenant label (None outside the
        multi-tenant serving front).
        """
        return {
            "tenant": self.tenant,
            "store": self.engine.store.stats(),
            "scan": self._executor.placement.stats(),
            "workload": dataclasses.asdict(self.last_stats),
            "health": {
                "quarantined": self.engine.store.quarantined(),
                "faults": faults.stats(),
            },
            "intel": (self.engine.intel.stats()
                      if self.engine.intel is not None
                      else {"enabled": False}),
        }

    def heal(self, manager=None, step: Optional[int] = None) -> dict:
        """Heal every quarantined synopsis and rejoin it to serving.

        With a ``CheckpointManager``, keys restore from the last good
        committed checkpoint and replay their parked ingest batches;
        without one they rebuild from their own row arrays. Returns
        ``{state_key: healed}`` for the keys that were quarantined — after
        a successful heal the store is bitwise-identical to one that never
        failed (pinned by ``tests/test_faults.py``).
        """
        return self.engine.heal(manager, step)

    def save(self, manager, step: int):
        """Checkpoint the learned synopses through a CheckpointManager."""
        self.engine.save_synopses(manager, step)

    def load(self, manager, step: Optional[int] = None):
        """Restore learned synopses; the session resumes smarter."""
        return self.engine.load_synopses(manager, step)

    def serve(self, max_batch: int = 64,
              budget: Optional[ErrorBudget] = None, engine_lock=None):
        """A microbatching ``AqpService`` front over this session's engine.

        The full ``budget`` contract (target, max_batches, delta) applies to
        every flush, builders are accepted, and tickets resolve to the same
        typed ``QueryAnswer`` the session's own execute returns. The
        session's tenant label rides along; ``engine_lock`` lets the
        multi-tenant front serialize services sharing this engine.
        """
        from repro.serving.aqp import AqpService

        budget = budget or ErrorBudget()
        # No mesh= forwarding: the service's BatchExecutor adopts
        # engine.scan, so served queries keep the (possibly sharded) scan
        # AND accrue into the same Session.stats()["scan"] telemetry.
        return AqpService(self.engine, max_batch=max_batch,
                          target_rel_error=budget.target_rel_error,
                          max_batches=budget.max_batches,
                          stop_delta=budget.delta,
                          deadline_s=budget.deadline_s,
                          result_wrapper=QueryAnswer.from_result,
                          tenant=self.tenant, engine_lock=engine_lock)
