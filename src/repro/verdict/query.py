"""Typed query builder: column names in, ``AggQuery`` out.

No SQL parser — predicates are built with small helper constructors
(``between``, ``equals``, ``one_of``, ``matches``, ``any_of``) that carry
column *names*; ``QueryBuilder.build`` resolves names to dimension indices
via the relation's ``Schema`` (``num_names`` / ``cat_names`` /
``measure_names``) and emits the engine-level ``AggQuery``. Unsupported
constructs (LIKE, disjunctions, MIN/MAX) are representable and flagged by
the engine's support checker, exactly as in paper §2.2.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple, Union

from repro.aqp import queries as Q
from repro.core.types import Schema

ColumnRef = Union[str, int]


@dataclasses.dataclass(frozen=True)
class _Between:
    column: ColumnRef
    lo: float
    hi: float


@dataclasses.dataclass(frozen=True)
class _Equals:
    column: ColumnRef
    value: object


@dataclasses.dataclass(frozen=True)
class _OneOf:
    column: ColumnRef
    values: Tuple


@dataclasses.dataclass(frozen=True)
class _Matches:
    pattern: str


@dataclasses.dataclass(frozen=True)
class _AnyOf:
    terms: Tuple


def between(column: ColumnRef, lo: float, hi: float) -> _Between:
    """Numeric range predicate: lo <= column <= hi."""
    return _Between(column, float(lo), float(hi))


def equals(column: ColumnRef, value) -> _Equals:
    """Equality on a numeric or categorical column, referenced by name
    (a bare index is rejected as ambiguous between the two kinds)."""
    return _Equals(column, value)


def one_of(column: ColumnRef, values: Sequence) -> _OneOf:
    """Categorical IN-list predicate."""
    return _OneOf(column, tuple(values))


def matches(pattern: str) -> _Matches:
    """Textual LIKE filter — representable but unsupported (§2.2)."""
    return _Matches(pattern)


def any_of(*terms) -> _AnyOf:
    """Disjunction — representable but unsupported (§2.2)."""
    return _AnyOf(tuple(terms))


def _resolve(names: Tuple[str, ...], ref: ColumnRef, what: str) -> int:
    if isinstance(ref, int):
        return ref
    try:
        return names.index(ref)
    except ValueError:
        raise KeyError(
            f"unknown {what} column {ref!r}; available: {list(names)}"
        ) from None


class QueryBuilder:
    """Fluent builder for one aggregate query over a schema.

    >>> q = (session.query().avg("v0")
    ...             .where(between("x0", 2, 8))
    ...             .group_by("c0"))

    Builders are executable wherever the Session takes a query; ``build()``
    returns the underlying ``AggQuery``.
    """

    def __init__(self, schema: Schema):
        self._schema = schema
        self._aggs = []
        self._preds = []
        self._groupby = []

    # ------------------------------------------------------------ aggregates
    def _agg(self, kind: str, measure) -> "QueryBuilder":
        idx = (None if measure is None
               else _resolve(self._schema.measure_names, measure, "measure"))
        self._aggs.append(Q.AggSpec(kind, idx))
        return self

    def avg(self, measure: ColumnRef) -> "QueryBuilder":
        return self._agg("AVG", measure)

    def sum(self, measure: ColumnRef) -> "QueryBuilder":
        return self._agg("SUM", measure)

    def count(self) -> "QueryBuilder":
        return self._agg("COUNT", None)

    def min(self, measure: ColumnRef) -> "QueryBuilder":
        """Representable but unsupported — the engine answers raw-only."""
        return self._agg("MIN", measure)

    def max(self, measure: ColumnRef) -> "QueryBuilder":
        """Representable but unsupported — the engine answers raw-only."""
        return self._agg("MAX", measure)

    # ------------------------------------------------------------ predicates
    def where(self, *predicates) -> "QueryBuilder":
        self._preds.extend(predicates)
        return self

    def group_by(self, *columns: ColumnRef) -> "QueryBuilder":
        self._groupby.extend(columns)
        return self

    # ----------------------------------------------------------------- build
    def _lower_predicate(self, p):
        sch = self._schema
        if isinstance(p, _Between):
            return Q.NumRange(_resolve(sch.num_names, p.column, "numeric"),
                              p.lo, p.hi)
        if isinstance(p, _Equals):
            if isinstance(p.column, str) and p.column in sch.cat_names:
                return Q.CatEq(sch.cat_names.index(p.column), int(p.value))
            if isinstance(p.column, str) and p.column in sch.num_names:
                return Q.NumEq(sch.num_names.index(p.column), float(p.value))
            if isinstance(p.column, int):
                # A bare index cannot disambiguate numeric vs categorical
                # dimensions; silently guessing would filter the wrong
                # column. Require a name here (or use Q.NumEq/Q.CatEq).
                raise KeyError(
                    f"equals({p.column!r}, ...) is ambiguous: pass a column "
                    "name, or use repro.aqp.queries.NumEq/CatEq directly"
                )
            raise KeyError(
                f"unknown column {p.column!r}; numeric: {list(sch.num_names)}"
                f", categorical: {list(sch.cat_names)}"
            )
        if isinstance(p, _OneOf):
            return Q.CatIn(_resolve(sch.cat_names, p.column, "categorical"),
                           tuple(int(v) for v in p.values))
        if isinstance(p, _Matches):
            return Q.TextLike(p.pattern)
        if isinstance(p, _AnyOf):
            return Q.Disjunction(
                tuple(self._lower_predicate(t) for t in p.terms)
            )
        # Already an engine-level predicate — pass through.
        return p

    def build(self) -> Q.AggQuery:
        if not self._aggs:
            raise ValueError("query has no aggregates; call .avg/.sum/.count")
        return Q.AggQuery(
            aggs=tuple(self._aggs),
            predicates=tuple(self._lower_predicate(p) for p in self._preds),
            groupby=tuple(
                _resolve(self._schema.cat_names, c, "group-by")
                for c in self._groupby
            ),
        )
