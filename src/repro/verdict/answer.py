"""Typed answers and reports for the public Session API.

``Cell``/``QueryAnswer`` replace the engine-level ``List[dict]`` cells with
frozen dataclasses; ``Cell.to_dict``/``from_dict`` round-trip bit-for-bit to
the engine representation, so facade answers can always be checked against
the engine's bitwise-parity oracle. ``PlanReport`` is ``Session.explain``'s
output: the plan the engine would run, including where each aggregate key's
learned state is placed (``SynopsisStore`` shard assignments).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.utils.stats import confidence_multiplier


@dataclasses.dataclass(frozen=True)
class Cell:
    """One output cell of an aggregate query.

    group:    the group-by value tuple (empty when no group-by)
    agg:      index of the aggregate within the query's select list
    kind:     'AVG' | 'SUM' | 'COUNT'
    estimate: the (possibly model-improved) answer
    beta2:    its variance; ``error_bound(delta)`` is the ±bound at
              confidence ``delta``
    """

    group: Tuple[int, ...]
    agg: int
    kind: str
    estimate: float
    beta2: float

    def error_bound(self, delta: float = 0.95) -> float:
        return float(confidence_multiplier(delta)) * float(np.sqrt(self.beta2))

    def rel_error(self, delta: float = 0.95) -> float:
        return self.error_bound(delta) / max(abs(self.estimate), 1e-9)

    def to_dict(self) -> dict:
        """The engine-level dict representation (bitwise round-trip)."""
        return {
            "group": self.group,
            "agg": self.agg,
            "kind": self.kind,
            "estimate": self.estimate,
            "beta2": self.beta2,
        }

    @staticmethod
    def from_dict(d: dict) -> "Cell":
        return Cell(
            group=tuple(d["group"]),
            agg=int(d["agg"]),
            kind=str(d["kind"]),
            estimate=d["estimate"],
            beta2=d["beta2"],
        )


@dataclasses.dataclass(frozen=True)
class QueryAnswer:
    """Typed result of one query through the Session facade.

    ``final`` is False only for the intermediate refinements yielded by
    ``Session.stream``. ``truncated_groups`` surfaces group-by cells dropped
    by the planner's ``n_max`` cap (see ``SnippetPlan.truncated_groups``).

    ``degraded``/``degraded_reasons``: the answer is honest but weaker than
    a healthy engine would serve — quarantined synopses left their groups
    on the raw sample estimate (Theorem 1's floor), or a deadline returned
    the best-so-far answer with its wider CI. Reasons are
    ``{state_key | "deadline": description}``.

    ``served_from``: ``"cache:exact"``/``"cache:subsumed"`` when the
    workload-intelligence plane answered without scanning (``repro.intel``);
    None for every executed answer.
    """

    cells: Tuple[Cell, ...]
    batches_used: int
    tuples_scanned: int
    supported: bool
    unsupported_reason: Optional[str] = None
    truncated_groups: int = 0
    final: bool = True
    degraded: bool = False
    degraded_reasons: dict = dataclasses.field(default_factory=dict)
    served_from: Optional[str] = None

    @property
    def failed(self) -> bool:
        """Degradation-ladder bottom check: a ``QueryAnswer`` always carries
        a valid estimate (``FailedAnswer.failed`` is True)."""
        return False

    @staticmethod
    def from_result(result, final: bool = True) -> "QueryAnswer":
        """Lift an engine ``QueryResult`` into the typed representation."""
        return QueryAnswer(
            cells=tuple(Cell.from_dict(c) for c in result.cells),
            batches_used=result.batches_used,
            tuples_scanned=result.tuples_scanned,
            supported=result.supported,
            unsupported_reason=result.unsupported_reason,
            truncated_groups=result.truncated_groups,
            final=final,
            degraded=bool(getattr(result, "degraded", False)),
            degraded_reasons=dict(getattr(result, "degraded_reasons", {})),
            served_from=getattr(result, "served_from", None),
        )

    def max_rel_error(self, delta: float = 0.95) -> float:
        return max((c.rel_error(delta) for c in self.cells), default=0.0)

    @property
    def value(self) -> float:
        """Single-cell convenience: the lone estimate."""
        if len(self.cells) != 1:
            raise ValueError(
                f"answer has {len(self.cells)} cells; use .cells directly"
            )
        return self.cells[0].estimate


@dataclasses.dataclass(frozen=True)
class FailedAnswer:
    """Typed terminal failure for ONE query — the bottom rung of the
    degradation ladder (improved → raw-sample → ``FailedAnswer``).

    ``AqpService.flush`` resolves a poison query's ticket with this after
    bisect isolation and bounded retries exhaust: the query failed, but it
    failed ALONE (the rest of its microbatch answered normally) and it
    failed LOUDLY (a typed value, never a hung ticket or a silent None).
    Mirrors ``QueryAnswer``'s shape loosely (``cells``/``failed``/``final``)
    so serving code can branch on ``answer.failed`` uniformly.
    """

    error: str  # repr of the terminal exception
    error_type: str  # exception class name (e.g. "InjectedFault")
    attempts: int  # execution attempts spent before giving up
    final: bool = True
    cells: Tuple = ()

    @property
    def failed(self) -> bool:
        return True

    def __str__(self) -> str:
        return (f"FailedAnswer({self.error_type} after {self.attempts} "
                f"attempt{'s' if self.attempts != 1 else ''}: {self.error})")


@dataclasses.dataclass(frozen=True)
class PlanReport:
    """What ``Session.explain`` saw: the plan without running the scan.

    ``q_buckets``/``fill_buckets``: predicted power-of-two serve tiles per
    aggregate-function key ``(agg, measure)`` — the (Q-bucket, fill-bucket)
    program the improve dispatch would compile/reuse. ``dedup_ratio`` is the
    within-query snippet reuse (shared FREQ rows across SUM/COUNT cells).
    ``placement``: per aggregate-function key, where the ``SynopsisStore``
    puts (or would put) its learned state — ``"local"`` for the default
    store, ``"shard<i>:<device>"`` under per-key mesh placement.
    ``scan_placement``: the scan plane's ``ScanPlacement`` (``"local"`` or
    ``"sharded:<n>x<axis>"``) — with a mesh, blocks pad/mask to shard over
    any relation size, and reported scanned-tuple counts stay true counts.
    ``scan_evaluator``: the per-block evaluator the placement WILL route
    through — ``"oracle"`` (pure jnp), ``"fused_masked_scan"`` (the fused
    Pallas kernel), or under a mesh ``"sharded_mask+{kernel,oracle}_agg"``
    (shard_map mask build + kernel/jnp aggregation of the gathered mask) —
    so ``explain`` never misreports a silently-dropped kernel request.
    """

    supported: bool
    unsupported_reason: Optional[str]
    n_cells: int
    n_groups: int
    truncated_groups: int
    n_snippets: int
    n_snippets_unique: int
    dedup_ratio: float
    q_buckets: dict
    fill_buckets: dict
    placement: dict = dataclasses.field(default_factory=dict)
    scan_placement: str = "local"
    scan_evaluator: str = "oracle"
    # state_key -> quarantine reason for every currently-quarantined
    # synopsis this query's keys would touch: the query WILL serve, but its
    # affected groups stay on the raw sample estimate until heal().
    quarantined: dict = dataclasses.field(default_factory=dict)
    # Workload intelligence (None when no intel plane is attached):
    # ``cache`` is the answer-cache status this query would see RIGHT NOW
    # ("exact" | "subsumed" | "miss" | "uncacheable"), ``route`` the serve
    # path the router would pick ("cache" | "improve" | "scan").
    cache: Optional[str] = None
    route: Optional[str] = None

    def __str__(self) -> str:
        head = ("supported" if self.supported
                else f"raw-only ({self.unsupported_reason})")
        lines = [
            f"plan: {head}",
            f"  scan={self.scan_placement} evaluator={self.scan_evaluator}",
            f"  cells={self.n_cells} groups={self.n_groups}"
            f" truncated_groups={self.truncated_groups}",
            f"  snippets={self.n_snippets} unique={self.n_snippets_unique}"
            f" dedup={self.dedup_ratio:.2f}x",
        ]
        if self.cache is not None:
            lines.append(
                f"  served from cache: {self.cache} → route={self.route}"
            )
        for key in sorted(self.q_buckets):
            where = self.placement.get(key, "local")
            lines.append(
                f"  agg_key={key}: Q-bucket={self.q_buckets[key]}"
                f" fill-bucket={self.fill_buckets[key]}"
                f" placement={where}"
            )
        for name, reason in sorted(self.quarantined.items()):
            lines.append(
                f"  QUARANTINED {name}: serving raw sample estimates"
                f" ({reason})"
            )
        return "\n".join(lines)
