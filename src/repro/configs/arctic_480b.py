"""arctic-480b [moe]: 35L d7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + parallel dense residual FFN.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.models.config import ArchConfig, MoECfg


def config() -> ArchConfig:
    return ArchConfig(
        name="arctic-480b", family="moe", n_layers=35, d_model=7168,
        n_heads=56, n_kv=8, head_dim=128, d_ff=4864, vocab=32000,
        act="silu", rope_theta=1e4,
        moe=MoECfg(n_experts=128, top_k=2, dense_residual=True),
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="arctic-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, head_dim=16, d_ff=96, vocab=256,
        act="silu", param_dtype="float32", compute_dtype="float32",
        moe=MoECfg(n_experts=8, top_k=2, dense_residual=True,
                   capacity_factor=8.0),  # no drops: deterministic smoke semantics
    )
