"""Assigned-architecture registry: ``get(name)`` / ``get_smoke(name)``."""
import importlib

ARCHS = {
    "arctic-480b": "arctic_480b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "qwen2.5-3b": "qwen2_5_3b",
    "gemma2-2b": "gemma2_2b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "starcoder2-3b": "starcoder2_3b",
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
    "rwkv6-3b": "rwkv6_3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "hymba-1.5b": "hymba_1_5b",
}


def _module(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[name]}")


def get(name: str):
    return _module(name).config()


def get_smoke(name: str):
    return _module(name).smoke()


def names():
    return list(ARCHS)
