"""phi3-mini-3.8b [dense]: 32L d3072 32H (MHA kv=32) d_ff=8192 vocab=32064,
RoPE SwiGLU. [arXiv:2404.14219; unverified]"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="phi3-mini-3.8b", family="dense", n_layers=32, d_model=3072,
        n_heads=32, n_kv=32, head_dim=96, d_ff=8192, vocab=32064, act="silu",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="phi3-smoke", family="dense", n_layers=3, d_model=64,
        n_heads=4, n_kv=4, head_dim=16, d_ff=128, vocab=256, act="silu",
        param_dtype="float32", compute_dtype="float32",
    )
