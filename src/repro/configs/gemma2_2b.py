"""gemma2-2b [dense]: 26L d2304 8H (GQA kv=4) d_ff=9216 vocab=256000,
alternating local(4096)/global attention, GeGLU, attn/final logit softcaps,
pre+post RMSNorm. [arXiv:2408.00118; hf]"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma2-2b", family="dense", n_layers=26, d_model=2304,
        n_heads=8, n_kv=4, head_dim=256, d_ff=9216, vocab=256000,
        act="gelu", attn_softcap=50.0, final_softcap=30.0,
        window=4096, layer_pattern="LG", post_norm=True,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="gemma2-smoke", family="dense", n_layers=4, d_model=64,
        n_heads=4, n_kv=2, head_dim=16, d_ff=128, vocab=256, act="gelu",
        attn_softcap=50.0, final_softcap=30.0, window=8,
        layer_pattern="LG", post_norm=True,
        param_dtype="float32", compute_dtype="float32",
    )
