"""qwen2.5-3b [dense]: 36L d2048 16H (GQA kv=2) d_ff=11008 vocab=151936,
GQA with QKV bias, SwiGLU, RoPE. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2.5-3b", family="dense", n_layers=36, d_model=2048,
        n_heads=16, n_kv=2, head_dim=128, d_ff=11008, vocab=151936,
        act="silu", qkv_bias=True, rope_theta=1e6,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen-smoke", family="dense", n_layers=3, d_model=64,
        n_heads=4, n_kv=2, head_dim=16, d_ff=128, vocab=256, act="silu",
        qkv_bias=True, param_dtype="float32", compute_dtype="float32",
    )
