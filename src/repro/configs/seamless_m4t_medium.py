"""seamless-m4t-medium [audio]: enc-dec, 12L encoder + 12L decoder, d1024
16H (MHA kv=16) d_ff=4096 vocab=256206. The audio frontend is a STUB
(input_specs provides precomputed frame embeddings); shape cells split
seq_len as enc seq/2 + dec seq/2 (DESIGN.md). [arXiv:2308.11596; hf]"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="seamless-m4t-medium", family="audio", n_layers=12, d_model=1024,
        n_heads=16, n_kv=16, head_dim=64, d_ff=4096, vocab=256206,
        act="gelu_mlp", enc_dec=True, enc_layers=12,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="seamless-smoke", family="audio", n_layers=2, d_model=64,
        n_heads=4, n_kv=4, head_dim=16, d_ff=128, vocab=256,
        act="gelu_mlp", enc_dec=True, enc_layers=2,
        param_dtype="float32", compute_dtype="float32",
    )
