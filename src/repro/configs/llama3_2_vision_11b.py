"""llama-3.2-vision-11b [vlm]: 40L d4096 32H (GQA kv=8) d_ff=14336
vocab=128256; gated cross-attention image layers every 5th layer; the vision
frontend is a STUB (input_specs provides precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.models.config import ArchConfig, CrossAttnCfg

N_IMG_TOKENS = 1600


def config() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-11b", family="vlm", n_layers=40, d_model=4096,
        n_heads=32, n_kv=8, head_dim=128, d_ff=14336, vocab=128256,
        act="silu", rope_theta=5e5,
        cross_attn=CrossAttnCfg(period=5, n_ctx=N_IMG_TOKENS),
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="vision-smoke", family="vlm", n_layers=4, d_model=64,
        n_heads=4, n_kv=2, head_dim=16, d_ff=128, vocab=256, act="silu",
        cross_attn=CrossAttnCfg(period=2, n_ctx=16),
        param_dtype="float32", compute_dtype="float32",
    )
