"""starcoder2-3b [dense]: 30L d3072 24H (GQA kv=2) d_ff=12288 vocab=49152,
GQA, RoPE, non-gated GeLU MLP, biases. [arXiv:2402.19173; hf]"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-3b", family="dense", n_layers=30, d_model=3072,
        n_heads=24, n_kv=2, head_dim=128, d_ff=12288, vocab=49152,
        act="gelu_mlp", qkv_bias=True, rope_theta=1e5,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-smoke", family="dense", n_layers=3, d_model=64,
        n_heads=4, n_kv=2, head_dim=16, d_ff=128, vocab=256,
        act="gelu_mlp", qkv_bias=True,
        param_dtype="float32", compute_dtype="float32",
    )
