"""hymba-1.5b [hybrid]: 32L d1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
parallel attention + mamba heads per layer, ssm_state=16, 128 meta tokens,
3 full-attention layers (first/middle/last), rest sliding-window.
[arXiv:2411.13676; hf]"""
from repro.models.config import ArchConfig, SSMCfg


def config() -> ArchConfig:
    return ArchConfig(
        name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
        n_heads=25, n_kv=5, head_dim=64, d_ff=5504, vocab=32001,
        act="silu", window=2048, meta_tokens=128,
        ssm=SSMCfg(kind="mamba", state=16, d_inner=1600),
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="hymba-smoke", family="hybrid", n_layers=6, d_model=64,
        n_heads=4, n_kv=2, head_dim=16, d_ff=128, vocab=256, act="silu",
        window=8, meta_tokens=4,
        ssm=SSMCfg(kind="mamba", state=4, d_inner=64),
        param_dtype="float32", compute_dtype="float32",
    )
