"""rwkv6-3b [ssm] "Finch": 32L d2560 attention-free (data-dependent-decay
linear attention), d_ff=8960, vocab=65536, 40 heads x 64. [arXiv:2404.05892; hf]"""
from repro.models.config import ArchConfig, SSMCfg


def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-3b", family="ssm", n_layers=32, d_model=2560,
        n_heads=40, n_kv=40, head_dim=64, d_ff=8960, vocab=65536,
        act="silu", ssm=SSMCfg(kind="rwkv6"),
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-smoke", family="ssm", n_layers=3, d_model=64,
        n_heads=4, n_kv=4, head_dim=16, d_ff=128, vocab=256, act="silu",
        ssm=SSMCfg(kind="rwkv6", dec_lora=8),
        param_dtype="float32", compute_dtype="float32",
    )
