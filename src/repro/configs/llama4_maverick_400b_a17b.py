"""llama4-maverick-400b-a17b [moe]: 48L d5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128e top-1, interleaved (MoE every other layer) + shared
expert -> ~400B total / 17B active. [hf:meta-llama/Llama-4-Scout-17B-16E;
unverified]"""
from repro.models.config import ArchConfig, MoECfg


def config() -> ArchConfig:
    return ArchConfig(
        name="llama4-maverick-400b-a17b", family="moe", n_layers=48,
        d_model=5120, n_heads=40, n_kv=8, head_dim=128, d_ff=8192,
        vocab=202048, act="silu", rope_theta=5e5,
        moe=MoECfg(n_experts=128, top_k=1, period=2, shared_expert=True),
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="llama4-smoke", family="moe", n_layers=4, d_model=64,
        n_heads=4, n_kv=2, head_dim=16, d_ff=96, vocab=256, act="silu",
        param_dtype="float32", compute_dtype="float32",
        moe=MoECfg(n_experts=8, top_k=1, period=2, shared_expert=True,
                   capacity_factor=8.0),  # no drops: deterministic smoke semantics
    )
