from repro.serving.engine import make_prefill_step, make_serve_step
from repro.serving.aqp import AqpService, Ticket
from repro.serving.front import Rejection, ServingFront, TenantSpec, serve_http
