"""Serving steps: prefill (prompt -> KV caches) and decode (one token/step).

``serve_step`` is the unit the decode/long-context dry-run cells lower: one new
token against a KV cache of ``max_len`` (ring-bounded for local-attention
layers, constant-size recurrent state for SSM layers).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.common import ShardCtx


def _plan(cfg):
    return cfg.decoder_plan() if cfg.enc_dec else cfg.layer_plan()


def make_prefill_step(cfg, sctx: ShardCtx = ShardCtx(), *, max_len: int,
                      n_ctx: int = 0):
    def prefill(params, tokens, ctx_tokens=None, enc_embeds=None):
        b = tokens.shape[0]
        caches = T.init_cache(cfg, _plan(cfg), b, max_len, n_ctx)
        if cfg.enc_dec:
            ctx_tokens = T.encode(cfg, params, enc_embeds, sctx)
        logits, caches = T.forward(cfg, params, tokens, sctx,
                                   ctx_tokens=ctx_tokens, mode="prefill",
                                   caches=caches)
        return logits[:, -1], caches

    return prefill


def make_serve_step(cfg, sctx: ShardCtx = ShardCtx(), sample: str = "greedy"):
    def serve(params, caches, tokens, pos):
        """tokens: (B,1) previous token; pos: () absolute position."""
        logits, caches = T.forward(cfg, params, tokens, sctx, mode="decode",
                                   caches=caches, pos=pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, caches

    return serve


def generate(cfg, params, prompt, steps: int, sctx: ShardCtx = ShardCtx(), *,
             max_len: Optional[int] = None, ctx_tokens=None, enc_embeds=None):
    """Greedy generation loop (examples/tests; production uses the launcher)."""
    max_len = max_len or (prompt.shape[1] + steps + cfg.meta_tokens)
    prefill = make_prefill_step(
        cfg, sctx, max_len=max_len,
        n_ctx=0 if ctx_tokens is None and enc_embeds is None else
        (ctx_tokens.shape[1] if ctx_tokens is not None else enc_embeds.shape[1]))
    serve = jax.jit(make_serve_step(cfg, sctx))
    logits, caches = prefill(params, prompt, ctx_tokens=ctx_tokens,
                             enc_embeds=enc_embeds)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    pos = prompt.shape[1] + cfg.meta_tokens
    for i in range(steps - 1):
        tok, caches = serve(params, caches, tok, jnp.asarray(pos + i, jnp.int32))
        out.append(tok)
    return jnp.concatenate(out, axis=1)
