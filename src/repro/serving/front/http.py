"""Stdlib HTTP transport for the serving front (no third-party deps).

A ``ThreadingHTTPServer`` exposing one ``ServingFront``:

- ``POST /v1/tenants/<name>/execute``: ``{"query": ..., "budget": ...?}``
  -> one answer-ladder JSON object (``kind``: answer | failed | rejected).
- ``POST /v1/tenants/<name>/explain``: same body -> plan-report JSON.
- ``POST /v1/tenants/<name>/stream``: same body -> chunked NDJSON, one
  refined answer per sample batch (``session.stream`` over the wire; the
  last line carries ``"final": true`` and is bit-for-bit the execute
  answer under the same budget).
- ``GET /v1/tenants/<name>/stats``: that tenant's observability block.
- ``GET /v1/stats``: every tenant + the shared intel plane.
- ``GET /v1/healthz``: liveness.

Status mapping: malformed JSON -> 400, unknown tenant/route -> 404, typed
admission ``Rejection`` -> its own ``status`` (429 rate-limit / 503
queue-full) with a ``Retry-After`` header — the rejection is data, never a
server error. Engine answers (including ``FailedAnswer``) are 200: the
request was served; the outcome is in the body's ``kind``.

Each request runs on its own thread (``ThreadingHTTPServer``), which is
exactly the concurrency the front's admission + engine-lock design expects.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.serving.front.wire import (
    WireError,
    answer_to_json,
    budget_from_json,
    query_from_json,
    report_to_json,
)


class FrontHTTPServer(ThreadingHTTPServer):
    """ThreadingHTTPServer carrying the front for its handlers.

    ``daemon_threads`` so in-flight request threads never block process
    exit; ``allow_reuse_address`` for fast test restarts on one port.
    """

    daemon_threads = True
    allow_reuse_address = True

    def __init__(self, address, front):
        self.front = front
        super().__init__(address, _Handler)


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"

    # ------------------------------------------------------------- plumbing
    def log_message(self, fmt, *args):  # noqa: D102 — silence default stderr
        pass

    @property
    def front(self):
        return self.server.front

    def _send_json(self, status: int, obj: dict, headers=()):
        body = json.dumps(obj).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in headers:
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def _error(self, status: int, message: str):
        self._send_json(status, {"kind": "error", "error": message})

    def _read_body(self) -> dict:
        length = int(self.headers.get("Content-Length") or 0)
        raw = self.rfile.read(length) if length else b"{}"
        try:
            obj = json.loads(raw or b"{}")
        except json.JSONDecodeError as e:
            raise WireError(f"invalid JSON body: {e}") from None
        if not isinstance(obj, dict):
            raise WireError("request body must be a JSON object")
        return obj

    def _route(self):
        """(verb, tenant) for /v1/tenants/<name>/<verb>, or (verb, None)."""
        parts = [p for p in self.path.split("?")[0].split("/") if p]
        if parts[:1] != ["v1"]:
            return None, None
        if len(parts) == 2:
            return parts[1], None  # /v1/stats, /v1/healthz
        if len(parts) == 4 and parts[1] == "tenants":
            return parts[3], parts[2]  # /v1/tenants/<name>/<verb>
        return None, None

    # --------------------------------------------------------------- verbs
    def do_GET(self):  # noqa: N802 — BaseHTTPRequestHandler contract
        verb, tenant = self._route()
        try:
            if verb == "healthz" and tenant is None:
                self._send_json(200, {"ok": True})
            elif verb == "stats":
                self._send_json(200, self.front.stats(tenant))
            else:
                self._error(404, f"no such route: GET {self.path}")
        except KeyError as e:
            self._error(404, str(e))

    def do_POST(self):  # noqa: N802
        verb, tenant = self._route()
        if verb not in ("execute", "explain", "stream") or tenant is None:
            self._error(404, f"no such route: POST {self.path}")
            return
        try:
            body = self._read_body()
            query = query_from_json(self._schema(tenant), body.get("query"))
            budget = budget_from_json(body.get("budget"))
        except WireError as e:
            self._error(400, str(e))
            return
        except KeyError as e:
            self._error(404, str(e))
            return
        if verb == "stream":
            self._stream(tenant, query, budget)
            return
        if verb == "execute":
            ans = self.front.execute(tenant, query, budget=budget)
        else:
            ans = self.front.explain(tenant, query, budget=budget)
        if getattr(ans, "rejected", False):
            self._send_json(
                ans.status, answer_to_json(ans),
                headers=[("Retry-After", f"{ans.retry_after_s:.3f}")])
        elif verb == "explain":
            self._send_json(200, report_to_json(ans))
        else:
            self._send_json(200, answer_to_json(ans))

    def _schema(self, tenant: str):
        return self.front.tenant(tenant).session.schema

    def _stream(self, tenant: str, query, budget):
        """Chunked NDJSON: one answer-ladder object per refinement round."""
        stream = self.front.stream(tenant, query, budget=budget)
        try:
            first = next(stream)
        except StopIteration:
            self._error(500, "stream produced no answers")
            return
        if getattr(first, "rejected", False):
            self._send_json(
                first.status, answer_to_json(first),
                headers=[("Retry-After", f"{first.retry_after_s:.3f}")])
            return
        self.send_response(200)
        self.send_header("Content-Type", "application/x-ndjson")
        self.send_header("Transfer-Encoding", "chunked")
        self.end_headers()

        def write_chunk(obj):
            data = (json.dumps(obj) + "\n").encode()
            self.wfile.write(f"{len(data):x}\r\n".encode() + data + b"\r\n")

        write_chunk(answer_to_json(first))
        for ans in stream:
            write_chunk(answer_to_json(ans))
        self.wfile.write(b"0\r\n\r\n")


def serve_http(front, host: str = "127.0.0.1", port: int = 0,
               block: bool = False) -> FrontHTTPServer:
    """Serve ``front`` over HTTP; returns the bound server.

    ``port=0`` binds an ephemeral port (``server.server_address``). With
    ``block=False`` (default) the accept loop runs on a daemon thread and
    the caller owns shutdown (``server.shutdown(); server.server_close()``).
    """
    server = FrontHTTPServer((host, port), front)
    if block:
        server.serve_forever()
    else:
        thread = threading.Thread(target=server.serve_forever,
                                  name="serving-front-http", daemon=True)
        thread.start()
    return server
