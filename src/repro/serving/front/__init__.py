"""Multi-tenant serving front (``repro.serving.front``).

The network-facing layer over the microbatching ``AqpService``: per-tenant
sessions with isolated-or-shared learned-state namespaces, clock-free
admission control (token bucket + bounded queue, typed ``Rejection``),
per-tenant observability (latency histograms + outcome counters), a JSON
wire codec, and a stdlib HTTP transport with an NDJSON streaming endpoint.
"""
from repro.serving.front.admission import (
    AdmissionConfig,
    AdmissionController,
    Rejection,
    TokenBucket,
)
from repro.serving.front.front import ServingFront, Tenant, TenantSpec
from repro.serving.front.http import FrontHTTPServer, serve_http
from repro.serving.front.metrics import LatencyHistogram, TenantMetrics
from repro.serving.front.wire import (
    WireError,
    answer_to_json,
    budget_from_json,
    query_from_json,
    report_to_json,
)

__all__ = [
    "AdmissionConfig",
    "AdmissionController",
    "FrontHTTPServer",
    "LatencyHistogram",
    "Rejection",
    "ServingFront",
    "Tenant",
    "TenantMetrics",
    "TenantSpec",
    "TokenBucket",
    "WireError",
    "answer_to_json",
    "budget_from_json",
    "query_from_json",
    "report_to_json",
    "serve_http",
]
