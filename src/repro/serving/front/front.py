"""The multi-tenant serving front: tenants, admission, routing, stats.

``ServingFront`` turns the single-engine ``AqpService`` microbatcher into a
real multi-tenant server. Each ``TenantSpec`` declares its isolation mode:

- ``"shared"`` tenants attach to ONE engine (``Session.attached``), so they
  read and write the same ``SynopsisStore`` and ``WorkloadIntel`` namespace
  — a query learned by tenant A makes tenant B's next repeat cheaper. All
  shared services serialize on one engine lock; the workload-intel plane
  still splits hit-rates per tenant (``IntelTelemetry.per_tenant``).
- ``"isolated"`` tenants get their own engine/Session: private learned
  state, private answer cache, and scans that run in parallel with every
  other tenant.

Every request passes the tenant's ``AdmissionController`` first (token
bucket + bounded queue depth, typed ``Rejection``), then routes through the
tenant's microbatching ``AqpService`` — so the miss path is EXACTLY the
``BatchExecutor`` lifecycle ``Session.execute`` runs, and answers are
bitwise-identical to a direct session call (pinned by
``tests/test_serving_front.py``).

This module is the composition/transport boundary, so it MAY read the wall
clock — but only to feed timestamps into the clock-free ``admission`` and
``metrics`` modules (analysis rule A008 holds there). Pass ``clock=`` to
replay admission decisions against a scripted clock.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Iterator, Optional

from repro.core.engine import EngineConfig
from repro.serving.front.admission import (
    AdmissionConfig,
    AdmissionController,
    Rejection,
)
from repro.serving.front.metrics import TenantMetrics
from repro.verdict.session import ErrorBudget, Session, connect


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's serving contract.

    isolation: ``"shared"`` (one learned-state namespace for all shared
        tenants) or ``"isolated"`` (private engine + store + cache).
    rate / burst / max_pending: admission knobs (see ``AdmissionConfig``).
    budget: the default ``ErrorBudget`` applied to this tenant's
        microbatched queries (per-request budgets override it).
    max_batch: the tenant's microbatch auto-flush threshold.
    """

    name: str
    isolation: str = "shared"
    rate: float = 50.0
    burst: int = 20
    max_pending: int = 256
    budget: Optional[ErrorBudget] = None
    max_batch: int = 64

    def __post_init__(self):
        if self.isolation not in ("shared", "isolated"):
            raise ValueError(
                f"isolation must be 'shared' or 'isolated', "
                f"got {self.isolation!r}")

    def admission(self) -> AdmissionConfig:
        return AdmissionConfig(rate=self.rate, burst=self.burst,
                               max_pending=self.max_pending)


class Tenant:
    """One registered tenant: session + service + admission + metrics."""

    def __init__(self, spec: TenantSpec, session: Session, engine_lock,
                 now: float):
        self.spec = spec
        self.session = session
        self.service = session.serve(max_batch=spec.max_batch,
                                     budget=spec.budget,
                                     engine_lock=engine_lock)
        self.admission = AdmissionController(spec.name, spec.admission(),
                                             now=now)
        self.metrics = TenantMetrics(spec.name)

    def stats(self) -> dict:
        svc = self.service
        return {
            "isolation": self.spec.isolation,
            "admission": self.admission.stats(),
            "metrics": self.metrics.snapshot(),
            "service": {
                "flushes": svc.flushes,
                "pending": svc.pending,
                "prescreened": svc.prescreened,
            },
            "health": {
                "quarantined": self.session.store.quarantined(),
            },
        }


class ServingFront:
    """Multi-tenant serving front over one relation.

    One front owns the shared engine (created on first shared tenant) and
    every isolated tenant's private engine. ``cache=True`` (default)
    attaches a ``WorkloadIntel`` plane to each engine, so repeat queries
    prescreen at submit; shared tenants share one cache namespace with
    per-tenant hit counters.

    ``clock``: the monotonic time source feeding admission and latency
    metrics (``time.monotonic`` by default). Inject a fake for
    deterministic admission replay.
    """

    def __init__(self, relation, config: Optional[EngineConfig] = None,
                 mesh=None, cache=True, clock=time.monotonic):
        self._relation = relation
        self._config = config
        self._mesh = mesh
        self._cache = cache
        self.clock = clock
        self._tenants: Dict[str, Tenant] = {}
        self._lock = threading.Lock()
        self._shared_session: Optional[Session] = None
        # One engine lock for ALL services over the shared engine: flushes
        # and prescreen lookups across shared tenants serialize here.
        self._shared_engine_lock = threading.Lock()

    # --------------------------------------------------------------- tenants
    def add_tenant(self, spec) -> Tenant:
        """Register a tenant (a ``TenantSpec`` or just a name)."""
        if isinstance(spec, str):
            spec = TenantSpec(spec)
        with self._lock:
            if spec.name in self._tenants:
                raise ValueError(f"tenant {spec.name!r} already registered")
            now = self.clock()
            if spec.isolation == "shared":
                if self._shared_session is None:
                    self._shared_session = connect(
                        self._relation, self._config, mesh=self._mesh,
                        cache=self._cache)
                session = Session.attached(self._shared_session,
                                           tenant=spec.name)
                tenant = Tenant(spec, session, self._shared_engine_lock, now)
            else:
                session = connect(self._relation, self._config,
                                  cache=self._cache, tenant=spec.name)
                tenant = Tenant(spec, session, None, now)
            self._tenants[spec.name] = tenant
            return tenant

    def tenant(self, name: str) -> Tenant:
        try:
            return self._tenants[name]
        except KeyError:
            raise KeyError(
                f"unknown tenant {name!r}; registered: "
                f"{sorted(self._tenants)}") from None

    @property
    def tenants(self) -> Dict[str, Tenant]:
        return dict(self._tenants)

    # --------------------------------------------------------------- serving
    def _admit(self, tenant: Tenant) -> Optional[Rejection]:
        rejection = tenant.admission.admit(self.clock(),
                                           tenant.service.pending)
        if rejection is not None:
            tenant.metrics.record_rejection(rejection)
        return rejection

    def execute(self, name: str, query, budget: Optional[ErrorBudget] = None):
        """Run one query for ``name``; returns an answer-ladder value.

        ``Rejection`` (admission refused — the query never executed),
        ``QueryAnswer`` (possibly ``degraded``), or ``FailedAnswer``
        (terminal fault after the service's retry+bisect ladder). With no
        per-request ``budget``, the query rides the tenant's microbatch
        service (coalescing with concurrent submitters under the tenant's
        default budget); an explicit budget executes directly through the
        tenant's session under the same engine lock.
        """
        tenant = self.tenant(name)
        rejection = self._admit(tenant)
        if rejection is not None:
            return rejection
        t0 = self.clock()
        if budget is None:
            ans = tenant.service.submit(query).result()
        else:
            with tenant.service._exec_lock:
                ans = tenant.session.execute(query, budget=budget)
        pre = (getattr(ans, "served_from", None) or "").startswith("cache:")
        tenant.metrics.record_outcome(ans, self.clock() - t0, op="execute",
                                      prescreened=pre)
        return ans

    def explain(self, name: str, query,
                budget: Optional[ErrorBudget] = None):
        """Plan report for ``name``'s query (read-only; still admitted,
        still serialized on the engine lock — it reads shared store
        state)."""
        tenant = self.tenant(name)
        rejection = self._admit(tenant)
        if rejection is not None:
            return rejection
        t0 = self.clock()
        with tenant.service._exec_lock:
            report = tenant.session.explain(query, budget=budget)
        tenant.metrics.record_outcome(report, self.clock() - t0, op="explain")
        return report

    def stream(self, name: str, query,
               budget: Optional[ErrorBudget] = None) -> Iterator:
        """Online-aggregation stream: per-batch refined ``QueryAnswer``s.

        Yields ``session.stream``'s refinements (last one ``final=True``,
        bit-for-bit the ``execute`` answer under the same budget). A
        ``Rejection`` is yielded alone when admission refuses. The whole
        stream holds the engine lock — a shared tenant's stream serializes
        with its neighbors exactly like any other engine access.
        """
        tenant = self.tenant(name)
        rejection = self._admit(tenant)
        if rejection is not None:
            yield rejection
            return
        t0 = self.clock()
        rounds = 0
        with tenant.service._exec_lock:
            for ans in tenant.session.stream(query, budget=budget):
                rounds += 1
                yield ans
        tenant.metrics.record_stream(rounds, self.clock() - t0)

    # ----------------------------------------------------------------- stats
    def stats(self, name: Optional[str] = None) -> dict:
        """Per-tenant observability (one tenant, or all + front totals).

        Each tenant block: admission counters (admitted / typed rejections
        by reason), outcome counters + latency histograms, microbatch
        service counters, quarantine state. The front block adds the shared
        intel plane's per-tenant hit rates.
        """
        if name is not None:
            return self.tenant(name).stats()
        shared = self._shared_session
        intel = shared.intel.stats() if (shared is not None
                                         and shared.intel is not None) else {
            "enabled": False}
        return {
            "tenants": {n: t.stats() for n, t in sorted(self._tenants.items())},
            "shared_intel": intel,
        }
