"""Admission control for the multi-tenant serving front.

Token-bucket rate limiting plus a bounded microbatch queue, per tenant —
BlinkDB's "bounded response time" contract starts here: a tenant that
exceeds its budget gets a typed ``Rejection`` (never an exception, never an
unbounded queue), with a ``retry_after_s`` hint so well-behaved clients can
back off instead of hammering.

Determinism (analysis rule A008): this module never reads the wall clock
and never draws randomness. Every decision is a pure function of the
injected ``now`` timestamp and the controller's own state, so an admission
trace replays exactly from a recorded (or synthetic) clock — the replay
tests drive a fake clock through the same code paths production runs.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Rejection:
    """Typed admission refusal for ONE request (never an exception).

    Mirrors the answer ladder's ``failed`` discriminator so serving code
    can branch uniformly: ``QueryAnswer.failed`` is False,
    ``FailedAnswer.failed`` is True, and a ``Rejection`` is ``rejected``
    before it ever becomes an answer at all.
    """

    reason: str  # "rate_limit" | "queue_full"
    tenant: str
    retry_after_s: float
    detail: str = ""

    @property
    def rejected(self) -> bool:
        return True

    @property
    def failed(self) -> bool:
        return False

    @property
    def status(self) -> int:
        """The HTTP status the transport maps this to."""
        return 429 if self.reason == "rate_limit" else 503

    def __str__(self) -> str:
        return (f"Rejection({self.reason} for {self.tenant}; "
                f"retry after {self.retry_after_s:.3f}s)")


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Per-tenant admission knobs.

    rate: token refill per second (sustained requests/sec); <= 0 disables
        rate limiting for the tenant.
    burst: bucket capacity — the instantaneous burst a cold tenant may
        spend before the sustained rate binds.
    max_pending: bound on the tenant's microbatch queue depth (submitted
        but not yet flushed); beyond it requests are rejected
        ``queue_full`` instead of growing the queue without bound.
    """

    rate: float = 50.0
    burst: int = 20
    max_pending: int = 256


class TokenBucket:
    """The classic token bucket, clock-free: callers supply ``now``.

    Fractional tokens accumulate continuously at ``rate`` per second up to
    ``burst``; ``try_take(now)`` spends one. Monotonic ``now`` values are
    the caller's contract (the front passes ``time.monotonic()``; replay
    tests pass a scripted sequence).
    """

    def __init__(self, rate: float, burst: int, now: float = 0.0):
        self.rate = float(rate)
        self.burst = float(burst)
        self._tokens = float(burst)
        self._stamp = float(now)

    def _refill(self, now: float):
        if now > self._stamp:
            self._tokens = min(self.burst,
                               self._tokens + (now - self._stamp) * self.rate)
        self._stamp = max(self._stamp, now)

    def try_take(self, now: float) -> bool:
        self._refill(now)
        if self._tokens >= 1.0:
            self._tokens -= 1.0
            return True
        return False

    def retry_after(self, now: float) -> float:
        """Seconds until one full token exists (0 when one is available)."""
        self._refill(now)
        if self._tokens >= 1.0:
            return 0.0
        if self.rate <= 0.0:
            return float("inf")
        return (1.0 - self._tokens) / self.rate


class AdmissionController:
    """One tenant's admission gate: token bucket + queue-depth bound.

    ``admit(now, queue_depth)`` returns ``None`` (admitted) or a typed
    ``Rejection``. Thread-safe: concurrent request handlers for one tenant
    serialize on the controller's lock, so token accounting never races.
    """

    def __init__(self, tenant: str, config: Optional[AdmissionConfig] = None,
                 now: float = 0.0):
        self.tenant = tenant
        self.config = config or AdmissionConfig()
        self._bucket = (TokenBucket(self.config.rate, self.config.burst, now)
                        if self.config.rate > 0 else None)
        self._lock = threading.Lock()
        self.admitted = 0
        self.rejected_rate = 0
        self.rejected_queue = 0

    def admit(self, now: float, queue_depth: int) -> Optional[Rejection]:
        with self._lock:
            if queue_depth >= self.config.max_pending:
                self.rejected_queue += 1
                return Rejection(
                    "queue_full", self.tenant,
                    # The queue drains a whole microbatch per flush; one
                    # token period is the natural retry hint.
                    retry_after_s=(1.0 / self.config.rate
                                   if self.config.rate > 0 else 1.0),
                    detail=f"{queue_depth} pending >= "
                           f"max_pending={self.config.max_pending}")
            if self._bucket is not None and not self._bucket.try_take(now):
                self.rejected_rate += 1
                return Rejection(
                    "rate_limit", self.tenant,
                    retry_after_s=self._bucket.retry_after(now),
                    detail=f"sustained rate {self.config.rate}/s, "
                           f"burst {self.config.burst}")
            self.admitted += 1
            return None

    def stats(self) -> dict:
        with self._lock:
            return {
                "admitted": self.admitted,
                "rejected_rate_limit": self.rejected_rate,
                "rejected_queue_full": self.rejected_queue,
                "rate": self.config.rate,
                "burst": self.config.burst,
                "max_pending": self.config.max_pending,
            }
