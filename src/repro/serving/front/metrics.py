"""Per-tenant serving observability: latency histograms + outcome counters.

Each tenant of the serving front gets one ``TenantMetrics`` block: log2-
bucketed latency histograms per operation (execute/explain/stream) and
counters over the full outcome ladder — answered, degraded (deadline or
quarantine), failed (typed ``FailedAnswer``), rejected (by admission
reason), prescreen hits. The front merges these with the admission and
workload-intel counters into ``ServingFront.stats()``.

Determinism (analysis rule A008): like ``admission``, this module never
reads a clock — latencies arrive as plain float durations measured by the
transport layer. Histogram bucketing is a pure function of the duration.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List


class LatencyHistogram:
    """Log2-bucketed latency histogram over (0, +inf) seconds.

    Bucket ``i`` covers ``[2**(i + LOW), 2**(i + LOW + 1))`` with ``LOW``
    = -20 (~1 microsecond); durations below the first bucket clamp into
    it, above the last into the last. 40 buckets span ~1us to ~17min.
    Quantiles interpolate within the winning bucket, which is exactly the
    fidelity a serving dashboard needs and cheap enough for the hot path.
    """

    LOW = -20
    N = 40

    def __init__(self):
        self.counts: List[int] = [0] * self.N
        self.total = 0
        self.sum_s = 0.0
        self.max_s = 0.0

    def _bucket(self, seconds: float) -> int:
        if seconds <= 0.0:
            return 0
        i = int(math.floor(math.log2(seconds))) - self.LOW
        return min(max(i, 0), self.N - 1)

    def record(self, seconds: float):
        self.counts[self._bucket(seconds)] += 1
        self.total += 1
        self.sum_s += seconds
        if seconds > self.max_s:
            self.max_s = seconds

    def quantile(self, q: float) -> float:
        """Approximate quantile (bucket lower edge) in seconds."""
        if self.total == 0:
            return 0.0
        rank = q * self.total
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                return 2.0 ** (i + self.LOW)
        return self.max_s

    def snapshot(self) -> dict:
        return {
            "count": self.total,
            "mean_s": self.sum_s / max(self.total, 1),
            "max_s": self.max_s,
            "p50_s": self.quantile(0.50),
            "p90_s": self.quantile(0.90),
            "p99_s": self.quantile(0.99),
        }


class TenantMetrics:
    """One tenant's serving-outcome counters + per-op latency histograms.

    Outcomes partition every request: ``answered`` (full-accuracy),
    ``degraded`` (honest but weaker — deadline best-so-far or quarantined
    keys), ``failed`` (typed ``FailedAnswer``), ``rejected_*`` (admission
    turned it away before execution). ``prescreen_hits`` counts answers the
    workload-intel cache served at submit without a microbatch slot.
    """

    def __init__(self, tenant: str):
        self.tenant = tenant
        self._lock = threading.Lock()
        self.latency: Dict[str, LatencyHistogram] = {}
        self.answered = 0
        self.degraded = 0
        self.failed = 0
        self.rejected: Dict[str, int] = {}
        self.prescreen_hits = 0
        self.streams = 0
        self.stream_rounds = 0

    def record_outcome(self, answer, duration_s: float, op: str = "execute",
                       prescreened: bool = False):
        """Classify one resolved answer into the outcome ladder."""
        with self._lock:
            self.latency.setdefault(op, LatencyHistogram()).record(duration_s)
            if getattr(answer, "failed", False):
                self.failed += 1
            elif getattr(answer, "degraded", False):
                self.degraded += 1
            else:
                self.answered += 1
            if prescreened:
                self.prescreen_hits += 1

    def record_rejection(self, rejection):
        with self._lock:
            self.rejected[rejection.reason] = (
                self.rejected.get(rejection.reason, 0) + 1)

    def record_stream(self, rounds: int, duration_s: float):
        with self._lock:
            self.latency.setdefault(
                "stream", LatencyHistogram()).record(duration_s)
            self.streams += 1
            self.stream_rounds += rounds

    def snapshot(self) -> dict:
        with self._lock:
            executed = self.answered + self.degraded + self.failed
            rejected = sum(self.rejected.values())
            return {
                "tenant": self.tenant,
                "requests": executed + rejected,
                "answered": self.answered,
                "degraded": self.degraded,
                "failed": self.failed,
                "rejected": dict(self.rejected),
                "prescreen_hits": self.prescreen_hits,
                "prescreen_hit_rate": self.prescreen_hits / max(executed, 1),
                "streams": self.streams,
                "stream_rounds": self.stream_rounds,
                "latency": {op: h.snapshot()
                            for op, h in sorted(self.latency.items())},
            }
