"""JSON wire codec for the serving front.

One request/response vocabulary shared by the HTTP transport and the load
bench: queries arrive as plain JSON and lower through the SAME typed
``QueryBuilder`` the in-process facade uses (so wire queries hit the exact
engine paths session queries do — nothing is re-implemented at the edge),
budgets lower to ``ErrorBudget``, and every rung of the answer ladder
(``QueryAnswer`` / ``FailedAnswer`` / ``Rejection``) serializes to a typed
JSON object discriminated by ``"kind"``.

Query JSON shape::

    {"aggs": [{"kind": "avg", "measure": "v0"}, {"kind": "count"}],
     "where": [{"op": "between", "column": "x0", "lo": 2, "hi": 8},
               {"op": "equals", "column": "c0", "value": 3},
               {"op": "one_of", "column": "c1", "values": [0, 2]}],
     "group_by": ["c0"]}

Budget JSON shape (all keys optional)::

    {"target_rel_error": 0.05, "max_batches": 4, "delta": 0.95,
     "deadline_s": 0.5}
"""
from __future__ import annotations

from typing import Optional

from repro.verdict.answer import FailedAnswer, QueryAnswer
from repro.verdict.query import QueryBuilder, between, equals, one_of
from repro.verdict.session import ErrorBudget


class WireError(ValueError):
    """Malformed request JSON — the transport maps this to HTTP 400."""


_AGG_KINDS = {"avg", "sum", "count", "min", "max"}


def query_from_json(schema, obj: dict) -> QueryBuilder:
    """Lower a query JSON object to a ``QueryBuilder`` over ``schema``.

    Raises ``WireError`` on unknown aggregate kinds, predicate ops, or
    column names (the builder's own ``KeyError`` is re-raised as
    ``WireError`` so the transport can 400 it with the message intact).
    """
    if not isinstance(obj, dict):
        raise WireError(f"query must be a JSON object, got {type(obj).__name__}")
    qb = QueryBuilder(schema)
    aggs = obj.get("aggs")
    if not aggs:
        raise WireError('query needs a non-empty "aggs" list')
    try:
        for a in aggs:
            kind = str(a.get("kind", "")).lower()
            if kind not in _AGG_KINDS:
                raise WireError(
                    f"unknown aggregate kind {kind!r}; "
                    f"expected one of {sorted(_AGG_KINDS)}")
            if kind == "count":
                qb.count()
            else:
                if "measure" not in a:
                    raise WireError(f'aggregate {kind!r} needs a "measure"')
                getattr(qb, kind)(a["measure"])
        for p in obj.get("where", ()):
            op = str(p.get("op", "")).lower()
            if op == "between":
                qb.where(between(p["column"], p["lo"], p["hi"]))
            elif op == "equals":
                qb.where(equals(p["column"], p["value"]))
            elif op == "one_of":
                qb.where(one_of(p["column"], p["values"]))
            else:
                raise WireError(
                    f"unknown predicate op {op!r}; "
                    "expected between | equals | one_of")
        gb = obj.get("group_by", ())
        if gb:
            qb.group_by(*gb)
        qb.build()  # validate eagerly: name resolution errors surface here
    except WireError:
        raise
    except (KeyError, TypeError, ValueError) as e:
        raise WireError(f"malformed query: {e}") from None
    return qb


def budget_from_json(obj: Optional[dict]) -> Optional[ErrorBudget]:
    """Lower a budget JSON object to an ``ErrorBudget`` (None passes)."""
    if obj is None:
        return None
    if not isinstance(obj, dict):
        raise WireError(
            f"budget must be a JSON object, got {type(obj).__name__}")
    known = {"target_rel_error", "max_batches", "delta", "deadline_s"}
    extra = set(obj) - known
    if extra:
        raise WireError(f"unknown budget keys {sorted(extra)}; "
                        f"expected a subset of {sorted(known)}")
    try:
        return ErrorBudget(
            target_rel_error=(None if obj.get("target_rel_error") is None
                              else float(obj["target_rel_error"])),
            max_batches=(None if obj.get("max_batches") is None
                         else int(obj["max_batches"])),
            delta=(None if obj.get("delta") is None
                   else float(obj["delta"])),
            deadline_s=(None if obj.get("deadline_s") is None
                        else float(obj["deadline_s"])),
        )
    except (TypeError, ValueError) as e:
        raise WireError(f"malformed budget: {e}") from None


def answer_to_json(ans) -> dict:
    """Serialize one answer-ladder value, discriminated by ``"kind"``.

    ``QueryAnswer`` -> ``{"kind": "answer", ...}``;
    ``FailedAnswer`` -> ``{"kind": "failed", ...}``;
    ``Rejection``    -> ``{"kind": "rejected", ...}``.
    """
    if isinstance(ans, QueryAnswer):
        return {
            "kind": "answer",
            "cells": [dict(c.to_dict(), group=list(c.group))
                      for c in ans.cells],
            "batches_used": ans.batches_used,
            "tuples_scanned": ans.tuples_scanned,
            "supported": ans.supported,
            "unsupported_reason": ans.unsupported_reason,
            "truncated_groups": ans.truncated_groups,
            "final": ans.final,
            "degraded": ans.degraded,
            "degraded_reasons": dict(ans.degraded_reasons),
            "served_from": ans.served_from,
        }
    if isinstance(ans, FailedAnswer):
        return {
            "kind": "failed",
            "error": ans.error,
            "error_type": ans.error_type,
            "attempts": ans.attempts,
        }
    # Rejection (duck-typed to avoid a circular import with admission).
    if getattr(ans, "rejected", False):
        return {
            "kind": "rejected",
            "reason": ans.reason,
            "tenant": ans.tenant,
            "retry_after_s": ans.retry_after_s,
            "detail": ans.detail,
        }
    raise TypeError(f"not an answer-ladder value: {type(ans).__name__}")


def report_to_json(report) -> dict:
    """Serialize a ``PlanReport`` (``explain``) — dict keys stringified
    because aggregate keys are tuples."""
    return {
        "kind": "plan",
        "supported": report.supported,
        "unsupported_reason": report.unsupported_reason,
        "n_cells": report.n_cells,
        "n_groups": report.n_groups,
        "truncated_groups": report.truncated_groups,
        "n_snippets": report.n_snippets,
        "n_snippets_unique": report.n_snippets_unique,
        "dedup_ratio": report.dedup_ratio,
        "q_buckets": {str(k): v for k, v in report.q_buckets.items()},
        "fill_buckets": {str(k): v for k, v in report.fill_buckets.items()},
        "placement": {str(k): v for k, v in report.placement.items()},
        "scan_placement": report.scan_placement,
        "scan_evaluator": report.scan_evaluator,
        "quarantined": dict(report.quarantined),
        "cache": report.cache,
        "route": report.route,
    }
