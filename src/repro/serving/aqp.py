"""Microbatching AQP front: collect operator queries, flush one fused scan.

The serving-side counterpart of ``repro.aqp.batch``: concurrent dashboard
clients submit ``AggQuery``s; the service coalesces up to ``max_batch``
requests and executes them through ``BatchExecutor`` under the service-wide
``target_rel_error``, so the relation's sample batches are scanned once per
flush instead of once per request. Tickets resolve to ``QueryResult``s after
the flush — the classic serving microbatch pattern (cf. decode-step batching
in ``repro.serving.engine``) applied to query answering.

Concurrency: the queue and ticket bookkeeping mutate only under the service
lock, the engine itself is driven under a separate execution lock (pass
``engine_lock=`` to share it between services whose engines share learned
state — the multi-tenant front does), and every ticket carries an event so
``Ticket.result()`` from one thread waits correctly for a flush running on
another. Every ticket resolves exactly once (``Ticket.resolutions``).

Fault isolation (the serving half of the degraded-mode contract): one poison
query can no longer strand its microbatch. ``flush`` retries a failed fused
execution with bounded exponential backoff (transient faults — e.g. a
``max_fires``-limited injected fault — clear on retry), then BISECTS the
batch to isolate the poison query, which resolves as a typed
``FailedAnswer`` on its own ticket while every other ticket still gets its
real answer. A ``finally`` backstop guarantees no ticket ever hangs, even if
the isolation machinery itself dies. Per-query wall-clock ``deadline_s``
(threaded from ``ErrorBudget.deadline_s``) bounds response time: on expiry
the best-so-far answer returns with its honest wider CI.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional

from repro.aqp.batch import BatchExecutor, BatchStats
from repro.aqp.queries import AggQuery
from repro.verdict.answer import FailedAnswer


@dataclasses.dataclass
class Ticket:
    """Handle for one submitted query; resolved by the owning flush.

    The result is stored on the ticket itself, so a long-lived service
    retains nothing once callers drop their tickets. ``resolutions`` counts
    resolve calls — the exactly-once contract the concurrency tests pin.
    """

    _service: "AqpService"
    _result: object = None
    _done: bool = False
    resolutions: int = 0
    _event: threading.Event = dataclasses.field(
        default_factory=threading.Event)

    def result(self, timeout: Optional[float] = None):
        """The query's ``QueryResult`` (flushes the queue if still pending).

        Safe under concurrency: if another thread's flush owns this ticket's
        batch, the local ``flush()`` finds an empty queue and this call
        waits on the ticket's event instead of returning a premature None.
        """
        if not self._done:
            self._service.flush()
        if not self._event.wait(timeout):
            raise TimeoutError("ticket unresolved after "
                               f"{timeout}s (flush still in flight?)")
        return self._result

    def _resolve(self, result) -> None:
        self._result = result
        self.resolutions += 1
        self._done = True
        self._event.set()


class AqpService:
    """Thread-safe synchronous microbatcher over one ``VerdictEngine``.

    ``max_batch``: auto-flush threshold; ``target_rel_error`` /
    ``max_batches`` / ``stop_delta``: the error-budget contract applied to
    every flush (per the batched engine's per-query early stopping);
    ``mesh``: optional device mesh for the sharded scan path;
    ``tenant``: optional tenant label threaded into the workload-intel
    per-tenant counters; ``engine_lock``: pass one lock to every service
    sharing an engine (shared-store tenancy) so engine execution — and the
    intel prescreen that mutates shared cache state — serializes across
    them while isolated engines keep scanning in parallel.
    """

    def __init__(self, engine, max_batch: int = 64,
                 target_rel_error: Optional[float] = None, mesh=None,
                 max_batches: Optional[int] = None,
                 stop_delta: Optional[float] = None,
                 result_wrapper=None,
                 deadline_s: Optional[float] = None,
                 max_retries: int = 2,
                 backoff_base_s: float = 0.01,
                 backoff_max_s: float = 0.5,
                 tenant: Optional[str] = None,
                 engine_lock: Optional[threading.Lock] = None):
        # Accept either a raw VerdictEngine or a repro.verdict Session.
        self.engine = getattr(engine, "engine", engine)
        self.max_batch = int(max_batch)
        self.target_rel_error = target_rel_error
        self.max_batches = max_batches
        self.stop_delta = stop_delta
        # Per-query wall-clock deadline (ErrorBudget.deadline_s): expiry
        # returns the best-so-far answer, degraded + honest, never blocks.
        self.deadline_s = deadline_s
        # Slice retry budget + bounded exponential backoff between attempts
        # (the failed fused execution retries WHOLE first — transient faults
        # clear without bisecting — then bisection isolates persistence).
        self.max_retries = int(max_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_max_s = float(backoff_max_s)
        # Applied to every QueryResult before it lands on a ticket —
        # Session.serve passes QueryAnswer.from_result so facade users get
        # the same typed answers session.execute returns.
        self.result_wrapper = result_wrapper
        self.tenant = tenant
        self.executor = BatchExecutor(self.engine, mesh=mesh)
        self._queue: List[tuple] = []  # (query, ticket) pairs
        # Queue/counter bookkeeping lock (never held across an engine call).
        self._lock = threading.Lock()
        # Engine execution lock: one flush (or prescreen) drives the engine
        # at a time; shared across services when their engines are shared.
        self._exec_lock = engine_lock if engine_lock is not None \
            else threading.Lock()
        self.flushes = 0
        # Queries resolved at submit() by the workload-intelligence answer
        # cache (repro.intel) — they never entered a microbatch.
        self.prescreened = 0
        self.last_stats: Optional[BatchStats] = None

    @property
    def pending(self) -> int:
        """Queries waiting for the next flush."""
        return len(self._queue)

    def submit(self, query: AggQuery) -> Ticket:
        """Enqueue one query; auto-flushes when the microbatch is full.

        Accepts an ``AggQuery`` or anything with ``.build()`` (the facade's
        ``QueryBuilder``). Thread-safe: the append and the threshold check
        happen under one lock, so concurrent submitters can neither lose an
        entry nor double-flush the same batch.
        """
        if not isinstance(query, AggQuery) and hasattr(query, "build"):
            query = query.build()
        ticket = Ticket(self)
        # Workload-intelligence pre-screen: a semantic-cache hit resolves
        # the ticket immediately — it never occupies a microbatch slot, so
        # repeated dashboard queries stop forcing flush cycles at all. The
        # lookup mutates shared LRU/counter state, so it runs under the
        # engine lock like every other engine-state access.
        intel = getattr(self.engine, "intel", None)
        if intel is not None:
            with self._exec_lock:
                served = intel.lookup(
                    self.engine, query,
                    target_rel_error=self.target_rel_error,
                    stop_delta=self.stop_delta, max_batches=self.max_batches,
                    tenant=self.tenant)
            if served is not None:
                if self.result_wrapper is not None:
                    served = self.result_wrapper(served)
                with self._lock:
                    self.prescreened += 1
                ticket._resolve(served)
                return ticket
        with self._lock:
            self._queue.append((query, ticket))
            full = len(self._queue) >= self.max_batch
        if full:
            self.flush()
        return ticket

    def _execute_slice(self, queries: List[AggQuery]) -> List:
        return self.executor.execute_many(
            queries,
            target_rel_error=self.target_rel_error,
            max_batches=self.max_batches,
            stop_delta=self.stop_delta,
            deadline_s=self.deadline_s,
            tenant=self.tenant,
        )

    def _resolve(self, queries: List[AggQuery], idxs: List[int],
                 results: List, counts: Dict[int, int],
                 top: bool = True) -> None:
        """Fill ``results[i]`` for every ``i`` in ``idxs``: on failure retry
        the SAME slice with bounded exponential backoff first (a transient
        fault clears on re-run without costing the O(log n) bisect), then
        bisect to isolate the poison query, and give a terminal failure a
        typed ``FailedAnswer`` — never an exception.

        Bisected sub-slices skip the multi-query retry (the transient
        hypothesis was already spent at the top level); single queries
        always retry, so a poison query gets its full budget before the
        typed failure. ``counts`` tracks ACTUAL executions per query index —
        ``FailedAnswer.attempts`` reports exactly how many times the query
        ran, not a retry-loop upper bound.

        Re-running a slice after a mid-batch failure can re-record some
        queries' raw answers; recording is idempotent at the synopsis level
        (duplicate snippets refresh LRU stamps and keep the better answer),
        so isolation never corrupts learned state.
        """
        def run():
            for i in idxs:
                counts[i] = counts.get(i, 0) + 1
            return self._execute_slice([queries[i] for i in idxs])

        try:
            out = run()
        except BaseException as e:  # noqa: BLE001 — isolate, then type it
            out = None
            retries = self.max_retries if (top or len(idxs) == 1) else 0
            for attempt in range(retries):
                time.sleep(min(self.backoff_base_s * 2 ** attempt,
                               self.backoff_max_s))
                try:
                    out = run()
                    break
                except BaseException as retry_e:  # noqa: BLE001
                    e = retry_e
            if out is None:
                if len(idxs) > 1:
                    mid = len(idxs) // 2
                    self._resolve(queries, idxs[:mid], results, counts,
                                  top=False)
                    self._resolve(queries, idxs[mid:], results, counts,
                                  top=False)
                else:
                    results[idxs[0]] = FailedAnswer(
                        error=repr(e), error_type=type(e).__name__,
                        attempts=counts[idxs[0]])
                return
        for i, r in zip(idxs, out):
            results[i] = r

    def flush(self) -> List:
        """Execute all pending queries in one fused scan.

        Every ticket RESOLVES, unconditionally and exactly once: to its
        (possibly wrapped) ``QueryResult``, or to a typed ``FailedAnswer``
        if its query keeps failing after retries and bisect isolation. The
        happy path is one fused ``execute_many`` exactly as before;
        isolation only engages on failure. Concurrent flushes serialize on
        the engine lock; the queue swap is atomic, so two racing flushes
        split the pending work instead of double-executing it.
        """
        with self._exec_lock:
            with self._lock:
                batch, self._queue = self._queue, []
            if not batch:
                return []
            queries = [q for q, _ in batch]
            results: List = [None] * len(batch)
            counts: Dict[int, int] = {}
            try:
                self._resolve(queries, list(range(len(batch))), results,
                              counts)
            finally:
                # Backstop: no ticket may ever hang or silently carry None,
                # even if the isolation machinery itself raised.
                out = []
                for (_, ticket), res in zip(batch, results):
                    if res is None:
                        res = FailedAnswer(
                            error="flush aborted before this query resolved",
                            error_type="RuntimeError", attempts=0)
                    elif (self.result_wrapper is not None
                          and not isinstance(res, FailedAnswer)):
                        res = self.result_wrapper(res)
                    ticket._resolve(res)
                    out.append(res)
                self.last_stats = self.executor.stats
                with self._lock:
                    self.flushes += 1
        return out

    def execute(self, queries: List[AggQuery]) -> List:
        """Convenience: submit a workload and return its results in order."""
        tickets = [self.submit(q) for q in queries]
        self.flush()
        return [t.result() for t in tickets]

    def drain(self):
        """Barrier over the engine's async synopsis ingest.

        Flushes never wait for learning — answers return while covariance
        builds catch up on the ingest threads (across every shard when the
        engine's store is sharded). Call this only at snapshot boundaries
        (checkpointing, refit, shutdown) where the fully-applied learned
        state is required.
        """
        self.engine.drain()

    def refit(self, **kw):
        """Offline learning boundary: drain pending ingest, then refit."""
        self.engine.refit(**kw)

    def heal(self, manager=None, step: Optional[int] = None) -> dict:
        """Heal quarantined synopses (optionally from a checkpoint's last
        good state) and rejoin them to serving; ``{state_key: healed}``."""
        return self.engine.heal(manager, step)

    def snapshot(self, manager, step: int):
        """Checkpoint the learned state (drains first; see repro.ft).

        Rides the store's structured-key, shard-tagged payload: a snapshot
        taken by a sharded service restores into a local one (and onto a
        different mesh shape) unchanged.
        """
        self.engine.save_synopses(manager, step)

    def stats(self) -> dict:
        """Operator snapshot: store placement/occupancy/back-pressure plus
        this service's microbatching counters and serving health."""
        from repro.ft import faults

        intel = getattr(self.engine, "intel", None)
        return {
            "store": self.engine.store.stats(),
            "tenant": self.tenant,
            "flushes": self.flushes,
            "pending": self.pending,
            "prescreened": self.prescreened,
            "health": {
                "quarantined": self.engine.store.quarantined(),
                "faults": faults.stats(),
            },
            "intel": (intel.stats() if intel is not None
                      else {"enabled": False}),
        }
