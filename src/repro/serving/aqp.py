"""Microbatching AQP front: collect operator queries, flush one fused scan.

The serving-side counterpart of ``repro.aqp.batch``: concurrent dashboard
clients submit ``AggQuery``s; the service coalesces up to ``max_batch``
requests and executes them through ``BatchExecutor`` under the service-wide
``target_rel_error``, so the relation's sample batches are scanned once per
flush instead of once per request. Tickets resolve to ``QueryResult``s after
the flush — the classic serving microbatch pattern (cf. decode-step batching
in ``repro.serving.engine``) applied to query answering.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.aqp.batch import BatchExecutor, BatchStats
from repro.aqp.queries import AggQuery


@dataclasses.dataclass
class Ticket:
    """Handle for one submitted query; resolved by the owning flush.

    The result is stored on the ticket itself, so a long-lived service
    retains nothing once callers drop their tickets.
    """

    _service: "AqpService"
    _result: object = None
    _done: bool = False

    def result(self):
        """The query's ``QueryResult`` (flushes the queue if still pending)."""
        if not self._done:
            self._service.flush()
        return self._result


class AqpService:
    """Synchronous microbatcher over one ``VerdictEngine``.

    ``max_batch``: auto-flush threshold; ``target_rel_error`` /
    ``max_batches`` / ``stop_delta``: the error-budget contract applied to
    every flush (per the batched engine's per-query early stopping);
    ``mesh``: optional device mesh for the sharded scan path.
    """

    def __init__(self, engine, max_batch: int = 64,
                 target_rel_error: Optional[float] = None, mesh=None,
                 max_batches: Optional[int] = None,
                 stop_delta: Optional[float] = None,
                 result_wrapper=None):
        # Accept either a raw VerdictEngine or a repro.verdict Session.
        self.engine = getattr(engine, "engine", engine)
        self.max_batch = int(max_batch)
        self.target_rel_error = target_rel_error
        self.max_batches = max_batches
        self.stop_delta = stop_delta
        # Applied to every QueryResult before it lands on a ticket —
        # Session.serve passes QueryAnswer.from_result so facade users get
        # the same typed answers session.execute returns.
        self.result_wrapper = result_wrapper
        self.executor = BatchExecutor(self.engine, mesh=mesh)
        self._queue: List[tuple] = []  # (query, ticket) pairs
        self.flushes = 0
        self.last_stats: Optional[BatchStats] = None

    @property
    def pending(self) -> int:
        """Queries waiting for the next flush."""
        return len(self._queue)

    def submit(self, query: AggQuery) -> Ticket:
        """Enqueue one query; auto-flushes when the microbatch is full.

        Accepts an ``AggQuery`` or anything with ``.build()`` (the facade's
        ``QueryBuilder``).
        """
        if not isinstance(query, AggQuery) and hasattr(query, "build"):
            query = query.build()
        ticket = Ticket(self)
        self._queue.append((query, ticket))
        if len(self._queue) >= self.max_batch:
            self.flush()
        return ticket

    def flush(self) -> List:
        """Execute all pending queries in one fused scan."""
        if not self._queue:
            return []
        batch, self._queue = self._queue, []
        results = self.executor.execute_many(
            [q for q, _ in batch],
            target_rel_error=self.target_rel_error,
            max_batches=self.max_batches,
            stop_delta=self.stop_delta,
        )
        if self.result_wrapper is not None:
            results = [self.result_wrapper(r) for r in results]
        for (_, ticket), res in zip(batch, results):
            ticket._result = res
            ticket._done = True
        self.last_stats = self.executor.stats
        self.flushes += 1
        return results

    def execute(self, queries: List[AggQuery]) -> List:
        """Convenience: submit a workload and return its results in order."""
        tickets = [self.submit(q) for q in queries]
        self.flush()
        return [t.result() for t in tickets]

    def drain(self):
        """Barrier over the engine's async synopsis ingest.

        Flushes never wait for learning — answers return while covariance
        builds catch up on the ingest threads (across every shard when the
        engine's store is sharded). Call this only at snapshot boundaries
        (checkpointing, refit, shutdown) where the fully-applied learned
        state is required.
        """
        self.engine.drain()

    def refit(self, **kw):
        """Offline learning boundary: drain pending ingest, then refit."""
        self.engine.refit(**kw)

    def snapshot(self, manager, step: int):
        """Checkpoint the learned state (drains first; see repro.ft).

        Rides the store's structured-key, shard-tagged payload: a snapshot
        taken by a sharded service restores into a local one (and onto a
        different mesh shape) unchanged.
        """
        self.engine.save_synopses(manager, step)

    def stats(self) -> dict:
        """Operator snapshot: store placement/occupancy/back-pressure plus
        this service's microbatching counters."""
        return {
            "store": self.engine.store.stats(),
            "flushes": self.flushes,
            "pending": self.pending,
        }
