"""Minimal gradient-based minimizer (Adam) used by offline model fitting.

optax is unavailable offline; this is a self-contained pytree Adam driven by
``jax.lax.scan`` so the full optimization is one compiled program.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def adam_minimize(
    loss_fn: Callable,
    params,
    *,
    steps: int = 200,
    lr: float = 0.05,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
):
    """Minimize ``loss_fn(params)`` with Adam; returns (params, loss_history)."""

    grad_fn = jax.value_and_grad(loss_fn)
    zeros = jax.tree.map(jnp.zeros_like, params)

    def step(carry, i):
        p, m, v = carry
        loss, g = grad_fn(p)
        # Guard against non-finite gradients (ill-conditioned Cholesky regions):
        # skip the update rather than poisoning the state.
        ok = jnp.isfinite(loss) & jax.tree_util.tree_reduce(
            lambda a, leaf: a & jnp.all(jnp.isfinite(leaf)), g, jnp.bool_(True)
        )
        m = jax.tree.map(lambda m_, g_: b1 * m_ + (1 - b1) * jnp.where(ok, g_, 0.0), m, g)
        v = jax.tree.map(
            lambda v_, g_: b2 * v_ + (1 - b2) * jnp.where(ok, g_ * g_, 0.0), v, g
        )
        t = i + 1
        mhat = jax.tree.map(lambda m_: m_ / (1 - b1**t), m)
        vhat = jax.tree.map(lambda v_: v_ / (1 - b2**t), v)
        p = jax.tree.map(
            lambda p_, mh, vh: p_ - jnp.where(ok, lr * mh / (jnp.sqrt(vh) + eps), 0.0),
            p,
            mhat,
            vhat,
        )
        return (p, m, v), loss

    (params, _, _), hist = jax.lax.scan(
        step, (params, zeros, zeros), jnp.arange(steps, dtype=jnp.float64)
    )
    return params, hist
