"""Normal-distribution helpers used by error bounds and model validation."""
from __future__ import annotations

import jax.numpy as jnp
from jax.scipy.special import erfinv


def confidence_multiplier(delta):
    """alpha_delta: a standard normal falls within (-alpha, alpha) w.p. ``delta``.

    Section 3.4 of the paper ("confidence interval multiplier").
    """
    return jnp.sqrt(2.0) * erfinv(jnp.asarray(delta))
