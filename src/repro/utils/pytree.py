"""Small helpers for dataclass-based pytrees (no flax/equinox offline)."""
from __future__ import annotations

import dataclasses

import jax


def pytree_dataclass(cls=None, *, meta_fields: tuple = ()):
    """Register a frozen dataclass as a jax pytree.

    ``meta_fields`` are static (hashed into the treedef); everything else is a leaf
    subtree.
    """

    def wrap(c):
        c = dataclasses.dataclass(frozen=True)(c)
        data_fields = tuple(
            f.name for f in dataclasses.fields(c) if f.name not in meta_fields
        )
        jax.tree_util.register_dataclass(
            c, data_fields=data_fields, meta_fields=tuple(meta_fields)
        )
        return c

    return wrap(cls) if cls is not None else wrap


def replace(obj, **kw):
    return dataclasses.replace(obj, **kw)
