"""Learned-state plane throughput: LocalSynopsisStore vs ShardedSynopsisStore.

Measures the placement seam introduced by the SynopsisStore redesign on the
two store-side hot paths:

  - ``improve_groups``: a mixed multi-key snippet batch improved through the
    store's stacked dispatch (one fused program locally, one per shard when
    sharded);
  - ``record`` + ``drain``: async ingest of raw answers across every key,
    then the full barrier (the sharded store waits on all shards
    concurrently).

Also re-runs the answer oracle through the store seam: a sharded-store
engine must answer a workload bitwise-identically to a local-store engine —
the acceptance property the regression gate pins (placement moves FLOPs,
never values). On a single-device container the sharded store degenerates to
one shard; run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the ``sharded`` CI matrix job) for real multi-device placement.

And measures the scan plane's masked padding seam
(``repro.aqp.executor.eval_partials_sharded``): throughput of a
mesh-INDIVISIBLE tuple block (padded + validity-masked up to the tile) vs
the divisible same-tile block, plus the ``scan/padded_parity`` flag — the
padded sharded scan must stay BITWISE equal to the unsharded oracle across
a mini matrix of block sizes (the regression gate pins it; the full matrix
lives in ``tests/test_sharded_scan.py``).

    PYTHONPATH=src python benchmarks/shard_bench.py [--smoke] [--out f.json]

Prints ``name,value`` CSV rows plus one ``BENCH {json}`` line; ``--out``
writes the same JSON to a file (uploaded as a CI artifact).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.aqp import workload as W
from repro.core.engine import EngineConfig, VerdictEngine
from repro.core.store import LocalSynopsisStore, ShardedSynopsisStore
from repro.core.types import AVG, FREQ, RawAnswer, Schema, make_snippets


def _random_batch(rng, sch, n, agg=AVG, measure=0):
    ranges = []
    for _ in range(n):
        r = {}
        for d in range(sch.n_num):
            a = rng.uniform(0, 0.6)
            r[d] = (a, a + rng.uniform(0.05, 0.4))
        ranges.append(r)
    return make_snippets(sch, agg=agg, measure=measure, num_ranges=ranges)


def _mixed_batch(rng, sch, n_per_key, n_measures):
    """One snippet batch spanning every aggregate key (AVG per measure +
    FREQ) — the shape the stacked/partitioned dispatch fuses."""
    from repro.core.types import SnippetBatch

    parts = [_random_batch(rng, sch, n_per_key, agg=AVG, measure=m)
             for m in range(n_measures)]
    parts.append(_random_batch(rng, sch, n_per_key, agg=FREQ))
    return SnippetBatch.concat(parts)


def _build_store(kind, sch, cfg):
    if kind == "sharded":
        return ShardedSynopsisStore(sch, cfg)
    return LocalSynopsisStore(sch, cfg)


def bench_store_paths(n_measures, fill, n_per_key, iters, seed=0):
    """p50 improve_groups latency + record/drain throughput, both stores."""
    rng = np.random.default_rng(seed)
    sch = Schema(num_lo=(0.0, 0.0), num_hi=(1.0, 1.0), cat_sizes=(4,),
                 n_measures=n_measures)
    cfg = EngineConfig(capacity=max(2 * fill, 64))
    out = {"n_keys": n_measures + 1, "fill": fill,
           "devices": jax.device_count()}
    for kind in ("local", "sharded"):
        rngk = np.random.default_rng(seed + 1)
        store = _build_store(kind, sch, cfg)
        train = _mixed_batch(rngk, sch, fill, n_measures)
        store.record(train, RawAnswer(rngk.normal(1.0, 0.3, train.n),
                                      rngk.uniform(0.01, 0.05, train.n)))
        store.drain()
        new = _mixed_batch(rngk, sch, n_per_key, n_measures)
        raw = RawAnswer(jnp.asarray(rngk.normal(1.0, 0.3, new.n)),
                        jnp.asarray(np.full(new.n, 0.02)))
        store.improve_groups(new, raw)  # warm the per-shard programs
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            imp = store.improve_groups(new, raw)
            imp.theta.block_until_ready()
            times.append((time.perf_counter() - t0) * 1e3)
        p50 = float(np.percentile(times, 50))
        # Pre-generate the ingest batches: the timed region measures
        # record+drain, not host-side test-data construction.
        batches = []
        for _ in range(iters):
            b = _mixed_batch(rngk, sch, 4, n_measures)
            batches.append((b, RawAnswer(rngk.normal(1.0, 0.3, b.n),
                                         rngk.uniform(0.01, 0.05, b.n))))
        t0 = time.perf_counter()
        for b, r in batches:
            store.record(b, r)
        store.drain()
        ingest_s = time.perf_counter() - t0
        out[kind] = {
            "improve_p50_ms": p50,
            "ingest_batches_per_sec": iters / max(ingest_s, 1e-9),
            "drain_stats": store.ingest_stats(),
        }
    out["improve_sharded_over_local"] = (
        out["local"]["improve_p50_ms"]
        / max(out["sharded"]["improve_p50_ms"], 1e-9))
    return out


def bench_oracle_parity(n_queries, n_rows, seed=2):
    """Sharded-store answers vs the local-store oracle, bit for bit."""
    rel = W.make_relation(seed=seed, n_rows=n_rows, n_num=2, cat_sizes=(4,),
                          n_measures=2, lengthscale=0.4, noise=0.2)
    qs = W.make_workload(1, rel.schema, n_queries,
                         agg_kinds=("AVG", "COUNT", "SUM"), cat_pred_prob=0.3)
    cfg = dict(sample_rate=0.15, n_batches=4, capacity=256, seed=0)
    local = VerdictEngine(rel, EngineConfig(**cfg))
    shard = VerdictEngine(
        rel, EngineConfig(**cfg),
        store=lambda sch, c: ShardedSynopsisStore(sch, c))
    r_local = local.execute_many(qs)
    r_shard = shard.execute_many(qs)
    equal = all(a.cells == b.cells and a.batches_used == b.batches_used
                for a, b in zip(r_local, r_shard))
    local.drain(), shard.drain()
    local_sd = local.synopses_state_dict()
    shard_sd = shard.synopses_state_dict()
    state_equal = local_sd.keys() == shard_sd.keys()
    for name, sd in local_sd.items():
        other = shard_sd[name]
        state_equal = state_equal and all(
            np.array_equal(sd[k], other[k]) for k in sd if k != "shard")
    return {"n_queries": n_queries, "bitwise_equal": bool(equal),
            "state_equal": bool(state_equal),
            "devices": jax.device_count()}


def bench_padded_scan(tile, n_snippets, iters, seed=4):
    """Masked padded sharded-scan throughput + the bitwise parity flag.

    Compares ``eval_partials_sharded`` on a mesh-divisible ``tile``-row
    block (no padding) against an indivisible block of ``tile - tile//8 - 1``
    rows that pads back up to the same tile — the price of shape-agnosticism
    is the masked padding, so the two should track each other closely.
    """
    from jax.sharding import Mesh

    from repro.aqp.executor import eval_partials, eval_partials_sharded
    from repro.core.types import Schema, make_snippets, pad_snippets

    rng = np.random.default_rng(seed)
    sch = Schema(num_lo=(0.0, 0.0), num_hi=(1.0, 1.0), cat_sizes=(4,),
                 n_measures=2)
    ranges = []
    for _ in range(n_snippets):
        a = rng.uniform(0, 0.6)
        ranges.append({0: (a, a + rng.uniform(0.05, 0.4))})
    snippets = pad_snippets(make_snippets(sch, agg=0, measure=0,
                                          num_ranges=ranges))
    mesh = Mesh(np.array(jax.devices()), ("data",))

    def block(t):
        return (jnp.asarray(rng.uniform(0, 1, (t, 2))),
                jnp.asarray(rng.integers(0, 4, (t, 1)), np.int32),
                jnp.asarray(rng.normal(1.0, 0.5, (t, 2))))

    out = {"tile": tile, "devices": jax.device_count()}
    t_indiv = tile - tile // 8 - 1  # pads back up to the same tile
    for name, t in (("unpadded", tile), ("padded", t_indiv)):
        num, cat, meas = block(t)
        eval_partials_sharded(mesh, "data", num, cat, meas, snippets)  # warm
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            p = eval_partials_sharded(mesh, "data", num, cat, meas, snippets)
            p.sums.block_until_ready()
            times.append(time.perf_counter() - t0)
        p50 = float(np.percentile(times, 50))
        out[name] = {"rows": t, "p50_ms": p50 * 1e3,
                     "tuples_per_sec": t / max(p50, 1e-9)}
    out["padded_over_unpadded"] = (
        out["padded"]["tuples_per_sec"]
        / max(out["unpadded"]["tuples_per_sec"], 1e-9))
    # Bitwise parity mini-matrix (the full one is tests/test_sharded_scan.py).
    parity = True
    for t in (7, tile // 8 + 3, t_indiv):
        num, cat, meas = block(t)
        want = eval_partials(num, cat, meas, snippets)
        got = eval_partials_sharded(mesh, "data", num, cat, meas, snippets)
        for f in ("sums", "sumsq", "count", "scanned"):
            parity = parity and bool(
                np.array_equal(np.asarray(getattr(got, f)),
                               np.asarray(getattr(want, f))))
    out["padded_parity"] = float(parity)
    return out


def bench(smoke=False):
    if smoke:
        paths = bench_store_paths(n_measures=2, fill=32, n_per_key=8,
                                  iters=20)
        oracle = bench_oracle_parity(n_queries=6, n_rows=2_000)
        scan = bench_padded_scan(tile=1024, n_snippets=32, iters=20)
    else:
        paths = bench_store_paths(n_measures=4, fill=128, n_per_key=16,
                                  iters=40)
        oracle = bench_oracle_parity(n_queries=20, n_rows=20_000)
        scan = bench_padded_scan(tile=8192, n_snippets=128, iters=40)
    report = {"paths": paths, "oracle": oracle, "scan": scan}
    rows = [
        ("scan/padded_tuples_per_sec",
         scan["padded"]["tuples_per_sec"]),
        ("scan/unpadded_tuples_per_sec",
         scan["unpadded"]["tuples_per_sec"]),
        ("scan/padded_over_unpadded", scan["padded_over_unpadded"]),
        ("scan/padded_parity", scan["padded_parity"]),
        ("shard/improve_p50_local_ms", paths["local"]["improve_p50_ms"]),
        ("shard/improve_p50_sharded_ms", paths["sharded"]["improve_p50_ms"]),
        ("shard/improve_sharded_over_local",
         paths["improve_sharded_over_local"]),
        ("shard/ingest_local_batches_per_sec",
         paths["local"]["ingest_batches_per_sec"]),
        ("shard/ingest_sharded_batches_per_sec",
         paths["sharded"]["ingest_batches_per_sec"]),
        ("shard/oracle_bitwise_equal",
         float(oracle["bitwise_equal"] and oracle["state_equal"])),
    ]
    return rows, report


def run():
    """Entry point for ``benchmarks.run`` suite registration."""
    rows, _ = bench()
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, CI smoke: checks the path end-to-end")
    ap.add_argument("--out", default="",
                    help="write the BENCH JSON report to this file")
    args = ap.parse_args()
    rows, report = bench(smoke=args.smoke)
    for name, val in rows:
        print(f"{name},{val:.4g}")
    blob = json.dumps(report)
    print(f"BENCH {blob}")
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob + "\n")
    if not (report["oracle"]["bitwise_equal"]
            and report["oracle"]["state_equal"]
            and report["scan"]["padded_parity"]):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
