"""Kernel micro-benchmarks: us/call for the jnp oracle path (the CPU-real
number) and interpret-mode kernel validation timing (correctness path; TPU
wall-time comes from the dry-run roofline, not this container)."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp


def _timeit(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)

    # se_covariance oracle at synopsis scale (n=512, l=4)
    from repro.kernels.se_covariance.ref import se_cov_matrix_ref

    n, l = 512, 4
    lo = jnp.asarray(rng.uniform(0, 0.6, (n, l)))
    hi = lo + 0.2
    ls = jnp.ones((l,))
    norm = jnp.ones((n,))
    f = jax.jit(lambda a, b: se_cov_matrix_ref(a, b, a, b, ls, 1.0, norm, norm))
    rows.append(("kernel/se_covariance_ref_512x512_us", _timeit(f, lo, hi)))

    # range_mask_agg oracle at scan-block scale (T=65536, Q=128)
    from repro.kernels.range_mask_agg.ref import range_mask_agg_ref

    t, q = 65536, 128
    x = jnp.asarray(rng.uniform(0, 1, (t, 3)), jnp.float32)
    payload = jnp.asarray(rng.normal(size=(t, 5)), jnp.float32)
    qlo = jnp.asarray(rng.uniform(0, 0.6, (q, 3)), jnp.float32)
    qhi = qlo + 0.3
    em = jnp.ones((t, q), jnp.float32)
    g = jax.jit(range_mask_agg_ref)
    rows.append(("kernel/range_mask_agg_ref_64k_x128_us",
                 _timeit(g, x, payload, qlo, qhi, em)))

    # gp_batch_infer oracle at serving scale (Q=256, C=1024)
    from repro.kernels.gp_batch_infer.ref import gp_batch_infer_ref

    qn, c = 256, 1024
    k = jnp.asarray(rng.normal(0, 0.1, (qn, c)), jnp.float32)
    sinv = jnp.eye(c, dtype=jnp.float32)
    h = jax.jit(gp_batch_infer_ref)
    args = (k, sinv, jnp.zeros((c,), jnp.float32),
            jnp.ones((qn,), jnp.float32), jnp.zeros((qn,), jnp.float32),
            jnp.zeros((qn,), jnp.float32), jnp.full((qn,), 0.01, jnp.float32))
    rows.append(("kernel/gp_batch_infer_ref_256x1024_us", _timeit(h, *args)))
    return rows
