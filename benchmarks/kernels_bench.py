"""Kernel micro-benchmarks: us/call for the jnp oracle path (the CPU-real
number) and interpret-mode kernel validation timing (correctness path; TPU
wall-time comes from the dry-run roofline, not this container).

``scan_metrics`` is the CI-gated subset for the fused masked-scan kernel:
a bitwise-parity flag and a machine-portable roofline fraction (deterministic
BlockSpec traffic arithmetic — never wall-clock)."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp


def _timeit(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)

    # se_covariance oracle at synopsis scale (n=512, l=4)
    from repro.kernels.se_covariance.ref import se_cov_matrix_ref

    n, l = 512, 4
    lo = jnp.asarray(rng.uniform(0, 0.6, (n, l)))
    hi = lo + 0.2
    ls = jnp.ones((l,))
    norm = jnp.ones((n,))
    f = jax.jit(lambda a, b: se_cov_matrix_ref(a, b, a, b, ls, 1.0, norm, norm))
    rows.append(("kernel/se_covariance_ref_512x512_us", _timeit(f, lo, hi)))

    # range_mask_agg oracle at scan-block scale (T=65536, Q=128)
    from repro.kernels.range_mask_agg.ref import range_mask_agg_ref

    t, q = 65536, 128
    x = jnp.asarray(rng.uniform(0, 1, (t, 3)), jnp.float32)
    payload = jnp.asarray(rng.normal(size=(t, 5)), jnp.float32)
    qlo = jnp.asarray(rng.uniform(0, 0.6, (q, 3)), jnp.float32)
    qhi = qlo + 0.3
    em = jnp.ones((t, q), jnp.float32)
    g = jax.jit(range_mask_agg_ref)
    rows.append(("kernel/range_mask_agg_ref_64k_x128_us",
                 _timeit(g, x, payload, qlo, qhi, em)))

    # gp_batch_infer oracle at serving scale (Q=256, C=1024)
    from repro.kernels.gp_batch_infer.ref import gp_batch_infer_ref

    qn, c = 256, 1024
    k = jnp.asarray(rng.normal(0, 0.1, (qn, c)), jnp.float32)
    sinv = jnp.eye(c, dtype=jnp.float32)
    h = jax.jit(gp_batch_infer_ref)
    args = (k, sinv, jnp.zeros((c,), jnp.float32),
            jnp.ones((qn,), jnp.float32), jnp.zeros((qn,), jnp.float32),
            jnp.zeros((qn,), jnp.float32), jnp.full((qn,), 0.01, jnp.float32))
    rows.append(("kernel/gp_batch_infer_ref_256x1024_us", _timeit(h, *args)))

    # fused masked scan: the canonical fold (jnp, CPU-real) at 64k x 128
    from repro.aqp.executor import eval_partials

    cat = jnp.asarray(rng.integers(0, 4, (t, 1)), jnp.int32)
    meas = jnp.asarray(rng.normal(size=(t, 2)))
    snips = _scan_snippets()
    s = jax.jit(eval_partials)
    rows.append(("kernel/fused_scan_oracle_64k_x128_us",
                 _timeit(s, jnp.asarray(rng.uniform(0, 1, (t, 2))), cat,
                         meas, snips)))
    rows.extend(scan_metrics())
    return rows


# --------------------------------------------------------- fused-scan gate
def _scan_snippets(n: int = 5):
    from repro.core.types import Schema, make_snippets, pad_snippets

    sch = Schema(num_lo=(0.0, 0.0), num_hi=(1.0, 1.0), cat_sizes=(4,),
                 n_measures=2)
    return pad_snippets(make_snippets(
        sch, agg=[0] * n, measure=[0] * n,
        num_ranges=[{0: (0.1 * i, 0.1 * i + 0.5)} for i in range(n)]))


def fused_scan_traffic_bytes(t_n: int, q_n: int, l: int, c: int, vmax: int,
                             m: int, tile_t: int, tile_q: int) -> float:
    """HBM traffic of one fused-kernel pass, from its BlockSpec tile model.

    Per snippet tile the relation streams through VMEM once (x f64, codes
    i32, valid f64, payload [m, m^2, 1] f64); lo/hi/cat are fetched once per
    snippet tile and the (Q, 2m+1) accumulator is written once. No (T, Q)
    mask ever touches HBM — that is the fusion; un-fusing it adds
    ~2*T*Q*8 bytes and collapses the roofline fraction below."""
    p = 2 * m + 1
    q_tiles = -(-q_n // tile_q)
    stream = q_tiles * t_n * (l * 8 + c * 4 + 1 * 8 + p * 8)
    snippet_side = q_n * (2 * l + c * vmax) * 8
    out = q_n * p * 8
    return float(stream + snippet_side + out)


def min_relation_stream_bytes(t_n: int, l: int, c: int, m: int) -> float:
    """The un-beatable floor: every relation byte read exactly once."""
    return float(t_n * (l * 8 + c * 4 + m * 8))


def scan_metrics():
    """CI-gated fused-scan metrics (machine-portable, no wall-clock).

    scan/kernel_bitwise_parity -- 1.0 iff fused-kernel partials equal the
        jnp oracle BIT FOR BIT on a mini parity matrix: tuple counts
        {1, 100, 1000}, a validity-masked padded block, and the
        aggregation-only (sharded gathered-mask) kernel leg.
    scan/bytes_per_sec_frac_of_peak -- achieved fraction of HBM peak
        bandwidth on the roofline model: with the kernel memory-bound at
        peak (memory_s = traffic / HBM_BW, see repro.launch.roofline), the
        useful byte rate is HBM_BW * min_stream / traffic. Deterministic
        BlockSpec arithmetic, so the gate is meaningful on any runner.
    """
    from repro.aqp.executor import eval_partials, pad_tuple_axis, \
        predicate_mask
    from repro.kernels import SCAN_TILE_Q, SCAN_TILE_T
    from repro.kernels.fused_masked_scan import (eval_partials_fused,
                                                 masked_partials_fused)

    rng = np.random.default_rng(7)
    snips = _scan_snippets()
    parity = 1.0

    def _bitwise(a, b):
        return all(
            np.array_equal(np.asarray(getattr(a, f)),
                           np.asarray(getattr(b, f)))
            for f in ("sums", "sumsq", "count", "scanned"))

    for t in (1, 100, 1000):
        num = jnp.asarray(rng.uniform(0, 1, (t, 2)))
        cat = jnp.asarray(rng.integers(0, 4, (t, 1)), jnp.int32)
        meas = jnp.asarray(rng.normal(size=(t, 2)))
        want = eval_partials(num, cat, meas, snips)
        parity *= float(_bitwise(eval_partials_fused(num, cat, meas, snips),
                                 want))
        mask = predicate_mask(num, cat, snips)
        parity *= float(_bitwise(
            masked_partials_fused(mask, meas, snips, want.scanned), want))
    num_p, cat_p, meas_p, valid = pad_tuple_axis(
        8, num, cat, meas)  # 1000 -> 1024: a genuinely padded block
    parity *= float(_bitwise(
        eval_partials_fused(num_p, cat_p, meas_p, snips, valid),
        eval_partials(num_p, cat_p, meas_p, snips, valid)))

    t_n, q_n, l, c, vmax, m = 65536, 128, 2, 1, 4, 2
    frac = (min_relation_stream_bytes(t_n, l, c, m)
            / fused_scan_traffic_bytes(t_n, q_n, l, c, vmax, m,
                                       SCAN_TILE_T, SCAN_TILE_Q))
    return [("scan/kernel_bitwise_parity", parity),
            ("scan/bytes_per_sec_frac_of_peak", frac)]


def scan_roofline_rows():
    """Roofline-report rows for the scan plane (reported, not gated).

    Contrasts the fused kernel's modeled HBM traffic against the compiled
    jnp oracle's XLA ``bytes accessed`` (the mask materialization the fusion
    eliminates), converts both to memory-bound seconds at HBM peak
    (``repro.launch.roofline``), and runs ``repro.launch.hlo_analysis`` over
    the sharded mask builder's post-SPMD HLO to certify the mask build is
    collective-free (the only cross-device traffic is the final gather).
    """
    from repro.aqp.executor import eval_partials
    from repro.kernels import SCAN_TILE_Q, SCAN_TILE_T
    from repro.launch.roofline import HBM_BW

    rng = np.random.default_rng(11)
    t_n, q_n, l, c, vmax, m = 65536, 128, 2, 1, 4, 2
    num = jnp.asarray(rng.uniform(0, 1, (t_n, l)))
    cat = jnp.asarray(rng.integers(0, vmax, (t_n, c)), jnp.int32)
    meas = jnp.asarray(rng.normal(size=(t_n, m)))
    snips = _scan_snippets()

    fused_bytes = fused_scan_traffic_bytes(t_n, q_n, l, c, vmax, m,
                                           SCAN_TILE_T, SCAN_TILE_Q)
    rows = [
        ("scan/fused_hbm_model_bytes", fused_bytes),
        ("scan/fused_memory_s_at_hbm_peak", fused_bytes / HBM_BW),
    ]
    ca = jax.jit(eval_partials).lower(num, cat, meas, snips) \
        .compile().cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    oracle_bytes = float(ca.get("bytes accessed", 0.0))
    if oracle_bytes:
        rows.append(("scan/jnp_oracle_bytes_accessed", oracle_bytes))
        rows.append(("scan/fused_traffic_reduction_x",
                     oracle_bytes / fused_bytes))
    try:
        from jax.sharding import Mesh

        from repro.aqp.executor import _sharded_mask_fn, pad_tuple_axis
        from repro.launch.hlo_analysis import collective_bytes

        n_dev = min(4, jax.device_count())
        mesh = Mesh(np.array(jax.devices()[:n_dev]), ("data",))
        num_p, cat_p, _, valid = pad_tuple_axis(n_dev, num, cat, None)
        hlo = _sharded_mask_fn(mesh, "data") \
            .lower(num_p, cat_p, valid, snips).compile().as_text()
        rows.append(("scan/sharded_mask_collective_bytes",
                     float(collective_bytes(hlo)["wire_bytes_total"])))
    except Exception:
        pass  # single-device container without forced topology
    return rows
