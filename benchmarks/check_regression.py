"""CI perf gate: fail when key benchmark metrics regress vs the committed
baseline.

Runs the smoke configurations of ``batch_bench`` and ``improve_bench`` and
compares a curated subset of their metrics against
``benchmarks/baseline.json``. Only machine-portable metrics are gated —
speedup ratios, dedup ratios, compiled-program counts, and the bitwise
oracle flag — never absolute milliseconds, so the gate is meaningful on
shared CI runners. A metric fails when it is more than ``tolerance``
(default 25%) WORSE than the baseline in its recorded direction; being
better never fails.

    PYTHONPATH=src python benchmarks/check_regression.py
    PYTHONPATH=src python benchmarks/check_regression.py --update  # re-baseline
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")


def current_metrics(improve_report: str = "", shard_report: str = "") -> dict:
    import batch_bench

    rows = dict(batch_bench.bench(n_queries=6, n_rows=2_000, n_batches=2))
    if shard_report and os.path.exists(shard_report):
        with open(shard_report) as f:
            rep = json.load(f)
        rows["shard/oracle_bitwise_equal"] = float(
            rep["oracle"]["bitwise_equal"] and rep["oracle"]["state_equal"])
        rows["scan/padded_parity"] = float(
            rep.get("scan", {}).get("padded_parity", 0.0))
    else:
        import shard_bench

        rows.update(dict(shard_bench.bench(smoke=True)[0]))
    if improve_report and os.path.exists(improve_report):
        # Reuse the already-run smoke's JSON artifact instead of paying the
        # jit compiles a second time (CI runs the bench right before us).
        with open(improve_report) as f:
            rep = json.load(f)
        for fill, r in rep["latency"].items():
            rows[f"improve/speedup_p50_n{fill}"] = r["speedup_p50"]
        rows["improve/mixed_q_programs"] = float(
            rep["mixed_q"]["programs_compiled"])
        rows["improve/oracle_bitwise_equal"] = float(
            rep["oracle"]["bitwise_equal"])
    else:
        import improve_bench

        imp_rows, _ = improve_bench.bench(smoke=True)
        rows.update(dict(imp_rows))
    # Workload-intelligence gate: the repeated-dashboard smoke must keep
    # serving from the semantic answer cache (hit rate) and keep hits
    # cheap (served-from-cache speedup) — the baseline holds the tentpole
    # acceptance floors (0.5 / 10x), not machine-volatile measurements.
    import cache_bench

    rows.update(dict(cache_bench.bench(smoke=True)))
    # Multi-tenant serving-front gate: under concurrent heavy-tail load,
    # every ticket resolves (none lost/hung), rate-limit refusals stay typed
    # Rejection values, and front answers stay bitwise-equal to a direct
    # Session.execute on an identical engine.
    import serving_bench

    rows.update(dict(serving_bench.bench(smoke=True)))
    # Fused-scan gate metrics: bitwise parity + BlockSpec roofline fraction
    # (both machine-portable; no wall-clock involved).
    import kernels_bench

    rows.update(dict(kernels_bench.scan_metrics()))
    # Fault-injection hooks (repro.ft.faults) live permanently on the serve
    # hot paths; their disabled cost is one global load + an `is None` test.
    # Gate that the registry is DISARMED whenever benchmarks run — an armed
    # plan here would mean the hooks leak into production timings.
    from repro.ft import faults

    rows["faults/hooks_inactive"] = float(not faults.active())
    # Static-analysis gate: the invariant checker (jaxpr/StableHLO + AST
    # rules, repro.analysis) must be clean in strict mode. Baseline is 0
    # with higher_is_better=false, so ANY violation fails the gate.
    from repro.analysis import violation_count

    rows["analysis/violations"] = float(violation_count(strict=True))
    return rows


def check(baseline: dict, rows: dict) -> int:
    tol = float(baseline.get("tolerance", 0.25))
    failures = 0
    print(f"{'metric':<40} {'baseline':>10} {'current':>10} {'status':>8}")
    for name, spec in sorted(baseline["metrics"].items()):
        if name not in rows:
            print(f"{name:<40} {'-':>10} {'-':>10} {'MISSING':>8}")
            failures += 1
            continue
        base, cur = float(spec["value"]), float(rows[name])
        if spec.get("higher_is_better", True):
            bad = cur < base * (1.0 - tol)
        else:
            bad = cur > base * (1.0 + tol)
        print(f"{name:<40} {base:>10.4g} {cur:>10.4g} "
              f"{'FAIL' if bad else 'ok':>8}")
        failures += bad
    return failures


def update(rows: dict) -> dict:
    gated = {
        # (metric, higher_is_better)
        "batch/speedup_queries_per_sec": True,
        "batch/dedup_ratio": True,
        "improve/speedup_p50_n8": True,
        "improve/mixed_q_programs": False,
        "improve/oracle_bitwise_equal": True,
        # Placement never changes answers: sharded-store answers and learned
        # state must stay bitwise-equal to the local store.
        "shard/oracle_bitwise_equal": True,
        # Layout is non-observable: the masked padded sharded scan must stay
        # bitwise-equal to the unsharded oracle for indivisible blocks.
        "scan/padded_parity": True,
        # The fused masked-scan kernel must stay bitwise-equal to the jnp
        # oracle (local, valid-masked and aggregation-only legs) ...
        "scan/kernel_bitwise_parity": True,
        # ... and its BlockSpec HBM traffic must stay within a constant of
        # the once-streamed relation floor (un-fusing the mask collapses
        # this fraction of achievable HBM peak).
        "scan/bytes_per_sec_frac_of_peak": True,
        # Semantic answer cache: repeated dashboards must keep hitting and
        # hits must stay an order of magnitude cheaper than execution.
        "intel/hit_rate": True,
        "intel/served_from_cache_speedup": True,
        # Serving front under concurrent multi-tenant load: exactly-once
        # ticket resolution, typed (never raised) admission refusals, and
        # bitwise miss-path parity with a direct session.
        "serving/all_tickets_resolved": True,
        "serving/rate_limit_typed": True,
        "serving/miss_path_bitwise_equal": True,
        # Chaos hooks must be disarmed (zero-cost) during benchmark runs.
        "faults/hooks_inactive": True,
        # The static invariant checker (repro.analysis --strict) is clean:
        # canonical fold shapes/order, collective-free mask build, bounded
        # compile cache, f64 policy, access-path discipline.
        "analysis/violations": False,
    }
    metrics = {
        name: {"value": rows[name], "higher_is_better": hib}
        for name, hib in gated.items()
    }
    # Pin the intel gates at the tentpole acceptance floors instead of the
    # (much higher, machine-volatile) measured values — CI gates the
    # contract, not this runner's speed.
    for name, floor in (("intel/hit_rate", 0.5),
                        ("intel/served_from_cache_speedup", 10.0)):
        metrics[name]["value"] = min(metrics[name]["value"], floor)
    return {
        "tolerance": 0.25,
        "metrics": metrics,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--improve-report", default="",
                    help="reuse this improve_bench JSON instead of re-running")
    ap.add_argument("--shard-report", default="",
                    help="reuse this shard_bench JSON instead of re-running")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current run")
    args = ap.parse_args()
    sys.path.insert(0, os.path.dirname(__file__))
    rows = current_metrics(args.improve_report, args.shard_report)
    if args.update:
        blob = update(rows)
        with open(args.baseline, "w") as f:
            json.dump(blob, f, indent=1)
            f.write("\n")
        print(f"baseline written to {args.baseline}")
        return
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = check(baseline, rows)
    if failures:
        raise SystemExit(f"{failures} benchmark metric(s) regressed >25%")
    print("benchmark gate: all metrics within tolerance")


if __name__ == "__main__":
    main()
