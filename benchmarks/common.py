"""Shared benchmark harness utilities."""
from __future__ import annotations

import time

import numpy as np

from repro.aqp.queries import assemble_results, decompose
from repro.core.engine import EngineConfig, VerdictEngine


def exact_cells(relation, engine, q):
    groups = engine._discover_groups(q)
    plan = decompose(relation.schema, q, groups)
    theta = relation.exact_answer(plan.snippets)
    cells = assemble_results(plan, theta, np.zeros(plan.snippets.n),
                             relation.cardinality)
    return {(c["group"], c["agg"]): c["estimate"] for c in cells}


def train_engines(relation, train_queries, *, sample_rate=0.15, n_batches=8,
                  capacity=512, refit_steps=60, seed=0, learn_sigma=True):
    """Returns (verdict, nolearn) with verdict trained on train_queries."""
    verdict = VerdictEngine(relation, EngineConfig(
        sample_rate=sample_rate, n_batches=n_batches, capacity=capacity,
        seed=seed))
    nolearn = VerdictEngine(relation, EngineConfig(
        sample_rate=sample_rate, n_batches=n_batches, capacity=capacity,
        seed=seed, learning=False))
    # Fused training pass: one scan serves the whole training workload
    # (identical answers to the query-at-a-time loop, see repro.aqp.batch).
    verdict.execute_many(train_queries)
    # learn_sigma: the analytic sigma^2 (App. F.3) underestimates the prior
    # variance (range-averaged answers shrink it), which over-tightens the
    # improved bounds; NLL-learning sigma^2 jointly (exact gradients) fixes
    # the calibration (EXPERIMENTS.md, Fig. 5 discussion).
    verdict.refit(steps=refit_steps, learn_sigma=learn_sigma)
    return verdict, nolearn


def eval_queries(relation, verdict, nolearn, queries, *, max_batches=2):
    """Per-cell records comparing improved vs raw answers at a fixed budget."""
    rows = []
    for q in queries:
        t0 = time.perf_counter()
        rv = verdict.execute(q, max_batches=max_batches)
        tv = time.perf_counter() - t0
        t0 = time.perf_counter()
        rn = nolearn.execute(q, max_batches=max_batches)
        tn = time.perf_counter() - t0
        exact = exact_cells(relation, verdict, q)
        for cv, cn in zip(rv.cells, rn.cells):
            ex = exact[(cv["group"], cv["agg"])]
            if abs(ex) < 1e-9:
                continue
            rows.append({
                "exact": ex,
                "v_est": cv["estimate"], "v_bound": np.sqrt(cv["beta2"]),
                "n_est": cn["estimate"], "n_bound": np.sqrt(cn["beta2"]),
                "v_err": abs(cv["estimate"] - ex) / abs(ex),
                "n_err": abs(cn["estimate"] - ex) / abs(ex),
                "v_rel_bound": np.sqrt(cv["beta2"]) / abs(ex),
                "n_rel_bound": np.sqrt(cn["beta2"]) / abs(ex),
                "v_time": tv, "n_time": tn,
            })
    return rows


def time_to_target(engine, queries, target):
    batches = tuples = t_total = 0
    for q in queries:
        t0 = time.perf_counter()
        r = engine.execute(q, target_rel_error=target)
        t_total += time.perf_counter() - t0
        batches += r.batches_used
        tuples += r.tuples_scanned
    return {"batches": batches, "tuples": tuples, "seconds": t_total}
