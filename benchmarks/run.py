"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. 'us_per_call' is populated for
timing benchmarks; claim-check rows put their metric in 'derived'.

    PYTHONPATH=src python -m benchmarks.run [--only table4,fig5]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import (batch_bench, cache_bench, improve_bench,
                            kernels_bench, paper_tables, roofline_report,
                            serving_bench, shard_bench)

    suites = {
        "batch": batch_bench.run,
        "cache": cache_bench.run,
        "serving": serving_bench.run,
        "improve": improve_bench.run,
        "shard": shard_bench.run,
        "table3": paper_tables.table3_generality,
        "table4": paper_tables.table4_speedup_error,
        "table5": paper_tables.table5_overhead,
        "fig5": paper_tables.fig5_bound_coverage,
        "fig6": paper_tables.fig6_sweeps,
        "fig7": paper_tables.fig7_param_learning,
        "fig9": paper_tables.fig9_model_validation,
        "fig12": paper_tables.fig12_data_append,
        "fig13": paper_tables.fig13_intertuple_covariance,
        "kernels": kernels_bench.run,
        "roofline": roofline_report.run,
    }
    only = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception:
            traceback.print_exc()
            print(f"{name}/ERROR,,")
            failed += 1
            continue
        for key, val in rows:
            if key.startswith("kernel/") or key.endswith("_us"):
                print(f"{key},{val:.1f},")
            else:
                print(f"{key},,{val:.6g}")
        print(f"# {name} took {time.time()-t0:.1f}s", file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
