"""Paper-claim reproductions: Table 3/4/5 and Figures 5/6/7/9/12/13.

Scaled to this CPU container (relation sizes in the tens of thousands of
rows); the *claims* being checked are scale-free: support fraction, error
reduction %, speedup ratio, bound validity, robustness across distributions.
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.aqp import workload as W
from repro.aqp.queries import (AggQuery, AggSpec, Disjunction, TextLike,
                               unsupported_reason)
from repro.core import covariance as C
from repro.core import learning
from repro.core.append import estimate_append_stats
from repro.core.engine import EngineConfig, VerdictEngine
from repro.core.types import AVG, GPParams, Schema, make_snippets
from benchmarks.common import eval_queries, time_to_target, train_engines


# ------------------------------------------------------------------ Table 3
def table3_generality():
    """Support-checker coverage on a Customer1-proxy + TPC-H-like workload."""
    rng = np.random.default_rng(0)
    rel = W.tpch_like(0, n_rows=1000)
    base = W.tpch_workload(1, rel.schema, n_queries=60)
    # Customer1 proxy: inject the unsupported constructs the paper reports
    # (textual filters, disjunctions, MIN/MAX) at roughly real-world rates.
    queries = []
    for i, q in enumerate(base):
        r = rng.random()
        if r < 0.12:
            q = AggQuery(q.aggs, q.predicates + (TextLike("%x%"),), q.groupby)
        elif r < 0.22:
            q = AggQuery(q.aggs, q.predicates + (Disjunction(()),), q.groupby)
        elif r < 0.28:
            q = AggQuery((AggSpec("MAX", 0),), q.predicates, q.groupby)
        queries.append(q)
    supported = sum(unsupported_reason(q) is None for q in queries)
    frac = supported / len(queries)
    # TPC-H: 21 aggregate query classes, 14 supported (paper Table 3).
    tpch_frac = 14 / 21
    return [("table3/customer_proxy_supported_frac", frac),
            ("table3/tpch_supported_frac_paper", tpch_frac)]


# ------------------------------------------------------------------ Table 4
def table4_speedup_error(seed=0):
    rel = W.make_relation(seed=seed, n_rows=20_000, n_num=2, cat_sizes=(4,),
                          n_measures=1, lengthscale=0.4, noise=0.2)
    train_q = W.make_workload(1, rel.schema, 30, agg_kinds=("AVG",),
                              width_range=(0.15, 0.5), cat_pred_prob=0.2)
    test_q = W.make_workload(2, rel.schema, 12, agg_kinds=("AVG",),
                             width_range=(0.15, 0.5), cat_pred_prob=0.2)
    verdict, nolearn = train_engines(rel, train_q)
    out = []
    # speedup: budget (batches/tuples) to reach target error bound
    for target in (0.025, 0.01):
        sv = time_to_target(verdict, test_q, target)
        sn = time_to_target(nolearn, test_q, target)
        out.append((f"table4/speedup_tuples_target{target}",
                    sn["tuples"] / max(sv["tuples"], 1)))
        out.append((f"table4/speedup_wallclock_target{target}",
                    sn["seconds"] / max(sv["seconds"], 1e-9)))
    # error reduction at fixed budget
    for budget in (1, 3):
        rows = eval_queries(rel, verdict, nolearn, test_q, max_batches=budget)
        vb = np.mean([r["v_rel_bound"] for r in rows])
        nb = np.mean([r["n_rel_bound"] for r in rows])
        ve = np.mean([r["v_err"] for r in rows])
        ne = np.mean([r["n_err"] for r in rows])
        out.append((f"table4/bound_reduction_budget{budget}", 1 - vb / nb))
        out.append((f"table4/actual_error_reduction_budget{budget}", 1 - ve / ne))
    return out


# ------------------------------------------------------------------ Table 5
def table5_overhead():
    """Verdict inference overhead per query (ms) vs synopsis size."""
    rel = W.make_relation(seed=3, n_rows=10_000, n_num=2, cat_sizes=(),
                          n_measures=1)
    out = []
    for n_past in (50, 200, 500):
        eng = VerdictEngine(rel, EngineConfig(sample_rate=0.1, n_batches=4,
                                              capacity=max(n_past, 64)))
        qs = W.make_workload(4, rel.schema, n_past // 5, agg_kinds=("AVG",),
                             cat_pred_prob=0.0)
        eng.execute_many(qs)
        q = W.make_workload(5, rel.schema, 1, agg_kinds=("AVG",),
                            cat_pred_prob=0.0)[0]
        eng.execute(q, max_batches=1)  # warm the jitted path
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            syn = next(iter(eng.store.values()))
            from repro.core.types import RawAnswer
            plan_q = W.make_workload(6, rel.schema, 1, agg_kinds=("AVG",),
                                     cat_pred_prob=0.0)[0]
            from repro.aqp.queries import decompose
            plan = decompose(rel.schema, plan_q)
            raw = RawAnswer(jnp.ones((plan.snippets.n,)),
                            jnp.full((plan.snippets.n,), 0.01))
            syn.improve(plan.snippets, raw)
        ms = (time.perf_counter() - t0) / reps * 1e3
        out.append((f"table5/inference_overhead_ms_n{n_past}", ms))
    return out


# ------------------------------------------------------------------ Figure 5
def fig5_bound_coverage():
    rel = W.make_relation(seed=4, n_rows=20_000, n_num=2, cat_sizes=(4,),
                          n_measures=1, lengthscale=0.4)
    train_q = W.make_workload(7, rel.schema, 30, agg_kinds=("AVG",))
    test_q = W.make_workload(8, rel.schema, 15, agg_kinds=("AVG",))
    verdict, nolearn = train_engines(rel, train_q)
    rows = eval_queries(rel, verdict, nolearn, test_q, max_batches=2)
    alpha = 1.96  # 95%
    cover = np.mean([r["v_err"] <= alpha * r["v_rel_bound"] for r in rows])
    return [("fig5/bound_coverage_at_95", float(cover))]


# ------------------------------------------------------------------ Figure 6
def fig6_sweeps():
    out = []
    # (a) diversity of predicate columns
    for frac in (0.2, 1.0):
        rel = W.make_relation(seed=5, n_rows=15_000, n_num=6, cat_sizes=(),
                              n_measures=1, lengthscale=0.4)
        tq = W.make_workload(9, rel.schema, 30, agg_kinds=("AVG",),
                             frac_frequent=frac, cat_pred_prob=0.0,
                             n_predicates=(1, 2))
        sq = W.make_workload(10, rel.schema, 10, agg_kinds=("AVG",),
                             frac_frequent=frac, cat_pred_prob=0.0,
                             n_predicates=(1, 2))
        v, n = train_engines(rel, tq)
        rows = eval_queries(rel, v, n, sq, max_batches=2)
        red = 1 - np.mean([r["v_err"] for r in rows]) / max(
            np.mean([r["n_err"] for r in rows]), 1e-12)
        out.append((f"fig6a/error_reduction_frac{frac}", red))
    # (b) data distributions
    for dist in ("uniform", "gaussian", "lognormal"):
        rel = W.make_relation(seed=6, n_rows=15_000, n_num=2, cat_sizes=(),
                              n_measures=1, distribution=dist)
        tq = W.make_workload(11, rel.schema, 25, agg_kinds=("AVG",),
                             cat_pred_prob=0.0)
        sq = W.make_workload(12, rel.schema, 10, agg_kinds=("AVG",),
                             cat_pred_prob=0.0)
        v, n = train_engines(rel, tq)
        rows = eval_queries(rel, v, n, sq, max_batches=2)
        red = 1 - np.mean([r["v_err"] for r in rows]) / max(
            np.mean([r["n_err"] for r in rows]), 1e-12)
        out.append((f"fig6b/error_reduction_{dist}", red))
    # (c) number of past queries
    rel = W.make_relation(seed=7, n_rows=15_000, n_num=2, cat_sizes=(),
                          n_measures=1)
    sq = W.make_workload(14, rel.schema, 10, agg_kinds=("AVG",),
                         cat_pred_prob=0.0)
    for n_past in (5, 20, 60):
        tq = W.make_workload(13, rel.schema, n_past, agg_kinds=("AVG",),
                             cat_pred_prob=0.0)
        v, n = train_engines(rel, tq)
        rows = eval_queries(rel, v, n, sq, max_batches=2)
        red = 1 - np.mean([r["v_err"] for r in rows]) / max(
            np.mean([r["n_err"] for r in rows]), 1e-12)
        out.append((f"fig6c/error_reduction_npast{n_past}", red))
    return out


# ------------------------------------------------------------------ Figure 7
def fig7_param_learning():
    rng = np.random.default_rng(0)
    sch = Schema(num_lo=(0.0, 0.0), num_hi=(1.0, 1.0), cat_sizes=(),
                 n_measures=1)
    out = []
    for true_ls in (0.15, 0.4):
        true = GPParams(log_ls=jnp.log(jnp.asarray([true_ls, true_ls])),
                        log_sigma2=jnp.log(2.0), mu=jnp.asarray(0.0))
        ranges = []
        for _ in range(80):
            r = {}
            for d_ in range(2):
                a = rng.uniform(0, 0.8)
                r[d_] = (a, a + rng.uniform(0.02, 0.2))
            ranges.append(r)
        b = make_snippets(sch, agg=AVG, measure=0, num_ranges=ranges)
        k = np.array(C.cov_matrix(b, b, true))
        k[np.diag_indices(80)] = np.asarray(C.cov_diag(b, true))
        chol = np.linalg.cholesky(k + 1e-10 * np.eye(80))
        theta = chol @ rng.normal(size=80) + 0.05 * rng.normal(size=80)
        fitted, _ = learning.fit(b, jnp.asarray(theta), jnp.full((80,), 0.05**2),
                                 sch, steps=150, lr=0.1)
        est = float(np.exp(np.asarray(fitted.log_ls)).mean())
        out.append((f"fig7/ls_true{true_ls}_estimated", est))
    return out


# ------------------------------------------------------------------ Figure 9
def fig9_model_validation():
    rel = W.make_relation(seed=8, n_rows=15_000, n_num=2, cat_sizes=(),
                          n_measures=1)
    tq = W.make_workload(15, rel.schema, 25, agg_kinds=("AVG",),
                         cat_pred_prob=0.0)
    sq = W.make_workload(16, rel.schema, 10, agg_kinds=("AVG",),
                         cat_pred_prob=0.0)
    out = []
    for scale in (0.1, 1.0, 10.0):
        v, n = train_engines(rel, tq)
        for syn in v.store.values():
            syn.params = GPParams(
                log_ls=syn.params.log_ls + float(np.log(scale)),
                log_sigma2=syn.params.log_sigma2, mu=syn.params.mu)
            syn.rebuild()
        rows = eval_queries(rel, v, n, sq, max_batches=2)
        viol = np.mean([r["v_err"] > 1.96 * r["v_rel_bound"] for r in rows])
        out.append((f"fig9/violation_rate_scale{scale}", float(viol)))
    return out


# ----------------------------------------------------------------- Figure 12
def fig12_data_append():
    rel = W.make_relation(seed=9, n_rows=12_000, n_num=2, cat_sizes=(),
                          n_measures=1, noise=0.1)
    tq = W.make_workload(17, rel.schema, 20, agg_kinds=("AVG",),
                         cat_pred_prob=0.0)
    sq = W.make_workload(18, rel.schema, 8, agg_kinds=("AVG",),
                         cat_pred_prob=0.0)
    out = []
    for frac, adjust in ((0.15, False), (0.15, True)):
        v, _ = train_engines(rel, tq)
        n_new = int(rel.cardinality * frac)
        extra = rel.take(np.arange(n_new))
        extra.measures = extra.measures + 1.0  # drifted appends
        merged = rel.concat(extra)
        if adjust:
            stats = estimate_append_stats(
                np.asarray(rel.measures[:500]), np.asarray(extra.measures[:500]),
                rel.cardinality, n_new)
            for syn in v.store.values():
                syn.apply_append(stats)
        # Appendix D setting: the AQP engine samples the *updated* relation
        # (raw answers see the appended data); the adjustment covers the
        # stale synopsis answers.
        from repro.aqp.sampler import build_sample
        v.relation = merged
        v.batches = build_sample(merged, rate=v.config.sample_rate,
                                 n_batches=v.config.n_batches,
                                 seed=v.config.seed)
        viols = []
        from benchmarks.common import exact_cells
        for q, r in zip(sq, v.execute_many(sq, max_batches=2)):
            exact = exact_cells(merged, v, q)
            for c in r.cells:
                ex = exact[(c["group"], c["agg"])]
                if abs(ex) < 1e-9:
                    continue
                viols.append(abs(c["estimate"] - ex)
                             > 1.96 * np.sqrt(c["beta2"]) + 1e-12)
        out.append((f"fig12/violation_rate_adjust{adjust}",
                    float(np.mean(viols))))
    return out


# ----------------------------------------------------------------- Figure 13
def fig13_intertuple_covariance():
    """Prevalence of non-zero inter-tuple correlation (UCI-proxy synthetic)."""
    out = []
    for ls, name in ((0.2, "smooth"), (2.0, "weak")):
        rel = W.make_relation(seed=10, n_rows=5_000, n_num=2, cat_sizes=(),
                              n_measures=1, lengthscale=ls, noise=0.2)
        x = np.asarray(rel.num[:, 0])
        m = np.asarray(rel.measures[:, 0])
        order = np.argsort(x)
        corr = np.corrcoef(m[order][:-1], m[order][1:])[0, 1]
        out.append((f"fig13/adjacent_corr_{name}", float(corr)))
    return out
