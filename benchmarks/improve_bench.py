"""Serve-path latency: bucket-padded improve vs the capacity-padded baseline.

Measures the tentpole claim of the bucketed serve path: padding the synopsis
state to fill-level buckets (powers of two) instead of full capacity makes
``Synopsis.improve`` cost scale with the actual fill, so at realistic fills
(n <= 256 against C = 2000) the p50 serve latency drops by well over the 5x
acceptance bar. Also checks the two safety properties that make the speedup
admissible:

  - batched answers stay bitwise equal to the sequential ``execute`` oracle
    (both serve through the same bucketed programs);
  - a mixed-Q workload compiles a bounded number of programs — one per
    (Q-bucket, fill-bucket) pair — instead of one per distinct Q.

    PYTHONPATH=src python benchmarks/improve_bench.py [--smoke] [--out f.json]

Prints ``name,value`` CSV rows plus one ``BENCH {json}`` line; ``--out``
writes the same JSON to a file (uploaded as a CI artifact).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax.numpy as jnp

from repro.aqp import workload as W
from repro.core.synopsis import (
    MIN_FILL_BUCKET,
    MIN_Q_BUCKET,
    Synopsis,
    _improve_padded,
)
from repro.core.types import (
    AVG,
    RawAnswer,
    Schema,
    bucket_size,
    make_snippets,
)


def _random_batch(rng, sch, n):
    ranges = []
    for _ in range(n):
        r = {}
        for d in range(sch.n_num):
            a = rng.uniform(0, 0.6)
            r[d] = (a, a + rng.uniform(0.05, 0.4))
        ranges.append(r)
    return make_snippets(sch, agg=AVG, measure=0, num_ranges=ranges)


def _capacity_padded_state(syn):
    """The pre-PR serve buffers: padded to full capacity C."""
    C = syn.capacity
    rows = np.asarray(syn._order, np.int64)
    n = len(rows)
    idx = np.concatenate([rows, np.zeros((C - n,), np.int64)])
    past = syn._row_batch(idx)
    valid = jnp.asarray(np.arange(C) < n, jnp.float64)
    sinv = np.eye(C)
    sinv[:n, :n] = np.asarray(syn._sigma_inv)
    alpha = np.zeros((C,))
    alpha[:n] = np.asarray(syn._alpha)
    return past, valid, jnp.asarray(sinv), jnp.asarray(alpha)


def _p50_ms(fn, iters):
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn()
        out[0].block_until_ready()
        times.append((time.perf_counter() - t0) * 1e3)
    return float(np.percentile(times, 50))


def bench_improve_latency(capacity, fills, q, iters, seed=0):
    """p50 serve latency per fill level: bucketed path vs capacity padding."""
    rng = np.random.default_rng(seed)
    sch = Schema(num_lo=(0.0, 0.0), num_hi=(1.0, 1.0), cat_sizes=(4,),
                 n_measures=1)
    out = {}
    for fill in fills:
        syn = Synopsis(sch, capacity=capacity, async_ingest=False)
        syn.add(_random_batch(rng, sch, fill), rng.normal(1.0, 0.3, fill),
                rng.uniform(0.01, 0.05, fill))
        new = _random_batch(rng, sch, q)
        raw = RawAnswer(jnp.asarray(rng.normal(1.0, 0.3, q)),
                        jnp.asarray(np.full(q, 0.02)))

        def bucketed():
            imp = syn.improve(new, raw)
            return (imp.theta, imp.beta2)

        base_state = _capacity_padded_state(syn)

        def baseline():
            theta, beta2, _ = _improve_padded(
                *base_state, syn.params, new, raw.theta, raw.beta2,
                syn.delta_v,
            )
            return (theta, beta2)

        bucketed()  # warm both programs (compile is a one-off cost)
        baseline()
        p50_b = _p50_ms(bucketed, iters)
        p50_c = _p50_ms(baseline, iters)
        out[str(fill)] = {
            "fill_bucket": syn._fill_bucket(),
            "p50_bucketed_ms": p50_b,
            "p50_capacity_ms": p50_c,
            "speedup_p50": p50_c / max(p50_b, 1e-9),
        }
    return out


def bench_mixed_q_programs(capacity, fills, q_list, seed=1):
    """Programs compiled by a mixed-Q workload vs the bucket-pair bound."""
    rng = np.random.default_rng(seed)
    sch = Schema(num_lo=(0.0, 0.0), num_hi=(1.0, 1.0), cat_sizes=(4,),
                 n_measures=1)
    syns = []
    for fill in fills:
        syn = Synopsis(sch, capacity=capacity, async_ingest=False)
        syn.add(_random_batch(rng, sch, fill), rng.normal(1.0, 0.3, fill),
                rng.uniform(0.01, 0.05, fill))
        syns.append(syn)
    before = _improve_padded._cache_size()
    for q in q_list:
        for syn in syns:
            new = _random_batch(rng, sch, q)
            raw = RawAnswer(jnp.asarray(rng.normal(1.0, 0.3, q)),
                            jnp.asarray(np.full(q, 0.02)))
            syn.improve(new, raw)
    programs = _improve_padded._cache_size() - before
    q_buckets = {bucket_size(q, MIN_Q_BUCKET) for q in q_list}
    fill_buckets = {syn._fill_bucket() for syn in syns}
    return {
        "distinct_q": len(set(q_list)),
        "q_buckets": sorted(q_buckets),
        "fill_buckets": sorted(fill_buckets),
        "programs_compiled": int(programs),
        "bound": len(q_buckets) * len(fill_buckets),
    }


def bench_oracle_parity(n_queries, n_rows, seed=2):
    """Facade answers vs the sequential per-query oracle, bit for bit."""
    import repro.verdict as vd

    rel = W.make_relation(seed=seed, n_rows=n_rows, n_num=2, cat_sizes=(4,),
                          n_measures=1, lengthscale=0.4, noise=0.2)
    qs = W.make_workload(1, rel.schema, n_queries,
                         agg_kinds=("AVG", "COUNT", "SUM"), cat_pred_prob=0.3)
    cfg = dict(sample_rate=0.15, n_batches=4, capacity=256, seed=0)
    seq = vd.connect(rel, vd.EngineConfig(**cfg))
    bat = vd.connect(rel, vd.EngineConfig(**cfg))
    r_seq = [seq.execute(q) for q in qs]
    r_bat = bat.execute_many(qs)
    equal = all(a.cells == b.cells and a.batches_used == b.batches_used
                for a, b in zip(r_seq, r_bat))
    return {"n_queries": n_queries, "bitwise_equal": bool(equal)}


def bench(smoke=False):
    if smoke:
        # Enough iterations for a stable p50 — these ops are sub-ms, and the
        # CI regression gate compares the speedup against a committed floor.
        capacity, fills, q, iters = 256, (8, 32), 8, 40
        q_list = [1, 3, 8, 12, 17]
        oracle = bench_oracle_parity(n_queries=6, n_rows=2_000)
    else:
        capacity, fills, q, iters = 2000, (16, 64, 256), 16, 40
        q_list = list(range(1, 9)) + [12, 16, 23, 31, 40, 64]
        oracle = bench_oracle_parity(n_queries=20, n_rows=20_000)
    latency = bench_improve_latency(capacity, fills, q, iters)
    mixed = bench_mixed_q_programs(capacity, fills[:2], q_list)
    report = {
        "capacity": capacity,
        "q": q,
        "min_fill_bucket": MIN_FILL_BUCKET,
        "min_q_bucket": MIN_Q_BUCKET,
        "latency": latency,
        "mixed_q": mixed,
        "oracle": oracle,
    }
    rows = []
    for fill, r in latency.items():
        rows.append((f"improve/p50_bucketed_ms_n{fill}", r["p50_bucketed_ms"]))
        rows.append((f"improve/p50_capacity_ms_n{fill}", r["p50_capacity_ms"]))
        rows.append((f"improve/speedup_p50_n{fill}", r["speedup_p50"]))
    rows.append(("improve/mixed_q_programs", float(mixed["programs_compiled"])))
    rows.append(("improve/mixed_q_bound", float(mixed["bound"])))
    rows.append(("improve/oracle_bitwise_equal", float(oracle["bitwise_equal"])))
    return rows, report


def run():
    """Entry point for ``benchmarks.run`` suite registration."""
    rows, _ = bench()
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, CI smoke: checks the path end-to-end")
    ap.add_argument("--out", default="",
                    help="write the BENCH JSON report to this file")
    args = ap.parse_args()
    rows, report = bench(smoke=args.smoke)
    for name, val in rows:
        print(f"{name},{val:.4g}")
    blob = json.dumps(report)
    print(f"BENCH {blob}")
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob + "\n")
    ok = (report["oracle"]["bitwise_equal"]
          and report["mixed_q"]["programs_compiled"] <= report["mixed_q"]["bound"])
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
