"""Workload-intelligence benchmark: the semantic answer cache under the two
workloads it exists for.

1. Repeated dashboard: a fixed pool of distinct targeted queries re-issued
   round after round (the refresh pattern of §8.6's motivating workload).
   Round 0 pays plan → scan → improve; every later round serves from the
   cache. Reports the steady-state hit rate and the median served-from-cache
   speedup — the tentpole acceptance gate (>= 10x at hit_rate >= 0.5).
2. Power-law workload: ``make_workload(frac_frequent=...)`` concentrates
   predicates on a few popular columns; queries are drawn from the pool with
   a zipf-ish skew, so exact repeats AND subsumable group-pins occur
   naturally. Reports the achieved hit rate split by exact vs subsumed.

Wall-clock lives HERE, never in ``repro.intel`` (analysis rule A007): the
serving plane derives keys and routes from plan content only; benchmarks
measure the latency those decisions buy.

    PYTHONPATH=src python benchmarks/cache_bench.py [--dry-run]
"""
from __future__ import annotations

import argparse
import statistics
import time

import numpy as np

import repro.verdict as vd
from repro.aqp import workload as W


def _time_each(session, queries, budget):
    """Per-query wall-clock of ``session.execute`` over ``queries``."""
    times, answers = [], []
    for q in queries:
        t0 = time.perf_counter()
        answers.append(session.execute(q, budget))
        times.append(time.perf_counter() - t0)
    return times, answers


def bench(smoke=False, n_rows=20_000, n_batches=6, pool=12, rounds=5,
          powerlaw_draws=60, seed=0):
    """Returns [(metric_name, value)] rows (benchmarks/run.py convention)."""
    if smoke:
        n_rows, n_batches, pool, rounds, powerlaw_draws = 2_000, 2, 4, 3, 12
    rel = W.make_relation(seed=seed, n_rows=n_rows, n_num=2, cat_sizes=(6,),
                          n_measures=1, lengthscale=0.4, noise=0.2)
    # Loose enough that recorded CIs keep licensing staleness-bumped
    # entries (the error-budget serve rule), tight enough that the improve
    # path does real work on a miss.
    budget = vd.ErrorBudget(target_rel_error=0.35)
    cfg = dict(sample_rate=0.15, n_batches=n_batches, capacity=512, seed=seed)

    # ---------------------------------------------------- repeated dashboard
    dash = vd.connect(rel, vd.EngineConfig(**cfg), cache=True)
    qs = W.make_workload(1, rel.schema, pool,
                         agg_kinds=("AVG", "COUNT", "SUM"), cat_pred_prob=0.3)
    dash.execute(W.make_workload(2, rel.schema, 1)[0], budget)  # jit warmup
    miss_times, _ = _time_each(dash, qs, budget)
    hit_times = []
    for _ in range(rounds - 1):
        t, _ = _time_each(dash, qs, budget)
        hit_times.extend(t)
    st = dash.stats()["intel"]
    speedup = statistics.median(miss_times) / statistics.median(hit_times)

    # -------------------------------------------------------- power-law wave
    plaw = vd.connect(rel, vd.EngineConfig(**cfg), cache=True)
    plaw_pool = W.make_workload(3, rel.schema, pool, frac_frequent=0.3,
                                n_predicates=(1, 2), cat_pred_prob=0.5)
    rng = np.random.default_rng(seed)
    # Zipf-skewed draws over the pool: a few dashboard favorites dominate,
    # the tail stays cold — the regime the paper's §8.6 workload models.
    ranks = np.arange(1, len(plaw_pool) + 1, dtype=np.float64)
    probs = (1.0 / ranks) / (1.0 / ranks).sum()
    draws = rng.choice(len(plaw_pool), size=powerlaw_draws, p=probs)
    for i in draws:
        plaw.execute(plaw_pool[int(i)], budget)
    pst = plaw.stats()["intel"]

    return [
        ("intel/hit_rate", st["hit_rate"]),
        ("intel/served_from_cache_speedup", speedup),
        ("intel/miss_ms_p50", statistics.median(miss_times) * 1e3),
        ("intel/hit_ms_p50", statistics.median(hit_times) * 1e3),
        ("intel/powerlaw_hit_rate", pst["hit_rate"]),
        ("intel/powerlaw_hits_exact", float(pst["hits_exact"])),
        ("intel/powerlaw_hits_subsumed", float(pst["hits_subsumed"])),
        ("intel/powerlaw_scan_routes", float(pst["routes"]["scan"])),
    ]


def run():
    """Entry point for ``benchmarks.run`` suite registration."""
    return bench()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=20_000)
    ap.add_argument("--pool", type=int, default=12)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--out", default="",
                    help="write name,value rows as JSON to this file")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny sizes, CI smoke: checks the path runs end-to-end")
    args = ap.parse_args()
    rows = bench(smoke=args.dry_run, n_rows=args.rows, pool=args.pool,
                 rounds=args.rounds)
    for name, val in rows:
        print(f"{name},{val:.4g}")
    if args.out:
        import json

        with open(args.out, "w") as f:
            json.dump(dict(rows), f, indent=1)
            f.write("\n")


if __name__ == "__main__":
    main()
