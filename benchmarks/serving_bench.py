"""Multi-tenant serving-front load benchmark (the PR-10 acceptance gate).

Drives a ``ServingFront`` with N concurrent tenants (mixed shared/isolated
isolation) over a heavy-tail (zipf-skewed) query mix, one client thread per
tenant, and checks the three contracts the front must keep under load:

1. ``serving/all_tickets_resolved``: every issued request resolves to
   exactly one answer-ladder value — no lost, hung, or double-resolved
   tickets, no exception escaping a client thread.
2. ``serving/rate_limit_typed``: an over-budget tenant's refusals are all
   typed ``Rejection`` values (never exceptions), and a throttled tenant
   actually gets refused (the limiter is live, not decorative).
3. ``serving/miss_path_bitwise_equal``: an answer served through the full
   front stack (admission -> microbatch service -> fused executor) is
   bitwise-identical to a direct ``Session.execute`` on an identical
   engine — the front adds tenancy and admission, never numerics.

Wall-clock lives HERE and in the front's transport layer, never in the
admission/metrics decision modules (analysis rule A008).

    PYTHONPATH=src python benchmarks/serving_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import statistics
import threading
import time

import numpy as np

import repro.verdict as vd
from repro.aqp import workload as W
from repro.serving.front import Rejection, ServingFront, TenantSpec


def _zipf_draws(rng, pool_size: int, n: int) -> np.ndarray:
    ranks = np.arange(1, pool_size + 1, dtype=np.float64)
    probs = (1.0 / ranks) / (1.0 / ranks).sum()
    return rng.choice(pool_size, size=n, p=probs)


def bench(smoke=False, n_tenants=8, n_rows=20_000, n_batches=4, pool=10,
          requests_per_tenant=24, seed=0):
    """Returns [(metric_name, value)] rows (benchmarks/run.py convention)."""
    if smoke:
        n_rows, n_batches, pool, requests_per_tenant = 2_000, 2, 5, 8
    assert n_tenants >= 8, "the acceptance gate requires >= 8 tenants"
    rel = W.make_relation(seed=seed, n_rows=n_rows, n_num=2, cat_sizes=(6,),
                          n_measures=1, lengthscale=0.4, noise=0.2)
    cfg = vd.EngineConfig(sample_rate=0.15, n_batches=n_batches,
                          capacity=512, seed=seed)

    front = ServingFront(rel, cfg)
    specs = []
    for i in range(n_tenants):
        # Every third tenant isolated (private engine, parallel scans); the
        # rest share one learned-state namespace. Tenant 0 is throttled hard
        # enough that the token bucket MUST refuse most of its burst.
        specs.append(TenantSpec(
            f"t{i}",
            isolation="isolated" if i % 3 == 2 else "shared",
            rate=(0.05 if i == 0 else 500.0),
            burst=(2 if i == 0 else 64),
            max_pending=64,
        ))
        front.add_tenant(specs[-1])

    # Per-tenant heavy-tail workloads: distinct pools so shared tenants
    # still overlap only through the shared store, plus zipf-skewed draws so
    # repeats (and prescreen hits) occur naturally.
    rng = np.random.default_rng(seed)
    pools = {
        s.name: W.make_workload(100 + i, rel.schema, pool,
                                agg_kinds=("AVG", "COUNT", "SUM"),
                                cat_pred_prob=0.3)
        for i, s in enumerate(specs)
    }
    plans = {s.name: _zipf_draws(rng, pool, requests_per_tenant)
             for s in specs}

    outcomes = {s.name: [] for s in specs}
    latencies = []
    errors = []

    def client(name: str):
        try:
            for i in plans[name]:
                t0 = time.perf_counter()
                ans = front.execute(name, pools[name][int(i)])
                latencies.append(time.perf_counter() - t0)
                outcomes[name].append(ans)
        except BaseException as e:  # noqa: BLE001 — the gate counts these
            errors.append((name, repr(e)))

    threads = [threading.Thread(target=client, args=(s.name,), daemon=True)
               for s in specs]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=600.0)
    wall = time.perf_counter() - t0
    hung = [t for t in threads if t.is_alive()]

    issued = n_tenants * requests_per_tenant
    resolved = sum(len(v) for v in outcomes.values())
    all_resolved = float(not errors and not hung and resolved == issued
                         and all(a is not None
                                 for v in outcomes.values() for a in v))

    rejections = [a for v in outcomes.values() for a in v
                  if getattr(a, "rejected", False)]
    throttled_rejections = [a for a in outcomes["t0"]
                            if getattr(a, "rejected", False)]
    rate_limit_typed = float(
        bool(throttled_rejections)
        and all(isinstance(a, Rejection) for a in rejections)
        and all(a.reason in ("rate_limit", "queue_full") for a in rejections))

    # ------------------------------------------------- miss-path parity gate
    # A FRESH isolated tenant vs a direct Session on an identical engine:
    # same config, same queries, cold stores on both sides — the front's
    # answer must be bitwise-identical, cell for cell.
    parity_qs = W.make_workload(999, rel.schema, 4,
                                agg_kinds=("AVG", "COUNT", "SUM"))
    front.add_tenant(TenantSpec("parity", isolation="isolated", rate=0.0))
    direct = vd.connect(rel, cfg)
    bitwise = True
    for q in parity_qs:
        a = front.execute("parity", q)
        b = direct.execute(q)
        bitwise &= (not getattr(a, "failed", True)
                    and [c.to_dict() for c in a.cells]
                    == [c.to_dict() for c in b.cells])

    stats = front.stats()
    prescreens = sum(t["service"]["prescreened"]
                     for t in stats["tenants"].values())
    return [
        ("serving/all_tickets_resolved", all_resolved),
        ("serving/rate_limit_typed", rate_limit_typed),
        ("serving/miss_path_bitwise_equal", float(bitwise)),
        ("serving/requests", float(issued)),
        ("serving/rejections", float(len(rejections))),
        ("serving/prescreen_hits", float(prescreens)),
        ("serving/throughput_rps", (resolved - len(rejections))
         / max(wall, 1e-9)),
        ("serving/latency_ms_p50",
         statistics.median(latencies) * 1e3 if latencies else 0.0),
    ]


def run():
    """Entry point for ``benchmarks.run`` suite registration."""
    return bench()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, CI smoke: checks the gates end-to-end")
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--rows", type=int, default=20_000)
    ap.add_argument("--requests", type=int, default=24)
    args = ap.parse_args()
    rows = bench(smoke=args.smoke, n_tenants=args.tenants, n_rows=args.rows,
                 requests_per_tenant=args.requests)
    for name, val in rows:
        print(f"{name},{val:.4g}")
    gates = dict(rows)
    for g in ("serving/all_tickets_resolved", "serving/rate_limit_typed",
              "serving/miss_path_bitwise_equal"):
        if gates[g] != 1.0:
            raise SystemExit(f"serving gate failed: {g} = {gates[g]}")


if __name__ == "__main__":
    main()
