"""Roofline table from the dry-run JSONL (EXPERIMENTS.md §Roofline source)."""
from __future__ import annotations

import json
import os

HEADERS = ("arch", "shape", "mesh", "label", "compute_s", "memory_s",
           "collective_s", "dominant", "useful_ratio", "args_gb", "temp_gb")


def load(path="experiments/dryrun.jsonl"):
    recs = {}
    if not os.path.exists(path):
        return []
    for line in open(path):
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        key = (r.get("arch"), r.get("shape"), r.get("mesh"), r.get("label"))
        recs[key] = r  # last record wins (reruns supersede failures)
    return list(recs.values())


def rows(path="experiments/dryrun.jsonl", label=None):
    out = []
    for r in load(path):
        if not r.get("ok"):
            continue
        if label and r.get("label") != label:
            continue
        rf = r["roofline"]
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "label": r.get("label", "baseline"),
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"], "dominant": rf["dominant"],
            "useful_ratio": r.get("useful_flops_ratio", 0.0),
            "args_gb": r["memory"]["argument_gb"],
            "temp_gb": r["memory"]["temp_gb"],
            "bound_s": rf["step_time_lower_bound_s"],
            "roofline_fraction": rf["roofline_fraction"],
        })
    return sorted(out, key=lambda x: (x["arch"], x["shape"], x["mesh"]))


def markdown(path="experiments/dryrun.jsonl", label="baseline"):
    lines = ["| arch | shape | mesh | compute s | memory s | collective s | "
             "dominant | useful ratio | roofline frac | arg GB/dev |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows(path, label):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | {r['dominant'].replace('_s','')} "
            f"| {r['useful_ratio']:.3f} | {r['roofline_fraction']:.3f} "
            f"| {r['args_gb']:.2f} |")
    return "\n".join(lines)


def run():
    rs = rows()
    ok = len(rs)
    doms = {}
    for r in rs:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    out = [("roofline/cells_ok", ok)]
    for k, v in sorted(doms.items()):
        out.append((f"roofline/dominant_{k}", v))
    if rs:
        out.append(("roofline/mean_useful_ratio",
                    sum(r["useful_ratio"] for r in rs) / ok))
    # Scan-plane roofline: fused-kernel HBM traffic model vs the compiled
    # jnp oracle's bytes accessed, plus the sharded mask build's collective
    # bytes (repro.launch.hlo_analysis) — live numbers, no dryrun needed.
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import kernels_bench

    out.extend(kernels_bench.scan_roofline_rows())
    return out
