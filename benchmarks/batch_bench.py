"""Single-query vs batched-executor throughput on a shared workload.

Measures the tentpole claim of the batched execution layer: serving N
queries per scan through ``Session.execute_many`` turns N x B
``eval_partials`` calls into B fused MXU passes, so queries/sec scales with
the workload instead of with Python dispatch overhead. Both paths run
through the public ``repro.verdict`` facade (one shared plan-IR lifecycle
underneath).

    PYTHONPATH=src python benchmarks/batch_bench.py [--queries 50] [--dry-run]

Reports queries/sec and scanned tuples/sec for both paths, the fused
speedup, and the cross-query dedup ratio.
"""
from __future__ import annotations

import argparse
import time

import repro.verdict as vd
from repro.aqp import workload as W


def bench(n_queries=50, n_rows=20_000, n_batches=6, sample_rate=0.15,
          repeat_frac=0.4, seed=0):
    """Returns [(metric_name, value)] rows (benchmarks/run.py convention).

    ``repeat_frac``: fraction of the workload that re-issues earlier queries
    (dashboard refreshes) — the cross-query dedup's natural food.
    """
    rel = W.make_relation(seed=seed, n_rows=n_rows, n_num=2, cat_sizes=(4,),
                          n_measures=1, lengthscale=0.4, noise=0.2)
    n_fresh = max(int(n_queries * (1.0 - repeat_frac)), 1)
    qs = W.make_workload(1, rel.schema, n_fresh, agg_kinds=("AVG", "COUNT", "SUM"),
                         cat_pred_prob=0.3)
    qs = (qs * (n_queries // n_fresh + 1))[:n_queries]
    cfg = dict(sample_rate=sample_rate, n_batches=n_batches, capacity=512,
               seed=seed)

    # Warm both sessions' jitted paths on a throwaway query (compile time is
    # a one-off cost; the claim under test is steady-state throughput).
    warm_q = W.make_workload(2, rel.schema, 1)[0]
    seq = vd.connect(rel, vd.EngineConfig(**cfg))
    bat = vd.connect(rel, vd.EngineConfig(**cfg))
    seq.execute(warm_q)
    bat.execute_many([warm_q])

    t0 = time.perf_counter()
    r_seq = [seq.execute(q) for q in qs]
    t_seq = time.perf_counter() - t0

    t0 = time.perf_counter()
    r_bat = bat.execute_many(qs)
    t_bat = time.perf_counter() - t0
    stats = bat.last_stats

    tuples_seq = sum(r.tuples_scanned for r in r_seq)
    tuples_bat = sum(r.tuples_scanned for r in r_bat)
    return [
        ("batch/seq_queries_per_sec", n_queries / t_seq),
        ("batch/fused_queries_per_sec", n_queries / t_bat),
        ("batch/speedup_queries_per_sec", t_seq / t_bat),
        ("batch/seq_tuples_per_sec", tuples_seq / t_seq),
        ("batch/fused_tuples_per_sec", tuples_bat / t_bat),
        ("batch/dedup_ratio", stats.dedup_ratio),
        ("batch/eval_calls_fused", float(stats.eval_calls)),
        ("batch/eval_calls_seq", float(sum(r.batches_used for r in r_seq))),
    ]


def run():
    """Entry point for ``benchmarks.run`` suite registration."""
    return bench()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=50)
    ap.add_argument("--rows", type=int, default=20_000)
    ap.add_argument("--batches", type=int, default=6)
    ap.add_argument("--out", default="",
                    help="write name,value rows as JSON to this file")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny sizes, CI smoke: checks the path runs end-to-end")
    args = ap.parse_args()
    if args.dry_run:
        rows = bench(n_queries=6, n_rows=2_000, n_batches=2)
    else:
        rows = bench(n_queries=args.queries, n_rows=args.rows,
                     n_batches=args.batches)
    for name, val in rows:
        print(f"{name},{val:.4g}")
    if args.out:
        import json

        with open(args.out, "w") as f:
            json.dump(dict(rows), f, indent=1)
            f.write("\n")


if __name__ == "__main__":
    main()
