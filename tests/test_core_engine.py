"""End-to-end Verdict engine behaviour: error reduction, speedup, validation,
learning recovery (Fig. 7), append adjustment (App. D), Theorem 1 at system level."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.aqp import workload as W
from repro.aqp.queries import AggQuery, AggSpec, NumRange, TextLike
from repro.core import covariance as C
from repro.core import learning
from repro.core.append import estimate_append_stats
from repro.core.engine import EngineConfig, VerdictEngine
from repro.core.types import AVG, GPParams, Schema, make_snippets


@pytest.fixture(scope="module")
def relation():
    return W.make_relation(seed=0, n_rows=12_000, n_num=2, cat_sizes=(4,),
                           n_measures=1, lengthscale=0.4, noise=0.2)


@pytest.fixture(scope="module")
def trained_engines(relation):
    train_q = W.make_workload(1, relation.schema, 40, agg_kinds=("AVG",),
                              width_range=(0.15, 0.5), cat_pred_prob=0.2)
    cfg_v = EngineConfig(sample_rate=0.15, n_batches=6, capacity=256, seed=0)
    cfg_n = EngineConfig(sample_rate=0.15, n_batches=6, capacity=256, seed=0,
                         learning=False)
    verdict = VerdictEngine(relation, cfg_v)
    nolearn = VerdictEngine(relation, cfg_n)
    verdict.execute_many(train_q)  # one fused scan for the training workload
    verdict.refit(steps=60)
    return verdict, nolearn


def _exact(relation, engine, q):
    groups = engine._discover_groups(q)
    from repro.aqp.queries import assemble_results, decompose

    plan = decompose(relation.schema, q, groups)
    theta = relation.exact_answer(plan.snippets)
    cells = assemble_results(plan, theta, np.zeros(plan.snippets.n), relation.cardinality)
    return {(c["group"], c["agg"]): c["estimate"] for c in cells}


def test_engine_reduces_error_bounds_and_actual_error(relation, trained_engines):
    verdict, nolearn = trained_engines
    test_q = W.make_workload(2, relation.schema, 15, agg_kinds=("AVG",),
                             width_range=(0.15, 0.5), cat_pred_prob=0.2)
    imp_bounds, raw_bounds, imp_errs, raw_errs = [], [], [], []
    n_accepted = 0
    rv_all = verdict.execute_many(test_q, max_batches=2)
    rn_all = nolearn.execute_many(test_q, max_batches=2)
    for q, rv, rn in zip(test_q, rv_all, rn_all):
        exact = _exact(relation, verdict, q)
        for cv, cn in zip(rv.cells, rn.cells):
            ex = exact[(cv["group"], cv["agg"])]
            if abs(ex) < 1e-9:
                continue
            imp_bounds.append(np.sqrt(cv["beta2"]) / abs(ex))
            raw_bounds.append(np.sqrt(cn["beta2"]) / abs(ex))
            imp_errs.append(abs(cv["estimate"] - ex) / abs(ex))
            raw_errs.append(abs(cn["estimate"] - ex) / abs(ex))
        n_accepted += int(np.asarray(rv.snippet_answer.accepted).sum())
    # Theorem 1 at the system level: bounds never worse on average, and the
    # learned model should measurably shrink both bounds and actual errors.
    assert np.mean(imp_bounds) < np.mean(raw_bounds)
    assert np.mean(imp_errs) < np.mean(raw_errs) * 1.05
    assert n_accepted > 0  # the model is actually being used


def test_engine_speedup_batches_to_target(relation, trained_engines):
    verdict, nolearn = trained_engines
    test_q = W.make_workload(3, relation.schema, 10, agg_kinds=("AVG",),
                             width_range=(0.2, 0.5), cat_pred_prob=0.0)
    rv_all = verdict.execute_many(test_q, target_rel_error=0.02)
    rn_all = nolearn.execute_many(test_q, target_rel_error=0.02)
    v_batches = sum(r.batches_used for r in rv_all)
    n_batches = sum(r.batches_used for r in rn_all)
    assert v_batches <= n_batches  # Verdict reaches the target no slower


def test_snippet_level_theorem1(relation, trained_engines):
    verdict, _ = trained_engines
    q = W.make_workload(4, relation.schema, 5, agg_kinds=("AVG",))[0]
    r = verdict.execute(q, max_batches=3)
    imp = r.snippet_answer
    assert np.all(np.asarray(imp.beta2) <= np.asarray(imp.raw_beta2) + 1e-12)


def test_unsupported_query_bypasses_learning(relation):
    eng = VerdictEngine(relation, EngineConfig(sample_rate=0.1, n_batches=4))
    q = AggQuery(aggs=(AggSpec("AVG", 0),),
                 predicates=(TextLike("%apple%"), NumRange(0, 1.0, 5.0)))
    r = eng.execute(q)
    assert not r.supported and "textual" in r.unsupported_reason
    assert len(eng.store) == 0  # nothing recorded
    q2 = AggQuery(aggs=(AggSpec("MIN", 0),), predicates=())
    assert not eng.execute(q2).supported


def test_groupby_and_sum_count(relation):
    eng = VerdictEngine(relation, EngineConfig(sample_rate=0.2, n_batches=4))
    q = AggQuery(aggs=(AggSpec("AVG", 0), AggSpec("COUNT"), AggSpec("SUM", 0)),
                 predicates=(NumRange(0, 2.0, 8.0),), groupby=(0,))
    r = eng.execute(q)
    assert r.supported
    groups = {c["group"] for c in r.cells}
    assert len(groups) == 4  # all 4 categories present
    exact = _exact(relation, eng, q)
    for c in r.cells:
        ex = exact[(c["group"], c["agg"])]
        err = abs(c["estimate"] - ex) / max(abs(ex), 1e-9)
        assert err < 0.2, (c, ex)


def test_validation_rejects_corrupt_model(relation):
    eng = VerdictEngine(relation, EngineConfig(sample_rate=0.15, n_batches=4,
                                               capacity=128))
    eng.execute_many(W.make_workload(5, relation.schema, 10, agg_kinds=("AVG",)))
    # Corrupt the model: shift the prior mean absurdly and rebuild.
    for syn in eng.store.values():
        syn.params = GPParams(log_ls=syn.params.log_ls - 5.0,  # tiny ls
                              log_sigma2=syn.params.log_sigma2 + 8.0,
                              mu=syn.params.mu + 1e3)
        syn.rebuild()
    q = W.make_workload(6, relation.schema, 3, agg_kinds=("AVG",))[0]
    r = eng.execute(q, max_batches=2)
    # The likely-region test must reject the corrupt model everywhere,
    # falling back to raw answers (Theorem 1 safety).
    assert not np.any(np.asarray(r.snippet_answer.accepted))
    np.testing.assert_allclose(np.asarray(r.snippet_answer.theta),
                               np.asarray(r.snippet_answer.raw_theta))


def test_learning_recovers_lengthscales():
    """Fig. 7 analog: fit on answers sampled from a known model."""
    rng = np.random.default_rng(0)
    sch = Schema(num_lo=(0.0, 0.0), num_hi=(1.0, 1.0), cat_sizes=(), n_measures=1)
    true = GPParams(log_ls=jnp.log(jnp.asarray([0.15, 0.6])),
                    log_sigma2=jnp.log(2.0), mu=jnp.asarray(0.0))
    ranges = []
    for _ in range(80):
        r = {}
        for d in range(2):
            a = rng.uniform(0, 0.8)
            r[d] = (a, a + rng.uniform(0.02, 0.2))
        ranges.append(r)
    b = make_snippets(sch, agg=AVG, measure=0, num_ranges=ranges)
    k = np.array(C.cov_matrix(b, b, true))
    k[np.diag_indices(80)] = np.asarray(C.cov_diag(b, true))
    chol = np.linalg.cholesky(k + 1e-10 * np.eye(80))
    beta = 0.05
    theta = chol @ rng.normal(size=80) + beta * rng.normal(size=80)
    fitted, hist = learning.fit(b, jnp.asarray(theta),
                                jnp.full((80,), beta**2), sch, steps=200, lr=0.1)
    ls = np.exp(np.asarray(fitted.log_ls))
    assert float(hist[-1]) < float(hist[0])  # NLL decreased
    # short lengthscale dim identified as clearly shorter than the long one
    assert ls[0] < ls[1]
    assert 0.05 < ls[0] < 0.45
    assert ls[1] > 0.3


def test_append_adjustment_keeps_bounds_valid():
    """App. D: after drifted appends, adjusted bounds stay valid."""
    rng = np.random.default_rng(1)
    rel = W.make_relation(seed=10, n_rows=8_000, n_num=2, cat_sizes=(),
                          n_measures=1, noise=0.1)
    eng = VerdictEngine(rel, EngineConfig(sample_rate=0.2, n_batches=4, capacity=64))
    qs = W.make_workload(7, rel.schema, 12, agg_kinds=("AVG",), cat_pred_prob=0.0)
    eng.execute_many(qs[:8])
    # Append 20% new rows with +0.8 shifted measure values.
    extra = rel.take(np.arange(1_600))
    extra.measures = extra.measures + 0.8
    stats = estimate_append_stats(
        np.asarray(rel.measures[:500]), np.asarray(extra.measures[:500]),
        rel.cardinality, extra.cardinality)
    assert stats.mu[0] == pytest.approx(0.8, abs=0.15)
    for syn in eng.store.values():
        before = syn.beta2().copy()
        syn.apply_append(stats)
        after = syn.beta2()
        assert np.all(np.asarray(after) >= np.asarray(before))  # only inflate
