"""Inference: Eq (11)/(12) equivalence to the joint conditional, Theorem 1,
incremental linear algebra, padding invariance."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import covariance as C
from repro.core import inference as I
from repro.core.types import AVG, GPParams, RawAnswer, Schema, make_snippets
from repro.core.synopsis import Synopsis, inv_append_block, inv_delete_block
import proptest as pt


def _schema(l=2, cats=(4,)):
    return Schema(num_lo=(0.0,) * l, num_hi=(1.0,) * l, cat_sizes=cats, n_measures=1)


def _random_batch(rng, sch, n, agg=AVG):
    ranges = []
    for _ in range(n):
        r = {}
        for d in range(sch.n_num):
            a = rng.uniform(0, 0.6)
            r[d] = (a, a + rng.uniform(0.05, 0.4))
        ranges.append(r)
    return make_snippets(sch, agg=agg, measure=0, num_ranges=ranges)


def test_eq11_12_matches_direct_conditional():
    """Verdict's O(n^2) forms == conditioning the full (n+2) joint (Eq. 4/5)."""
    rng = np.random.default_rng(3)
    sch = _schema()
    p = GPParams(log_ls=jnp.log(jnp.asarray([0.4, 0.7])), log_sigma2=jnp.log(1.7),
                 mu=jnp.asarray(0.9))
    n = 8
    past = _random_batch(rng, sch, n)
    new = _random_batch(rng, sch, 1)
    theta_past = rng.normal(1.0, 0.5, n)
    beta2_past = rng.uniform(0.01, 0.1, n) ** 2
    theta_new = float(rng.normal(1.0, 0.5))
    beta2_new = float(rng.uniform(0.05, 0.2) ** 2)

    # --- direct: joint over (raw_1..raw_{n+1}, exact_{n+1}), condition on raws
    kxx = np.asarray(C.cov_matrix(past, past, p))
    kxn = np.asarray(C.cov_matrix(past, new, p))[:, 0]
    knn = float(np.asarray(C.cov_diag(new, p))[0])
    mu_past = np.asarray(C.prior_mean(past, p))
    mu_new = float(np.asarray(C.prior_mean(new, p))[0])

    sig = np.zeros((n + 2, n + 2))
    sig[:n, :n] = kxx + np.diag(beta2_past)
    sig[:n, n] = sig[n, :n] = kxn
    sig[:n, n + 1] = sig[n + 1, :n] = kxn
    sig[n, n] = knn + beta2_new
    sig[n + 1, n + 1] = knn
    sig[n, n + 1] = sig[n + 1, n] = knn
    mu_vec = np.concatenate([mu_past, [mu_new, mu_new]])
    obs = np.concatenate([theta_past, [theta_new]])
    s11 = sig[: n + 1, : n + 1]
    k_col = sig[: n + 1, n + 1]
    mu_c = mu_new + k_col @ np.linalg.solve(s11, obs - mu_vec[: n + 1])
    var_c = sig[n + 1, n + 1] - k_col @ np.linalg.solve(s11, k_col)

    # --- Verdict path: past-only posterior + product-of-Gaussians blend
    sigma_n = kxx + np.diag(beta2_past)
    sinv = np.linalg.inv(sigma_n)
    alpha = sinv @ (theta_past - mu_past)
    th, b2, gamma2 = I.model_based_answer(
        jnp.asarray(kxn[None, :]), jnp.asarray([knn]), jnp.asarray(sinv),
        jnp.asarray(alpha), jnp.asarray([mu_new]),
        jnp.asarray([theta_new]), jnp.asarray([beta2_new]),
    )
    assert float(th[0]) == pytest.approx(mu_c, rel=1e-8)
    assert float(b2[0]) == pytest.approx(var_c, rel=1e-8)


@pt.given(n_cases=10, seed=7, n=pt.choice([1, 8, 20]), b=pt.floats(0.01, 0.5))
def test_theorem1_improved_error_never_larger(n, b):
    rng = np.random.default_rng(int(n * 1000 + b * 100))
    sch = _schema()
    p = GPParams.init(sch)
    past = _random_batch(rng, sch, n)
    new = _random_batch(rng, sch, 3)
    kxx = np.asarray(C.cov_matrix(past, past, p)) + np.diag(rng.uniform(0.01, 0.2, n))
    sinv = np.linalg.inv(kxx)
    alpha = sinv @ rng.normal(0, 1, n)
    k = np.asarray(C.cov_matrix(new, past, p))
    kap = np.asarray(C.cov_diag(new, p))
    raw_beta2 = np.full(3, b**2)
    th, b2, _ = I.model_based_answer(
        jnp.asarray(k), jnp.asarray(kap), jnp.asarray(sinv), jnp.asarray(alpha),
        jnp.zeros(3), jnp.zeros(3), jnp.asarray(raw_beta2))
    assert np.all(np.asarray(b2) <= raw_beta2 + 1e-15)


def test_exact_raw_answer_passthrough():
    th, b2 = I.combine(jnp.asarray([5.0]), jnp.asarray([1.0]),
                       jnp.asarray([3.0]), jnp.asarray([0.0]))
    assert float(th[0]) == 3.0 and float(b2[0]) == 0.0


def test_incremental_inverse_matches_full():
    rng = np.random.default_rng(1)
    a = rng.normal(size=(6, 6))
    sig = a @ a.T + 6 * np.eye(6)
    inv = jnp.asarray(np.linalg.inv(sig[:3, :3]))
    for i in range(3, 6):
        inv = inv_append_block(inv, jnp.asarray(sig[i:i + 1, :i]),
                               jnp.asarray(sig[i:i + 1, i:i + 1]), jitter=0.0)
    np.testing.assert_allclose(np.asarray(inv), np.linalg.inv(sig), rtol=1e-8)
    # delete row 2
    keep = [0, 1, 3, 4, 5]
    inv_del = inv_delete_block(inv, [2])
    np.testing.assert_allclose(
        np.asarray(inv_del), np.linalg.inv(sig[np.ix_(keep, keep)]), rtol=1e-7)


def test_chol_append_matches_full():
    rng = np.random.default_rng(2)
    a = rng.normal(size=(5, 5))
    sig = a @ a.T + 5 * np.eye(5)
    chol = jnp.asarray(np.linalg.cholesky(sig[:2, :2]))
    for i in range(2, 5):
        chol = I.chol_append_row(chol, jnp.asarray(sig[:i, i]), sig[i, i], jitter=0.0)
    np.testing.assert_allclose(np.asarray(chol), np.linalg.cholesky(sig), rtol=1e-8)


def test_synopsis_padding_invariance():
    """Same improved answers whatever the capacity padding."""
    rng = np.random.default_rng(5)
    sch = _schema()
    past = _random_batch(rng, sch, 10)
    theta = rng.normal(1, 0.3, 10)
    beta2 = rng.uniform(0.01, 0.05, 10)
    new = _random_batch(rng, sch, 4)
    raw = RawAnswer(jnp.asarray(rng.normal(1, 0.3, 4)), jnp.asarray(np.full(4, 0.02)))
    outs = []
    for cap in (16, 64, 256):
        syn = Synopsis(sch, capacity=cap)
        syn.add(past, theta, beta2)
        imp = syn.improve(new, raw)
        outs.append((np.asarray(imp.theta), np.asarray(imp.beta2)))
    for t, b in outs[1:]:
        np.testing.assert_allclose(t, outs[0][0], rtol=1e-7)
        np.testing.assert_allclose(b, outs[0][1], rtol=1e-7)


def test_synopsis_lru_eviction_and_duplicates():
    rng = np.random.default_rng(6)
    sch = _schema()
    syn = Synopsis(sch, capacity=8)
    b1 = _random_batch(rng, sch, 8)
    syn.add(b1, rng.normal(1, 0.1, 8), np.full(8, 0.02))
    syn.drain()
    assert syn.n == 8
    # duplicate insert: refreshes stamp, keeps better answer
    syn.add(b1[0], np.asarray([2.0]), np.asarray([0.001]))
    syn.drain()
    assert syn.n == 8
    assert syn._theta[0] == pytest.approx(2.0)
    # new snippet evicts the LRU one (row 1 now oldest)
    b2 = _random_batch(rng, sch, 1)
    syn.add(b2, np.asarray([1.5]), np.asarray([0.02]))
    syn.drain()
    assert syn.n == 8
    assert len(syn._order) == 8


def test_synopsis_incremental_matches_rebuild():
    rng = np.random.default_rng(7)
    sch = _schema()
    syn = Synopsis(sch, capacity=32)
    for i in range(3):
        b = _random_batch(rng, sch, 4)
        syn.add(b, rng.normal(1, 0.2, 4), rng.uniform(0.01, 0.05, 4))
    syn.drain()
    inv_inc = np.asarray(syn._sigma_inv).copy()
    syn.rebuild()
    np.testing.assert_allclose(inv_inc, np.asarray(syn._sigma_inv), rtol=1e-6)


def test_synopsis_state_roundtrip():
    rng = np.random.default_rng(8)
    sch = _schema()
    syn = Synopsis(sch, capacity=16)
    syn.add(_random_batch(rng, sch, 6), rng.normal(1, 0.2, 6), np.full(6, 0.02))
    state = syn.state_dict()
    syn2 = Synopsis(sch, capacity=16)
    syn2.load_state_dict(state)
    new = _random_batch(rng, sch, 2)
    raw = RawAnswer(jnp.asarray([1.0, 1.1]), jnp.asarray([0.02, 0.02]))
    i1 = syn.improve(new, raw)
    i2 = syn2.improve(new, raw)
    np.testing.assert_allclose(np.asarray(i1.theta), np.asarray(i2.theta), rtol=1e-7)
