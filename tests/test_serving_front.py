"""Multi-tenant serving front (``repro.serving.front``): admission control
(clock-free replayable token bucket, bounded queue, typed rejections),
shared-vs-isolated tenancy over one relation, the JSON wire codec, the
HTTP/NDJSON transport, and the per-tenant observability surface — with
miss-path answers pinned bitwise-equal to a direct ``Session.execute``."""
import json
import threading
import urllib.error
import urllib.request

import pytest

import repro.verdict as vd
from repro.aqp import workload as W
from repro.core.engine import EngineConfig
from repro.serving.front import (
    AdmissionConfig,
    AdmissionController,
    LatencyHistogram,
    Rejection,
    ServingFront,
    TenantSpec,
    TokenBucket,
    WireError,
    answer_to_json,
    budget_from_json,
    query_from_json,
    serve_http,
)
from repro.verdict.answer import FailedAnswer, QueryAnswer


@pytest.fixture(scope="module")
def relation():
    return W.make_relation(seed=0, n_rows=3_000, n_num=2, cat_sizes=(4,),
                           n_measures=1, lengthscale=0.4, noise=0.2)


def _cfg(**kw):
    base = dict(sample_rate=0.2, n_batches=4, capacity=128, seed=0)
    base.update(kw)
    return EngineConfig(**base)


def _cells(ans):
    return [c.to_dict() for c in ans.cells]


QJ = {"aggs": [{"kind": "avg", "measure": "v0"}],
      "where": [{"op": "between", "column": "x0", "lo": 2.0, "hi": 8.0}]}


class FakeClock:
    """Scripted monotonic clock: admission replay's time source."""

    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ----------------------------------------------------------- admission unit


def test_token_bucket_is_a_pure_function_of_now():
    b = TokenBucket(rate=2.0, burst=3, now=0.0)
    takes = [b.try_take(0.0) for _ in range(4)]
    assert takes == [True, True, True, False]  # burst spent, bucket dry
    assert b.retry_after(0.0) == pytest.approx(0.5)
    assert not b.try_take(0.4)      # 0.8 tokens refilled — still short
    assert b.try_take(0.5)          # exactly one token at 2/s
    # Non-monotonic input never mints tokens from the past.
    assert not b.try_take(0.1)


def test_admission_replays_exactly_from_a_scripted_clock():
    script = [0.0, 0.01, 0.02, 0.6, 0.61, 1.4]

    def run():
        ctl = AdmissionController(
            "t", AdmissionConfig(rate=2.0, burst=2, max_pending=8), now=0.0)
        return [ctl.admit(now, queue_depth=0) is None for now in script]

    first, second = run(), run()
    assert first == second == [True, True, False, True, False, True]


def test_queue_full_rejection_is_typed_with_retry_hint():
    ctl = AdmissionController("t", AdmissionConfig(rate=10.0, burst=5,
                                                   max_pending=3))
    rej = ctl.admit(0.0, queue_depth=3)
    assert isinstance(rej, Rejection) and rej.rejected and not rej.failed
    assert rej.reason == "queue_full" and rej.status == 503
    assert rej.retry_after_s == pytest.approx(0.1)
    assert ctl.stats()["rejected_queue_full"] == 1
    # Below the bound the same request admits (queue was the only barrier).
    assert ctl.admit(0.0, queue_depth=2) is None


def test_rate_limit_rejection_is_typed():
    ctl = AdmissionController("t", AdmissionConfig(rate=1.0, burst=1,
                                                   max_pending=8))
    assert ctl.admit(0.0, queue_depth=0) is None
    rej = ctl.admit(0.0, queue_depth=0)
    assert isinstance(rej, Rejection)
    assert rej.reason == "rate_limit" and rej.status == 429
    assert rej.retry_after_s == pytest.approx(1.0)
    st = ctl.stats()
    assert st["admitted"] == 1 and st["rejected_rate_limit"] == 1


def test_latency_histogram_quantiles():
    h = LatencyHistogram()
    for ms in (1, 1, 2, 2, 4, 8, 1000):
        h.record(ms / 1e3)
    snap = h.snapshot()
    assert snap["count"] == 7
    assert snap["max_s"] == pytest.approx(1.0)
    assert 0.0005 <= snap["p50_s"] <= 0.004
    assert snap["p99_s"] >= 0.5


# ------------------------------------------------------------------ tenancy


def test_shared_tenants_share_learned_state(relation):
    front = ServingFront(relation, _cfg())
    front.add_tenant(TenantSpec("a", isolation="shared"))
    front.add_tenant(TenantSpec("b", isolation="shared"))
    front.add_tenant(TenantSpec("iso", isolation="isolated"))
    a, b, iso = (front.tenant(n) for n in ("a", "b", "iso"))
    assert a.session.engine is b.session.engine
    assert a.session.store is b.session.store
    assert iso.session.engine is not a.session.engine
    q = query_from_json(a.session.schema, QJ)
    first = front.execute("a", q)
    # Tenant b's IDENTICAL query prescreens from the SHARED cache ...
    second = front.execute("b", q)
    assert second.served_from == "cache:exact"
    assert _cells(second) == _cells(first)
    # ... while the isolated tenant's private cache is cold: it executes.
    third = front.execute("iso", q)
    assert third.served_from is None
    # The shared intel plane splits hit rates per tenant.
    per_tenant = front.stats()["shared_intel"]["per_tenant"]
    assert per_tenant["a"]["hits"] == 0 and per_tenant["b"]["hits"] == 1


def test_shared_services_share_one_engine_lock(relation):
    front = ServingFront(relation, _cfg())
    front.add_tenant("a")
    front.add_tenant("b")
    front.add_tenant(TenantSpec("iso", isolation="isolated"))
    a, b, iso = (front.tenant(n) for n in ("a", "b", "iso"))
    assert a.service._exec_lock is b.service._exec_lock
    assert iso.service._exec_lock is not a.service._exec_lock


def test_miss_path_bitwise_equal_to_direct_session(relation):
    """The tentpole parity gate: through admission + microbatch service,
    a fresh tenant's answer is bitwise-identical to Session.execute."""
    front = ServingFront(relation, _cfg())
    front.add_tenant(TenantSpec("t", isolation="isolated"))
    direct = vd.connect(relation, _cfg())
    qs = W.make_workload(7, relation.schema, 4,
                         agg_kinds=("AVG", "COUNT", "SUM"))
    for q in qs:
        a = front.execute("t", q)
        b = direct.execute(q)
        assert isinstance(a, QueryAnswer) and not a.failed
        assert _cells(a) == _cells(b)
        assert a.batches_used == b.batches_used


def test_duplicate_and_unknown_tenants(relation):
    front = ServingFront(relation, _cfg())
    front.add_tenant("a")
    with pytest.raises(ValueError, match="already registered"):
        front.add_tenant("a")
    with pytest.raises(KeyError, match="unknown tenant"):
        front.execute("ghost", None)
    with pytest.raises(ValueError, match="isolation"):
        TenantSpec("x", isolation="galactic")


def test_front_rejections_are_values_and_counted(relation):
    clock = FakeClock()
    front = ServingFront(relation, _cfg(), clock=clock)
    front.add_tenant(TenantSpec("t", rate=1.0, burst=1, max_pending=8))
    q = query_from_json(front.tenant("t").session.schema, QJ)
    first = front.execute("t", q)
    assert isinstance(first, QueryAnswer)
    rej = front.execute("t", q)  # clock unmoved: bucket is dry
    assert isinstance(rej, Rejection) and rej.reason == "rate_limit"
    clock.advance(1.5)
    again = front.execute("t", q)
    assert not getattr(again, "rejected", False)
    st = front.stats("t")
    assert st["admission"]["admitted"] == 2
    assert st["admission"]["rejected_rate_limit"] == 1
    assert st["metrics"]["rejected"] == {"rate_limit": 1}


def test_stream_yields_refinements_and_final_matches_execute(relation):
    front = ServingFront(relation, _cfg(), cache=False)
    front.add_tenant(TenantSpec("t", isolation="isolated"))
    q = query_from_json(front.tenant("t").session.schema, QJ)
    rounds = list(front.stream("t", q))
    assert len(rounds) == 4  # one refinement per sample batch
    assert [r.final for r in rounds] == [False, False, False, True]
    twin = vd.connect(relation, _cfg())
    assert _cells(rounds[-1]) == _cells(twin.execute(q))
    st = front.stats("t")["metrics"]
    assert st["streams"] == 1 and st["stream_rounds"] == 4


def test_per_tenant_stats_schema(relation):
    front = ServingFront(relation, _cfg())
    front.add_tenant("t")
    q = query_from_json(front.tenant("t").session.schema, QJ)
    front.execute("t", q)
    st = front.stats("t")
    assert st["isolation"] == "shared"
    assert {"admitted", "rejected_rate_limit", "rejected_queue_full",
            "rate", "burst", "max_pending"} <= set(st["admission"])
    m = st["metrics"]
    assert m["requests"] == 1 and m["answered"] == 1
    assert m["failed"] == 0 and m["degraded"] == 0
    assert "execute" in m["latency"]
    assert {"count", "mean_s", "p50_s", "p90_s", "p99_s",
            "max_s"} <= set(m["latency"]["execute"])
    assert st["service"]["flushes"] == 1
    assert st["health"]["quarantined"] == {}


# --------------------------------------------------------------- wire codec


def test_wire_query_lowers_through_the_builder(relation):
    s = vd.connect(relation, _cfg())
    wire_q = query_from_json(s.schema, {
        "aggs": [{"kind": "avg", "measure": "v0"}, {"kind": "count"}],
        "where": [{"op": "between", "column": "x0", "lo": 2, "hi": 8},
                  {"op": "equals", "column": "c0", "value": 1},
                  {"op": "one_of", "column": "c0", "values": [0, 1]}],
        "group_by": ["c0"],
    })
    built = (s.query().avg("v0").count()
             .where(vd.between("x0", 2, 8), vd.equals("c0", 1),
                    vd.one_of("c0", [0, 1]))
             .group_by("c0"))
    assert wire_q.build() == built.build()


@pytest.mark.parametrize("bad,msg", [
    ({"aggs": []}, "non-empty"),
    ({"aggs": [{"kind": "median", "measure": "v0"}]}, "unknown aggregate"),
    ({"aggs": [{"kind": "avg"}]}, "needs a"),
    ({"aggs": [{"kind": "avg", "measure": "nope"}]}, "malformed query"),
    ({"aggs": [{"kind": "count"}],
      "where": [{"op": "like", "column": "x0"}]}, "unknown predicate"),
    ([1, 2], "JSON object"),
])
def test_wire_query_errors_are_typed(relation, bad, msg):
    s = vd.connect(relation, _cfg())
    with pytest.raises(WireError, match=msg):
        query_from_json(s.schema, bad)


def test_wire_budget_roundtrip():
    b = budget_from_json({"target_rel_error": 0.1, "max_batches": 2,
                          "delta": 0.9, "deadline_s": 1.5})
    assert b == vd.ErrorBudget(0.1, 2, 0.9, 1.5)
    assert budget_from_json(None) is None
    with pytest.raises(WireError, match="unknown budget keys"):
        budget_from_json({"deadline": 1.0})


def test_wire_answer_ladder_discriminated():
    failed = FailedAnswer(error="boom", error_type="InjectedFault",
                          attempts=3)
    rej = Rejection("rate_limit", "t", 0.25)
    assert answer_to_json(failed)["kind"] == "failed"
    assert answer_to_json(failed)["attempts"] == 3
    r = answer_to_json(rej)
    assert r["kind"] == "rejected" and r["retry_after_s"] == 0.25
    with pytest.raises(TypeError):
        answer_to_json(object())


# ----------------------------------------------------------- HTTP transport


@pytest.fixture(scope="module")
def http_front(relation):
    front = ServingFront(relation, _cfg())
    front.add_tenant(TenantSpec("web", isolation="shared"))
    front.add_tenant(TenantSpec("tiny", rate=0.001, burst=1, max_pending=8))
    server = serve_http(front)
    host, port = server.server_address
    yield front, f"http://{host}:{port}"
    server.shutdown()
    server.server_close()


def _post(base, path, body):
    req = urllib.request.Request(
        base + path, data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req) as r:
            return r.status, json.loads(r.read()), dict(r.headers)
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read()), dict(e.headers)


def _get(base, path):
    try:
        with urllib.request.urlopen(base + path) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def test_http_execute_roundtrip_bitwise(http_front, relation):
    front, base = http_front
    status, body, _ = _post(base, "/v1/tenants/web/execute", {"query": QJ})
    assert status == 200 and body["kind"] == "answer"
    twin = vd.connect(relation, _cfg())
    direct = twin.execute(query_from_json(twin.schema, QJ))
    got = [dict(c, group=tuple(c["group"])) for c in body["cells"]]
    assert got == _cells(direct)  # JSON round-trip keeps float64 bits


def test_http_explain(http_front):
    _, base = http_front
    status, body, _ = _post(base, "/v1/tenants/web/explain", {"query": QJ})
    assert status == 200 and body["kind"] == "plan"
    assert body["supported"] is True and body["n_snippets"] > 0


def test_http_stream_ndjson(http_front, relation):
    _, base = http_front
    req = urllib.request.Request(
        base + "/v1/tenants/web/stream",
        data=json.dumps({
            "query": {"aggs": [{"kind": "sum", "measure": "v0"}],
                      "where": [{"op": "between", "column": "x1",
                                 "lo": 1.0, "hi": 6.0}]},
        }).encode(),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req) as r:
        assert r.status == 200
        assert r.headers["Content-Type"] == "application/x-ndjson"
        rounds = [json.loads(line) for line in r]
    assert len(rounds) == 4
    assert [x["final"] for x in rounds] == [False, False, False, True]
    assert all(x["kind"] == "answer" for x in rounds)


def test_http_admission_rejection_statuses(http_front):
    _, base = http_front
    st1, _, _ = _post(base, "/v1/tenants/tiny/execute", {"query": QJ})
    assert st1 == 200  # the burst token
    st2, body, headers = _post(base, "/v1/tenants/tiny/execute",
                               {"query": QJ})
    assert st2 == 429 and body["kind"] == "rejected"
    assert body["reason"] == "rate_limit"
    assert float(headers["Retry-After"]) > 0


def test_http_error_mapping(http_front):
    _, base = http_front
    st, body, _ = _post(base, "/v1/tenants/ghost/execute", {"query": QJ})
    assert st == 404 and body["kind"] == "error"
    st, body, _ = _post(base, "/v1/tenants/web/execute",
                        {"query": {"aggs": []}})
    assert st == 400 and "non-empty" in body["error"]
    st, body, _ = _post(base, "/v1/tenants/web/execute", {"query": QJ,
                        "budget": {"deadline": 1}})
    assert st == 400 and "unknown budget keys" in body["error"]
    st, body = _get(base, "/v1/nope")
    assert st == 404


def test_http_stats_and_healthz(http_front):
    _, base = http_front
    st, body = _get(base, "/v1/healthz")
    assert st == 200 and body == {"ok": True}
    st, body = _get(base, "/v1/tenants/web/stats")
    assert st == 200 and body["metrics"]["tenant"] == "web"
    st, body = _get(base, "/v1/stats")
    assert st == 200 and "web" in body["tenants"]
    assert body["shared_intel"]["enabled"] is True


def test_http_concurrent_tenants_all_resolve(http_front, relation):
    """Concurrent HTTP clients across tenants: every request gets a typed
    body, never a hung socket or a 500."""
    front, base = http_front
    for name in ("c1", "c2", "c3"):
        front.add_tenant(TenantSpec(name, isolation="shared"))
    results = []

    def client(name):
        status, body, _ = _post(base, f"/v1/tenants/{name}/execute",
                                {"query": QJ})
        results.append((status, body["kind"]))

    threads = [threading.Thread(target=client, args=(n,))
               for n in ("c1", "c2", "c3") for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not any(t.is_alive() for t in threads)
    assert len(results) == 9
    assert all(s == 200 and k == "answer" for s, k in results)
