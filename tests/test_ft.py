"""Fault tolerance: checkpoint round-trip + atomicity, restart-equivalence,
elastic plan, pipeline determinism + straggler assignment."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.pipeline import TokenPipeline
from repro.ft.checkpoint import CheckpointManager
from repro.ft.elastic import rescale_plan


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": [jnp.ones((2,), jnp.int32), {"c": jnp.float32(3.5)}]}
    mgr.save(5, tree, {"note": "x"})
    mgr.save_async(7, jax.tree.map(lambda x: x * 2, tree), {"note": "y"})
    mgr.wait()
    assert mgr.all_steps() == [5, 7]
    restored, extra = mgr.restore(tree)  # latest
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(tree["a"]) * 2)
    assert extra["note"] == "y"
    r5, _ = mgr.restore(tree, step=5)
    np.testing.assert_allclose(np.asarray(r5["a"]), np.asarray(tree["a"]))


def test_checkpoint_retention_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.zeros((4,))}
    for s in (1, 2, 3):
        mgr.save(s, tree)
    assert mgr.all_steps() == [2, 3]
    # a torn write (no COMMITTED marker) is invisible
    os.makedirs(tmp_path / "step_0000000009", exist_ok=True)
    assert mgr.latest_step() == 3


def test_train_restart_equivalence(tmp_path):
    """Uninterrupted run == crash-at-step-N + resume (bitwise on loss)."""
    from repro.launch import train as TR

    d1 = str(tmp_path / "run_a")
    loss_a = TR.main(["--arch", "qwen2.5-3b", "--smoke", "--steps", "8",
                      "--batch", "2", "--seq", "16", "--accum", "1",
                      "--ckpt-every", "3", "--ckpt-dir", d1])
    d2 = str(tmp_path / "run_b")
    with pytest.raises(SystemExit):
        TR.main(["--arch", "qwen2.5-3b", "--smoke", "--steps", "8",
                 "--batch", "2", "--seq", "16", "--accum", "1",
                 "--ckpt-every", "3", "--ckpt-dir", d2,
                 "--simulate-failure", "5"])
    loss_b = TR.main(["--arch", "qwen2.5-3b", "--smoke", "--steps", "8",
                      "--batch", "2", "--seq", "16", "--accum", "1",
                      "--ckpt-every", "3", "--ckpt-dir", d2])
    assert loss_a == pytest.approx(loss_b, rel=1e-6)


def test_elastic_rescale_plan():
    plan = rescale_plan({"data": 16, "model": 16},
                        {"pod": 2, "data": 16, "model": 16}, 256)
    assert plan["new_data_parallel"] == 32
    with pytest.raises(ValueError):
        rescale_plan({"data": 16, "model": 16}, {"data": 32, "model": 8}, 256)
    with pytest.raises(ValueError):
        rescale_plan({"data": 16, "model": 16}, {"data": 24, "model": 16}, 100)


def test_pipeline_determinism_and_stealing():
    p1 = TokenPipeline(vocab=100, seq_len=8, global_batch=16, n_hosts=2,
                       host_id=0, over_factor=4)
    p2 = TokenPipeline(vocab=100, seq_len=8, global_batch=16, n_hosts=2,
                       host_id=0, over_factor=4)
    a, _ = p1.next_batch()
    b, _ = p2.next_batch()
    np.testing.assert_array_equal(a, b)  # determinism
    # straggler: host 1 runs at 1/3 speed -> gets fewer units
    p3 = TokenPipeline(vocab=100, seq_len=8, global_batch=24, n_hosts=2,
                       host_id=0, over_factor=6)
    buckets = p3.assignments(speeds=[1.0, 0.33])
    assert len(buckets[0]) > len(buckets[1])
    assert sorted(buckets[0] + buckets[1]) == list(range(12))
    # global coverage: units partition the global batch regardless of speeds
    gb = p3.global_batch_at(0)
    assert gb.shape[0] == 24


def test_synopsis_checkpoint_roundtrip_makes_new_engine_smarter(tmp_path):
    """The engine 'gets smarter every time' across process restarts: synopsis
    state checkpoints through CheckpointManager and a fresh engine restores
    it bit for bit, serving the same improved answers as the original."""
    from repro.aqp import workload as W
    from repro.core.engine import EngineConfig, VerdictEngine

    rel = W.make_relation(seed=3, n_rows=6_000, n_num=2, cat_sizes=(4,),
                          n_measures=1, lengthscale=0.4, noise=0.2)
    cfg = EngineConfig(sample_rate=0.15, n_batches=4, capacity=128, seed=0)
    eng = VerdictEngine(rel, cfg)
    train = W.make_workload(1, rel.schema, 12, agg_kinds=("AVG", "COUNT"))
    eng.execute_many(train)
    eng.refit(steps=20)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    eng.save_synopses(mgr, step=1)

    fresh = VerdictEngine(rel, cfg)  # simulated process restart
    extra = fresh.load_synopses(mgr)
    assert extra["kind"] == "verdict-synopses"
    assert fresh.store.keys() == eng.store.keys()
    for key, syn in eng.store.items():
        got = fresh.store.get(key).state_dict()
        want = syn.state_dict()
        assert got.keys() == want.keys()
        for k in want:
            np.testing.assert_array_equal(got[k], want[k], err_msg=str((key, k)))
    # The restored engine answers test queries exactly like the original.
    test_q = W.make_workload(2, rel.schema, 4, agg_kinds=("AVG",))
    r_old = [eng.execute(q, max_batches=2) for q in test_q]
    r_new = [fresh.execute(q, max_batches=2) for q in test_q]
    for a, b in zip(r_old, r_new):
        assert a.cells == b.cells
    # And it is measurably smarter than a cold engine: model answers accepted.
    accepted = sum(int(np.asarray(r.snippet_answer.accepted).sum())
                   for r in r_new)
    assert accepted > 0


def test_quantize_int8_error_feedback():
    from repro.distributed.compression import dequantize, quantize_int8

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 0.1, (256,)).astype(np.float32))
    q, scale = quantize_int8(x)
    assert q.dtype == jnp.int8
    err = x - dequantize(q, scale)
    assert float(jnp.max(jnp.abs(err))) <= float(scale) * 0.5 + 1e-9
