"""The analyzer's own contract: every rule flags its deliberately-violating
fixture AND stays silent on the real codebase.

The trace-layer fixtures are mini-programs reproducing real historical bugs:
``_reverted_masked_tile_fold`` is pinned to the PR-6 pre-fix fold shape
(tiles only the tuple axis, full-width snippet dots) so T001 reproduces the
1-ulp Q-pad-invariance break as a *diagnostic* instead of a parity flake,
and the ``badrepo/local_eps.py`` fixture is literally the pre-PR-6
kernel-local ``1e-7`` epsilon drift.
"""
import pathlib

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import ast_rules
from repro.analysis import trace_rules as tr
from repro.analysis.cli import main, run_repo_analysis
from repro.analysis.findings import (ERROR, INFO, WARN, Finding, gate_count,
                                     render_json, render_text, sort_findings)
from repro.analysis.programs import (REP_M, REP_Q, REP_T, Program,
                                     engine_programs)

TESTS = pathlib.Path(__file__).resolve().parent
BADREPO = TESTS / "badrepo"

MASK = jax.ShapeDtypeStruct((REP_T, REP_Q), jnp.float64)
PAYLOAD = jax.ShapeDtypeStruct((REP_T, 2 * REP_M + 1), jnp.float64)
FOLD_DN = (((0,), (0,)), ((), ()))


def _rules(findings):
    return {f.rule for f in findings}


# ----------------------------------------------------------- findings layer


def test_finding_severity_validated():
    with pytest.raises(ValueError):
        Finding("T999", "fatal", "x", "y")


def test_gate_count_strict_vs_lax():
    fs = [Finding("R", ERROR, "a", "m"), Finding("R", WARN, "b", "m"),
          Finding("R", INFO, "c", "m")]
    assert gate_count(fs, strict=True) == 2
    assert gate_count(fs, strict=False) == 1
    assert [f.severity for f in sort_findings(fs)] == [ERROR, WARN, INFO]
    assert "T999" not in render_json(fs)
    assert "1 error, 1 warn, 1 info" in render_text(fs)


# ------------------------------------------------- T001: the PR-6 fold bug


def _reverted_masked_tile_fold(mask, payload):
    """masked_tile_fold as it stood BEFORE PR 6: pads/tiles only the tuple
    axis and contracts the full snippet width in one variable-shape dot per
    tuple tile. XLA picks its contraction order from the operand shapes, so
    the reduction order — and hence the last ulp — changed with Q padding.
    Pinned here so T001 reproduces that bug as a diagnostic forever."""
    from repro.kernels import SCAN_TILE_T as TT

    t, q = mask.shape
    tp = -(-t // TT) * TT
    mask = jnp.pad(mask, ((0, tp - t), (0, 0)))
    payload = jnp.pad(payload, ((0, tp - t), (0, 0)))
    acc = jnp.zeros((q, payload.shape[1]), payload.dtype)
    for i in range(tp // TT):
        sl = slice(i * TT, (i + 1) * TT)
        acc = acc + jax.lax.dot_general(
            mask[sl], payload[sl], FOLD_DN,
            preferred_element_type=payload.dtype)
    return acc


def test_t001_reverted_fold_reproduces_pr6_bug():
    p = Program("reverted_masked_tile_fold", _reverted_masked_tile_fold,
                (MASK, PAYLOAD), frozenset({"fold-dot"}))
    found = tr.check_fold_dot_shapes(p)
    assert found and all(f.rule == "T001" and f.severity == ERROR
                         for f in found)
    # the diagnostic names the actual (512, Q) shape the bug compiled
    assert any(f"(512, {REP_Q})" in f.message for f in found)


def test_t001_requires_a_fold_dot_at_all():
    p = Program("sum_everything", lambda m, pl: (m.sum() + pl.sum()),
                (MASK, PAYLOAD), frozenset({"fold-dot"}))
    found = tr.check_fold_dot_shapes(p)
    assert [f.rule for f in found] == ["T001"]
    assert "no tuple-axis fold dot" in found[0].message


# ------------------------------------------------------- T002: fold order


def _tiled_fold(mask, payload, order="asc", shape_tree=False):
    from repro.kernels import SCAN_TILE_Q as TQ, SCAN_TILE_T as TT

    t, q = mask.shape
    tp, qp = -(-t // TT) * TT, -(-q // TQ) * TQ
    mask = jnp.pad(mask, ((0, tp - t), (0, qp - q)))
    payload = jnp.pad(payload, ((0, tp - t), (0, 0)))
    cols = []
    for j in range(qp // TQ):
        dots = [
            jax.lax.dot_general(
                mask[i * TT:(i + 1) * TT, j * TQ:(j + 1) * TQ],
                payload[i * TT:(i + 1) * TT], FOLD_DN,
                preferred_element_type=payload.dtype)
            for i in range(tp // TT)
        ]
        if shape_tree:
            acc = (dots[0] + dots[1]) + (dots[2] + dots[0])
        elif order == "desc":
            acc = dots[-1]
            for d in reversed(dots[:-1]):
                acc = acc + d
        else:
            acc = dots[0]
            for d in dots[1:]:
                acc = acc + d
        cols.append(acc)
    return jnp.concatenate(cols, 0)[:q]


def test_t002_descending_fold_flagged():
    p = Program("descending_fold",
                lambda m, pl: _tiled_fold(m, pl, order="desc"),
                (MASK, PAYLOAD), frozenset({"fold-order"}))
    found = tr.check_fold_order(p)
    assert found and _rules(found) == {"T002"}
    assert any("ascending" in f.message for f in found)


def test_t002_tree_fold_flagged():
    p = Program("tree_fold", lambda m, pl: _tiled_fold(m, pl, shape_tree=True),
                (MASK, PAYLOAD), frozenset({"fold-order"}))
    found = tr.check_fold_order(p)
    assert found and _rules(found) == {"T002"}
    assert any("tree" in f.message for f in found)


def test_t002_canonical_fold_clean():
    from repro.aqp import executor

    p = Program("ok", executor.masked_tile_fold, (MASK, PAYLOAD),
                frozenset({"fold-order"}))
    assert tr.check_fold_order(p) == []


# ----------------------------------------- T003/T004: collective discipline


def _psum_mask_build():
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mesh = Mesh(np.array(jax.devices()), ("data",))

    def build(x):
        return shard_map(lambda v: v - jax.lax.psum(v.sum(), "data"),
                         mesh=mesh, in_specs=(P("data"),),
                         out_specs=P("data"))(x)

    n = 64 * len(jax.devices())
    return Program("psum_mask_build", jax.jit(build),
                   (jax.ShapeDtypeStruct((n, 2), jnp.float64),),
                   frozenset({"mask-build", "agg"}), t=n, q=2)


def test_t003_stray_psum_flagged():
    found = tr.check_mask_build_collectives(_psum_mask_build())
    assert [f.rule for f in found] == ["T003"]
    assert "all_reduce" in found[0].message


def test_t004_bound_zero_flags_the_same_psum():
    assert _rules(tr.check_agg_collectives(_psum_mask_build(), bound=0)) \
        == {"T004"}
    assert tr.check_agg_collectives(_psum_mask_build(), bound=1) == []


# ------------------------------------------------------- T005: HBM escape


def test_t005_oracle_mask_would_be_flagged_fused_is_not():
    from repro.aqp import executor
    from repro.analysis.programs import abstract_snippets, block_structs
    from repro.kernels.fused_masked_scan import ops as fms_ops

    num, cat, meas, valid = block_structs()
    snips = abstract_snippets()
    oracle = Program("oracle_as_fused", executor.eval_partials,
                     (num, cat, meas, snips, valid), frozenset({"fused"}))
    found = tr.check_no_tq_buffer(oracle)
    assert [f.rule for f in found] == ["T005"]

    fused = Program("fused", fms_ops.eval_partials_fused,
                    (num, cat, meas, snips, valid), frozenset({"fused"}))
    assert tr.check_no_tq_buffer(fused) == []


# ------------------------------------------------------------ T006: dtype


def test_t006_f32_leak_flagged():
    from repro.aqp import executor

    def leaky(mask, payload):
        lossy = mask.astype(jnp.float32).astype(jnp.float64)
        return executor.masked_tile_fold(lossy, payload)

    p = Program("f32_leak", leaky, (MASK, PAYLOAD),
                frozenset({"partials-f64"}))
    found = tr.check_partials_f64(p)
    assert found and _rules(found) == {"T006"}
    assert any("convert_element_type" in f.message for f in found)


def test_t006_f32_output_flagged():
    p = Program("f32_out", lambda m, pl: (m.T @ pl).astype(jnp.float32),
                (MASK, PAYLOAD), frozenset({"partials-f64"}))
    found = tr.check_partials_f64(p)
    assert any("output has dtype float32" in f.message for f in found)


# ------------------------------------------------------------ T007: cache


class _FakeJit:
    """Mimics a jitted callable whose cache key leaks per-call state."""

    def __init__(self, leak):
        self.leak = leak
        self.keys = set()

    def _clear_cache(self):
        self.keys.clear()

    def _cache_size(self):
        return len(self.keys)

    def __call__(self, past, valid, sinv, alpha, params, new, *rest):
        key = (past.lo.shape, new.lo.shape)  # the padded (fill, Q) buckets
        if self.leak:
            key += (len(self.keys),)  # a fresh compile every call
        self.keys.add(key)


def test_t007_cache_key_leak_flagged():
    found = tr.check_improve_cache_cardinality(jitted=_FakeJit(leak=True))
    assert [f.rule for f in found] == ["T007"]
    assert "compiled" in found[0].message


def test_t007_bucketed_cache_clean():
    assert tr.check_improve_cache_cardinality(jitted=_FakeJit(leak=False)) \
        == []


def test_t007_unhashable_static_arg_flagged():
    from functools import partial

    # static_argnums=1 makes the `valid` ndarray part of the cache key
    bad = partial(jax.jit, static_argnums=(1,))(
        lambda past, valid, *rest: past.lo.sum())
    found = tr.check_improve_cache_cardinality(jitted=bad)
    assert found and found[0].rule == "T007"
    assert "unhashable" in found[0].message


# --------------------------------------------------------- AST-layer rules


@pytest.fixture(scope="module")
def bad_files():
    return ast_rules.parse_tree(BADREPO)


def test_a001_direct_synopses_write_flagged(bad_files):
    found = ast_rules.check_synopses_access(bad_files)
    locs = {f.location for f in found}
    assert _rules(found) == {"A001"}
    assert any(loc.startswith("uses_synopses.py:") for loc in locs)
    assert len(found) == 2  # the shim write AND the private-dict read


def test_a002_unguarded_apply_flagged(bad_files):
    found = ast_rules.check_guarded_apply(bad_files)
    assert _rules(found) == {"A002"}
    assert found[0].location.startswith("direct_apply.py:")


def test_a003_unregistered_seam_flagged(bad_files):
    found = ast_rules.check_fault_seams(bad_files)
    bad = [f for f in found if "store.drian" in f.message]
    assert bad and bad[0].severity == ERROR
    assert bad[0].location.startswith("bad_seam.py:")


def test_a003_unwrapped_registration_flagged():
    found = ast_rules.check_fault_seams([], points=("ghost.seam",))
    assert [f.rule for f in found] == ["A003"]
    assert "never wrapped" in found[0].message


def test_a004_clock_and_rng_in_kernel_flagged(bad_files):
    found = ast_rules.check_kernel_determinism(bad_files)
    assert _rules(found) == {"A004"}
    msgs = " ".join(f.message for f in found)
    assert "time" in msgs and "np.random" in msgs
    # scope: the same sins OUTSIDE kernels/ are not this rule's business
    outside = [f for f in found
               if not f.location.startswith("kernels/")]
    assert outside == []


def test_a007_clock_and_rng_in_intel_flagged(bad_files):
    found = ast_rules.check_intel_determinism(bad_files)
    assert _rules(found) == {"A007"}
    msgs = " ".join(f.message for f in found)
    assert "time" in msgs and "np.random" in msgs
    # scope: the rule only polices the workload-intelligence plane — the
    # kernels fixture's identical sins belong to A004, not A007
    outside = [f for f in found if not f.location.startswith("intel/")]
    assert outside == []


def test_a008_clock_and_rng_in_front_decisions_flagged(bad_files):
    found = ast_rules.check_front_determinism(bad_files)
    assert _rules(found) == {"A008"}
    msgs = " ".join(f.message for f in found)
    assert "time" in msgs and "random" in msgs
    # scope: only the DECISION modules (admission/metrics) — the transport
    # layer (front.py, http.py) legitimately owns the clock
    outside = [f for f in found
               if not f.location.startswith("serving/front/")]
    assert outside == []


def test_a008_scope_is_exactly_the_decision_modules():
    assert set(ast_rules.FRONT_DECISION_MODULES) == {
        "serving/front/admission.py", "serving/front/metrics.py"}
    assert "A008" in ast_rules.AST_RULES


def test_a005_orphan_module_flagged():
    found = ast_rules.check_dead_code(BADREPO, importer_roots=())
    orphans = [f for f in found if f.location == "orphan.py"]
    assert orphans and orphans[0].severity == ERROR
    assert "dead module" in orphans[0].message


def test_a006_local_epsilon_flagged(bad_files):
    found = ast_rules.check_epsilon_discipline(bad_files, scope=None)
    assert _rules(found) == {"A006"}
    assert found[0].location.startswith("local_eps.py:")
    assert "1e-07" in found[0].message
    # the shared epsilon of record is NOT in the violating band's allowlist
    # by accident: the definition site is excluded by name
    defsite = ast_rules.check_epsilon_discipline(
        bad_files, scope=None, def_sites=("local_eps.py",))
    assert defsite == []


# --------------------------------------- the real codebase passes, strict


@pytest.fixture(scope="module")
def repo_findings():
    return run_repo_analysis()


def test_repo_is_clean_under_strict_gate(repo_findings):
    bad = [f for f in repo_findings if f.severity in (ERROR, WARN)]
    assert gate_count(repo_findings, strict=True) == 0, render_text(bad)


def test_repo_inventory_is_explicit(repo_findings):
    # the dead-code inventory emits INFO entries, each carrying its reason
    inv = [f for f in repo_findings if f.rule == "A005"]
    assert inv and all(f.severity == INFO for f in inv)
    assert all("kept:" in f.message or "importlib" in f.message
               for f in inv)


def test_every_engine_program_lowers(repo_findings):
    # reaching here means jaxpr+StableHLO lowering succeeded for all of them
    names = {p.name for p in engine_programs()}
    assert {"masked_tile_fold", "eval_partials", "eval_partials_fused",
            "masked_partials_fused", "sharded_mask_build"} <= names


def test_cli_ast_layer_exits_zero(capsys):
    rc = main(["--layer", "ast", "--strict"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "finding" in out


def test_cli_json_format(capsys):
    rc = main(["--layer", "ast", "--rules", "A005", "--format", "json"])
    assert rc == 0
    import json

    data = json.loads(capsys.readouterr().out)
    assert all(d["rule"] == "A005" for d in data)
