"""Launch-layer unit tests that don't require compiles: HLO collective parser,
roofline math, cell list policy, mesh builders (shape only)."""
import pytest

from repro.launch import hlo_analysis as H
from repro.launch import roofline as R
from repro.launch.cells import LONG_OK, SHAPES, cell_list

HLO = """
HloModule jit_step

%body.1 (arg: (f32[8,16], s32[])) -> (f32[8,16], s32[]) {
  %p = f32[8,16] parameter(0)
  %ar = f32[8,16]{1,0} all-reduce(%p), replica_groups={}
  ROOT %t = (f32[8,16], s32[]) tuple(%ar, %c)
}

%cond.1 (arg: (f32[8,16], s32[])) -> pred[] {
  %iv = s32[] get-tuple-element(%arg), index=1
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%iv, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %ag = f32[16,16]{1,0} all-gather(%a), dimensions={0}
  %w = (f32[8,16], s32[]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,16] get-tuple-element(%w), index=0
}
"""


def test_collective_parser_loop_multiplication():
    out = H.collective_bytes(HLO)
    # all-gather in main: 16*16*4 = 1024 B; all-reduce in the 12-trip body:
    # 8*16*4 = 512 B * 12 trips * wire factor 2.
    assert out["bytes_by_kind"]["all-gather"] == pytest.approx(1024)
    assert out["bytes_by_kind"]["all-reduce"] == pytest.approx(512 * 12)
    assert out["wire_bytes_by_kind"]["all-reduce"] == pytest.approx(512 * 12 * 2)
    assert out["op_counts"]["all-reduce"] == 12


def test_shape_bytes_tuple_and_dtypes():
    assert H._shape_bytes("(bf16[2,3], f32[4])") == 2 * 3 * 2 + 4 * 4
    assert H._shape_bytes("s8[10]") == 10
    assert H._shape_bytes("pred[]") == 1


def test_roofline_terms_and_dominance():
    r = R.roofline(197e12, 819e9 * 2, 50e9 * 0.5)
    assert r["compute_s"] == pytest.approx(1.0)
    assert r["memory_s"] == pytest.approx(2.0)
    assert r["collective_s"] == pytest.approx(0.5)
    assert r["dominant"] == "memory_s"
    assert r["roofline_fraction"] == pytest.approx(0.5)


def test_combine_costs():
    tot = R.combine_costs({"flops": 10.0, "bytes accessed": 100.0},
                          [(3, {"flops": 2.0, "bytes accessed": 5.0})])
    assert tot["flops_per_device"] == 16.0
    assert tot["bytes_per_device"] == 115.0


def test_cell_list_policy():
    cells = cell_list()
    assert len(cells) == 33  # 10 archs x 3 shapes + 3 long_500k
    longs = {a for a, s in cells if s == "long_500k"}
    assert longs == LONG_OK
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}


def test_model_flops():
    from repro import configs

    cfg = configs.get("qwen2.5-3b")
    mf = R.model_flops(cfg, "train", 4096, 256)
    assert mf == pytest.approx(6 * cfg.n_params * 4096 * 256)
    moe = configs.get("arctic-480b")
    assert moe.n_active_params < 0.1 * moe.n_params  # top-2 of 128 + dense


def test_sharding_rules_resolve():
    from repro.distributed.sharding import DEFAULT_RULES, PURE_DP_RULES

    assert DEFAULT_RULES["ffn"] == "model"
    assert PURE_DP_RULES["_batch_axes"] == ("data", "model")
