"""Shape-agnostic masked sharded scan: the mesh×relation parity matrix.

Pins the tentpole guarantees of the scan plane:

  - ``eval_partials_sharded`` accepts ANY (tuple count, mesh size)
    combination — no divisibility precondition — and its partials are
    BITWISE equal to the unsharded ``eval_partials`` oracle across the full
    matrix {1, 7, 63, 64, 100, 1000} tuples × {1, 2, 4, 8} devices,
    including shards that are entirely padding;
  - ``Partials.scanned`` is the validity-mask sum: the TRUE tuple count,
    never the padded shape;
  - zero-padded rows provably contribute nothing: their mask rows are
    exactly 0.0 (checked at the mask level, where exactness is a theorem,
    not a reduction-order accident);
  - ``ScanPlacement`` is the placement seam: local placement is
    bit-identical to the direct call, sharded placement places blocks via
    ``NamedSharding`` + ``device_put`` and reports true scan telemetry.

Device counts are carved out of the topology conftest.py forces (see
``forced_devices``), so the same file is the 1-device degenerate case and
the 8-device CI matrix leg.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.aqp.executor import (
    Partials,
    ScanPlacement,
    ShardedScanPlacement,
    eval_partials,
    eval_partials_sharded,
    pad_tuple_axis,
    padded_tuple_count,
    predicate_mask,
    scan_placement,
)
from repro.aqp.relation import Relation
from repro.core.types import Schema, make_snippets, pad_snippets

TUPLE_COUNTS = (1, 7, 63, 64, 100, 1000)
DEVICE_COUNTS = (1, 2, 4, 8)

SCHEMA = Schema(num_lo=(0.0, 0.0), num_hi=(10.0, 10.0), cat_sizes=(4,),
                n_measures=2)


def _block(t, seed=0):
    """One random tuple block (normalized num, cat codes, measures)."""
    rng = np.random.default_rng(seed)
    num = jnp.asarray(rng.uniform(0, 1, (t, SCHEMA.n_num)))
    cat = jnp.asarray(rng.integers(0, 4, (t, SCHEMA.n_cat)), jnp.int32)
    measures = jnp.asarray(rng.normal(1.0, 2.0, (t, SCHEMA.n_measures)))
    return num, cat, measures


def _snippets():
    """A padded fused set incl. a zero-match snippet (empty range)."""
    ranges = [{0: (a, a + 3.0)} for a in np.linspace(0.0, 6.0, 5)]
    ranges.append({0: (9.99, 9.991), 1: (0.0, 0.001)})  # matches ~nothing
    agg = [0, 0, 1, 1, 0, 0]
    measure = [0, 1, 0, 0, 1, 0]
    return pad_snippets(
        make_snippets(SCHEMA, agg=agg, measure=measure, num_ranges=ranges))


def _assert_partials_bitwise(got: Partials, want: Partials):
    for f in ("sums", "sumsq", "count", "scanned"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f)), np.asarray(getattr(want, f)),
            err_msg=f)


# --------------------------------------------------------------- the matrix
@pytest.mark.parametrize("n_dev", DEVICE_COUNTS)
@pytest.mark.parametrize("t", TUPLE_COUNTS)
def test_parity_matrix_bitwise(t, n_dev, forced_devices):
    """The acceptance oracle: masked sharded partials == unsharded oracle,
    bit for bit, for every (tuple count, mesh size) cell — including cells
    where entire shards are padding (t < n_dev) and where the tuple axis is
    indivisible by the mesh."""
    mesh = Mesh(np.array(forced_devices(n_dev)), ("data",))
    num, cat, measures, snippets = *_block(t, seed=t), _snippets()
    oracle = eval_partials(num, cat, measures, snippets)
    sharded = eval_partials_sharded(mesh, "data", num, cat, measures,
                                    snippets)
    _assert_partials_bitwise(sharded, oracle)
    # scanned is the TRUE tuple count — not the padded tile.
    assert float(sharded.scanned) == float(t)
    assert padded_tuple_count(t, n_dev) >= t
    assert padded_tuple_count(t, n_dev) % n_dev == 0


@pytest.mark.parametrize("n_dev", DEVICE_COUNTS)
def test_all_padding_shards(n_dev, forced_devices):
    """t=1 over n devices: n-1 shards hold ONLY padding rows and contribute
    exactly nothing; the lone real tuple decides every statistic."""
    mesh = Mesh(np.array(forced_devices(n_dev)), ("data",))
    num, cat, measures, snippets = *_block(1, seed=3), _snippets()
    sharded = eval_partials_sharded(mesh, "data", num, cat, measures,
                                    snippets)
    _assert_partials_bitwise(
        sharded, eval_partials(num, cat, measures, snippets))
    assert float(sharded.scanned) == 1.0
    assert np.all(np.asarray(sharded.count) <= 1.0)


def test_zero_match_snippets_stay_zero(forced_devices):
    """A snippet matching no tuples yields exact zeros in both paths (the
    padding mask must not leak tuples into empty predicates)."""
    mesh = Mesh(np.array(forced_devices(min(4, jax.device_count()))),
                ("data",))
    num, cat, measures, snippets = *_block(100, seed=5), _snippets()
    zero_row = 5  # the ~empty range built in _snippets
    for parts in (
        eval_partials(num, cat, measures, snippets),
        eval_partials_sharded(mesh, "data", num, cat, measures, snippets),
    ):
        assert float(parts.count[zero_row]) == 0.0
        assert float(parts.sums[zero_row]) == 0.0
        assert float(parts.sumsq[zero_row]) == 0.0


# ------------------------------------------------------------ mask semantics
def test_padding_rows_are_exact_zero_in_mask():
    """The provable core of 'padding contributes nothing': every invalid
    row of the validity-masked predicate mask is exactly 0.0, and every
    valid row is bitwise-untouched."""
    num, cat, measures = _block(100, seed=7)
    snippets = _snippets()
    num_p, cat_p, meas_p, valid = pad_tuple_axis(8, num, cat, measures)
    assert num_p.shape[0] == 128 and float(jnp.sum(valid)) == 100.0
    base = predicate_mask(num, cat, snippets)
    masked = predicate_mask(num_p, cat_p, snippets, valid=valid)
    np.testing.assert_array_equal(np.asarray(masked[:100]), np.asarray(base))
    assert np.all(np.asarray(masked[100:]) == 0.0)
    # Padding payloads are zeros too: mask-weighted sums can't see them.
    assert np.all(np.asarray(meas_p[100:]) == 0.0)


def test_masked_eval_partials_scanned_is_mask_sum():
    """eval_partials(valid=...) reports scanned == sum(valid) — a real
    count — and an all-ones mask is bitwise identical to no mask."""
    num, cat, measures = _block(64, seed=11)
    snippets = _snippets()
    plain = eval_partials(num, cat, measures, snippets)
    ones = eval_partials(num, cat, measures, snippets,
                         jnp.ones((64,)))
    _assert_partials_bitwise(ones, plain)
    num_p, cat_p, meas_p, valid = pad_tuple_axis(8, *_block(63, seed=11))
    parts = eval_partials(num_p, cat_p, meas_p, snippets, valid)
    assert float(parts.scanned) == 63.0
    # All-invalid: everything is exactly zero, scanned included.
    dead = eval_partials(num_p, cat_p, meas_p, snippets,
                         jnp.zeros((num_p.shape[0],)))
    for f in ("sums", "sumsq", "count", "scanned"):
        assert np.all(np.asarray(getattr(dead, f)) == 0.0), f


def test_caller_supplied_valid_mask_threads_through_sharded(forced_devices):
    """A caller's own validity mask composes with the padding mask: rows it
    zeroes vanish from counts and scanned in the sharded path too."""
    mesh = Mesh(np.array(forced_devices(min(2, jax.device_count()))),
                ("data",))
    num, cat, measures = _block(100, seed=13)
    snippets = _snippets()
    valid = jnp.asarray((np.arange(100) % 3 != 0).astype(np.float64))
    sharded = eval_partials_sharded(mesh, "data", num, cat, measures,
                                    snippets, valid=valid)
    assert float(sharded.scanned) == float(np.sum(np.asarray(valid)))
    base = predicate_mask(num, cat, snippets, valid=valid)
    np.testing.assert_array_equal(np.asarray(sharded.count),
                                  np.asarray(jnp.sum(base, axis=0)))


# ------------------------------------------------------------ the placement
def test_scan_placement_local_is_bit_identical():
    num, cat, measures = _block(100, seed=17)
    snippets = _snippets()
    rel = Relation(SCHEMA, num, cat, measures, num_normalized=num)
    place = scan_placement(None)
    assert isinstance(place, ScanPlacement) and place.kind == "local"
    assert place.describe() == "local" and place.n_shards == 1
    _assert_partials_bitwise(place.eval_block(rel, snippets),
                             eval_partials(num, cat, measures, snippets))
    st = place.stats()
    assert st["blocks_evaluated"] == 1 and st["tuples_scanned"] == 100
    assert st["pad_rows"] == 0


@pytest.mark.parametrize("n_dev", DEVICE_COUNTS)
def test_scan_placement_sharded_places_and_matches(n_dev, forced_devices):
    """ShardedScanPlacement: blocks are placed over the mesh via
    NamedSharding+device_put, results stay oracle-bitwise, and the
    telemetry separates true tuples from padding overhead."""
    mesh = Mesh(np.array(forced_devices(n_dev)), ("data",))
    num, cat, measures = _block(100, seed=19)
    snippets = _snippets()
    rel = Relation(SCHEMA, num, cat, measures, num_normalized=num)
    place = scan_placement(mesh)
    assert isinstance(place, ShardedScanPlacement)
    assert place.describe() == f"sharded:{n_dev}xdata"
    _assert_partials_bitwise(place.eval_block(rel, snippets),
                             eval_partials(num, cat, measures, snippets))
    st = place.stats()
    assert st["kind"] == "sharded" and st["n_shards"] == n_dev
    assert st["tuples_scanned"] == 100
    assert st["pad_rows"] == padded_tuple_count(100, n_dev) - 100
    # place() really shards the tuple axis over the mesh devices (only the
    # mask-stage arrays travel; the measure payload never does).
    num_p, cat_p, _, valid_p = pad_tuple_axis(n_dev, num, cat, None)
    placed = place.place(num_p, cat_p, valid_p)
    assert set(placed[0].devices()) == set(mesh.devices.flat)


def test_padded_tuple_count_tiles_power_of_two():
    """Power-of-two tiling (logarithmic program count), rounded up to the
    mesh — the round-up is a no-op for power-of-two meshes."""
    assert [padded_tuple_count(t, 1) for t in (1, 7, 63, 64, 100, 1000)] == \
        [1, 8, 64, 64, 128, 1024]
    assert padded_tuple_count(1, 8) == 8
    assert padded_tuple_count(100, 8) == 128
    assert padded_tuple_count(8, 3) == 9  # non-pow2 mesh still divides
    for t in (1, 7, 63, 64, 100, 1000):
        for n in (1, 2, 3, 4, 6, 8):
            p = padded_tuple_count(t, n)
            assert p >= t and p % n == 0


def test_batch_executor_routes_through_placement(forced_devices):
    """BatchExecutor._eval is placement.eval_block: a mesh builds a sharded
    placement, no mesh adopts the engine's (local) one, and a full
    workload over an INDIVISIBLE relation/mesh combination answers
    bitwise-identically to the unsharded engine."""
    from repro.aqp import workload as W
    from repro.aqp.batch import BatchExecutor
    from repro.core.engine import EngineConfig, VerdictEngine

    n_dev = min(8, jax.device_count())
    mesh = Mesh(np.array(forced_devices(n_dev)), ("data",))
    rel = W.make_relation(seed=1, n_rows=3_700, n_num=2, cat_sizes=(4,),
                          n_measures=1, lengthscale=0.4, noise=0.2)
    cfg = dict(sample_rate=0.15, n_batches=3, capacity=128, seed=0)
    local_eng = VerdictEngine(rel, EngineConfig(**cfg))
    shard_eng = VerdictEngine(rel, EngineConfig(**cfg))
    # 3700*0.15 = 555 sample rows over 3 batches: 185 per block — divisible
    # by nothing in the matrix but 1; the old scan refused this outright.
    assert all(len(b) % n_dev != 0 for b in shard_eng.batches.batch_rows
               ) or n_dev == 1
    bx_local = BatchExecutor(local_eng)
    assert bx_local.placement is local_eng.scan  # engine seam adopted
    bx_shard = BatchExecutor(shard_eng, mesh=mesh)
    assert bx_shard.placement.mesh is mesh and bx_shard.mesh is mesh
    qs = W.make_workload(1, rel.schema, 6, agg_kinds=("AVG", "COUNT", "SUM"),
                         cat_pred_prob=0.3)
    r_local = bx_local.execute_many(qs)
    r_shard = bx_shard.execute_many(qs)
    for a, b in zip(r_local, r_shard):
        assert a.cells == b.cells
        assert a.batches_used == b.batches_used
        assert a.tuples_scanned == b.tuples_scanned
    # Workload accounting counts true tuples, not padded tiles.
    per_batch = [len(b) for b in shard_eng.batches.batch_rows]
    assert bx_shard.stats.tuples_scanned == \
        sum(per_batch[:bx_shard.stats.batches_scanned])
    assert bx_shard.placement.pad_rows > 0 or n_dev == 1
