"""Fused masked-scan kernel: bitwise oracle parity + the drift regressions.

Pins the tentpole guarantees of ``repro.kernels.fused_masked_scan``:

  - ``eval_partials_fused`` (predicate compare + categorical membership +
    validity mask + partials accumulation in ONE Pallas pass) is BITWISE
    equal to the pure-jnp ``eval_partials`` oracle across the full
    {1, 7, 63, 64, 100, 1000} tuple matrix, under BOTH local and sharded
    placement (the kernel's sequential tuple-tile grid performs the scan
    plane's canonical ``masked_tile_fold`` — parity by construction, pinned
    here with ``assert_array_equal``, not allclose);
  - the shared ``RANGE_EPS`` boundary epsilon: kernel, oracle and ref agree
    at exactly ``lo``, at ``lo ± 1e-12`` and at ``lo ± 1e-7`` (regression:
    the range_mask_agg kernel used ±1e-7 while the oracle used ±1e-12, so
    boundary tuples disagreed between paths);
  - ``eval_partials_kernel`` accepts ``valid=`` and reports ``scanned`` as
    the mask sum (regression: it reported the padded shape, silently
    deflating every CLT error bound on padded blocks);
  - ``ShardedScanPlacement`` routes a kernel ``local_eval`` through the
    kernel aggregation and REPORTS the evaluator it used (regression: the
    kernel request was silently dropped and ``explain`` misreported).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.aqp.executor import (
    ScanPlacement,
    eval_partials,
    eval_partials_sharded,
    masked_tile_fold,
    pad_tuple_axis,
    scan_placement,
)
from repro.aqp.relation import Relation
from repro.core.types import Schema, make_snippets, pad_snippets
from repro.kernels import RANGE_EPS, SCAN_TILE_T
from repro.kernels.fused_masked_scan import (
    eval_partials_fused,
    fused_masked_scan_ref,
    masked_partials_fused,
)
from repro.kernels.fused_masked_scan.kernel import fused_masked_scan_pallas

from test_sharded_scan import (
    DEVICE_COUNTS,
    SCHEMA,
    TUPLE_COUNTS,
    _assert_partials_bitwise,
    _block,
    _snippets,
)


# ------------------------------------------------------------ parity matrix
@pytest.mark.parametrize("t", TUPLE_COUNTS)
def test_fused_local_parity_matrix_bitwise(t):
    """The acceptance oracle, local leg: fused-kernel partials == pure-jnp
    oracle, bit for bit, for every tuple count — including blocks smaller
    than one kernel tile and blocks spanning several."""
    num, cat, measures, snippets = *_block(t, seed=t), _snippets()
    oracle = eval_partials(num, cat, measures, snippets)
    fused = eval_partials_fused(num, cat, measures, snippets)
    _assert_partials_bitwise(fused, oracle)
    assert float(fused.scanned) == float(t)


@pytest.mark.parametrize("n_dev", DEVICE_COUNTS)
@pytest.mark.parametrize("t", TUPLE_COUNTS)
def test_fused_sharded_parity_matrix_bitwise(t, n_dev, forced_devices):
    """The acceptance oracle, mesh leg: sharded mask build + kernel
    aggregation == unsharded oracle, bit for bit, for every (tuple count,
    mesh size) cell — ``use_kernels=True`` composing with a mesh."""
    mesh = Mesh(np.array(forced_devices(n_dev)), ("data",))
    num, cat, measures, snippets = *_block(t, seed=t), _snippets()
    oracle = eval_partials(num, cat, measures, snippets)
    sharded = eval_partials_sharded(
        mesh, "data", num, cat, measures, snippets,
        agg_fn=masked_partials_fused)
    _assert_partials_bitwise(sharded, oracle)
    assert float(sharded.scanned) == float(t)


def test_fused_valid_mask_parity_bitwise():
    """The ``valid=`` leg: padded blocks produce identical bits through the
    kernel, and ``scanned`` is the mask sum in both paths."""
    snippets = _snippets()
    num_p, cat_p, meas_p, valid = pad_tuple_axis(8, *_block(100, seed=23))
    oracle = eval_partials(num_p, cat_p, meas_p, snippets, valid)
    fused = eval_partials_fused(num_p, cat_p, meas_p, snippets, valid)
    _assert_partials_bitwise(fused, oracle)
    assert float(fused.scanned) == 100.0


def test_fused_cat_free_schema_bitwise():
    """Schemas with no categorical dims run through the kernel's dummy
    all-member column and still match the oracle bitwise."""
    schema = Schema(num_lo=(0.0,), num_hi=(1.0,), cat_sizes=(),
                    n_measures=1)
    rng = np.random.default_rng(29)
    num = jnp.asarray(rng.uniform(0, 1, (200, 1)))
    cat = jnp.zeros((200, 0), jnp.int32)
    measures = jnp.asarray(rng.normal(size=(200, 1)))
    snippets = pad_snippets(make_snippets(
        schema, agg=[0, 1], measure=[0, 0],
        num_ranges=[{0: (0.2, 0.8)}, {0: (0.0, 0.5)}]))
    _assert_partials_bitwise(
        eval_partials_fused(num, cat, measures, snippets),
        eval_partials(num, cat, measures, snippets))


def test_fused_kernel_matches_its_ref_bitwise():
    """Raw kernel vs its pure-jnp ref (pre-padded inputs, no epilogue):
    the kernel package's own parity contract at the array level."""
    rng = np.random.default_rng(31)
    t, l, c, v, p, q = 1024, 2, 1, 4, 3, 128
    x = jnp.asarray(rng.uniform(0, 1, (t, l)))
    codes = jnp.asarray(rng.integers(0, v, (t, c)), jnp.int32)
    valid = jnp.asarray((rng.uniform(size=(t, 1)) > 0.1).astype(np.float64))
    payload = jnp.asarray(rng.normal(size=(t, p)))
    lo = jnp.asarray(rng.uniform(0, 0.5, (q, l)))
    hi = lo + 0.4
    cat = jnp.asarray(rng.integers(0, 2, (q, c * v)).astype(np.float64))
    out_k = fused_masked_scan_pallas(x, codes, valid, payload, lo, hi, cat,
                                     tile_t=SCAN_TILE_T, tile_q=q,
                                     interpret=True)
    out_r = fused_masked_scan_ref(x, codes, valid, payload, lo, hi, cat)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_masked_tile_fold_is_the_canonical_reduction():
    """``_partials_from_mask``'s contraction is ``masked_tile_fold``: one
    fold shared by oracle, gathered sharded mask, and kernel. Padding the
    tuple axis with zero rows never changes a single bit."""
    rng = np.random.default_rng(37)
    mask = jnp.asarray((rng.uniform(size=(700, 8)) > 0.5).astype(np.float64))
    payload = jnp.asarray(rng.normal(size=(700, 5)))
    base = masked_tile_fold(mask, payload)
    padded = masked_tile_fold(
        jnp.concatenate([mask, jnp.zeros((324, 8))]),
        jnp.concatenate([payload, jnp.zeros((324, 5))]))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(padded))


# --------------------------------------------------- regression: RANGE_EPS
def _boundary_block(offsets):
    """One tuple per offset, numeric value = 0.5 + offset (normalized)."""
    num = jnp.asarray([[0.5 + d, 0.5] for d in offsets])
    cat = jnp.zeros((len(offsets), 1), jnp.int32)
    measures = jnp.ones((len(offsets), 2))
    return num, cat, measures


def test_unified_epsilon_boundary_cases():
    """Kernel, oracle and fused kernel agree at the predicate boundary:
    exactly ``lo``, ``lo ± 1e-12`` (inside the shared epsilon) and
    ``lo ± 1e-7`` (the OLD kernel epsilon — now outside below the range).

    Regression: ``range_mask_agg`` widened ranges by ±1e-7 while the oracle
    used ±1e-12, so a tuple 5e-8 below the bound was counted by the kernel
    and not by the oracle. With the shared ``RANGE_EPS`` every path excludes
    it.
    """
    from repro.kernels.range_mask_agg.ops import eval_partials_kernel

    assert RANGE_EPS == 1e-12
    # lo = 0.5 normalized on dim 0 (schema units 0..10); dim 1 unconstrained.
    snippets = pad_snippets(make_snippets(
        SCHEMA, agg=[0], measure=[0],
        num_ranges=[{0: (0.5 * 10.0, 0.9 * 10.0)}]))
    offsets = (0.0, 1e-12, -1e-12, 1e-7, -1e-7, -5e-8)
    in_range = (True, True, True, True, False, False)
    num, cat, measures = _boundary_block(offsets)
    oracle = eval_partials(num, cat, measures, snippets)
    fused = eval_partials_fused(num, cat, measures, snippets)
    rma = eval_partials_kernel(num, cat, measures, snippets)
    want = float(sum(in_range))
    assert float(oracle.count[0]) == want
    assert float(fused.count[0]) == want
    # The pre-PR range_mask_agg kernel counted the -5e-8 and -1e-7 tuples
    # (inside its 1e-7 widening): count was 6.0, not 4.0.
    assert float(rma.count[0]) == want
    _assert_partials_bitwise(fused, oracle)


# ----------------------------------- regression: eval_partials_kernel valid=
def test_range_mask_agg_kernel_accepts_valid_and_reports_true_scanned():
    """Regression: ``eval_partials_kernel`` had no ``valid=`` and reported
    ``scanned = float(padded_shape)`` — padded blocks deflated every CLT
    error bound. Now: ``valid=`` accepted, invalid rows contribute nothing,
    ``scanned`` is the mask sum."""
    from repro.kernels.range_mask_agg.ops import eval_partials_kernel

    snippets = _snippets()
    num_p, cat_p, meas_p, valid = pad_tuple_axis(8, *_block(100, seed=41))
    assert num_p.shape[0] == 128  # really padded
    parts = eval_partials_kernel(num_p, cat_p, meas_p, snippets, valid)
    assert float(parts.scanned) == 100.0  # NOT 128.0
    # Invalid rows contribute nothing: same counts as the unpadded block.
    plain = eval_partials_kernel(*_block(100, seed=41), snippets)
    np.testing.assert_allclose(np.asarray(parts.count),
                               np.asarray(plain.count), rtol=0, atol=0)
    assert float(plain.scanned) == 100.0


# -------------------------------- regression: sharded evaluator telemetry
def test_sharded_placement_routes_kernel_and_reports_evaluator(
        forced_devices):
    """Regression: ``ShardedScanPlacement.eval_block`` ignored
    ``local_eval`` — ``use_kernels=True`` under a mesh silently fell back
    to jnp and ``stats()``/``explain`` misreported. Now the kernel request
    routes through the kernel aggregation and the telemetry names the
    evaluator actually used."""
    n_dev = min(4, jax.device_count())
    mesh = Mesh(np.array(forced_devices(n_dev)), ("data",))
    num, cat, measures = _block(100, seed=43)
    snippets = _snippets()
    rel = Relation(SCHEMA, num, cat, measures, num_normalized=num)
    place = scan_placement(mesh)
    oracle = eval_partials(num, cat, measures, snippets)

    _assert_partials_bitwise(place.eval_block(rel, snippets), oracle)
    assert place.stats()["evaluator"] == "sharded_mask+oracle_agg"
    assert place.evaluator_for(None) == "sharded_mask+oracle_agg"

    _assert_partials_bitwise(
        place.eval_block(rel, snippets, local_eval=eval_partials_fused),
        oracle)
    assert place.stats()["evaluator"] == "sharded_mask+kernel_agg"
    assert place.evaluator_for(eval_partials_fused) == \
        "sharded_mask+kernel_agg"


def test_local_placement_reports_evaluator():
    """Local placement telemetry names the per-block evaluator too."""
    num, cat, measures = _block(64, seed=47)
    snippets = _snippets()
    rel = Relation(SCHEMA, num, cat, measures, num_normalized=num)
    place = ScanPlacement()
    assert place.stats()["evaluator"] is None  # nothing ran yet
    place.eval_block(rel, snippets)
    assert place.stats()["evaluator"] == "oracle"
    place.eval_block(rel, snippets, local_eval=eval_partials_fused)
    assert place.stats()["evaluator"] == "fused_masked_scan"
    assert place.evaluator_for(eval_partials_fused) == "fused_masked_scan"


# ------------------------------------------------------ engine composition
def test_engine_use_kernels_is_bitwise_and_explains_itself(forced_devices):
    """End to end: a ``use_kernels=True`` engine answers EXACTLY the same
    cells locally and over a mesh (the scan partials are bitwise, and the
    rest of the pipeline sees identical inputs), tracks the oracle engine
    within the improve path's f32 tolerance (the GP-inference kernel — not
    this PR's scan plane — is the only divergence left), and
    ``Session.explain`` reports the evaluator that will run."""
    from repro.aqp import workload as W
    from repro.aqp.batch import BatchExecutor
    from repro.core.engine import EngineConfig, VerdictEngine
    from repro.verdict.session import Session

    rel = W.make_relation(seed=2, n_rows=2_000, n_num=2, cat_sizes=(4,),
                          n_measures=1, lengthscale=0.4, noise=0.2)
    cfg = dict(sample_rate=0.2, n_batches=3, capacity=128, seed=0)
    eng_oracle = VerdictEngine(rel, EngineConfig(**cfg))
    eng_kernel = VerdictEngine(rel, EngineConfig(**cfg, use_kernels=True))
    qs = W.make_workload(3, rel.schema, 5, agg_kinds=("AVG", "COUNT", "SUM"),
                         cat_pred_prob=0.3)
    r_oracle = BatchExecutor(eng_oracle).execute_many(qs)
    r_kernel = BatchExecutor(eng_kernel).execute_many(qs)
    for a, b in zip(r_oracle, r_kernel):
        for ca, cb in zip(a.cells, b.cells):
            assert abs(ca["estimate"] - cb["estimate"]) <= \
                1e-3 * max(1.0, abs(ca["estimate"]))

    # Kernel path local vs kernel path sharded: EXACT — the fused kernel and
    # the sharded mask+kernel aggregation are the same canonical fold.
    n_dev = min(8, jax.device_count())
    mesh = Mesh(np.array(forced_devices(n_dev)), ("data",))
    eng_mesh = VerdictEngine(rel, EngineConfig(**cfg, use_kernels=True))
    r_mesh = BatchExecutor(eng_mesh, mesh=mesh).execute_many(qs)
    for a, b in zip(r_kernel, r_mesh):
        assert a.cells == b.cells
        assert a.batches_used == b.batches_used
        assert a.tuples_scanned == b.tuples_scanned

    s = Session(rel, EngineConfig(**cfg, use_kernels=True), mesh=mesh)
    report = s.explain(qs[0])
    assert report.scan_evaluator == "sharded_mask+kernel_agg"
    assert "evaluator=sharded_mask+kernel_agg" in str(report)
    assert s.stats()["scan"]["evaluator"] is None  # nothing scanned yet
    s_local = Session(rel, EngineConfig(**cfg, use_kernels=True))
    assert s_local.explain(qs[0]).scan_evaluator == "fused_masked_scan"
    s_local.execute(qs[0])
    assert s_local.stats()["scan"]["evaluator"] == "fused_masked_scan"
