"""A008 fixture: wall-clock + RNG inside a serving-front decision module.

The real ``repro.serving.front.admission`` takes ``now`` as an argument;
reading the clock (or jittering) INSIDE the decision makes admission
traces unreplayable and rate-limit tests flaky.
"""
import random
import time


def admit(tokens: float, rate: float) -> bool:
    # BAD: the decision depends on when the checker happens to run.
    tokens += rate * time.monotonic()
    # BAD: probabilistic shedding is unreplayable.
    return tokens >= 1.0 and random.random() > 0.01
