"""A007 fixture: wall-clock + RNG inside the workload-intelligence plane.

Every line here is a determinism sin the real ``repro.intel`` must never
commit — a cache key salted with the clock stops persisting across
processes, and an RNG-jittered router feature makes route decisions
unreplayable.
"""
import time

import numpy as np


def cache_key(sig_json: str) -> str:
    # BAD: the key changes every call — the cache can never hit.
    return f"{sig_json}:{time.time()}"


def router_feature(fill_bucket: int) -> float:
    # BAD: jittered features make route decisions unreplayable.
    return fill_bucket + np.random.uniform(0.0, 1.0)
