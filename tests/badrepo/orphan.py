"""A005 fixture: a module nothing imports."""


def unused():
    return 42
