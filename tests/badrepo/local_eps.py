"""A006 fixture: a kernel-local epsilon (the pre-PR-6 drift, literally)."""

EPS = 1e-7  # should be RANGE_EPS from repro.kernels


def open_upper(x, hi):
    return x < hi + EPS
