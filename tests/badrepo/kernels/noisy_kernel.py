"""A004 fixture: wall-clock and RNG inside a kernel module."""
import time

import numpy as np


def jittery_scan(x):
    return x * np.random.rand() + time.time()
