"""A003 fixture: fires a fault seam that is not registered in POINTS."""
from repro.ft import faults


def drain(name):
    faults.fire("store.drian", key=name)  # typo: not in faults.POINTS
