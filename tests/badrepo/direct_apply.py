"""A002 fixture: mutates Synopsis state without the quarantine fence."""


def fast_ingest(syn, item):
    syn._apply_add(*item)  # skips _guarded_apply: a raise corrupts serving
