"""A001 fixture: bypasses the SynopsisStore API with a direct dict write."""


def clobber(engine, key, syn):
    engine.synopses[key] = syn  # direct write through the deprecated shim
    return engine.store._synopses  # and a private-dict read
