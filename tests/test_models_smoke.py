"""Per-arch smoke tests: reduced config, one forward + one train step on CPU,
asserting output shapes and no NaNs; plus a greedy decode round trip."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import params as PM
from repro.models import transformer as T
from repro.training.optimizer import adamw
from repro.training.train_loop import make_train_step

B, S = 2, 16


def _batch(cfg, key):
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (B, S), 0, cfg.vocab, jnp.int32)
    labels = jnp.roll(tokens, -1, axis=1)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.cross_attn:
        batch["ctx"] = jax.random.normal(
            k2, (B, cfg.cross_attn.n_ctx, cfg.d_model), jnp.float32)
    if cfg.enc_dec:
        batch["enc"] = jax.random.normal(k2, (B, S, cfg.d_model), jnp.float32)
    return batch


# The two heaviest smoke configs dominate tier-1 wall clock; run them via
# `pytest -m slow` (CI nightly) instead of on every tier-1 invocation.
_HEAVY = {"hymba-1.5b", "arctic-480b"}


@pytest.mark.parametrize(
    "arch",
    [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a
     for a in configs.names()],
)
def test_forward_and_train_step(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = PM.init_params(cfg, key)
    batch = _batch(cfg, key)

    ctx = batch.get("ctx")
    if cfg.enc_dec:
        ctx = T.encode(cfg, params, batch["enc"])
    logits, _ = T.forward(cfg, params, batch["tokens"], ctx_tokens=ctx)
    assert logits.shape == (B, S, PM.vocab_padded(cfg))
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    step = jax.jit(make_train_step(cfg, adamw(), accum=2))
    mb = jax.tree.map(lambda x: jnp.stack([x, x]), batch)  # (accum=2, B, ...)
    opt_state = adamw().init(params)
    new_params, opt_state, metrics = step(params, opt_state, mb, 1e-3)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # parameters actually moved
    delta = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_params))
    assert max(delta) > 0


@pytest.mark.parametrize(
    "arch",
    [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a
     for a in ["qwen2.5-3b", "gemma2-2b", "rwkv6-3b", "hymba-1.5b",
               "seamless-m4t-medium", "llama-3.2-vision-11b", "arctic-480b"]],
)
def test_decode_matches_prefill(arch):
    """Greedy decode equals teacher-forced forward argmax (cache correctness)."""
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = PM.init_params(cfg, key)
    batch = _batch(cfg, key)
    tokens = batch["tokens"]
    ctx = batch.get("ctx")
    enc = batch.get("enc")

    from repro.serving.engine import make_prefill_step, make_serve_step

    max_len = S + cfg.meta_tokens + 4
    n_ctx = (ctx.shape[1] if ctx is not None else (S if enc is not None else 0))
    prefill = make_prefill_step(cfg, max_len=max_len, n_ctx=n_ctx)
    serve = make_serve_step(cfg)

    # Teacher-forced logits over the full sequence:
    ctx_full = T.encode(cfg, params, enc) if cfg.enc_dec else ctx
    full_logits, _ = T.forward(cfg, params, tokens, ctx_tokens=ctx_full)

    # Prefill on the first S-1 tokens, then decode one step:
    last_logit, caches = prefill(params, tokens[:, : S - 1], ctx_tokens=ctx,
                                 enc_embeds=enc)
    np.testing.assert_allclose(
        np.asarray(last_logit), np.asarray(full_logits[:, S - 2]),
        rtol=2e-3, atol=2e-3)

    pos = jnp.asarray(S - 1 + cfg.meta_tokens, jnp.int32)
    nxt, caches = serve(params, caches, tokens[:, S - 1 : S], pos)
    want = np.argmax(np.asarray(full_logits[:, S - 1]), axis=-1)
    np.testing.assert_array_equal(np.asarray(nxt)[:, 0], want)


def test_rwkv_chunked_matches_sequential():
    cfg = configs.get_smoke("rwkv6-3b")
    from repro.models import rwkv as R

    key = jax.random.PRNGKey(2)
    params = PM.init_params(cfg, key)
    p = params["groups"][0]["sub0"]["ssm"]
    lp = jax.tree.map(lambda x: x[0], p)  # first layer of the stacked group
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 37, cfg.d_model), jnp.float32)
    got, _ = R.rwkv6_mix(cfg, lp, x)
    want = R.rwkv6_mix_ref(cfg, lp, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_mamba_chunked_matches_sequential():
    cfg = configs.get_smoke("hymba-1.5b")
    from repro.models import mamba as M

    key = jax.random.PRNGKey(4)
    params = PM.init_params(cfg, key)
    lp = params["groups"][1]["sub0"]["ssm"]
    lp = jax.tree.map(lambda x: x[0], lp)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 23, cfg.d_model), jnp.float32)
    got, _ = M.mamba_mix(cfg, lp, x)
    want = M.mamba_mix_ref(cfg, lp, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_param_counts_match_formula():
    """params.count_params ~ ArchConfig.n_params (within padding slack)."""
    for arch in configs.names():
        cfg = configs.get(arch)
        counted = PM.count_params(cfg)
        formula = cfg.n_params
        assert abs(counted - formula) / formula < 0.06, (
            arch, counted, formula)


def test_full_config_dimensions():
    """The exact assigned dimensions are preserved in full configs."""
    spec = {
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    }
    for arch, (nl, d, h, kv, ff, v) in spec.items():
        cfg = configs.get(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv,
                cfg.d_ff, cfg.vocab) == (nl, d, h, kv, ff, v), arch
        # layer plan covers exactly n_layers (decoder side)
        total = sum(len(unit) * rep for unit, rep in cfg.layer_plan())
        assert total == cfg.n_layers, arch
