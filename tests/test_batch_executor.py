"""BatchExecutor: fused-scan accounting, bitwise parity with the sequential
engine, ground-truth coverage, dedup, and the serving microbatch facade."""
import numpy as np
import pytest

from repro.aqp import workload as W
from repro.aqp.batch import BatchExecutor
from repro.aqp.queries import AggQuery, AggSpec, CatEq, NumRange, TextLike
from repro.core.engine import EngineConfig, VerdictEngine
from repro.serving.aqp import AqpService
from repro.utils.stats import confidence_multiplier


@pytest.fixture(scope="module")
def relation():
    return W.make_relation(seed=0, n_rows=10_000, n_num=2, cat_sizes=(4,),
                           n_measures=1, lengthscale=0.4, noise=0.2)


@pytest.fixture(scope="module")
def workload(relation):
    qs = W.make_workload(1, relation.schema, 30,
                         agg_kinds=("AVG", "COUNT", "SUM"), cat_pred_prob=0.3)
    # Dashboard-style repetition: the last 20 queries re-issue earlier ones,
    # so cross-query dedup has something to fuse.
    return (qs + qs[:20])[:50]


def _cfg(**kw):
    base = dict(sample_rate=0.15, n_batches=6, capacity=256, seed=0)
    base.update(kw)
    return EngineConfig(**base)


def _numpy_exact(relation, q):
    """Ground-truth aggregate computed with plain NumPy (no jnp paths)."""
    num = np.asarray(relation.num)
    cat = np.asarray(relation.cat)
    meas = np.asarray(relation.measures)
    mask = np.ones(len(num), bool)
    for p in q.predicates:
        if isinstance(p, NumRange):
            mask &= (num[:, p.dim] >= p.lo) & (num[:, p.dim] <= p.hi)
        elif isinstance(p, CatEq):
            mask &= cat[:, p.dim] == p.value
        else:  # pragma: no cover - workload only emits the two above
            raise AssertionError(p)
    groups = (sorted({tuple(r) for r in cat[mask][:, list(q.groupby)]})
              if q.groupby else [()])
    out = {}
    for gv in groups:
        gmask = mask.copy()
        for dim, val in zip(q.groupby, gv):
            gmask &= cat[:, dim] == val
        for ai, a in enumerate(q.aggs):
            if a.kind == "COUNT":
                out[(tuple(gv), ai)] = float(gmask.sum())
            elif a.kind == "AVG":
                out[(tuple(gv), ai)] = float(meas[gmask, a.measure].mean())
            else:
                out[(tuple(gv), ai)] = float(meas[gmask, a.measure].sum())
    return out


def _assert_results_equal(r_seq, r_bat):
    assert len(r_seq) == len(r_bat)
    for a, b in zip(r_seq, r_bat):
        assert a.supported == b.supported
        assert a.batches_used == b.batches_used
        assert a.tuples_scanned == b.tuples_scanned
        assert a.cells == b.cells  # dict equality on floats == bitwise
        if a.snippet_answer is not None:
            for f in ("theta", "beta2", "raw_theta", "raw_beta2", "accepted"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(a.snippet_answer, f)),
                    np.asarray(getattr(b.snippet_answer, f)), err_msg=f)


def test_one_eval_call_per_sample_batch(relation, workload):
    """50-query workload: the fused scan evaluates every sample batch exactly
    once, asserted via a counting wrapper around the engine's eval fn."""
    eng = VerdictEngine(relation, _cfg())
    calls = {"n": 0}
    inner = eng._eval_fn

    def counting(*args, **kw):
        calls["n"] += 1
        return inner(*args, **kw)

    eng._eval_fn = counting
    bx = BatchExecutor(eng)
    results = bx.execute_many(workload)
    assert calls["n"] == eng.batches.n_batches  # exactly one per sample batch
    assert bx.stats.eval_calls == calls["n"]
    assert len(results) == 50
    # Sequential execution would have scanned per query:
    assert sum(r.batches_used for r in results) == 50 * eng.batches.n_batches


def test_batched_matches_sequential_bitwise(relation, workload):
    """Answers (cells and per-snippet improved answers) are bit-for-bit equal
    to query-at-a-time execution, including the evolving synopsis state."""
    seq = VerdictEngine(relation, _cfg())
    bat = VerdictEngine(relation, _cfg())
    r_seq = [seq.execute(q) for q in workload]
    r_bat = BatchExecutor(bat).execute_many(workload)
    _assert_results_equal(r_seq, r_bat)
    # The learned state is equally identical: same snippets, same answers.
    assert seq.store.keys() == bat.store.keys()
    for key in seq.store:
        np.testing.assert_array_equal(seq.store.get(key).theta(),
                                      bat.store.get(key).theta())


def test_batched_matches_sequential_with_early_stopping(relation, workload):
    seq = VerdictEngine(relation, _cfg())
    bat = VerdictEngine(relation, _cfg())
    target = 0.03
    r_seq = [seq.execute(q, target_rel_error=target) for q in workload]
    bx = BatchExecutor(bat)
    r_bat = bx.execute_many(workload, target_rel_error=target)
    _assert_results_equal(r_seq, r_bat)
    assert any(r.batches_used < seq.batches.n_batches for r in r_seq)
    # Fused scan cost: max over queries, not sum.
    assert bx.stats.eval_calls == max(r.batches_used for r in r_bat)


def test_cross_query_dedup_fuses_repeated_snippets(relation, workload):
    eng = VerdictEngine(relation, _cfg())
    bx = BatchExecutor(eng)
    bx.execute_many(workload)
    st = bx.stats
    assert st.n_queries == 50
    # 20 of 50 queries are repeats: their snippets must fuse away.
    assert st.n_snippets_fused < st.n_snippets_total
    assert st.dedup_ratio > 1.5


def test_batched_covers_numpy_ground_truth(relation, workload):
    """Both paths' answers cover the exact NumPy aggregate within the
    report_delta CLT bound (statistical claim, fixed seed)."""
    eng = VerdictEngine(relation, _cfg())
    results = eng.execute_many(workload[:30])
    alpha = float(confidence_multiplier(eng.config.report_delta))
    checked = covered = 0
    for q, r in zip(workload[:30], results):
        exact = _numpy_exact(relation, q)
        for c in r.cells:
            ex = exact[(tuple(c["group"]), c["agg"])]
            if abs(ex) < 1e-9:
                continue
            checked += 1
            covered += abs(c["estimate"] - ex) <= alpha * np.sqrt(c["beta2"]) + 1e-9
    assert checked >= 25
    assert covered / checked >= 0.9  # 95%-bound coverage with slack


def test_unsupported_and_empty_group_queries_match_sequential(relation):
    qs = [
        AggQuery(aggs=(AggSpec("AVG", 0),),
                 predicates=(TextLike("%x%"), NumRange(0, 1.0, 5.0))),
        AggQuery(aggs=(AggSpec("MIN", 0),), predicates=()),
        AggQuery(aggs=(AggSpec("AVG", 0),),
                 predicates=(NumRange(0, 2.0, 8.0),), groupby=(0,)),
        # Empty result set: predicate selects nothing, group-by finds no groups.
        AggQuery(aggs=(AggSpec("COUNT"),),
                 predicates=(NumRange(0, 99.0, 100.0),), groupby=(0,)),
    ]
    seq = VerdictEngine(relation, _cfg())
    bat = VerdictEngine(relation, _cfg())
    r_seq = [seq.execute(q) for q in qs]
    r_bat = BatchExecutor(bat).execute_many(qs)
    assert not r_bat[0].supported and "textual" in r_bat[0].unsupported_reason
    assert not r_bat[1].supported
    assert r_bat[3].cells == [] and r_bat[3].supported
    _assert_results_equal(r_seq, r_bat)
    assert len(bat.store) == len(seq.store)  # no learning from raw-only


def test_workload_of_only_empty_plans(relation):
    """All queries unsupported AND with empty plans: the fused set is empty
    (regression: np.stack on an empty dedup crashed here)."""
    q = AggQuery(aggs=(AggSpec("AVG", 0),),
                 predicates=(TextLike("%x%"), NumRange(0, 99.0, 100.0)),
                 groupby=(0,))
    seq = VerdictEngine(relation, _cfg())
    bat = VerdictEngine(relation, _cfg())
    r_seq = [seq.execute(q)]
    r_bat = BatchExecutor(bat).execute_many([q])
    assert r_bat[0].cells == [] and not r_bat[0].supported
    _assert_results_equal(r_seq, r_bat)


def test_kernel_engine_parity_including_raw_only(relation):
    """With use_kernels=True, supported queries scan through the kernel and
    raw-only probes through pure eval_partials — in BOTH paths — so results
    still agree bitwise."""
    qs = W.make_workload(5, relation.schema, 6, agg_kinds=("AVG", "COUNT"))
    qs.append(AggQuery(aggs=(AggSpec("AVG", 0),),
                       predicates=(TextLike("%a%"), NumRange(0, 2.0, 8.0))))
    seq = VerdictEngine(relation, _cfg(n_batches=3, use_kernels=True))
    bat = VerdictEngine(relation, _cfg(n_batches=3, use_kernels=True))
    r_seq = [seq.execute(q) for q in qs]
    r_bat = BatchExecutor(bat).execute_many(qs)
    _assert_results_equal(r_seq, r_bat)


def test_execute_many_entrypoint_and_learning_improves(relation):
    """engine.execute_many is the public route; batched learning feeds the
    synopsis so later waves get improved (accepted) answers."""
    eng = VerdictEngine(relation, _cfg())
    train = W.make_workload(2, relation.schema, 20, agg_kinds=("AVG",),
                            width_range=(0.15, 0.5), cat_pred_prob=0.2)
    eng.execute_many(train)
    eng.refit(steps=40)
    test_q = W.make_workload(3, relation.schema, 8, agg_kinds=("AVG",),
                             width_range=(0.15, 0.5), cat_pred_prob=0.2)
    results = eng.execute_many(test_q, max_batches=2)
    accepted = sum(int(np.asarray(r.snippet_answer.accepted).sum())
                   for r in results)
    assert accepted > 0
    for r in results:
        imp = r.snippet_answer
        assert np.all(np.asarray(imp.beta2) <= np.asarray(imp.raw_beta2) + 1e-12)


def test_fused_group_discovery_single_probe(relation, workload):
    """execute_many discovers every query's group-by values with ONE
    predicate_mask eval over the first sample batch (the sequential path pays
    one per group-by query), and the discovered groups are identical."""
    import repro.aqp.executor as X

    gq = [AggQuery(aggs=(AggSpec("AVG", 0), AggSpec("COUNT")),
                   predicates=(NumRange(0, lo, lo + 4.0),), groupby=(0,))
          for lo in (1.0, 2.0, 3.0, 4.0)]
    mixed = workload[:6] + gq
    eng = VerdictEngine(relation, _cfg())
    # Warm every jitted shape first so the counted run traces nothing (a
    # trace would call the patched predicate_mask from inside eval_partials).
    BatchExecutor(eng).execute_many(mixed)
    calls = {"n": 0}
    inner = X.predicate_mask

    def counting(*args, **kw):
        calls["n"] += 1
        return inner(*args, **kw)

    X.predicate_mask = counting
    try:
        eng2 = VerdictEngine(relation, _cfg())
        BatchExecutor(eng2).execute_many(mixed)
        fused_calls = calls["n"]
        calls["n"] = 0
        eng3 = VerdictEngine(relation, _cfg())
        seq_groups = [eng3._discover_groups(q) for q in mixed]
        seq_calls = calls["n"]
    finally:
        X.predicate_mask = inner
    assert fused_calls == 1
    assert seq_calls == len(gq)  # one probe per group-by query sequentially
    # The fused probe finds exactly the groups the per-query probes find.
    assert eng3._discover_groups_many(mixed) == seq_groups


def test_aqp_service_microbatches(relation, workload):
    eng_svc = VerdictEngine(relation, _cfg())
    eng_ref = VerdictEngine(relation, _cfg())
    svc = AqpService(eng_svc, max_batch=8)
    tickets = [svc.submit(q) for q in workload[:10]]
    assert svc.flushes == 1  # 8 hit the auto-flush threshold, 2 still queued
    results = [t.result() for t in tickets]  # forces the second flush
    assert svc.flushes == 2
    r_ref = BatchExecutor(eng_ref).execute_many(workload[:8])
    _assert_results_equal(r_ref, results[:8])
    assert svc.last_stats is not None
    # Convenience wrapper returns results in submission order.
    more = svc.execute(workload[10:14])
    assert len(more) == 4 and all(r.supported for r in more)
