"""AqpService microbatcher: auto-flush threshold, ticket resolution, stats
propagation, and bitwise parity of microbatched answers vs direct
``execute_many`` (previously untested beyond one smoke case)."""
import threading

import pytest

import repro.verdict as vd
from repro.aqp import workload as W
from repro.core.engine import EngineConfig, VerdictEngine
from repro.serving.aqp import AqpService


@pytest.fixture(scope="module")
def relation():
    return W.make_relation(seed=0, n_rows=5_000, n_num=2, cat_sizes=(4,),
                           n_measures=1, lengthscale=0.4, noise=0.2)


@pytest.fixture(scope="module")
def workload(relation):
    return W.make_workload(1, relation.schema, 12,
                           agg_kinds=("AVG", "COUNT", "SUM"),
                           cat_pred_prob=0.3)


def _cfg(**kw):
    base = dict(sample_rate=0.15, n_batches=4, capacity=128, seed=0)
    base.update(kw)
    return EngineConfig(**base)


def test_auto_flush_threshold(relation, workload):
    svc = AqpService(VerdictEngine(relation, _cfg()), max_batch=4)
    tickets = [svc.submit(q) for q in workload[:3]]
    assert svc.flushes == 0 and svc.pending == 3
    assert not any(t._done for t in tickets)
    t4 = svc.submit(workload[3])  # hits the threshold exactly
    assert svc.flushes == 1 and svc.pending == 0
    assert all(t._done for t in tickets) and t4._done
    # Resolved tickets answer without another flush.
    assert t4.result() is not None
    assert svc.flushes == 1


def test_ticket_result_triggers_flush_once(relation, workload):
    svc = AqpService(VerdictEngine(relation, _cfg()), max_batch=8)
    t1 = svc.submit(workload[0])
    t2 = svc.submit(workload[1])
    assert svc.flushes == 0
    r1 = t1.result()  # forces the flush for the whole pending batch
    assert svc.flushes == 1 and svc.pending == 0
    assert r1 is not None and t2._done
    assert t2.result() is not None
    assert svc.flushes == 1  # no extra flush for the sibling


def test_stats_propagation(relation, workload):
    svc = AqpService(VerdictEngine(relation, _cfg()), max_batch=5)
    assert svc.last_stats is None
    svc.execute(workload[:5])
    assert svc.flushes == 1
    st = svc.last_stats
    assert st is not None and st.n_queries == 5
    assert st.eval_calls > 0 and st.batches_scanned > 0
    assert st.n_snippets_fused <= st.n_snippets_total
    svc.execute(workload[5:8])
    assert svc.flushes == 2 and svc.last_stats.n_queries == 3


def test_microbatched_parity_vs_direct_execute_many(relation, workload):
    """Flushing a workload in microbatches is bitwise identical to direct
    ``execute_many`` with the same flush boundaries — and, because replay is
    per query in submission order, to ONE big fused call too."""
    svc = AqpService(VerdictEngine(relation, _cfg()), max_batch=5)
    tickets = [svc.submit(q) for q in workload[:10]]
    r_svc = [t.result() for t in tickets]
    assert svc.flushes == 2  # 5 + 5

    ref = VerdictEngine(relation, _cfg())
    r_ref = ref.execute_many(workload[:5]) + ref.execute_many(workload[5:10])
    one = VerdictEngine(relation, _cfg())
    r_one = one.execute_many(workload[:10])
    for a, b, c in zip(r_svc, r_ref, r_one):
        assert a.cells == b.cells == c.cells  # dict float equality == bitwise
        assert a.batches_used == b.batches_used == c.batches_used
        assert a.supported == b.supported == c.supported


def test_service_accepts_session_facade(relation, workload):
    session = vd.connect(relation, _cfg())
    svc = session.serve(max_batch=4,
                        budget=vd.ErrorBudget(target_rel_error=0.05))
    assert svc.engine is session.engine
    assert svc.target_rel_error == 0.05
    assert svc.executor.mesh is session._executor.mesh  # sharding preserved
    results = svc.execute(workload[:4])
    assert len(results) == 4
    assert all(r.batches_used >= 1 for r in results)
    # Constructing AqpService directly from a Session works too (the
    # executor must be bound to the unwrapped engine, not the facade).
    svc2 = AqpService(session, max_batch=8)
    assert svc2.engine is session.engine
    assert svc2.execute(workload[:2])[0].supported


def test_service_honors_full_error_budget(relation, workload):
    """serve(budget=...) threads max_batches AND delta through every flush,
    not just the target."""
    session = vd.connect(relation, _cfg())
    svc = session.serve(budget=vd.ErrorBudget(max_batches=2, delta=0.9))
    results = svc.execute(workload[:4])
    assert all(r.batches_used == 2 for r in results)
    assert svc.max_batches == 2 and svc.stop_delta == 0.9


def test_serve_returns_typed_answers_and_lowers_builders(relation):
    """Through session.serve() the microbatcher speaks the facade types:
    QueryBuilder in, QueryAnswer (typed Cells) out — same as execute."""
    from repro.verdict.answer import Cell, QueryAnswer

    session = vd.connect(relation, _cfg())
    svc = session.serve(max_batch=4)
    q = session.query().avg("v0").where(vd.between("x0", 2.0, 8.0))
    ticket = svc.submit(q)  # builder, not AggQuery
    ans = ticket.result()
    assert isinstance(ans, QueryAnswer)
    assert ans.cells and isinstance(ans.cells[0], Cell)
    # Bitwise-equal to the session's own execute on a fresh twin.
    twin = vd.connect(relation, _cfg())
    direct = twin.execute(twin.query().avg("v0")
                          .where(vd.between("x0", 2.0, 8.0)))
    assert [c.to_dict() for c in ans.cells] == \
           [c.to_dict() for c in direct.cells]
    # The raw engine-level service still lowers builders too.
    raw_svc = AqpService(VerdictEngine(relation, _cfg()), max_batch=4)
    assert raw_svc.submit(session.query().count()).result().supported


def test_forced_raw_only_contract(relation, workload):
    """_execute_raw_only forces the raw-only lifecycle even for a supported
    query: raw answers over the probe, supported=False, nothing learned."""
    eng = VerdictEngine(relation, _cfg())
    q = workload[0]  # a supported query
    r = eng._execute_raw_only(q, "forced by caller", max_batches=2)
    assert not r.supported and r.unsupported_reason == "forced by caller"
    assert r.batches_used == 2 and r.cells
    assert len(eng.store) == 0  # no learning happened


# ------------------------------------------------ concurrency + retry ladder


def test_concurrent_submit_every_ticket_resolves_exactly_once(relation,
                                                              workload):
    """Stress the lock-free-era races: many threads submitting through the
    auto-flush threshold concurrently. Every ticket must resolve to a real
    answer EXACTLY once — no lost entries, no double-flushed batches, no
    premature None from a result() racing another thread's flush."""
    svc = AqpService(VerdictEngine(relation, _cfg()), max_batch=3)
    n_threads, per_thread = 6, 4
    tickets = [[] for _ in range(n_threads)]
    start = threading.Barrier(n_threads)

    def submitter(slot):
        start.wait()
        for i in range(per_thread):
            q = workload[(slot * per_thread + i) % len(workload)]
            tickets[slot].append(svc.submit(q))

    threads = [threading.Thread(target=submitter, args=(s,))
               for s in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    assert not any(t.is_alive() for t in threads)
    svc.flush()  # drain the sub-threshold remainder
    all_tickets = [t for slot in tickets for t in slot]
    assert len(all_tickets) == n_threads * per_thread
    for t in all_tickets:
        ans = t.result(timeout=60)
        assert ans is not None and ans.supported is not None
        assert t.resolutions == 1  # exactly once, despite racing flushes
    assert svc.pending == 0
    # No query lost or duplicated across the racing flushes.
    assert sum(t.resolutions for t in all_tickets) == len(all_tickets)


def test_transient_fault_retries_whole_slice_before_bisecting(relation,
                                                              workload):
    """The docstring's promised order — retry the FULL failed slice with
    backoff first, only then bisect. A transient fault (fires once) must
    cost exactly 2 ``_execute_slice`` calls (fail + clean retry), never the
    O(log n) bisect cascade, and every answer stays a real QueryAnswer."""
    from repro.ft import faults

    svc = AqpService(VerdictEngine(relation, _cfg()), max_batch=64,
                     max_retries=2, backoff_base_s=0.001)
    calls = []
    inner = svc._execute_slice

    def counting(queries):
        calls.append(len(queries))
        return inner(queries)

    svc._execute_slice = counting
    tickets = [svc.submit(q) for q in workload[:4]]
    with faults.inject(faults.FaultSpec("scan.eval", hits=(0,))):
        svc.flush()
    assert calls == [4, 4]  # full slice, failed; full slice again, clean
    for t in tickets:
        ans = t.result()
        assert not getattr(ans, "failed", False)
        assert t.resolutions == 1
    # Bitwise: the retried batch matches a never-faulted twin engine.
    twin = AqpService(VerdictEngine(relation, _cfg()), max_batch=64)
    clean = twin.execute(workload[:4])
    for t, c in zip(tickets, clean):
        assert t.result().cells == c.cells


def test_deadline_degraded_flush_never_primes_the_answer_cache(relation):
    """A deadline-bounded service returns best-so-far degraded answers; the
    workload-intel prescreen must never serve those back as full-accuracy
    cache hits on the next submit."""
    session = vd.connect(relation, _cfg(), cache=True)
    svc = session.serve(max_batch=4,
                        budget=vd.ErrorBudget(deadline_s=0.0))
    q = session.query().avg("v0").where(vd.between("x0", 2.0, 8.0)).build()
    first = svc.submit(q).result()
    assert first.degraded and "deadline" in first.degraded_reasons
    # Nothing degraded was recorded: the repeat is NOT prescreened, it
    # re-enters a microbatch and executes again.
    second_ticket = svc.submit(q)
    assert svc.prescreened == 0 and svc.pending == 1
    second = second_ticket.result()
    assert second.degraded and second.served_from is None
    assert session.stats()["intel"]["insertions"] == 0
