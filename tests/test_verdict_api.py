"""Public ``repro.verdict`` Session API: typed builder, explain, stream,
ErrorBudget early-stop, and bitwise equivalence with engine-level execution."""
import numpy as np
import pytest

import repro.verdict as vd
from repro.aqp import workload as W
from repro.aqp.queries import AggQuery, AggSpec, CatEq, NumRange, TextLike
from repro.core.engine import EngineConfig, VerdictEngine
from repro.verdict.answer import Cell


@pytest.fixture(scope="module")
def relation():
    return W.make_relation(seed=0, n_rows=6_000, n_num=2, cat_sizes=(4,),
                           n_measures=1, lengthscale=0.4, noise=0.2)


def _cfg(**kw):
    base = dict(sample_rate=0.15, n_batches=5, capacity=128, seed=0)
    base.update(kw)
    return EngineConfig(**base)


# ------------------------------------------------------------------ builder
def test_builder_resolves_names(relation):
    s = vd.connect(relation, _cfg())
    q = (s.query().avg("v0").count()
         .where(vd.between("x0", 2.0, 8.0), vd.equals("c0", 1))
         .group_by("c0").build())
    assert q == AggQuery(
        aggs=(AggSpec("AVG", 0), AggSpec("COUNT", None)),
        predicates=(NumRange(0, 2.0, 8.0), CatEq(0, 1)),
        groupby=(0,),
    )
    # Unsupported constructs are representable and flagged, not rejected.
    q2 = s.query().min("v0").where(vd.matches("%x%")).build()
    assert q2.aggs[0].kind == "MIN"
    assert isinstance(q2.predicates[0], TextLike)


def test_builder_errors(relation):
    s = vd.connect(relation, _cfg())
    with pytest.raises(KeyError, match="nope"):
        s.query().avg("nope").build()
    with pytest.raises(KeyError, match="group-by"):
        s.query().count().group_by("x9").build()
    with pytest.raises(ValueError, match="no aggregates"):
        s.query().build()
    # equals() by bare index is ambiguous (numeric vs categorical dim) and
    # must be rejected rather than silently guessed.
    with pytest.raises(KeyError, match="ambiguous"):
        s.query().count().where(vd.equals(0, 2.5)).build()


# ----------------------------------------------------- execute equivalence
def test_execute_matches_engine_bitwise_and_cell_roundtrip(relation):
    """Facade answers are the engine's answers, typed: every Cell
    round-trips to the engine dict representation bit for bit."""
    qs = W.make_workload(1, relation.schema, 8,
                         agg_kinds=("AVG", "COUNT", "SUM"), cat_pred_prob=0.3)
    qs.append(AggQuery(aggs=(AggSpec("AVG", 0),),
                       predicates=(TextLike("%a%"), NumRange(0, 2.0, 8.0))))
    session = vd.connect(relation, _cfg())
    engine = VerdictEngine(relation, _cfg())
    answers = [session.execute(q) for q in qs]
    results = [engine.execute(q) for q in qs]
    for a, r in zip(answers, results):
        assert a.supported == r.supported
        assert a.batches_used == r.batches_used
        assert a.tuples_scanned == r.tuples_scanned
        assert a.unsupported_reason == r.unsupported_reason
        assert [c.to_dict() for c in a.cells] == r.cells  # bitwise
        for c, d in zip(a.cells, r.cells):
            assert Cell.from_dict(d) == c  # round-trip
    # execute_many through the facade matches too, in one fused scan.
    s2 = vd.connect(relation, _cfg())
    many = s2.execute_many(qs)
    for a, r in zip(many, results):
        assert [c.to_dict() for c in a.cells] == r.cells
    assert s2.last_stats.n_queries == len(qs)
    assert s2.last_stats.eval_calls > 0


# ------------------------------------------------------------------ explain
def test_explain_reports_plan(relation):
    s = vd.connect(relation, _cfg())
    q = (s.query().avg("v0").count()
         .where(vd.between("x0", 2.0, 8.0)).group_by("c0"))
    rep = s.explain(q)
    assert rep.supported and rep.unsupported_reason is None
    assert rep.n_groups == 4 and rep.truncated_groups == 0
    assert rep.n_cells == 8  # (AVG, COUNT) x 4 groups
    assert rep.n_snippets == rep.n_snippets_unique == 8
    assert rep.dedup_ratio == 1.0
    # Predicted serve tiles are powers of two >= the per-key row counts.
    for key, qb in rep.q_buckets.items():
        assert qb & (qb - 1) == 0 and qb >= 4
    assert "supported" in str(rep)
    # Nothing was learned or scanned beyond the group-discovery probe.
    assert len(s.store) == 0 or all(
        syn.n == 0 for syn in s.store.values())

    bad = s.query().avg("v0").where(vd.matches("%x%"))
    rep2 = s.explain(bad)
    assert not rep2.supported and "textual" in rep2.unsupported_reason


def test_truncated_groups_surfaced(relation):
    """The planner's n_max cap is no longer silent: explain, the engine
    result and the typed answer all report the dropped group-by cells."""
    cfg = _cfg(n_max=2)
    s = vd.connect(relation, cfg)
    q = s.query().count().group_by("c0")
    rep = s.explain(q)
    assert rep.n_groups == 2 and rep.truncated_groups == 2
    ans = s.execute(q)
    assert len(ans.cells) == 2
    assert ans.truncated_groups == 2
    eng = VerdictEngine(relation, cfg)
    res = eng.execute(q.build())
    assert res.truncated_groups == 2 and res.plan.truncated_groups == 2


# ------------------------------------------------------------------- stream
def test_stream_refines_and_final_matches_execute(relation):
    qs = W.make_workload(2, relation.schema, 3, agg_kinds=("AVG",),
                         width_range=(0.2, 0.5), cat_pred_prob=0.0)
    s_stream = vd.connect(relation, _cfg())
    s_exec = vd.connect(relation, _cfg())
    for q in qs:
        partials = list(s_stream.stream(q))
        direct = s_exec.execute(q)
        assert len(partials) == s_stream.config.n_batches
        assert [p.final for p in partials[:-1]] == [False] * (len(partials) - 1)
        assert partials[-1].final
        assert [c.to_dict() for c in partials[-1].cells] == \
               [c.to_dict() for c in direct.cells]  # bitwise, state included
        # Raw-answer refinement: scanning more batches helped at least once.
        errs = [p.max_rel_error() for p in partials]
        assert min(errs[1:]) <= errs[0]


def test_stream_with_budget_early_stops_like_execute(relation):
    budget = vd.ErrorBudget(target_rel_error=0.08)
    s_stream = vd.connect(relation, _cfg())
    s_exec = vd.connect(relation, _cfg())
    q = W.make_workload(3, relation.schema, 1, agg_kinds=("AVG",),
                        width_range=(0.3, 0.5), cat_pred_prob=0.0)[0]
    partials = list(s_stream.stream(q, budget))
    direct = s_exec.execute(q, budget)
    assert partials[-1].batches_used == direct.batches_used
    assert len(partials) == direct.batches_used  # stopped as soon as met
    assert [c.to_dict() for c in partials[-1].cells] == \
           [c.to_dict() for c in direct.cells]


# -------------------------------------------------------------- ErrorBudget
def test_error_budget_max_batches(relation):
    s = vd.connect(relation, _cfg())
    q = s.query().avg("v0").where(vd.between("x0", 1.0, 9.0))
    a = s.execute(q, vd.ErrorBudget(max_batches=2))
    assert a.batches_used == 2


def test_error_budget_target_early_stop(relation):
    s = vd.connect(relation, _cfg())
    q = s.query().avg("v0").where(vd.between("x0", 0.5, 9.5)).build()
    a = s.execute(q, vd.ErrorBudget(target_rel_error=0.05))
    assert a.batches_used < s.config.n_batches
    assert a.max_rel_error() <= 0.05
    # No target: the full budget is spent.
    b = s.execute(q)
    assert b.batches_used == s.config.n_batches


def test_error_budget_delta_monotone(relation):
    """A stricter confidence level needs at least as many batches."""
    q = AggQuery(aggs=(AggSpec("AVG", 0),),
                 predicates=(NumRange(0, 1.0, 9.0),))
    used = {}
    for delta in (0.5, 0.995):
        s = vd.connect(relation, _cfg())
        a = s.execute(q, vd.ErrorBudget(target_rel_error=0.02, delta=delta))
        used[delta] = a.batches_used
    assert used[0.5] <= used[0.995]


def test_online_answers_rides_the_shared_scan(relation):
    """repro.aqp.online is a thin generator over PhysicalPlan: its raw
    answers and partials equal a hand-rolled unpadded accumulation bitwise
    (pad invariance of per-snippet partials)."""
    from repro.aqp.executor import (Partials, estimates_from_partials,
                                    eval_partials)
    from repro.aqp.online import online_answers
    from repro.aqp.queries import decompose

    eng = VerdictEngine(relation, _cfg())
    plan = decompose(relation.schema,
                     AggQuery(aggs=(AggSpec("AVG", 0), AggSpec("COUNT"),),
                              predicates=(NumRange(0, 2.0, 8.0),)))
    outs = list(online_answers(eng.batches, plan.snippets))
    assert len(outs) == eng.batches.n_batches
    acc = Partials.zeros(plan.snippets.n)
    for (raw, state), rows in zip(outs, eng.batches.batch_rows):
        block = eng.batches.relation.take(rows)
        acc = acc + eval_partials(block.num_normalized, block.cat,
                                  block.measures, plan.snippets)
        np.testing.assert_array_equal(np.asarray(state.partials.count),
                                      np.asarray(acc.count))
        np.testing.assert_array_equal(np.asarray(state.partials.sums),
                                      np.asarray(acc.sums))
        theta, beta2, _ = estimates_from_partials(acc, plan.snippets)
        np.testing.assert_array_equal(np.asarray(raw.theta),
                                      np.asarray(theta))
        np.testing.assert_array_equal(np.asarray(raw.beta2),
                                      np.asarray(beta2))
    assert outs[-1][1].batches_used == eng.batches.n_batches


def test_mesh_session_indivisible_relation_matches_local_bitwise(
        relation, forced_devices):
    """A Session over a mesh whose size does NOT divide the sample batches
    (180-tuple blocks here) answers bitwise-identically to the local
    session — the masked, padded sharded scan makes layout non-observable —
    and explain()/stats() report TRUE scanned-tuple counts, never padded
    tiles."""
    import dataclasses

    import jax
    import numpy as np
    from jax.sharding import Mesh

    n_dev = min(8, jax.device_count())
    mesh = Mesh(np.array(forced_devices(n_dev)), ("data",))
    local = vd.connect(relation, _cfg())
    shard = vd.connect(relation, _cfg(), mesh=mesh)
    batch_sizes = [len(b) for b in shard.engine.batches.batch_rows]
    assert any(t % n_dev != 0 for t in batch_sizes) or n_dev == 1
    qs = W.make_workload(1, relation.schema, 8,
                         agg_kinds=("AVG", "COUNT", "SUM"),
                         cat_pred_prob=0.3)
    a_local = local.execute_many(qs)
    a_shard = shard.execute_many(qs)
    for a, b in zip(a_local, a_shard):
        assert dataclasses.asdict(a) == dataclasses.asdict(b)  # bitwise
        # tuples_scanned is the true per-query count: the sum of the real
        # (un-padded) block sizes it consumed.
        assert b.tuples_scanned == sum(batch_sizes[:b.batches_used])
    # explain() names the scan placement; stats() counts true tuples only.
    rep = shard.explain(shard.query().avg("v0"))
    assert rep.scan_placement == f"sharded:{n_dev}xdata"
    assert f"scan=sharded:{n_dev}xdata" in str(rep)
    assert local.explain(local.query().avg("v0")).scan_placement == "local"
    st = shard.stats()
    true_scanned = sum(batch_sizes[:max(r.batches_used for r in a_shard)])
    assert st["scan"]["kind"] == "sharded"
    assert st["scan"]["n_shards"] == n_dev
    assert st["scan"]["tuples_scanned"] == true_scanned
    assert st["workload"]["tuples_scanned"] == true_scanned
    if n_dev > 1:
        assert st["scan"]["pad_rows"] > 0  # padding happened, invisibly


def test_answer_value_convenience(relation):
    s = vd.connect(relation, _cfg())
    a = s.execute(s.query().count())
    assert a.value == pytest.approx(relation.cardinality, rel=0.05)
    grouped = s.execute(s.query().count().group_by("c0"))
    with pytest.raises(ValueError):
        grouped.value
