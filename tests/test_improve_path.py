"""Serve/learn hot path: bucket-padded improve parity vs the capacity-padded
baseline, bounded jit program counts across mixed-Q workloads, stacked
multi-synopsis dispatch parity, and async-ingest determinism under drain().

Strictness notes (pinned by probes on the XLA CPU backend, same on TPU dot
paths): padding columns/rows carry exact zeros (identity Sigma^{-1} blocks,
zero alpha), so padding itself never changes a partial sum. What CAN change
between *different* padded widths is how XLA groups the live elements inside
a reduction (gemv vs gemm strategies, k-blocking), which perturbs results by
O(eps). Hence:
  - bucketed vs capacity-padded baseline: ULP-level allclose + identical
    validation decisions;
  - everything that runs through ONE program family — async vs sync ingest,
    stacked vs per-synopsis dispatch, batched vs sequential engines — is
    asserted strictly bitwise.
"""
import threading

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.synopsis import (
    Synopsis,
    SynopsisQuarantinedError,
    _improve_padded,
)
from repro.core.types import (
    AVG,
    FREQ,
    RawAnswer,
    Schema,
    SnippetBatch,
    bucket_size,
    make_snippets,
)


def _schema():
    return Schema(num_lo=(0.0, 0.0), num_hi=(1.0, 1.0), cat_sizes=(4,),
                  n_measures=1)


def _random_batch(rng, sch, n, agg=AVG):
    ranges = []
    for _ in range(n):
        r = {}
        for d in range(sch.n_num):
            a = rng.uniform(0, 0.6)
            r[d] = (a, a + rng.uniform(0.05, 0.4))
        ranges.append(r)
    return make_snippets(sch, agg=agg, measure=0, num_ranges=ranges)


def _filled(rng, sch, n, capacity, **kw):
    syn = Synopsis(sch, capacity=capacity, **kw)
    syn.add(_random_batch(rng, sch, n), rng.normal(1.0, 0.3, n),
            rng.uniform(0.01, 0.05, n))
    syn.drain()
    return syn


def _capacity_padded_improve(syn, new, raw):
    """The pre-PR serve path: state padded to full capacity, Q unpadded."""
    C = syn.capacity
    rows = np.asarray(syn._order, np.int64)
    n = len(rows)
    idx = np.concatenate([rows, np.zeros((C - n,), np.int64)])
    past = syn._row_batch(idx)
    valid = jnp.asarray(np.arange(C) < n, jnp.float64)
    sinv = np.eye(C)
    sinv[:n, :n] = np.asarray(syn._sigma_inv)
    alpha = np.zeros((C,))
    alpha[:n] = np.asarray(syn._alpha)
    theta, beta2, accepted = _improve_padded(
        past, valid, jnp.asarray(sinv), jnp.asarray(alpha), syn.params,
        new, raw.theta, raw.beta2, syn.delta_v,
    )
    return np.asarray(theta), np.asarray(beta2), np.asarray(accepted)


# ------------------------------------------------------------------- buckets
def test_bucket_size():
    assert bucket_size(0) == 8
    assert bucket_size(1) == 8
    assert bucket_size(8) == 8
    assert bucket_size(9) == 16
    assert bucket_size(100) == 128
    assert bucket_size(100, cap=96) == 96  # clamped to capacity
    assert bucket_size(3, minimum=4) == 4


def test_fill_bucket_tracks_fill_not_capacity():
    rng = np.random.default_rng(0)
    sch = _schema()
    syn = _filled(rng, sch, 10, capacity=2000)
    assert syn._fill_bucket() == 16
    state = syn._padded_state()
    assert state[2].shape == (16, 16)  # Sigma^{-1} tile, not (2000, 2000)


def test_bucketed_improve_matches_capacity_padded_baseline():
    """Across fill levels and Q sizes the bucketed program returns the
    capacity-padded baseline's answers to within ULPs, with identical
    validation (accept/reject) decisions."""
    rng = np.random.default_rng(1)
    sch = _schema()
    for capacity in (64, 128):
        for fill in (1, 3, 17, 60):
            if fill > capacity:
                continue
            syn = _filled(rng, sch, fill, capacity=capacity)
            for q in (1, 5, 16, 33):
                new = _random_batch(rng, sch, q)
                raw = RawAnswer(jnp.asarray(rng.normal(1.0, 0.3, q)),
                                jnp.asarray(np.full(q, 0.02)))
                imp = syn.improve(new, raw)
                t0, b0, a0 = _capacity_padded_improve(syn, new, raw)
                np.testing.assert_allclose(np.asarray(imp.theta), t0,
                                           rtol=1e-12, atol=1e-13)
                np.testing.assert_allclose(np.asarray(imp.beta2), b0,
                                           rtol=1e-12, atol=1e-13)
                np.testing.assert_array_equal(np.asarray(imp.accepted), a0)


def test_improve_compile_count_bounded_across_mixed_q():
    """One compiled program per (Q-bucket, fill-bucket) pair — a mixed-Q
    workload against evolving fills must not recompile per distinct Q."""
    rng = np.random.default_rng(2)
    sch = _schema()
    syn = _filled(rng, sch, 5, capacity=256)   # fill bucket 8
    syn2 = _filled(rng, sch, 20, capacity=256)  # fill bucket 32
    before = _improve_padded._cache_size()
    for q in list(range(1, 9)) + [12, 16, 23, 31]:  # Q buckets: 8, 16, 32
        for s in (syn, syn2):
            new = _random_batch(rng, sch, q)
            raw = RawAnswer(jnp.asarray(rng.normal(1.0, 0.3, q)),
                            jnp.asarray(np.full(q, 0.02)))
            s.improve(new, raw)
    added = _improve_padded._cache_size() - before
    assert added <= 3 * 2  # |{8,16,32}| Q-buckets x |{8,32}| fill-buckets
    # Steady state: repeating the workload compiles nothing new.
    before = _improve_padded._cache_size()
    for q in (1, 5, 12, 31):
        new = _random_batch(rng, sch, q)
        raw = RawAnswer(jnp.asarray(rng.normal(1.0, 0.3, q)),
                        jnp.asarray(np.full(q, 0.02)))
        syn.improve(new, raw)
    assert _improve_padded._cache_size() == before


# ------------------------------------------------------------------- stacked
def test_stacked_dispatch_bitwise_matches_per_synopsis_improve():
    """VerdictEngine._improve's single stacked dispatch over multiple
    aggregate keys equals the per-synopsis improve calls bit for bit."""
    from repro.aqp import workload as W
    from repro.core.engine import EngineConfig, VerdictEngine

    rng = np.random.default_rng(3)
    rel = W.make_relation(seed=0, n_rows=4_000, n_num=2, cat_sizes=(4,),
                          n_measures=1)
    eng = VerdictEngine(rel, EngineConfig(sample_rate=0.2, n_batches=3,
                                          capacity=64, seed=0))
    # Train both synopses (AVG measure 0 and FREQ).
    for q in W.make_workload(1, rel.schema, 8, agg_kinds=("AVG", "COUNT")):
        eng.execute(q)
    snips = SnippetBatch.concat([
        _random_batch(rng, rel.schema, 5, agg=AVG),
        _random_batch(rng, rel.schema, 3, agg=FREQ),
    ])
    raw = RawAnswer(jnp.asarray(rng.normal(1.0, 0.3, snips.n)),
                    jnp.asarray(np.full(snips.n, 0.02)))
    assert len(eng.store) == 2  # the dispatch actually stacks two groups
    imp = eng._improve(snips, raw)
    agg = np.asarray(snips.agg)
    theta = np.asarray(raw.theta)
    beta2 = np.asarray(raw.beta2)
    for key in ((AVG, 0), (FREQ, 0)):
        rows = np.where(agg == key[0])[0]
        syn = eng.synopsis_for(*key)
        ref = syn.improve(
            snips[jnp.asarray(rows)],
            RawAnswer(jnp.asarray(theta[rows]), jnp.asarray(beta2[rows])),
        )
        np.testing.assert_array_equal(np.asarray(imp.theta)[rows],
                                      np.asarray(ref.theta))
        np.testing.assert_array_equal(np.asarray(imp.beta2)[rows],
                                      np.asarray(ref.beta2))
        np.testing.assert_array_equal(np.asarray(imp.accepted)[rows],
                                      np.asarray(ref.accepted))


# -------------------------------------------------------------- async ingest
def test_async_ingest_matches_sync_bitwise():
    """Interleaved add/improve through the ingest thread produces bitwise the
    same model state and answers as synchronous ingestion (FIFO application
    makes the post-drain state independent of worker timing)."""
    rng_a = np.random.default_rng(4)
    rng_b = np.random.default_rng(4)
    sch = _schema()
    a = Synopsis(sch, capacity=32, async_ingest=True)
    b = Synopsis(sch, capacity=32, async_ingest=False)
    for step in range(6):
        for syn, rng in ((a, rng_a), (b, rng_b)):
            n = 3 + step % 3
            snips = _random_batch(rng, sch, n)
            theta = rng.normal(1.0, 0.3, n)
            beta2 = rng.uniform(0.01, 0.05, n)
            syn.add(snips, theta, beta2)
            new = _random_batch(rng, sch, 4)
            raw = RawAnswer(jnp.asarray(rng.normal(1.0, 0.3, 4)),
                            jnp.asarray(np.full(4, 0.02)))
            imp = syn.improve(new, raw)
            syn._last = (np.asarray(imp.theta), np.asarray(imp.beta2))
        np.testing.assert_array_equal(a._last[0], b._last[0])
        np.testing.assert_array_equal(a._last[1], b._last[1])
    a.drain()
    assert a.n == b.n
    np.testing.assert_array_equal(np.asarray(a._sigma_inv),
                                  np.asarray(b._sigma_inv))
    np.testing.assert_array_equal(a._theta[: a.n], b._theta[: b.n])


def test_add_is_nonblocking_and_drain_is_the_barrier():
    """add() returns while the model update is still pending; drain() applies
    everything. Uses a gate inside the apply function, so the assertion is
    deterministic, not timing-dependent."""
    rng = np.random.default_rng(5)
    sch = _schema()
    syn = Synopsis(sch, capacity=16, async_ingest=True)
    gate = threading.Event()
    inner = syn._apply_add

    def gated(*args):
        gate.wait(timeout=30)
        inner(*args)

    syn._apply_add = gated  # picked up when add() lazily builds the queue
    syn.add(_random_batch(rng, sch, 3), np.ones(3), np.full(3, 0.1))
    assert syn.n == 0  # returned with the covariance build still queued
    gate.set()
    syn.drain()
    assert syn.n == 3
    assert len(syn._order) == 3


def test_failed_ingest_quarantines_not_poisons():
    """A mid-apply failure QUARANTINES this synopsis instead of poisoning
    every later barrier: drain() stays a plain barrier (never raises), the
    failed batch and everything after it park unapplied in FIFO order,
    improve degrades to the raw sample estimate, state_dict refuses with a
    typed error, and heal() replays the parked batches to a state bitwise
    identical to a synopsis that never failed."""
    rng = np.random.default_rng(6)
    sch = _schema()
    syn = Synopsis(sch, capacity=16, async_ingest=True)
    b1 = (_random_batch(rng, sch, 2), np.ones(2), np.full(2, 0.1))
    b2 = (_random_batch(rng, sch, 2), np.full(2, 2.0), np.full(2, 0.2))
    applied = {"n": 0}

    def boom(*args):
        applied["n"] += 1
        raise ValueError("injected ingest failure")

    syn._apply_add = boom
    syn.add(*b1)
    syn.add(*b2)
    syn.drain()  # plain barrier — a failed apply no longer raises here
    assert applied["n"] == 1  # batch 1 failed; batch 2 parked, never applied
    assert syn.quarantined
    assert "injected ingest failure" in syn.quarantine_reason
    stats = syn.ingest_stats()
    assert stats["quarantined"] and stats["quarantine_count"] == 1
    assert stats["unapplied"] == 2  # the failed batch AND the one behind it
    # Serving degrades to the raw floor (Theorem 1's equality case).
    raw = RawAnswer(theta=jnp.asarray([1.5, 2.5]), beta2=jnp.asarray([0.3, 0.4]))
    imp = syn.improve(_random_batch(rng, sch, 2), raw)
    np.testing.assert_array_equal(np.asarray(imp.theta), [1.5, 2.5])
    np.testing.assert_array_equal(np.asarray(imp.beta2), [0.3, 0.4])
    assert not bool(np.asarray(imp.accepted).any())
    with pytest.raises(SynopsisQuarantinedError):
        syn.state_dict()  # a half-applied model never checkpoints
    # Heal: restore the real applier and replay the parked batches in order.
    del syn._apply_add
    assert syn.heal()
    assert not syn.quarantined
    assert syn.ingest_stats()["unapplied"] == 0
    twin = Synopsis(sch, capacity=16, async_ingest=False)
    twin.add(*b1)
    twin.add(*b2)
    got, want = syn.state_dict(), twin.state_dict()
    for k in want:
        if k == "ingest_high_water":  # telemetry, not model state
            continue
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)


def test_state_dict_returns_copies_not_views():
    """Snapshots must not mutate when the ring buffers evolve afterwards."""
    rng = np.random.default_rng(7)
    sch = _schema()
    syn = _filled(rng, sch, 4, capacity=4)
    snap = syn.state_dict()
    theta_before = snap["theta"].copy()
    lo_before = snap["lo"].copy()
    # Overflow the capacity so every ring-buffer row is rewritten.
    syn.add(_random_batch(rng, sch, 4), rng.normal(5.0, 0.1, 4),
            rng.uniform(0.001, 0.002, 4))
    syn.drain()
    np.testing.assert_array_equal(snap["theta"], theta_before)
    np.testing.assert_array_equal(snap["lo"], lo_before)
    # And the snapshot still round-trips into an equivalent synopsis.
    syn2 = Synopsis(sch, capacity=4)
    syn2.load_state_dict(snap)
    np.testing.assert_array_equal(np.asarray(syn2.theta()), theta_before)
