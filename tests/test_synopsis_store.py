"""SynopsisStore: the placement seam of the learned state.

Pins the API-redesign guarantees:
  - ``ShardedSynopsisStore`` answers (cells, per-snippet improved answers,
    learned state) are BITWISE equal to ``LocalSynopsisStore`` on the same
    workload — placement moves FLOPs, never values;
  - checkpoints use structured keys (``"agg<k>-measure<m>"``), carry shard
    tags, restore from the legacy ``"<agg>_<measure>"`` format, and re-place
    onto a different device count (mesh shape) bit for bit;
  - the serve-tile ladder floors are per-deployment ``EngineConfig`` knobs;
  - no module outside ``repro/core/store.py`` constructs or indexes the raw
    synopsis dict (source tripwire); ``VerdictEngine.synopses`` survives
    only as a deprecated shim.

Multi-device placement runs against the topology conftest.py forces (8
fake host CPU devices by default; the CI device-count matrix also runs the
1-device leg, where the same assertions pin the single-shard degenerate
case). Tests that NEED several devices declare it via the shared
``forced_devices`` fixture instead of per-job ``XLA_FLAGS`` env blocks.
"""
import os
import re

import numpy as np
import jax
import pytest

import repro.verdict as vd
from repro.aqp import workload as W
from repro.core.engine import EngineConfig, VerdictEngine
from repro.core.store import (
    LocalSynopsisStore,
    ShardedSynopsisStore,
    parse_state_key,
    state_key,
)
from repro.ft.checkpoint import CheckpointManager


@pytest.fixture(scope="module")
def relation():
    return W.make_relation(seed=0, n_rows=8_000, n_num=2, cat_sizes=(4,),
                           n_measures=2, lengthscale=0.4, noise=0.2)


@pytest.fixture(scope="module")
def workload(relation):
    # AVG over both measures + COUNT/SUM → at least three aggregate keys,
    # so a multi-device store actually spreads state.
    return W.make_workload(1, relation.schema, 24,
                           agg_kinds=("AVG", "COUNT", "SUM"),
                           cat_pred_prob=0.3)


def _cfg(**kw):
    base = dict(sample_rate=0.15, n_batches=4, capacity=128, seed=0)
    base.update(kw)
    return EngineConfig(**base)


def _sharded(relation, cfg=None, devices=None):
    cfg = cfg or _cfg()
    store = lambda schema, c: ShardedSynopsisStore(  # noqa: E731
        schema, c, devices=devices)
    return VerdictEngine(relation, cfg, store=store)


def _assert_results_equal(r_a, r_b):
    assert len(r_a) == len(r_b)
    for a, b in zip(r_a, r_b):
        assert a.supported == b.supported
        assert a.batches_used == b.batches_used
        assert a.cells == b.cells  # dict equality on floats == bitwise
        if a.snippet_answer is not None:
            for f in ("theta", "beta2", "raw_theta", "raw_beta2", "accepted"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(a.snippet_answer, f)),
                    np.asarray(getattr(b.snippet_answer, f)), err_msg=f)


# ------------------------------------------------------------------ parity
def test_sharded_store_bitwise_matches_local(relation, workload):
    """The acceptance oracle: identical workload through a local-store and a
    sharded-store engine (scan held constant) gives bitwise-identical
    answers AND bitwise-identical learned state, across every key."""
    local = VerdictEngine(relation, _cfg())  # default LocalSynopsisStore
    shard = _sharded(relation)
    assert isinstance(local.store, LocalSynopsisStore)
    assert isinstance(shard.store, ShardedSynopsisStore)
    r_local = local.execute_many(workload)
    r_shard = shard.execute_many(workload)
    _assert_results_equal(r_local, r_shard)
    # Learning evolved identically: same keys, same stored answers/state.
    assert local.store.keys() == shard.store.keys()
    local.drain(), shard.drain()
    for key in local.store:
        a = local.store.get(key).state_dict()
        b = shard.store.get(key).state_dict()
        assert a.keys() == b.keys()
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=str((key, k)))


def test_sharded_store_places_keys_across_devices(relation, workload):
    """Keys actually land on their assigned devices, placement is a pure
    function of the key, and per-shard dispatch sets cover all groups."""
    eng = _sharded(relation)
    eng.execute_many(workload[:8])
    store = eng.store
    n_dev = len(store.devices)
    for key, syn in store.items():
        i = store.shard_index(key)
        assert i == (key[0] * 8191 + key[1]) % n_dev
        assert syn.device is store.devices[i]
        # The committed model state lives on the assigned device.
        state = syn._padded_state()
        assert next(iter(state[2].devices())) == store.devices[i]
    if jax.device_count() >= 8 and len(store) >= 2:
        # With the forced 8-CPU-device topology the keys must not collapse
        # onto one device (the hash spreads (agg, measure) keys).
        assert len({store.shard_index(k) for k in store}) >= 2


def test_connect_mesh_builds_sharded_store(relation, forced_devices):
    """connect(mesh=...) shards the learned state from the mesh's devices
    (the scan rides the same mesh; exercised by the facade smoke)."""
    from jax.sharding import Mesh

    mesh = Mesh(np.array(forced_devices(jax.device_count())), ("data",))
    s = vd.connect(relation, _cfg(), mesh=mesh)
    assert isinstance(s.store, ShardedSynopsisStore)
    assert s.store.devices == list(np.asarray(mesh.devices).flat)
    assert s._executor.mesh is mesh
    # Without a mesh the default is the local store.
    assert isinstance(vd.connect(relation, _cfg()).store, LocalSynopsisStore)


# ------------------------------------------------------------- checkpoints
def test_checkpoint_replaces_onto_different_mesh_shape(relation, workload,
                                                       tmp_path):
    """A sharded checkpoint re-places onto a different device count (and
    onto the local store) bit for bit; answers after restore are identical."""
    eng = _sharded(relation)
    eng.execute_many(workload[:10])
    eng.refit(steps=15)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    eng.save_synopses(mgr, step=1)

    devices = jax.devices()
    narrow = _sharded(relation, devices=devices[:1])   # "smaller mesh"
    extra = narrow.load_synopses(mgr)
    assert extra["kind"] == "verdict-synopses"
    local = VerdictEngine(relation, _cfg())            # local re-placement
    local.load_synopses(mgr)
    assert narrow.store.keys() == eng.store.keys() == local.store.keys()
    for key, syn in eng.store.items():
        want = syn.state_dict()
        for other in (narrow, local):
            got = other.store.get(key).state_dict()
            for k in want:
                np.testing.assert_array_equal(got[k], want[k],
                                              err_msg=str((key, k)))
    test_q = workload[10:14]
    r_orig = eng.execute_many(test_q, max_batches=2)
    r_narrow = narrow.execute_many(test_q, max_batches=2)
    r_local = local.execute_many(test_q, max_batches=2)
    _assert_results_equal(r_orig, r_narrow)
    _assert_results_equal(r_orig, r_local)


def test_state_keys_structured_with_shard_tags(relation, workload):
    eng = _sharded(relation)
    eng.execute_many(workload[:6])
    state = eng.synopses_state_dict()
    for name, sd in state.items():
        key = parse_state_key(name)
        assert re.fullmatch(r"agg\d+-measure\d+", name)
        assert state_key(key) == name
        assert int(sd["shard"]) == eng.store.shard_index(key)
    # ingest_stats shares the structured key space.
    assert set(eng.ingest_stats()) == set(state)


def test_legacy_underscore_state_keys_still_load(relation, workload):
    """Pre-store checkpoints used "<agg>_<measure>" keys parsed via
    str.split("_"); the structured loader keeps accepting them."""
    donor = VerdictEngine(relation, _cfg())
    donor.execute_many(workload[:6])
    state = donor.synopses_state_dict()
    legacy = {}
    for name, sd in state.items():
        key = parse_state_key(name)
        sd = dict(sd)
        sd.pop("shard")
        legacy[f"{key[0]}_{key[1]}"] = sd
    fresh = VerdictEngine(relation, _cfg())
    fresh.load_synopses_state_dict(legacy)
    assert fresh.store.keys() == donor.store.keys()
    for key in donor.store:
        a = donor.store.get(key).state_dict()
        b = fresh.store.get(key).state_dict()
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])
    with pytest.raises(ValueError, match="state key"):
        parse_state_key("avg-of-v0")


# ------------------------------------------------------------ config knobs
def test_bucket_ladder_floors_are_config_knobs(relation):
    """EngineConfig.min_fill_bucket/min_q_bucket reach the synopses; the
    defaults stay the historical module constants."""
    from repro.core.synopsis import MIN_FILL_BUCKET, MIN_Q_BUCKET

    assert EngineConfig().min_fill_bucket == MIN_FILL_BUCKET == 8
    assert EngineConfig().min_q_bucket == MIN_Q_BUCKET == 8
    eng = VerdictEngine(relation, _cfg(min_fill_bucket=32, min_q_bucket=16))
    syn = eng.synopsis_for(0, 0)
    assert syn.min_fill_bucket == 32 and syn.min_q_bucket == 16
    assert syn._fill_bucket() == 32  # empty fill still tiles to the floor
    s = vd.connect(relation, _cfg(min_q_bucket=16))
    rep = s.explain(s.query().avg("v0"))
    assert rep.q_buckets and all(qb >= 16 for qb in rep.q_buckets.values())


# -------------------------------------------------------- operator surface
def test_session_stats_and_explain_placement(relation, workload,
                                             forced_devices):
    from jax.sharding import Mesh

    mesh = Mesh(np.array(forced_devices(jax.device_count())), ("data",))
    # No divisibility dance: the masked sharded scan pads 300-tuple sample
    # batches (8000 rows * 0.15 / 4) over whatever mesh size is present.
    mesh_cfg = _cfg()
    s = vd.connect(relation, _cfg())
    s.execute_many(workload[:6])
    st = s.stats()
    assert st["store"]["kind"] == "local" and st["store"]["n_shards"] == 1
    assert st["workload"]["n_queries"] == 6
    for entry in st["store"]["keys"].values():
        assert {"n", "capacity", "shard", "placement", "ingest"} <= set(entry)
        assert entry["placement"] == "local"
        assert {"max_pending", "high_water", "shed_count", "quarantined",
                "quarantine_reason", "unapplied",
                "quarantine_count"} == set(entry["ingest"])
        assert not entry["ingest"]["quarantined"]
    sharded_session = vd.connect(relation, mesh_cfg, mesh=mesh)
    sharded_session.execute_many(workload[:6])
    st2 = sharded_session.stats()
    assert st2["store"]["kind"] == "sharded"
    assert st2["store"]["n_shards"] == jax.device_count()
    occ = st2["store"]["shards"]
    assert sum(sh["n_keys"] for sh in occ) == st2["store"]["n_keys"]
    assert sum(sh["fill"] for sh in occ) == sum(
        syn.n for syn in sharded_session.store.values())
    # explain reports placement even for keys that do not exist yet.
    rep = sharded_session.explain(
        sharded_session.query().avg("v1").where(vd.between("x0", 2, 8)))
    for key, where in rep.placement.items():
        assert where.startswith(f"shard{sharded_session.store.shard_index(key)}:")


def test_engine_synopses_shim_is_deprecated_but_live(relation, workload):
    eng = VerdictEngine(relation, _cfg())
    eng.execute_many(workload[:4])
    with pytest.deprecated_call():
        mapping = eng.synopses
    assert mapping is eng.store.synopses  # the live dict, not a copy
    assert set(mapping) == set(eng.store.keys())


def test_no_raw_synopsis_dict_access_outside_store():
    """Tripwire for the acceptance criterion: the raw key → Synopsis dict is
    constructed and indexed ONLY inside repro/core/store.py (everything else
    goes through the SynopsisStore surface or the deprecated shim)."""
    src_root = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    offenders = []
    for dirpath, _, files in os.walk(src_root):
        for fname in files:
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, src_root)
            if rel == os.path.join("core", "store.py"):
                continue
            if rel == os.path.join("analysis", "ast_rules.py"):
                # the static checker's A001 rule polices exactly this
                # access path, so it necessarily names the attribute
                continue
            text = open(path).read()
            # `_synopses` as its own identifier (not load_/save_synopses),
            # or direct indexing of a `.synopses` mapping.
            if re.search(r"(?<![A-Za-z0-9])_synopses\b", text) \
                    or re.search(r"\.synopses\[", text):
                offenders.append(rel)
    assert offenders == []
