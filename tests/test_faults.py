"""Chaos suite: deterministic fault injection across every serving seam.

Acceptance contract (the degraded-mode half of the paper's Theorem 1 story):
under an armed fault plan EVERY query resolves — bitwise-equal to the
no-fault oracle when the fault misses it, raw + ``degraded`` when it hits,
typed ``FailedAnswer`` when it keeps failing — with no hung tickets and no
store-wide drain poison; after ``heal()`` the learned state is bitwise-equal
to a never-failed run. The whole suite runs under the CI device matrix
(``REPRO_FORCE_HOST_DEVICES`` ∈ {1, 8}); the sharded legs skip gracefully on
a single-device topology.
"""
import warnings

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

import repro.verdict as vd
from repro.aqp import workload as W
from repro.core.engine import EngineConfig
from repro.core.store import agg_key, state_key
from repro.core.types import AVG
from repro.ft import faults
from repro.ft.checkpoint import CheckpointCorruptError, CheckpointManager
from repro.serving.aqp import AqpService
from repro.verdict.answer import FailedAnswer


@pytest.fixture(scope="module")
def relation():
    return W.make_relation(seed=0, n_rows=4_000, n_num=2, cat_sizes=(4,),
                           n_measures=1, lengthscale=0.4, noise=0.2)


def _cfg(**kw):
    base = dict(sample_rate=0.2, n_batches=4, capacity=128, seed=0)
    base.update(kw)
    return EngineConfig(**base)


def _queries(session):
    b = vd.between
    return [
        (session.query().avg("v0").where(b("x0", 2.0, 8.0))
         .group_by("c0").build()),
        session.query().count().where(b("x0", 1.0, 6.0)).build(),
        session.query().sum("v0").where(b("x1", 0.0, 7.0)).build(),
        session.query().avg("v0").where(b("x1", 3.0, 9.0)).build(),
    ]


def _cells(ans):
    return [c.to_dict() for c in ans.cells]


AVG_KEY = state_key(agg_key(AVG, 0))


# ------------------------------------------------------------------ registry
def test_registry_determinism_and_zero_cost():
    # Disabled: one global load + None check; no counters, no stats.
    assert not faults.active()
    assert faults.stats() == {}
    faults.fire("scan.eval")  # no-op, must not raise
    with pytest.raises(ValueError, match="unknown injection point"):
        faults.FaultSpec("not.a.point")

    # hits schedule: per-(point, key) counters, key filter honored.
    with faults.inject(faults.FaultSpec("ingest.apply", key="a",
                                        hits=(1, 3))) as plan:
        fired = []
        for i in range(5):
            try:
                faults.fire("ingest.apply", key="a")
            except faults.InjectedFault as e:
                fired.append((i, e.point, e.key, e.hit))
            faults.fire("ingest.apply", key="b")  # never fires: key filter
        assert fired == [(1, "ingest.apply", "a", 1),
                         (3, "ingest.apply", "a", 3)]
        assert faults.stats() == {"ingest.apply": {"calls": 10, "fires": 2}}
        assert plan.calls == {"ingest.apply": 10}
    assert not faults.active()
    assert faults.stats() == {}

    # Seeded Bernoulli stream: same seed → same fire pattern; max_fires caps.
    def pattern(seed, max_fires=None):
        out = []
        spec = faults.FaultSpec("scan.eval", rate=0.5, max_fires=max_fires)
        with faults.inject(spec, seed=seed):
            for i in range(40):
                try:
                    faults.fire("scan.eval")
                except faults.InjectedFault:
                    out.append(i)
        return out

    assert pattern(7) == pattern(7)
    assert pattern(7) != pattern(8)
    assert len(pattern(7, max_fires=3)) == 3
    assert pattern(7, max_fires=3) == pattern(7)[:3]


# ------------------------------------------------- service-level isolation
def test_transient_scan_fault_absorbed_bitwise(relation):
    """A transient scan fault (fires once) is absorbed by bisect/retry: every
    ticket resolves to a REAL answer, bitwise-equal to a no-fault oracle."""
    oracle = vd.connect(relation, _cfg())
    chaos = vd.connect(relation, _cfg())
    qs = _queries(oracle)
    oracle_svc = oracle.serve(budget=vd.ErrorBudget(max_batches=3))
    want = [oracle_svc.submit(q) for q in qs]
    oracle_svc.flush()
    svc = chaos.serve(budget=vd.ErrorBudget(max_batches=3))
    tickets = [svc.submit(q) for q in qs]
    with faults.inject(faults.FaultSpec("scan.eval", hits=(0,))) as plan:
        svc.flush()
        assert plan.fires.get("scan.eval") == 1
    for t, w in zip(tickets, want):
        assert t._done and not t.result().failed
        assert _cells(t.result()) == _cells(w.result())


def test_persistent_scan_fault_typed_failure_no_hung_tickets(relation):
    """A persistent scan fault cannot hang the microbatch: every ticket
    resolves to a typed FailedAnswer after bounded retries."""
    session = vd.connect(relation, _cfg())
    svc = AqpService(session.engine, max_batch=64, max_batches=3,
                     max_retries=1, backoff_base_s=0.001)
    tickets = [svc.submit(q) for q in _queries(session)]
    with faults.inject(faults.FaultSpec("scan.eval", rate=1.0)):
        out = svc.flush()
    assert len(out) == len(tickets)
    for t in tickets:
        assert t._done
        ans = t.result()
        assert isinstance(ans, FailedAnswer) and ans.failed
        assert ans.error_type == "InjectedFault"
        # attempts counts ACTUAL executions of this query (not a retry-loop
        # bound): full batch of 4 (1 + max_retries backoff retry), its
        # bisected half of 2, then the single (1 + max_retries).
        assert ans.attempts == 5
    # The service stays usable after the chaos clears.
    ok = svc.submit(_queries(session)[1])
    svc.flush()
    assert not isinstance(ok.result(), FailedAnswer)  # raw QueryResult again


# --------------------------------------------- quarantine → degrade → heal
def test_ingest_fault_quarantines_degrades_and_heals_bitwise(relation):
    """The tentpole end-to-end: a poisoned ingest apply quarantines ONE
    synopsis, queries keep resolving (raw floor, flagged degraded), health
    telemetry surfaces it everywhere, and heal() replays the parked batches
    back to a store bitwise-identical to a never-failed oracle session."""
    oracle = vd.connect(relation, _cfg())
    chaos = vd.connect(relation, _cfg())
    qs = _queries(oracle)
    want = oracle.execute_many(qs)
    # Quiesce the oracle's async ingest BEFORE arming the plan: its pending
    # applies share the fault key (same state_key) and would otherwise race
    # the chaos session for the scheduled hit.
    oracle.drain()
    with faults.inject(faults.FaultSpec("ingest.apply", key=AVG_KEY,
                                        hits=(0,))):
        got = chaos.execute_many(qs)
        # Every query resolved; the AVG key is quarantined after its first
        # record, so the LATER avg query is degraded (raw floor) while
        # non-AVG queries stay bitwise-equal to the oracle.
        assert len(got) == len(qs)
        assert got[3].degraded and AVG_KEY in got[3].degraded_reasons
        assert got[2].degraded  # SUM improves through the AVG synopsis too
        assert not got[1].degraded  # COUNT rides the FREQ key: unaffected
        assert _cells(got[1]) == _cells(want[1])
        # Health is visible at every level.
        health = chaos.stats()["health"]
        assert AVG_KEY in health["quarantined"]
        assert health["faults"]["ingest.apply"]["fires"] == 1
        rep = chaos.explain(qs[0])
        assert AVG_KEY in rep.quarantined
        assert "QUARANTINED" in str(rep)
        # drain() is a plain barrier — the poison no longer raises here.
        chaos.drain()
    # Disarmed: telemetry goes quiet, quarantine persists until heal().
    assert chaos.stats()["health"]["faults"] == {}
    assert AVG_KEY in chaos.stats()["health"]["quarantined"]
    assert chaos.heal() == {AVG_KEY: True}
    assert chaos.stats()["health"]["quarantined"] == {}
    # Learned state is bitwise-identical to the never-failed session: the
    # parked batches replayed in their original FIFO order.
    got_sd = chaos.engine.store.state_dict()
    want_sd = oracle.engine.store.state_dict()
    assert sorted(got_sd) == sorted(want_sd)
    for name in want_sd:
        for k in want_sd[name]:
            if k == "ingest_high_water":  # telemetry, not model state
                continue
            np.testing.assert_array_equal(got_sd[name][k], want_sd[name][k],
                                          err_msg=f"{name}/{k}")
    # And serving is bitwise-equal from here on.
    got2 = chaos.execute_many(qs)
    want2 = oracle.execute_many(qs)
    for g, w in zip(got2, want2):
        assert not g.degraded
        assert _cells(g) == _cells(w)


def test_drain_fault_blast_radius_is_one_synopsis(relation):
    """A failed ingest barrier quarantines the ONE synopsis it struck —
    drain() never raises and the rest of the store keeps serving."""
    session = vd.connect(relation, _cfg())
    qs = _queries(session)
    session.execute_many(qs)
    assert len(session.store) >= 2
    with faults.inject(faults.FaultSpec("store.drain", key=AVG_KEY,
                                        hits=(0,))):
        session.drain()  # never raises
    quarantined = session.stats()["health"]["quarantined"]
    assert list(quarantined) == [AVG_KEY]
    assert session.heal() == {AVG_KEY: True}
    assert session.stats()["health"]["quarantined"] == {}


def test_heal_restores_from_last_good_checkpoint(tmp_path, relation):
    """Session.heal(manager) heals from the newest committed checkpoint and
    replays parked batches — model state matches a never-failed twin."""
    chaos = vd.connect(relation, _cfg())
    twin = vd.connect(relation, _cfg())
    qs = _queries(chaos)
    chaos.execute_many(qs)
    twin.execute_many(qs)
    twin.drain()  # its async applies must not race the armed plan below
    mgr = CheckpointManager(str(tmp_path / "ckpt"))
    chaos.save(mgr, step=0)
    with faults.inject(faults.FaultSpec("ingest.apply", key=AVG_KEY,
                                        hits=(0,))):
        chaos.execute_many(qs)
        chaos.drain()  # quarantine lands while the plan is armed
    twin.execute_many(qs)
    assert AVG_KEY in chaos.stats()["health"]["quarantined"]
    assert chaos.heal(mgr) == {AVG_KEY: True}
    got_sd = chaos.engine.store.state_dict()
    want_sd = twin.engine.store.state_dict()
    for name in want_sd:
        for k in want_sd[name]:
            if k == "ingest_high_water":
                continue
            np.testing.assert_array_equal(got_sd[name][k], want_sd[name][k],
                                          err_msg=f"{name}/{k}")
    # heal(manager) with no committed checkpoint degrades to rebuild —
    # warn, not fail.
    with faults.inject(faults.FaultSpec("ingest.apply", key=AVG_KEY,
                                        hits=(0,))):
        chaos.execute_many(qs)
    empty_mgr = CheckpointManager(str(tmp_path / "nothing"))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        healed = chaos.heal(empty_mgr)
    assert healed == {AVG_KEY: True}
    assert any("restore unavailable" in str(w.message) for w in caught)


# ------------------------------------------------------------------ deadline
def test_deadline_returns_best_so_far_degraded(relation):
    session = vd.connect(relation, _cfg())
    q = _queries(session)[0]
    ans = session.execute(q, vd.ErrorBudget(deadline_s=0.0))
    assert ans.final
    assert ans.batches_used == 1  # at least one round always runs
    assert ans.degraded and "deadline" in ans.degraded_reasons
    assert len(ans.cells) > 0  # best-so-far answer, honest wider CI
    # A generous deadline changes nothing, bitwise.
    s2 = vd.connect(relation, _cfg())
    s3 = vd.connect(relation, _cfg())
    slow = s2.execute(q, vd.ErrorBudget(deadline_s=3600.0))
    free = s3.execute(q)
    assert not slow.degraded
    assert _cells(slow) == _cells(free)


def test_deadline_in_stream_and_serve(relation):
    session = vd.connect(relation, _cfg())
    q = _queries(session)[0]
    seen = list(session.stream(q, vd.ErrorBudget(deadline_s=0.0)))
    assert seen[-1].final and seen[-1].degraded
    assert "deadline" in seen[-1].degraded_reasons
    svc = vd.connect(relation, _cfg()).serve(
        budget=vd.ErrorBudget(deadline_s=0.0))
    t = svc.submit(q)
    svc.flush()
    ans = t.result()
    assert not ans.failed and ans.degraded
    assert "deadline" in ans.degraded_reasons


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_write_fault_is_invisible_torn_write(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "c"), keep=5)
    tree = {"a": np.arange(4.0), "b": np.ones((2, 2))}
    mgr.save(0, tree)
    assert mgr.all_steps() == [0]
    with faults.inject(faults.FaultSpec("checkpoint.write", hits=(0,))):
        with pytest.raises(faults.InjectedFault):
            mgr.save(1, tree)
    # Torn write: no COMMITTED marker, step invisible, older step intact.
    assert mgr.all_steps() == [0]
    restored, _ = mgr.restore_blind()
    np.testing.assert_array_equal(restored["a"], tree["a"])
    mgr.save(1, tree)  # the seam recovers once the fault clears
    assert mgr.all_steps() == [0, 1]


def test_async_save_failure_surfaces_on_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "c"), keep=5)
    tree = {"a": np.arange(3.0)}
    mgr.save(0, tree)
    with faults.inject(faults.FaultSpec("checkpoint.write", hits=(0,))):
        mgr.save_async(1, tree)
        with pytest.raises(RuntimeError, match="async checkpoint save"):
            mgr.wait()  # inside the with: the daemon thread must see the plan
    assert mgr.all_steps() == [0]
    mgr.save_async(1, tree)  # exception was consumed; the manager recovers
    mgr.wait()
    assert mgr.all_steps() == [0, 1]


def test_corrupt_checkpoint_falls_back_to_earlier_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "c"), keep=5)
    mgr.save(0, {"a": np.zeros(3)})
    mgr.save(1, {"a": np.ones(3)})
    # Bit-rot the newest shard: checksum verification must reject it and
    # restore must fall back to step 0 with a warning, not crash.
    shard = tmp_path / "c" / "step_0000000001" / "shard_0.npz"
    raw = bytearray(shard.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    shard.write_bytes(bytes(raw))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        restored, _ = mgr.restore_blind()
    np.testing.assert_array_equal(restored["a"], np.zeros(3))
    assert any("falling back" in str(w.message) for w in caught)
    # An injected read fault walks back the same way.
    mgr2 = CheckpointManager(str(tmp_path / "d"), keep=5)
    mgr2.save(0, {"a": np.zeros(3)})
    mgr2.save(1, {"a": np.ones(3)})
    with faults.inject(faults.FaultSpec("checkpoint.read", key="step_1", hits=(0,))):
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            restored, _ = mgr2.restore_blind()
    np.testing.assert_array_equal(restored["a"], np.zeros(3))
    assert any("falling back" in str(w.message) for w in caught)
    # No intact step left → the typed corruption error.
    with faults.inject(faults.FaultSpec("checkpoint.read", rate=1.0)):
        with pytest.raises(CheckpointCorruptError), warnings.catch_warnings():
            warnings.simplefilter("ignore")
            mgr2.restore_blind()


# ------------------------------------------------------------ sharded matrix
def test_sharded_store_quarantine_blast_radius(relation, forced_devices):
    """Sharded leg of the chaos matrix: the quarantine blast radius stays
    one synopsis (hence at most one shard); drain never raises across the
    shard barrier threads, and heal restores bitwise parity with a
    never-failed sharded twin."""
    n_dev = min(8, jax.device_count())
    if n_dev < 2:
        pytest.skip("needs a multi-device topology")
    mesh = Mesh(np.array(forced_devices(n_dev)), ("data",))
    chaos = vd.connect(relation, _cfg(), mesh=mesh)
    twin = vd.connect(relation, _cfg(), mesh=mesh)
    qs = _queries(chaos)
    with faults.inject(faults.FaultSpec("ingest.apply", key=AVG_KEY,
                                        hits=(0,))):
        got = chaos.execute_many(qs)
        assert len(got) == len(qs)
        assert got[3].degraded
        assert list(chaos.stats()["health"]["quarantined"]) == [AVG_KEY]
        chaos.drain()  # parallel per-shard barrier; never raises
    want = twin.execute_many(qs)
    assert _cells(got[1]) == _cells(want[1])  # fault missed → bitwise oracle
    assert chaos.heal() == {AVG_KEY: True}
    got_sd = chaos.engine.store.state_dict()
    want_sd = twin.engine.store.state_dict()
    assert sorted(got_sd) == sorted(want_sd)
    for name in want_sd:
        for k in want_sd[name]:
            if k == "ingest_high_water":
                continue
            np.testing.assert_array_equal(got_sd[name][k], want_sd[name][k],
                                          err_msg=f"{name}/{k}")
