"""Workload-intelligence suite: semantic answer cache, subsumption serving,
staleness/quarantine licensing, the learned serve-path router, and the
checkpoint/chaos legs.

The binding contract is the bitwise oracle:

- a cache MISS is bitwise-identical to the cache-disabled engine (the miss
  path runs the unchanged plan lifecycle);
- an exact HIT is bitwise-identical to the originally recorded final answer;
- a SUBSUMED answer is exactly reproducible from the recorded cached cells
  (filter + project, no recomputation);
- router-chosen paths never violate the caller's ErrorBudget ("scan" serves
  the most refined full-budget answer, bitwise-equal to the always-improve
  engine when neither meets the target).
"""
import numpy as np
import pytest

import repro.verdict as vd
from repro.aqp import queries as Q
from repro.aqp.plan import plan_workload
from repro.aqp import workload as W
from repro.core.engine import EngineConfig
from repro.core.store import agg_key, state_key
from repro.core.types import AVG
from repro.ft import faults
from repro.ft.checkpoint import CheckpointManager
from repro.intel import IntelConfig, QuerySignature, RouterConfig
from repro.kernels import RANGE_EPS


@pytest.fixture(scope="module")
def relation():
    return W.make_relation(seed=0, n_rows=3_000, n_num=2, cat_sizes=(4,),
                           n_measures=1, lengthscale=0.4, noise=0.2)


def _cfg(**kw):
    base = dict(sample_rate=0.2, n_batches=4, capacity=128, seed=0)
    base.update(kw)
    return EngineConfig(**base)


def _cells(ans):
    return [c.to_dict() for c in ans.cells]


AVG_KEY = state_key(agg_key(AVG, 0))
B = vd.ErrorBudget(target_rel_error=0.5)


def _q_grouped(s):
    return (s.query().avg("v0").where(vd.between("x0", 2.0, 8.0))
            .group_by("c0").build())


def _q_plain(s):
    return s.query().avg("v0").where(vd.between("x1", 1.0, 6.0)).build()


# ------------------------------------------------------------- default off


def test_cache_off_by_default(relation):
    s = vd.connect(relation, _cfg())
    assert s.intel is None and s.engine.intel is None
    ans = s.execute(_q_grouped(s), B)
    assert ans.served_from is None
    assert s.stats()["intel"] == {"enabled": False}
    rep = s.explain(_q_grouped(s))
    assert rep.cache is None and rep.route is None
    assert "served from cache" not in str(rep)


# -------------------------------------------------- exact hits, miss parity


def test_exact_hit_bitwise_and_miss_parity(relation):
    s = vd.connect(relation, _cfg(), cache=True)
    twin = vd.connect(relation, _cfg())  # cache-disabled oracle
    q1, q2 = _q_grouped(s), _q_plain(s)
    first = s.execute_many([q1, q2], B)
    want = twin.execute_many([q1, q2], B)
    # Miss path: bitwise-identical to the cache-disabled engine.
    for g, w in zip(first, want):
        assert g.served_from is None
        assert _cells(g) == _cells(w)
    # Repeat: exact hits, bitwise-identical to the recorded answers, and
    # the hit query drops out of the fused batch (no new scan work).
    q3 = s.query().count().where(vd.between("x0", 1.0, 9.0)).build()
    again = s.execute_many([q1, q3, q2], B)
    assert again[0].served_from == "cache:exact"
    assert again[2].served_from == "cache:exact"
    assert again[1].served_from is None  # the new query executed
    assert _cells(again[0]) == _cells(first[0])
    assert _cells(again[2]) == _cells(first[1])
    st = s.stats()["intel"]
    assert st["enabled"] and st["hits_exact"] == 2
    assert st["entries"] == 3 and st["insertions"] == 3
    assert st["routes"]["cache"] == 2


def test_full_accuracy_exact_hit_requires_full_budget(relation):
    s = vd.connect(relation, _cfg(), cache=True)
    q = _q_plain(s)
    first = s.execute(q)  # no target: full budget, route "scan"
    again = s.execute(q)
    assert again.served_from == "cache:exact"
    assert _cells(again) == _cells(first)
    # A tighter batch budget is a different answer — never served from an
    # entry recorded under the full budget.
    capped = s.execute(q, vd.ErrorBudget(max_batches=2))
    assert capped.served_from is None
    assert capped.batches_used == 2


def test_uncacheable_query_counted_and_served_raw(relation):
    s = vd.connect(relation, _cfg(), cache=True)
    bad = Q.AggQuery(aggs=(Q.AggSpec("AVG", 0),),
                     predicates=(Q.TextLike("x%"),))
    a1 = s.execute(bad)
    a2 = s.execute(bad)
    assert not a1.supported and not a2.supported
    assert a2.served_from is None
    assert s.stats()["intel"]["uncacheable"] == 2


# ------------------------------------------------------ staleness licensing


def test_ingest_invalidates_full_accuracy_then_refreshes(relation):
    s = vd.connect(relation, _cfg(), cache=True)
    q_a, q_b = _q_grouped(s), _q_plain(s)
    s.execute(q_a)  # cached, full accuracy
    assert s.execute(q_a).served_from == "cache:exact"
    # q_b records through the same AVG synopsis: generation bumps at
    # enqueue, so q_a's entry is stale the moment the answer lands.
    s.execute(q_b)
    refreshed = s.execute(q_a)
    assert refreshed.served_from is None  # stale → refused → re-executed
    assert s.stats()["intel"]["stale_refused"] >= 1
    # The re-execution re-recorded a fresh entry: hits resume.
    assert s.execute(q_a).served_from == "cache:exact"


def test_stale_entry_serves_within_error_budget(relation):
    s = vd.connect(relation, _cfg(), cache=True)
    q_a, q_b = _q_grouped(s), _q_plain(s)
    first = s.execute(q_a, B)
    s.execute(q_b, B)  # staleness-bump q_a's aggregate key
    served = s.execute(q_a, B)
    # The recorded CI still meets the caller's budget: bounded staleness
    # is licensed by the error budget, and the answer is exactly the
    # recorded one.
    assert served.served_from == "cache:exact"
    assert _cells(served) == _cells(first)
    assert served.max_rel_error(0.95) <= B.target_rel_error
    assert s.stats()["intel"]["stale_served"] >= 1


# ------------------------------------------------------------- subsumption


def test_subsumption_group_pin_and_subset(relation):
    s = vd.connect(relation, _cfg(), cache=True)
    full = s.execute(_q_grouped(s), B)  # GROUP BY c0, all groups
    # Pin one group: served from the cached cells, bitwise.
    pin = (s.query().avg("v0").where(vd.between("x0", 2.0, 8.0))
           .where(vd.equals("c0", 1)).group_by("c0").build())
    got = s.execute(pin, B)
    assert got.served_from == "cache:subsumed"
    assert _cells(got) == [c for c in _cells(full) if c["group"] == (1,)]
    # Subset of groups: the cached cells filtered, original order kept.
    sub = (s.query().avg("v0").where(vd.between("x0", 2.0, 8.0))
           .where(vd.one_of("c0", [3, 0])).group_by("c0").build())
    got2 = s.execute(sub, B)
    assert got2.served_from == "cache:subsumed"
    assert _cells(got2) == [c for c in _cells(full)
                            if c["group"][0] in (0, 3)]
    # A dropped grouped dim must be pinned: an ungrouped spelling over the
    # full member set aggregates ACROSS groups — never servable from
    # per-group AVG cells.
    merged = (s.query().avg("v0")
              .where(vd.between("x0", 2.0, 8.0)).build())
    got3 = s.execute(merged, B)
    assert got3.served_from is None
    assert s.stats()["intel"]["hits_subsumed"] == 2


def test_subsumption_range_eps_boundary(relation):
    s = vd.connect(relation, _cfg(), cache=True)
    aggs = (Q.AggSpec("AVG", 0),)
    base = Q.AggQuery(aggs=aggs, predicates=(Q.NumRange(0, 2.0, 8.0),),
                      groupby=(0,))
    first = s.execute(base, B)
    # Bounds within RANGE_EPS select the same tuples by construction of
    # predicate_mask: servable, and exactly the recorded cells.
    near = Q.AggQuery(aggs=aggs,
                      predicates=(Q.NumRange(0, 2.0 + RANGE_EPS / 2,
                                             8.0 - RANGE_EPS / 2),),
                      groupby=(0,))
    got = s.execute(near, B)
    assert got.served_from == "cache:subsumed"
    assert _cells(got) == _cells(first)
    # Past the epsilon the boxes differ semantically: a miss, executed.
    far = Q.AggQuery(aggs=aggs,
                     predicates=(Q.NumRange(0, 2.0 + 1e-6, 8.0),),
                     groupby=(0,))
    assert s.execute(far, B).served_from is None


def test_truncated_entry_never_subsumes(relation):
    # n_max=2 truncates the 4-value group-by: the cached cells are an
    # incomplete group set, unusable for subsumption (a pinned group may be
    # one of the dropped ones) — but an exact repeat still serves, with the
    # truncation surfaced.
    s = vd.connect(relation, _cfg(n_max=2), cache=True)
    q = _q_grouped(s)
    first = s.execute(q, B)
    assert first.truncated_groups > 0
    again = s.execute(q, B)
    assert again.served_from == "cache:exact"
    assert again.truncated_groups == first.truncated_groups
    pin = (s.query().avg("v0").where(vd.between("x0", 2.0, 8.0))
           .where(vd.equals("c0", 1)).group_by("c0").build())
    assert s.execute(pin, B).served_from is None


# -------------------------------------------- canonical keys (satellite 1)


def test_signature_canonicalization_matrix(relation):
    """Commutative/duplicated/reordered spellings of one query hash to one
    cache key AND intern to the same snippet rows (the NumEq-overwrite fix:
    canonical predicate boxes are order-independent)."""
    s = vd.connect(relation, _cfg())
    schema = s.schema
    aggs = (Q.AggSpec("AVG", 0),)
    spellings = [
        Q.AggQuery(aggs, (Q.NumRange(0, 2.0, 8.0), Q.CatIn(0, (1, 3, 2)))),
        Q.AggQuery(aggs, (Q.CatIn(0, (3, 2, 1)), Q.NumRange(0, 2.0, 8.0))),
        Q.AggQuery(aggs, (Q.NumRange(0, 2.0, 8.0), Q.NumRange(0, 2.0, 8.0),
                          Q.CatIn(0, (2, 1, 3, 1)))),
        Q.AggQuery(aggs, (Q.NumRange(0, 0.0, 8.0), Q.NumRange(0, 2.0, 10.0),
                          Q.CatIn(0, (1, 2, 3)))),
    ]
    digests = {QuerySignature.from_query(schema, q).digest()
               for q in spellings}
    assert len(digests) == 1
    wp = plan_workload(s.engine, spellings)
    for lp in wp.logical[1:]:
        np.testing.assert_array_equal(lp.rows, wp.logical[0].rows)
    # Full cross-query dedup: the fused set is one query's snippets.
    assert wp.stats.n_snippets_fused == wp.logical[0].plan.snippets.n
    # NumEq ∧ NumRange commutes (the pre-fix overwrite ordered it).
    eq_then_range = Q.AggQuery(
        aggs, (Q.NumEq(0, 5.0), Q.NumRange(0, 2.0, 8.0)))
    range_then_eq = Q.AggQuery(
        aggs, (Q.NumRange(0, 2.0, 8.0), Q.NumEq(0, 5.0)))
    assert (QuerySignature.from_query(schema, eq_then_range).digest()
            == QuerySignature.from_query(schema, range_then_eq).digest())
    boxes = [Q.predicates_to_arrays(schema, q.predicates)[0][0]
             for q in (eq_then_range, range_then_eq)]
    assert boxes[0] == boxes[1] == (5.0, 5.0)
    # Distinct semantics stay distinct.
    other = Q.AggQuery(aggs, (Q.NumRange(0, 2.0, 8.0),))
    assert QuerySignature.from_query(schema, other).digest() not in digests


# ------------------------------------------- quarantine / heal (satellite 2)


def test_quarantine_refuses_and_cache_survives_heal_bitwise(relation):
    s = vd.connect(relation, _cfg(), cache=True)
    q_cached, q_poison = _q_grouped(s), _q_plain(s)
    s.execute(q_cached)
    assert s.execute(q_cached).served_from == "cache:exact"
    s.drain()  # quiesce: pending applies must not race the armed plan
    key = QuerySignature.from_query(s.schema, q_cached).digest()

    def entry_of(key):
        return next(e for e in s.intel.cache.state_dict(s.store)["entries"]
                    if e["key"] == key)

    before = entry_of(key)
    with faults.inject(faults.FaultSpec("ingest.apply", key=AVG_KEY,
                                        hits=(0,))):
        s.execute(q_poison)  # its record trips the poisoned async apply
        s.drain()  # barrier: the quarantine lands
        assert AVG_KEY in s.stats()["health"]["quarantined"]
        # A degraded key NEVER serves a pre-quarantine cached answer.
        during = s.execute(q_cached)
        assert during.served_from is None and during.degraded
        assert s.stats()["intel"]["quarantine_refused"] >= 1
    assert s.heal() == {AVG_KEY: True}
    # The entry itself survived the whole episode bitwise: degraded
    # answers are never inserted, refused lookups never mutate entries.
    assert entry_of(key) == before
    # Healed ≠ the state the entries saw: full-accuracy lookups refuse
    # (stale) and re-record; then hits resume against the healed store.
    refreshed = s.execute(q_cached)
    assert refreshed.served_from is None and not refreshed.degraded
    assert s.execute(q_cached).served_from == "cache:exact"


# ---------------------------------------------------- checkpoint round-trip


def test_cache_checkpoint_roundtrip(tmp_path, relation):
    s = vd.connect(relation, _cfg(), cache=True)
    q = _q_grouped(s)
    first = s.execute(q)
    s.drain()
    mgr = CheckpointManager(str(tmp_path / "ckpt"))
    s.save(mgr, step=1)
    # A fresh process: same relation, restored synopses + intel plane.
    s2 = vd.connect(relation, _cfg(), cache=True)
    s2.load(mgr, step=1)
    assert s2.stats()["intel"]["entries"] == 1
    got = s2.execute(q)
    assert got.served_from == "cache:exact"
    assert _cells(got) == _cells(first)
    # And a cache-less session restores the same payload untouched — the
    # reserved "intel" key never leaks into synopsis restore.
    s3 = vd.connect(relation, _cfg())
    s3.load(mgr, step=1)
    got_sd, want_sd = s3.engine.store.state_dict(), s.engine.store.state_dict()
    assert sorted(got_sd) == sorted(want_sd)
    for name in want_sd:
        for k in want_sd[name]:
            np.testing.assert_array_equal(got_sd[name][k], want_sd[name][k],
                                          err_msg=f"{name}/{k}")
    ans = s3.execute(q)
    assert ans.served_from is None and not ans.degraded


# ----------------------------------------------------------------- router


def test_router_learns_scan_route_bitwise(relation):
    tight = vd.ErrorBudget(target_rel_error=1e-9)  # never met: full budget
    cfg = IntelConfig(router=RouterConfig(probe_every=4, learn_ladder=False))
    s = vd.connect(relation, _cfg(), cache=cfg)
    twin = vd.connect(
        relation, _cfg(),
        cache=IntelConfig(router=RouterConfig(route_switching=False,
                                              learn_ladder=False)))
    los = [1.0 + 0.25 * i for i in range(8)]  # distinct: no cache hits
    for lo in los[:2]:
        # Cold start + optimistic E[batches]: the first queries route
        # "improve" — exactly the pre-intel engine.
        q = s.query().avg("v0").where(vd.between("x0", lo, 9.5)).build()
        s.execute(q, tight)
        twin.execute(q, tight)
    assert s.stats()["intel"]["routes"]["scan"] == 0
    for lo in los[2:]:
        # E[batches] has learned ≈ max_batches: improving every round buys
        # nothing, the router flips to "scan" — and the answer stays
        # bitwise-equal to the always-improve engine (both exhaust the
        # budget; the full-budget answer is the most refined one).
        q = s.query().avg("v0").where(vd.between("x0", lo, 9.5)).build()
        a, w = s.execute(q, tight), twin.execute(q, tight)
        assert a.batches_used == w.batches_used == 4
        assert _cells(a) == _cells(w)
    routes = s.stats()["intel"]["routes"]
    assert routes["scan"] > 0
    # The deterministic probe re-measures the improve path periodically.
    assert routes["improve"] > 2
    fb = max(s.stats()["intel"]["router"]["expected_batches"])
    assert s.stats()["intel"]["router"]["expected_batches"][fb] == 4.0


def test_learned_ladder_floors_are_answer_invariant(relation):
    cfg = IntelConfig(router=RouterConfig(ladder_every=3))
    s = vd.connect(relation, _cfg(), cache=cfg)
    plain = vd.connect(relation, _cfg())
    qs = [s.query().avg("v0").where(vd.between("x0", 1.0 + 0.5 * i, 9.0))
          .group_by("c0").build() for i in range(4)]
    for q in qs:
        assert _cells(s.execute(q, B)) == _cells(plain.execute(q, B))
    floors = s.stats()["intel"]["router"]["learned_floors"]
    assert floors is not None
    assert s.config.min_q_bucket == floors[0]
    # The ladder moved the serve tiles, not the answers: a fresh query is
    # still bitwise-equal to the static-floor engine.
    fresh = s.query().sum("v0").where(vd.between("x1", 2.0, 7.0)).build()
    assert _cells(s.execute(fresh, B)) == _cells(plain.execute(fresh, B))


# --------------------------------------------------------- serving surface


def test_service_prescreen_skips_microbatch(relation):
    s = vd.connect(relation, _cfg(), cache=True)
    svc = s.serve(budget=B)
    q = _q_grouped(s)
    t1 = svc.submit(q)
    first = t1.result()  # flushes
    t2 = svc.submit(q)
    # Resolved at submit: never occupied a microbatch slot.
    assert t2._done and svc.pending == 0
    assert svc.prescreened == 1
    got = t2.result()
    assert got.served_from == "cache:exact"
    assert _cells(got) == _cells(first)
    st = svc.stats()
    assert st["prescreened"] == 1 and st["intel"]["enabled"]


def test_explain_reports_cache_status_and_is_readonly(relation):
    s = vd.connect(relation, _cfg(), cache=True)
    q = _q_grouped(s)
    rep = s.explain(q, budget=B)
    assert rep.cache == "miss" and rep.route in ("improve", "scan")
    s.execute(q, B)
    lookups = s.stats()["intel"]["lookups"]
    rep2 = s.explain(q, budget=B)
    assert rep2.cache == "exact" and rep2.route == "cache"
    assert "served from cache: exact → route=cache" in str(rep2)
    # Peeking never moves counters, LRU order, or probe streaks.
    assert s.stats()["intel"]["lookups"] == lookups
    pin = (s.query().avg("v0").where(vd.between("x0", 2.0, 8.0))
           .where(vd.equals("c0", 1)).group_by("c0").build())
    assert s.explain(pin, budget=B).cache == "subsumed"


def test_stream_short_circuits_on_hit(relation):
    s = vd.connect(relation, _cfg(), cache=True)
    q = _q_plain(s)
    first = s.execute(q, B)
    rounds = list(s.stream(q, B))
    assert len(rounds) == 1 and rounds[0].final
    assert rounds[0].served_from == "cache:exact"
    assert _cells(rounds[0]) == _cells(first)


def test_deadline_degraded_answer_never_cached_as_full_accuracy(relation):
    """Satellite gate: an answer that returned early on a deadline is honest
    but WEAKER — recording it into the answer cache would replay a degraded
    CI as if it were the full-budget answer. It must never be inserted, and
    the next full-budget call must execute (then cache normally)."""
    s = vd.connect(relation, _cfg(), cache=True)
    q = _q_grouped(s)
    ans = s.execute(q, vd.ErrorBudget(deadline_s=0.0))
    assert ans.degraded and "deadline" in ans.degraded_reasons
    st = s.stats()["intel"]
    assert st["insertions"] == 0 and st["entries"] == 0
    # Full-budget re-execute: a MISS (nothing degraded was cached) ...
    full = s.execute(q, B)
    assert full.served_from is None and not full.degraded
    # ... which now caches, and the repeat serves at full accuracy.
    hit = s.execute(q, B)
    assert hit.served_from == "cache:exact" and not hit.degraded
    assert _cells(hit) == _cells(full)


def test_per_tenant_intel_counters_and_roundtrip(relation):
    """The serving front's per-tenant hit-rate surface: one shared intel
    plane splits lookups/hits by the tenant label threaded through
    ``Session.attached`` sessions, and the split survives a state_dict
    round-trip."""
    from repro.verdict.session import Session

    s = vd.connect(relation, _cfg(), cache=True)
    alice = Session.attached(s, tenant="alice")
    bob = Session.attached(s, tenant="bob")
    q = _q_grouped(s)
    a1 = alice.execute(q, B)          # miss (cold), then cached
    b1 = bob.execute(q, B)            # exact hit from alice's entry
    assert b1.served_from == "cache:exact"
    assert _cells(b1) == _cells(a1)
    pt = s.stats()["intel"]["per_tenant"]
    assert pt["alice"] == {"lookups": 1, "hits": 0, "hit_rate": 0.0}
    assert pt["bob"] == {"lookups": 1, "hits": 1, "hit_rate": 1.0}
    # Unlabeled traffic stays out of the per-tenant split.
    s.execute(q, B)
    assert s.stats()["intel"]["per_tenant"] == pt
    # Persistence: the split rides the same blob the cache/router use.
    state = s.intel.state_dict(s.store)
    fresh = vd.connect(relation, _cfg(), cache=True)
    fresh.intel.load_state_dict(state, fresh.store)
    assert fresh.intel.telemetry.per_tenant == {
        "alice": {"lookups": 1, "hits": 0},
        "bob": {"lookups": 1, "hits": 1}}
