"""Per-kernel allclose vs pure-jnp oracles, with shape/dtype sweeps
(interpret mode executes the kernel bodies on CPU)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import covariance as C
from repro.core.types import AVG, GPParams, Schema, make_snippets
from repro.kernels.se_covariance.ops import se_cov_matrix
from repro.kernels.se_covariance.ref import se_cov_matrix_ref
from repro.kernels.range_mask_agg.ops import eval_partials_kernel, range_mask_agg
from repro.kernels.range_mask_agg.ref import range_mask_agg_ref
from repro.kernels.gp_batch_infer.ops import gp_batch_infer
from repro.kernels.gp_batch_infer.ref import gp_batch_infer_ref


def _ranges(rng, n, l, dtype=np.float32):
    lo = rng.uniform(0, 0.6, (n, l)).astype(dtype)
    hi = (lo + rng.uniform(0.05, 0.4, (n, l))).astype(dtype)
    return lo, hi


# ------------------------------------------------------------- se_covariance
@pytest.mark.parametrize("ni,nj,l", [(8, 8, 1), (100, 30, 3), (128, 128, 2),
                                     (257, 64, 5), (1, 300, 4)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_se_covariance_matches_ref(ni, nj, l, dtype):
    rng = np.random.default_rng(ni * 1000 + nj + l)
    lo_i, hi_i = _ranges(rng, ni, l, dtype)
    lo_j, hi_j = _ranges(rng, nj, l, dtype)
    ls = rng.uniform(0.2, 1.2, (l,)).astype(dtype)
    norm_i = rng.uniform(0.5, 2.0, (ni,)).astype(dtype)
    norm_j = rng.uniform(0.5, 2.0, (nj,)).astype(dtype)
    sigma2 = 1.7
    got = se_cov_matrix(jnp.asarray(lo_i), jnp.asarray(hi_i), jnp.asarray(lo_j),
                        jnp.asarray(hi_j), jnp.asarray(ls), sigma2,
                        jnp.asarray(norm_i), jnp.asarray(norm_j),
                        tile_i=64, tile_j=64)
    want = se_cov_matrix_ref(
        jnp.asarray(lo_i, jnp.float64), jnp.asarray(hi_i, jnp.float64),
        jnp.asarray(lo_j, jnp.float64), jnp.asarray(hi_j, jnp.float64),
        jnp.asarray(ls, jnp.float64), sigma2,
        jnp.asarray(norm_i, jnp.float64), jnp.asarray(norm_j, jnp.float64))
    rtol = 2e-5 if dtype == np.float32 else 1e-10
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol,
                               atol=1e-7)


def test_se_covariance_matches_core_cov_matrix():
    """Kernel path == repro.core.covariance.cov_matrix (AVG normalization)."""
    sch = Schema(num_lo=(0.0, 0.0), num_hi=(1.0, 1.0), cat_sizes=(), n_measures=1)
    p = GPParams(log_ls=jnp.log(jnp.asarray([0.4, 0.8])),
                 log_sigma2=jnp.log(1.3), mu=jnp.asarray(0.0))
    rng = np.random.default_rng(0)
    ranges = [{0: (a, a + w), 1: (b, b + v)} for a, w, b, v in
              rng.uniform(0.05, 0.4, (20, 4))]
    b = make_snippets(sch, agg=AVG, measure=0, num_ranges=ranges)
    want = np.asarray(C.cov_matrix(b, b, p))
    lo, hi, w = C.widened(b.lo, b.hi)
    norm = jnp.prod(w, axis=-1)
    got = se_cov_matrix(lo, hi, lo, hi, p.ls, float(p.sigma2), norm, norm,
                        tile_i=32, tile_j=32)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5)


# ------------------------------------------------------------ range_mask_agg
@pytest.mark.parametrize("t,q,l,m", [(64, 16, 2, 1), (1000, 37, 3, 2),
                                     (4096, 128, 1, 1), (513, 200, 4, 3)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_range_mask_agg_matches_ref(t, q, l, m, dtype):
    rng = np.random.default_rng(t + q)
    x = rng.uniform(0, 1, (t, l)).astype(dtype)
    payload = rng.normal(0, 1, (t, 2 * m + 1)).astype(dtype)
    lo, hi = _ranges(rng, q, l, dtype)
    em = (rng.uniform(0, 1, (t, q)) > 0.3).astype(dtype)
    got = range_mask_agg(jnp.asarray(x), jnp.asarray(payload), jnp.asarray(lo),
                         jnp.asarray(hi), jnp.asarray(em),
                         tile_t=256, tile_q=64)
    want = range_mask_agg_ref(jnp.asarray(x, jnp.float64),
                              jnp.asarray(payload, jnp.float64),
                              jnp.asarray(lo, jnp.float64),
                              jnp.asarray(hi, jnp.float64),
                              jnp.asarray(em, jnp.float64))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_eval_partials_kernel_matches_executor():
    """Kernel Partials == pure-jnp executor Partials on a real workload."""
    from repro.aqp import workload as W
    from repro.aqp.executor import eval_partials
    from repro.aqp.queries import decompose

    rel = W.make_relation(seed=3, n_rows=5000, n_num=2, cat_sizes=(5,),
                          n_measures=2)
    qs = W.make_workload(4, rel.schema, 8)
    plans = [decompose(rel.schema, q) for q in qs]
    from repro.core.types import SnippetBatch

    snips = SnippetBatch.concat([p.snippets for p in plans])
    want = eval_partials(rel.num_normalized, rel.cat, rel.measures, snips)
    got = eval_partials_kernel(rel.num_normalized, rel.cat, rel.measures, snips)
    np.testing.assert_allclose(np.asarray(got.count), np.asarray(want.count))
    np.testing.assert_allclose(np.asarray(got.sums), np.asarray(want.sums),
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(got.sumsq), np.asarray(want.sumsq),
                               rtol=2e-4)


@pytest.mark.parametrize("cat_sizes", [(), (5,)])
@pytest.mark.parametrize("n_rows", [1000, 1237])  # incl. non-multiple of tile_t
def test_eval_partials_kernel_on_deduped_fused_batches(cat_sizes, n_rows):
    """Kernel vs pure-jnp parity on the fused path's actual input: randomized
    cross-query DEDUPED snippet batches, zero-categorical-columns case, and
    snippet/tuple counts that are not multiples of the kernel tiles."""
    from repro.aqp import workload as W
    from repro.aqp.plan import SnippetInterner
    from repro.aqp.executor import eval_partials
    from repro.aqp.queries import decompose
    from repro.core.types import pad_snippets

    rel = W.make_relation(seed=11, n_rows=n_rows, n_num=3, cat_sizes=cat_sizes,
                          n_measures=2)
    qs = W.make_workload(12, rel.schema, 20,
                         cat_pred_prob=0.4 if cat_sizes else 0.0)
    qs = qs + qs[:7]  # repeats: dedup has work to do
    dedup = SnippetInterner(rel.schema)
    for q in qs:
        dedup.intern(decompose(rel.schema, q).snippets)
    assert dedup.n < sum(decompose(rel.schema, q).snippets.n for q in qs)
    for snips in (dedup.fused(), pad_snippets(dedup.fused())):
        want = eval_partials(rel.num_normalized, rel.cat, rel.measures, snips)
        got = eval_partials_kernel(rel.num_normalized, rel.cat, rel.measures,
                                   snips)
        np.testing.assert_allclose(np.asarray(got.count),
                                   np.asarray(want.count))
        np.testing.assert_allclose(np.asarray(got.sums),
                                   np.asarray(want.sums), rtol=2e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(got.sumsq),
                                   np.asarray(want.sumsq), rtol=2e-4, atol=1e-3)


# ------------------------------------------------------------ gp_batch_infer
@pytest.mark.parametrize("q,c", [(1, 16), (64, 128), (100, 300), (256, 1000)])
def test_gp_batch_infer_matches_ref(q, c):
    rng = np.random.default_rng(q + c)
    a = rng.normal(size=(c, c)).astype(np.float32)
    sinv = (a @ a.T / c + np.eye(c)).astype(np.float32)
    k = rng.normal(0, 0.1, (q, c)).astype(np.float32)
    alpha = rng.normal(0, 1, (c,)).astype(np.float32)
    kappa2 = (np.abs(k @ sinv @ k.T).diagonal() + rng.uniform(0.05, 0.5, q)).astype(np.float32)
    mu = rng.normal(0, 1, (q,)).astype(np.float32)
    rawt = rng.normal(0, 1, (q,)).astype(np.float32)
    rawb = rng.uniform(0.0, 0.3, (q,)).astype(np.float32)
    rawb[0] = 0.0  # exercise the exact-answer passthrough
    got = gp_batch_infer(*map(jnp.asarray, (k, sinv, alpha, kappa2, mu, rawt, rawb)),
                         tile_q=64, tile_c=128)
    want = gp_batch_infer_ref(*map(lambda v: jnp.asarray(v, jnp.float64),
                                   (k, sinv, alpha, kappa2, mu, rawt, rawb)))
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=5e-3,
                                   atol=5e-5)


def test_gp_batch_infer_theorem1():
    rng = np.random.default_rng(9)
    c, q = 64, 32
    a = rng.normal(size=(c, c)).astype(np.float32)
    sinv = (a @ a.T / c + np.eye(c)).astype(np.float32)
    k = rng.normal(0, 0.05, (q, c)).astype(np.float32)
    kappa2 = np.abs(k @ sinv @ k.T).diagonal() + 0.3
    rawb = rng.uniform(0.01, 0.3, (q,)).astype(np.float32)
    _, beta2, _ = gp_batch_infer(
        jnp.asarray(k), jnp.asarray(sinv), jnp.zeros((c,), jnp.float32),
        jnp.asarray(kappa2, jnp.float32), jnp.zeros((q,), jnp.float32),
        jnp.zeros((q,), jnp.float32), jnp.asarray(rawb))
    assert np.all(np.asarray(beta2) <= rawb + 1e-7)


def test_engine_with_kernel_scan_path():
    """VerdictEngine(use_kernels=True) reproduces the jnp engine's answers.

    The scan leg is bitwise (tests/test_fused_scan.py); the residual 1e-3
    tolerance here is the improve path's f32 gp_batch_infer kernel.
    """
    from repro.aqp import workload as W
    from repro.core.engine import EngineConfig, VerdictEngine

    rel = W.make_relation(seed=5, n_rows=8000, n_num=2, cat_sizes=(4,), n_measures=1)
    qs = W.make_workload(6, rel.schema, 4, agg_kinds=("AVG", "COUNT"))
    r_jnp = VerdictEngine(rel, EngineConfig(sample_rate=0.2, n_batches=3, seed=1))
    r_ker = VerdictEngine(rel, EngineConfig(sample_rate=0.2, n_batches=3, seed=1,
                                            use_kernels=True))
    for q in qs:
        a = r_jnp.execute(q, max_batches=3)
        b = r_ker.execute(q, max_batches=3)
        for ca, cb in zip(a.cells, b.cells):
            assert abs(ca["estimate"] - cb["estimate"]) <= 1e-3 * max(1.0, abs(ca["estimate"]))
