"""Covariance math: closed form vs quadrature, limits, structure properties."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import covariance as C
from repro.core.types import AVG, FREQ, GPParams, Schema, make_snippets
import proptest as pt


def quad_double_integral(a, b, c, d, z, n=400):
    xs = np.linspace(a, b, n)
    ys = np.linspace(c, d, n)
    dx = (b - a) / (n - 1)
    dy = (d - c) / (n - 1)
    xx, yy = np.meshgrid(xs, ys, indexing="ij")
    f = np.exp(-((xx - yy) ** 2) / z**2)
    # trapezoid weights
    wx = np.ones(n); wx[0] = wx[-1] = 0.5
    wy = np.ones(n); wy[0] = wy[-1] = 0.5
    return float((f * wx[:, None] * wy[None, :]).sum() * dx * dy)


@pt.given(n_cases=20, a=pt.floats(0, 0.5), w1=pt.floats(0.01, 0.5),
          c=pt.floats(0, 0.5), w2=pt.floats(0.01, 0.5), z=pt.floats(0.05, 2.0))
def test_double_integral_matches_quadrature(a, w1, c, w2, z):
    got = float(C.se_double_integral(a, a + w1, c, c + w2, z))
    want = quad_double_integral(a, a + w1, c, c + w2, z)
    assert got == pytest.approx(want, rel=2e-3, abs=1e-9)


def test_double_integral_symmetry_and_positivity():
    g1 = float(C.se_double_integral(0.1, 0.4, 0.6, 0.9, 0.3))
    g2 = float(C.se_double_integral(0.6, 0.9, 0.1, 0.4, 0.3))
    assert g1 == pytest.approx(g2, rel=1e-12)
    assert g1 > 0


def _schema(l=2, cats=(4,), m=1):
    return Schema(num_lo=(0.0,) * l, num_hi=(1.0,) * l, cat_sizes=cats, n_measures=m)


def test_point_limit_equals_kernel():
    """Normalized AVG covariance of two equality predicates -> SE kernel."""
    sch = _schema()
    p = GPParams.init(sch)
    b = make_snippets(
        sch, agg=AVG, measure=0,
        num_ranges=[{0: (0.2, 0.2), 1: (0.5, 0.5)}, {0: (0.6, 0.6), 1: (0.5, 0.5)}],
        cat_sets=[{0: (1,)}, {0: (1,)}],
    )
    cov = np.asarray(C.cov_matrix(b, b, p))
    expected = np.exp(-((0.2 - 0.6) ** 2) / 1.0**2)  # ls=1, sigma2=1
    assert cov[0, 1] == pytest.approx(expected, rel=1e-3)
    assert cov[0, 0] == pytest.approx(1.0, rel=1e-3)


def test_cov_diag_matches_matrix_diagonal():
    sch = _schema()
    p = GPParams.init(sch)
    b = make_snippets(
        sch, agg=[AVG, FREQ], measure=[0, 0],
        num_ranges=[{0: (0.1, 0.6)}, {1: (0.3, 0.9)}],
        cat_sets=[{}, {0: (0, 2)}],
    )
    full = np.asarray(C.cov_matrix(b, b, p))
    diag = np.asarray(C.cov_diag(b, p))
    np.testing.assert_allclose(np.diag(full), diag, rtol=1e-10)


def test_cov_matrix_symmetric_psd():
    sch = _schema(l=3, cats=(5, 3))
    p = GPParams(log_ls=jnp.log(jnp.asarray([0.3, 0.5, 1.0])),
                 log_sigma2=jnp.log(2.0), mu=jnp.asarray(0.0))
    rng = np.random.default_rng(0)
    n = 12
    ranges = []
    cat_sets = []
    for _ in range(n):
        r = {}
        for d in range(3):
            if rng.random() < 0.7:
                a = rng.uniform(0, 0.7)
                r[d] = (a, a + rng.uniform(0.05, 0.3))
        ranges.append(r)
        cs = {}
        if rng.random() < 0.5:
            cs[0] = tuple(rng.choice(5, size=2, replace=False).tolist())
        cat_sets.append(cs)
    b = make_snippets(sch, agg=AVG, measure=0, num_ranges=ranges, cat_sets=cat_sets)
    cov = np.asarray(C.cov_matrix(b, b, p))
    np.testing.assert_allclose(cov, cov.T, rtol=1e-10)
    evals = np.linalg.eigvalsh(cov)
    assert evals.min() > -1e-8 * evals.max()


def test_disjoint_categorical_zero_covariance():
    sch = _schema()
    p = GPParams.init(sch)
    b = make_snippets(
        sch, agg=FREQ, measure=0,
        num_ranges=[{0: (0.0, 1.0)}, {0: (0.0, 1.0)}],
        cat_sets=[{0: (0, 1)}, {0: (2, 3)}],
    )
    cov = np.asarray(C.cov_matrix(b, b, p))
    assert cov[0, 1] == pytest.approx(0.0, abs=1e-12)


def test_avg_normalization_shrinks_with_category_width():
    """AVG over more independent categories has smaller prior variance."""
    sch = _schema()
    p = GPParams.init(sch)
    b = make_snippets(
        sch, agg=AVG, measure=0,
        num_ranges=[{}, {}],
        cat_sets=[{0: (0,)}, {0: (0, 1, 2, 3)}],
    )
    d = np.asarray(C.cov_diag(b, p))
    assert d[1] < d[0]


def test_freq_additive_over_categories():
    """FREQ variance over V categories = V * single-category variance."""
    sch = _schema()
    p = GPParams.init(sch)
    b = make_snippets(
        sch, agg=FREQ, measure=0,
        num_ranges=[{}, {}],
        cat_sets=[{0: (0,)}, {0: (0, 1, 2, 3)}],
    )
    d = np.asarray(C.cov_diag(b, p))
    assert d[1] == pytest.approx(4 * d[0], rel=1e-9)
