"""Workload generators: the §8.6 power-law column-access scheme."""
import numpy as np
import pytest

from repro.aqp import workload as W


def test_power_law_probs_halving_chains_off_frequent_mass():
    """§8.6: frequent columns uniform; every tail column is HALF its
    predecessor, starting from half the per-frequent-column probability
    (regression: the first tail probability used to be a hardcoded 0.5,
    independent of the frequent-column mass)."""
    probs = W.power_law_probs(10, 0.3)  # k = 3 frequent columns
    assert probs.shape == (10,)
    assert probs.sum() == pytest.approx(1.0)
    # Frequent block is uniform.
    np.testing.assert_allclose(probs[:3], probs[0])
    # Tail: each column half the previous — INCLUDING the first tail column
    # relative to the last frequent one.
    for i in range(3, 10):
        assert probs[i] == pytest.approx(probs[i - 1] / 2.0)
    # Unnormalized masses are 1,1,1,1/2,1/4,... so the head holds most mass.
    assert probs[:3].sum() > 0.5


def test_power_law_probs_all_frequent_is_uniform():
    probs = W.power_law_probs(6, 1.0)
    np.testing.assert_allclose(probs, 1.0 / 6.0)


def test_power_law_probs_minimum_one_frequent():
    probs = W.power_law_probs(4, 0.0)  # k clamps to 1
    assert probs[1] == pytest.approx(probs[0] / 2.0)
    assert probs[3] == pytest.approx(probs[0] / 8.0)


def test_power_law_column_empirical_distribution():
    """Sampled column frequencies match the analytic scheme."""
    rng = np.random.default_rng(0)
    n_cols, frac = 8, 0.25  # k = 2
    draws = np.array([W._power_law_column(rng, n_cols, frac)
                      for _ in range(20_000)])
    emp = np.bincount(draws, minlength=n_cols) / len(draws)
    np.testing.assert_allclose(emp, W.power_law_probs(n_cols, frac),
                               atol=0.01)


def test_make_workload_still_deterministic():
    """The fix is behavior-preserving for the default all-ones head, so
    seeded workloads stay reproducible."""
    sch = W.make_relation(seed=0, n_rows=100, n_num=3, cat_sizes=(4, 3),
                          n_measures=1).schema
    a = W.make_workload(7, sch, 10, frac_frequent=0.5)
    b = W.make_workload(7, sch, 10, frac_frequent=0.5)
    assert a == b
