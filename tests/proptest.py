"""Tiny property-based-testing shim (hypothesis is unavailable offline).

Provides seeded random-case generation with failure reporting that prints the
seed and generated arguments so cases are reproducible. API intentionally
mirrors the hypothesis style loosely: ``@given(cases(...))``.
"""
from __future__ import annotations


import numpy as np


class Gen:
    """A generator of random values given a numpy Generator."""

    def __init__(self, fn, desc=""):
        self.fn = fn
        self.desc = desc

    def __call__(self, rng):
        return self.fn(rng)


def floats(lo, hi):
    return Gen(lambda rng: float(rng.uniform(lo, hi)), f"floats[{lo},{hi}]")


def ints(lo, hi):
    return Gen(lambda rng: int(rng.integers(lo, hi + 1)), f"ints[{lo},{hi}]")


def arrays(shape_gen, lo=-1.0, hi=1.0):
    def make(rng):
        shape = shape_gen(rng) if callable(shape_gen) else shape_gen
        return rng.uniform(lo, hi, size=shape)

    return Gen(make, "arrays")


def choice(options):
    return Gen(lambda rng: options[int(rng.integers(0, len(options)))], f"choice{options}")


def given(n_cases: int = 25, seed: int = 0, **gens):
    """Run the test for ``n_cases`` random draws of the declared generators."""

    def deco(fn):
        def wrapper():
            for case in range(n_cases):
                rng = np.random.default_rng(seed * 100003 + case)
                drawn = {k: g(rng) for k, g in gens.items()}
                try:
                    fn(**drawn)
                except Exception as e:  # pragma: no cover - reporting path
                    raise AssertionError(
                        f"property failed on case {case} (seed={seed}): "
                        f"{ {k: _short(v) for k, v in drawn.items()} }: {e}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco


def _short(v):
    a = np.asarray(v)
    if a.ndim == 0 or a.size <= 8:
        return v
    return f"array{a.shape} mean={a.mean():.4g}"
