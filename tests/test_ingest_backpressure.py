"""Async-ingest back-pressure: bounded queue, shed-to-sync under overload,
high-water telemetry in stats/state_dict, unchanged drain() semantics."""
import time

import numpy as np
import pytest

from repro.core.synopsis import MAX_PENDING_DEFAULT, Synopsis
from repro.core.types import AVG, Schema, make_snippets


def _schema():
    return Schema(num_lo=(0.0, 0.0), num_hi=(1.0, 1.0), cat_sizes=(4,),
                  n_measures=1)


def _batch(rng, sch, n):
    ranges = []
    for _ in range(n):
        r = {}
        for d in range(sch.n_num):
            a = rng.uniform(0, 0.6)
            r[d] = (a, a + rng.uniform(0.05, 0.4))
        ranges.append(r)
    return make_snippets(sch, agg=AVG, measure=0, num_ranges=ranges)


def _adds(seed, n_batches=8, k=3):
    rng = np.random.default_rng(seed)
    sch = _schema()
    return sch, [
        (_batch(rng, sch, k), rng.normal(1.0, 0.3, k),
         rng.uniform(0.01, 0.05, k))
        for _ in range(n_batches)
    ]


def test_default_bound_and_idle_stats():
    syn = Synopsis(_schema(), capacity=32)
    assert syn.max_pending == MAX_PENDING_DEFAULT
    assert syn.ingest_stats() == {
        "max_pending": MAX_PENDING_DEFAULT, "high_water": 0, "shed_count": 0,
    }


def test_overload_sheds_to_sync_and_matches_synchronous_state():
    """With a tiny bound and a slowed-down apply, producers overrun the
    queue; the shed path (drain + apply inline) keeps FIFO order, so the
    final state is bitwise identical to fully synchronous ingestion."""
    sch, adds = _adds(seed=0, n_batches=8)
    syn = Synopsis(sch, capacity=64, max_pending=2)
    inner = syn._apply_add

    def slow(*args):
        time.sleep(0.05)
        inner(*args)

    syn._apply_add = slow  # bound before the lazy queue is created
    for b, th, b2 in adds:
        syn.add(b, th, b2)
    syn.drain()
    stats = syn.ingest_stats()
    assert stats["high_water"] <= 2  # the bound held
    assert stats["shed_count"] >= 1  # overload actually shed
    assert stats["max_pending"] == 2

    twin = Synopsis(sch, capacity=64, async_ingest=False)
    for b, th, b2 in adds:
        twin.add(b, th, b2)
    assert syn.n == twin.n
    np.testing.assert_array_equal(np.asarray(syn.theta()),
                                  np.asarray(twin.theta()))
    np.testing.assert_array_equal(np.asarray(syn.beta2()),
                                  np.asarray(twin.beta2()))
    np.testing.assert_array_equal(np.asarray(syn._sigma_inv),
                                  np.asarray(twin._sigma_inv))


def test_high_water_mark_in_state_dict_roundtrip():
    sch, adds = _adds(seed=1, n_batches=4)
    syn = Synopsis(sch, capacity=64, max_pending=2)
    for b, th, b2 in adds:
        syn.add(b, th, b2)
    sd = syn.state_dict()
    assert "ingest_high_water" in sd
    assert int(sd["ingest_high_water"]) == syn.ingest_high_water
    restored = Synopsis(sch, capacity=64)
    restored.load_state_dict(sd)
    assert restored.ingest_high_water == syn.ingest_high_water
    # The telemetry survives a second snapshot (checkpoint round-trip).
    np.testing.assert_array_equal(restored.state_dict()["ingest_high_water"],
                                  sd["ingest_high_water"])
    # Pre-back-pressure checkpoints (no key) still load.
    legacy = {k: v for k, v in sd.items() if k != "ingest_high_water"}
    fresh = Synopsis(sch, capacity=64)
    fresh.load_state_dict(legacy)
    assert fresh.ingest_high_water == 0


def test_drain_semantics_unchanged():
    sch, adds = _adds(seed=2, n_batches=3)
    syn = Synopsis(sch, capacity=64, max_pending=1)
    for b, th, b2 in adds:
        syn.add(b, th, b2)
    syn.drain()
    syn.drain()  # idempotent
    assert syn.n > 0
    assert syn.ingest_stats()["high_water"] <= 1
