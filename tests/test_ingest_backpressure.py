"""Async-ingest back-pressure and failure quarantine: bounded queue,
shed-to-sync under overload, high-water telemetry in stats/state_dict,
drain() as a never-raising barrier, per-key quarantine with raw-floor
serving, and heal() back to bitwise parity with a never-failed store."""
import time
import warnings

import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineConfig
from repro.core.store import LocalSynopsisStore, state_key
from repro.core.synopsis import MAX_PENDING_DEFAULT, Synopsis
from repro.core.types import AVG, FREQ, RawAnswer, Schema, make_snippets


def _schema():
    return Schema(num_lo=(0.0, 0.0), num_hi=(1.0, 1.0), cat_sizes=(4,),
                  n_measures=1)


def _batch(rng, sch, n):
    ranges = []
    for _ in range(n):
        r = {}
        for d in range(sch.n_num):
            a = rng.uniform(0, 0.6)
            r[d] = (a, a + rng.uniform(0.05, 0.4))
        ranges.append(r)
    return make_snippets(sch, agg=AVG, measure=0, num_ranges=ranges)


def _adds(seed, n_batches=8, k=3):
    rng = np.random.default_rng(seed)
    sch = _schema()
    return sch, [
        (_batch(rng, sch, k), rng.normal(1.0, 0.3, k),
         rng.uniform(0.01, 0.05, k))
        for _ in range(n_batches)
    ]


def test_default_bound_and_idle_stats():
    syn = Synopsis(_schema(), capacity=32)
    assert syn.max_pending == MAX_PENDING_DEFAULT
    assert syn.ingest_stats() == {
        "max_pending": MAX_PENDING_DEFAULT, "high_water": 0, "shed_count": 0,
        "quarantined": False, "quarantine_reason": None,
        "unapplied": 0, "quarantine_count": 0,
    }


def test_overload_sheds_to_sync_and_matches_synchronous_state():
    """With a tiny bound and a slowed-down apply, producers overrun the
    queue; the shed path (drain + apply inline) keeps FIFO order, so the
    final state is bitwise identical to fully synchronous ingestion."""
    sch, adds = _adds(seed=0, n_batches=8)
    syn = Synopsis(sch, capacity=64, max_pending=2)
    inner = syn._apply_add

    def slow(*args):
        time.sleep(0.05)
        inner(*args)

    syn._apply_add = slow  # bound before the lazy queue is created
    for b, th, b2 in adds:
        syn.add(b, th, b2)
    syn.drain()
    stats = syn.ingest_stats()
    assert stats["high_water"] <= 2  # the bound held
    assert stats["shed_count"] >= 1  # overload actually shed
    assert stats["max_pending"] == 2

    twin = Synopsis(sch, capacity=64, async_ingest=False)
    for b, th, b2 in adds:
        twin.add(b, th, b2)
    assert syn.n == twin.n
    np.testing.assert_array_equal(np.asarray(syn.theta()),
                                  np.asarray(twin.theta()))
    np.testing.assert_array_equal(np.asarray(syn.beta2()),
                                  np.asarray(twin.beta2()))
    np.testing.assert_array_equal(np.asarray(syn._sigma_inv),
                                  np.asarray(twin._sigma_inv))


def test_high_water_mark_in_state_dict_roundtrip():
    sch, adds = _adds(seed=1, n_batches=4)
    syn = Synopsis(sch, capacity=64, max_pending=2)
    for b, th, b2 in adds:
        syn.add(b, th, b2)
    sd = syn.state_dict()
    assert "ingest_high_water" in sd
    assert int(sd["ingest_high_water"]) == syn.ingest_high_water
    restored = Synopsis(sch, capacity=64)
    restored.load_state_dict(sd)
    assert restored.ingest_high_water == syn.ingest_high_water
    # The telemetry survives a second snapshot (checkpoint round-trip).
    np.testing.assert_array_equal(restored.state_dict()["ingest_high_water"],
                                  sd["ingest_high_water"])
    # Pre-back-pressure checkpoints (no key) still load.
    legacy = {k: v for k, v in sd.items() if k != "ingest_high_water"}
    fresh = Synopsis(sch, capacity=64)
    fresh.load_state_dict(legacy)
    assert fresh.ingest_high_water == 0


def test_drain_semantics_unchanged():
    sch, adds = _adds(seed=2, n_batches=3)
    syn = Synopsis(sch, capacity=64, max_pending=1)
    for b, th, b2 in adds:
        syn.add(b, th, b2)
    syn.drain()
    syn.drain()  # idempotent
    assert syn.n > 0
    assert syn.ingest_stats()["high_water"] <= 1


# ---------------------------------------------------------------- quarantine
def _freq_batch(rng, sch, n):
    ranges = []
    for _ in range(n):
        r = {}
        for d in range(sch.n_num):
            a = rng.uniform(0, 0.6)
            r[d] = (a, a + rng.uniform(0.05, 0.4))
        ranges.append(r)
    return make_snippets(sch, agg=FREQ, measure=0, num_ranges=ranges)


def test_store_quarantine_blast_radius_and_heal():
    """Store-level blast radius: one key's failed apply quarantines THAT
    synopsis only. store.drain() stays a plain barrier, the healthy key
    keeps improving, the sick key serves the raw floor (reported via the
    health dict), checkpointing skips it with a warning instead of
    failing, and store.heal() restores bitwise parity with a twin that
    never failed."""
    rng = np.random.default_rng(11)
    sch = _schema()
    cfg = EngineConfig(capacity=64, async_ingest=True)
    store = LocalSynopsisStore(sch, cfg)
    avg_key, freq_key = (AVG, 0), (FREQ, 0)
    sick = store.for_key(avg_key)
    assert sick.name == state_key(avg_key)

    def boom(*args):
        raise ValueError("injected apply failure")

    sick._apply_add = boom
    avg_adds = [( _batch(rng, sch, 3), rng.normal(1.0, 0.3, 3),
                  rng.uniform(0.01, 0.05, 3)) for _ in range(2)]
    freq_adds = [(_freq_batch(rng, sch, 3), rng.uniform(10, 20, 3),
                  rng.uniform(0.01, 0.05, 3)) for _ in range(2)]
    for (b, th, b2), (fb, fth, fb2) in zip(avg_adds, freq_adds):
        store.record(b, RawAnswer(jnp.asarray(th), jnp.asarray(b2)))
        store.record(fb, RawAnswer(jnp.asarray(fth), jnp.asarray(fb2)))
    store.drain()  # never raises — the failure is quarantined per key
    assert list(store.quarantined()) == [state_key(avg_key)]
    assert "injected apply failure" in store.quarantined()[state_key(avg_key)]
    assert store.stats()["quarantined"] == store.quarantined()
    healthy = store.get(freq_key)
    assert not healthy.quarantined and healthy.n > 0

    # Sick key degrades to the raw floor and reports into `health`.
    probe = _batch(rng, sch, 2)
    raw = RawAnswer(jnp.asarray([1.0, 2.0]), jnp.asarray([0.3, 0.4]))
    health = {}
    imp = store.improve_groups(probe, raw, health=health)
    np.testing.assert_array_equal(np.asarray(imp.theta), [1.0, 2.0])
    assert not bool(np.asarray(imp.accepted).any())
    assert list(health) == [state_key(avg_key)]

    # Healthy key still improves through the same store call.
    fprobe = _freq_batch(rng, sch, 2)
    fhealth = {}
    store.improve_groups(
        fprobe, RawAnswer(jnp.asarray([12.0, 13.0]), jnp.asarray([0.3, 0.4])),
        health=fhealth)
    assert fhealth == {}

    # Checkpointing skips the sick key with a warning — one bad key must
    # not block persisting the healthy learned state.
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        sd = store.state_dict()
    assert state_key(freq_key) in sd and state_key(avg_key) not in sd
    assert any("quarantined" in str(w.message) for w in caught)

    # Heal: the parked batches replay in order; the healed synopsis is
    # bitwise identical to one that never failed.
    del sick._apply_add
    assert store.heal() == {state_key(avg_key): True}
    assert store.quarantined() == {}
    twin = Synopsis(sch, capacity=64, async_ingest=False)
    for b, th, b2 in avg_adds:
        twin.add(b, th, b2)
    got, want = sick.state_dict(), twin.state_dict()
    for k in want:
        if k == "ingest_high_water":
            continue
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)


def test_heal_from_last_good_state_replays_parked_batches():
    """heal(states=...) restores the last-good snapshot, then replays the
    parked batches — the post-heal state matches applying every batch on
    an unfailed synopsis."""
    rng = np.random.default_rng(12)
    sch = _schema()
    cfg = EngineConfig(capacity=64, async_ingest=True)
    store = LocalSynopsisStore(sch, cfg)
    key = (AVG, 0)
    adds = [(_batch(rng, sch, 3), rng.normal(1.0, 0.3, 3),
             rng.uniform(0.01, 0.05, 3)) for _ in range(4)]
    for b, th, b2 in adds[:2]:
        store.record(b, RawAnswer(jnp.asarray(th), jnp.asarray(b2)))
    good = store.state_dict()  # last-good checkpoint payload
    syn = store.get(key)

    def boom(*args):
        raise ValueError("apply failure after the checkpoint")

    syn._apply_add = boom
    for b, th, b2 in adds[2:]:
        store.record(b, RawAnswer(jnp.asarray(th), jnp.asarray(b2)))
    store.drain()
    assert store.quarantined()
    del syn._apply_add
    assert store.heal(states=good) == {state_key(key): True}
    twin = Synopsis(sch, capacity=64, async_ingest=False)
    for b, th, b2 in adds:
        twin.add(b, th, b2)
    got, want = syn.state_dict(), twin.state_dict()
    for k in want:
        if k == "ingest_high_water":
            continue
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)
