"""Property tests for the synopsis' incremental inverse maintenance:
blocked rank-k append/delete vs ``jnp.linalg.inv``, round-trips, and the
evict-then-insert ordering ``Synopsis.add`` exercises (through the async
ingest queue: every add is followed by a ``drain()`` barrier before state is
inspected)."""
import numpy as np
import jax.numpy as jnp

import proptest as pt
from repro.core.synopsis import (
    Synopsis,
    inv_append_block,
    inv_delete_block,
)
from repro.core.types import AVG, Schema, SnippetBatch, make_snippets


def _spd(rng, n, scale=1.0):
    a = rng.normal(size=(n, n))
    return scale * (a @ a.T / n + np.eye(n))


def _grow(rng, spd, k):
    """Extend an SPD matrix by k rows/cols, staying SPD."""
    n = spd.shape[0]
    b = rng.normal(0, 0.3, size=(k, n))
    d = b @ np.linalg.solve(spd, b.T) + _spd(rng, k)
    full = np.zeros((n + k, n + k))
    full[:n, :n] = spd
    full[:n, n:] = b.T
    full[n:, :n] = b
    full[n:, n:] = d
    return full, b, d


@pt.given(n_cases=8, seed=2, n=pt.choice([2, 9, 17]), k=pt.choice([1, 3, 6]))
def test_inv_append_block_matches_direct_inverse(n, k):
    rng = np.random.default_rng(n * 31 + k)
    full, b, d = _grow(rng, _spd(rng, n), k)
    got = inv_append_block(jnp.asarray(np.linalg.inv(full[:n, :n])),
                           jnp.asarray(b), jnp.asarray(d))
    np.testing.assert_allclose(np.asarray(got), np.linalg.inv(full),
                               rtol=1e-6, atol=1e-8)


def test_inv_append_block_k1_matches_direct_inverse():
    """The k=1 case (the old per-row path) is just a 1-block append."""
    rng = np.random.default_rng(7)
    n = 9
    full, b, d = _grow(rng, _spd(rng, n), 1)
    ainv = jnp.asarray(np.linalg.inv(full[:n, :n]))
    blk = inv_append_block(ainv, jnp.asarray(b), jnp.asarray(d))
    np.testing.assert_allclose(np.asarray(blk), np.linalg.inv(full),
                               rtol=1e-6, atol=1e-8)


@pt.given(n_cases=8, seed=4, n=pt.choice([4, 9, 17]), k=pt.choice([1, 3]))
def test_inv_delete_block_matches_direct_inverse(n, k):
    rng = np.random.default_rng(n * 17 + k)
    spd = _spd(rng, n)
    pos = np.sort(rng.choice(n, size=min(k, n - 1), replace=False))
    keep = np.setdiff1d(np.arange(n), pos)
    got = inv_delete_block(jnp.asarray(np.linalg.inv(spd)), pos)
    np.testing.assert_allclose(np.asarray(got),
                               np.linalg.inv(spd[np.ix_(keep, keep)]),
                               rtol=1e-6, atol=1e-8)


@pt.given(n_cases=8, seed=5, n=pt.choice([2, 9, 17]), k=pt.choice([1, 4]))
def test_append_then_delete_roundtrip(n, k):
    """Appending k rows then deleting them restores the original inverse."""
    rng = np.random.default_rng(n * 13 + k)
    spd = _spd(rng, n)
    ainv = np.linalg.inv(spd)
    full, b, d = _grow(rng, spd, k)
    grown = inv_append_block(jnp.asarray(ainv), jnp.asarray(b), jnp.asarray(d))
    back = inv_delete_block(grown, np.arange(n, n + k))
    np.testing.assert_allclose(np.asarray(back), ainv, rtol=1e-6, atol=1e-8)


# --------------------------------------------------------- Synopsis.add path
def _schema():
    return Schema(num_lo=(0.0, 0.0), num_hi=(1.0, 1.0), cat_sizes=(),
                  n_measures=1)


def _snips(rng, n):
    ranges = []
    for _ in range(n):
        r = {}
        for d in range(2):
            a = rng.uniform(0, 0.7)
            r[d] = (a, a + rng.uniform(0.05, 0.3))
        ranges.append(r)
    return make_snippets(_schema(), agg=AVG, measure=0, num_ranges=ranges)


def _model_inverse_error(syn):
    syn.drain()  # async ingest barrier before touching model internals
    rows = np.asarray(syn._order, np.int64)
    sig = syn._sigma[np.ix_(rows, rows)]
    direct = np.linalg.inv(sig + 1e-10 * np.eye(len(rows)))
    return np.max(np.abs(np.asarray(syn._sigma_inv) - direct))


@pt.given(n_cases=5, seed=6, capacity=pt.choice([4, 8]), total=pt.choice([13, 21]),
          chunk=pt.choice([1, 3, 7]))
def test_synopsis_add_evict_then_insert_keeps_inverse_consistent(
        capacity, total, chunk):
    """Chunked adds overflowing capacity (evict + blocked insert in one call)
    must leave Sigma^{-1} equal to the direct inverse of the kept rows."""
    rng = np.random.default_rng(capacity * 1000 + total * 10 + chunk)
    syn = Synopsis(_schema(), capacity=capacity)
    snips = _snips(rng, total)
    theta = rng.normal(1.0, 0.3, total)
    beta2 = rng.uniform(0.01, 0.2, total)
    for s in range(0, total, chunk):
        e = min(s + chunk, total)
        syn.add(snips[jnp.arange(s, e)], theta[s:e], beta2[s:e])
        syn.drain()
        assert syn.n <= capacity
        assert len(syn._order) == syn.n
        assert _model_inverse_error(syn) < 1e-6
    assert syn.n == min(capacity, total)


def test_synopsis_add_dedup_keeps_better_answer_and_refreshes_lru():
    rng = np.random.default_rng(0)
    syn = Synopsis(_schema(), capacity=8)
    snips = _snips(rng, 4)
    syn.add(snips, np.full(4, 1.0), np.full(4, 0.1))
    syn.drain()
    assert syn.n == 4
    # Re-add the same snippets with a worse error: values must not change.
    syn.add(snips, np.full(4, 9.0), np.full(4, 0.5))
    syn.drain()
    assert syn.n == 4
    np.testing.assert_allclose(syn.theta(), np.full(4, 1.0))
    np.testing.assert_allclose(syn.beta2(), np.full(4, 0.1))
    # Better error: replaced, and the model diagonal follows (delete+insert).
    syn.add(snips[jnp.arange(1)], np.asarray([2.0]), np.asarray([0.01]))
    syn.drain()
    assert syn.n == 4
    assert float(syn.theta()[0]) == 2.0
    assert float(syn.beta2()[0]) == 0.01
    assert _model_inverse_error(syn) < 1e-6
    # LRU: rows 1..3 are now stale; filling capacity evicts them first.
    fresh = _snips(np.random.default_rng(1), 7)
    syn.add(fresh, np.full(7, 1.0), np.full(7, 0.1))
    syn.drain()
    assert syn.n == 8
    remaining = {float(t) for t in syn.theta()}
    assert 2.0 in remaining  # row 0 was refreshed by the better re-add
    assert _model_inverse_error(syn) < 1e-6


def test_synopsis_add_more_new_than_capacity_keeps_most_recent():
    rng = np.random.default_rng(3)
    syn = Synopsis(_schema(), capacity=5)
    snips = _snips(rng, 12)
    theta = np.arange(12, dtype=float)
    syn.add(snips, theta, np.full(12, 0.1))
    syn.drain()
    assert syn.n == 5
    # The most recent ``capacity`` snippets survive (LRU semantics).
    assert sorted(float(t) for t in syn.theta()) == [7.0, 8.0, 9.0, 10.0, 11.0]
    assert _model_inverse_error(syn) < 1e-6


def test_synopsis_add_overflow_respects_intra_batch_lru():
    """A snippet re-occurring late in an overflowing batch is the most
    recently used and must survive the truncation."""
    rng = np.random.default_rng(5)
    syn = Synopsis(_schema(), capacity=2)
    base = _snips(rng, 3)
    # Batch [A, B, C, A]: with capacity 2 the survivors must be {C, A}.
    batch = SnippetBatch.concat([base, base[jnp.arange(1)]])
    syn.add(batch, np.asarray([1.0, 2.0, 3.0, 1.0]), np.full(4, 0.1))
    syn.drain()
    assert syn.n == 2
    assert sorted(float(t) for t in syn.theta()) == [1.0, 3.0]
    assert _model_inverse_error(syn) < 1e-6


def test_synopsis_add_skips_nonfinite_answers():
    rng = np.random.default_rng(4)
    syn = Synopsis(_schema(), capacity=8)
    snips = _snips(rng, 3)
    syn.add(snips, np.asarray([1.0, np.nan, 2.0]),
            np.asarray([0.1, 0.1, np.inf]))
    syn.drain()
    assert syn.n == 1
