"""Shared pytest wiring: paths + the forced multi-device host topology.

Multi-device tests (the sharded scan parity matrix, the SynopsisStore
placement suite) need fake host CPU devices, which XLA only honors if the
flag is set BEFORE the backend initializes — i.e. before any test module
imports jax. This conftest therefore forces the topology at collection
time, and tests *declare* the device count they need through the
``forced_devices`` fixture instead of every CI job duplicating
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` env blocks.

``REPRO_FORCE_HOST_DEVICES`` overrides the forced count (CI's device-count
matrix sets 1 and 8); an explicit pre-set ``xla_force_host_platform_device_count``
in ``XLA_FLAGS`` always wins. Every test must pass under ANY topology —
``forced_devices(n)`` skips (never fails) when the host has fewer than
``n`` devices, so the single-device leg degenerates gracefully.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "tests"))

_FORCED = int(os.environ.get("REPRO_FORCE_HOST_DEVICES", "8"))
if (_FORCED > 1
        and "xla_force_host_platform_device_count"
        not in os.environ.get("XLA_FLAGS", "")):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_FORCED}"
    ).strip()

import pytest  # noqa: E402


@pytest.fixture
def forced_devices():
    """``forced_devices(n)`` → the first ``n`` host devices, or skip.

    The declaration point for multi-device tests: parametrize over device
    counts and carve each mesh out of the forced topology, e.g.::

        def test_parity(forced_devices):
            mesh = Mesh(np.array(forced_devices(4)), ("data",))

    Skips when the topology is too small (e.g. the CI matrix leg with
    ``REPRO_FORCE_HOST_DEVICES=1``) so device counts never silently lie.
    """
    import jax

    def take(n: int):
        if jax.device_count() < n:
            pytest.skip(f"needs {n} host devices, have {jax.device_count()}"
                        " (see conftest.py / REPRO_FORCE_HOST_DEVICES)")
        return jax.devices()[:n]

    return take
