"""In-situ use: Verdict answering analytics over model-fleet serving telemetry.

The natural coupling between the paper's engine and the LM substrate it ships
with: request logs (latency, tokens, batch, model id, timestamp) become a
relation; operators ask streams of aggregate dashboard queries through the
``repro.verdict`` Session API (typed builder over named columns), microbatched
by ``AqpService``; Verdict learns the telemetry distribution and answers from
ever-smaller samples.

    PYTHONPATH=src python examples/fleet_analytics.py [--smoke]
"""
import argparse

import numpy as np

import repro.verdict as vd
from repro.aqp.relation import Relation
from repro.core.types import Schema


def make_telemetry(seed=0, n=200_000):
    rng = np.random.default_rng(seed)
    ts = rng.uniform(0, 72.0, n)  # hours
    prompt_len = rng.uniform(16, 4096, n)
    batch = rng.integers(1, 9, n).astype(float)
    model = rng.integers(0, 10, n)  # the 10 assigned archs
    diurnal = 1.0 + 0.4 * np.sin(2 * np.pi * ts / 24.0)
    model_cost = np.linspace(0.5, 3.0, 10)[model]
    latency_ms = (20 + 0.08 * prompt_len) * diurnal * model_cost \
        + rng.normal(0, 8, n)
    tokens_out = rng.uniform(16, 512, n)
    schema = Schema(
        num_lo=(0.0, 16.0, 1.0), num_hi=(72.0, 4096.0, 8.0),
        cat_sizes=(10,), n_measures=2,
        num_names=("hour", "prompt_len", "batch"),
        cat_names=("model",), measure_names=("latency_ms", "tokens_out"))
    num = np.stack([ts, prompt_len, batch], 1)
    return Relation.from_columns(schema, num, model[:, None].astype(np.int32),
                                 np.stack([latency_ms, tokens_out], 1))


def main(smoke: bool = False):
    rel = make_telemetry(n=10_000 if smoke else 200_000)
    # cache=True attaches the workload-intelligence plane (repro.intel):
    # repeated dashboard queries serve from the semantic answer cache.
    session = vd.connect(rel, vd.EngineConfig(sample_rate=0.05, n_batches=8,
                                              capacity=512), cache=True)
    svc = session.serve(max_batch=16,
                        budget=vd.ErrorBudget(target_rel_error=0.02))
    rng = np.random.default_rng(1)

    def dashboard_wave(n):
        # Typed builder: named columns resolved through the schema.
        return [
            session.query().avg("latency_ms").where(
                vd.between("hour", t0, t0 + rng.uniform(2, 12)),
                vd.equals("model", int(rng.integers(0, 10))),
            ).build()
            for t0 in rng.uniform(0, 60, n)
        ]

    print("operator dashboard queries (avg latency by window/model),")
    print("microbatched: each wave is ONE fused scan serving all queries:")
    waves = ((0, 4), (1, 5)) if smoke else ((0, 12), (1, 13))
    for wave, n in waves:
        results = svc.execute(dashboard_wave(n))
        st = svc.last_stats
        print(f"  wave {wave}: {n} queries, {st.eval_calls} sample-batch scans, "
              f"dedup {st.n_snippets_total}->{st.n_snippets_fused}")
        for i, r in enumerate(results):
            c = r.cells[0]  # typed Cell via the Session facade
            print(f"  q{i:02d}: avg latency {c.estimate:8.2f} ms "
                  f"+- {c.error_bound(0.95):6.2f}  "
                  f"(batches used: {r.batches_used})")
        if wave == 0:
            session.refit(steps=10 if smoke else 50)
            print("  --- refit: engine has learned the diurnal pattern ---")
    # §8.6 repeated-dashboard regime: a power-law pool of favorite panels
    # (broad per-model latency breakdowns) re-issued wave after wave — the
    # answer cache's natural food. The loose budget matters twice: misses
    # early-stop, and the recorded CIs keep licensing staleness-bumped
    # entries on later waves (the error-budget serve rule).
    panel_budget = vd.ErrorBudget(target_rel_error=0.3)
    pool = [
        session.query().avg("latency_ms")
        .where(vd.between("hour", 0.0, 18.0 + 6.0 * i))
        .group_by("model").build()
        for i in range(4 if smoke else 8)
    ]
    ranks = np.arange(1, len(pool) + 1, dtype=np.float64)
    probs = (1.0 / ranks) / (1.0 / ranks).sum()
    draws = rng.choice(len(pool), size=(16 if smoke else 60), p=probs)
    for wave in np.array_split(draws, 4):  # dashboard refresh cycles
        session.execute_many([pool[int(i)] for i in wave],
                             budget=panel_budget)
    # A pinned drill-down is SUBSUMED by its cached grouped panel: served
    # from the recorded cells, no scan at all.
    drill = (session.query().avg("latency_ms")
             .where(vd.between("hour", 0.0, 18.0), vd.equals("model", 3))
             .group_by("model"))
    drilled = session.execute(drill, panel_budget)
    intel = session.stats()["intel"]
    print(f"  power-law wave ({len(draws)} queries over {len(pool)} panels):")
    print(f"    cache hit rate {intel['hit_rate']:.0%} "
          f"(exact={intel['hits_exact']} subsumed={intel['hits_subsumed']} "
          f"misses={intel['misses']})")
    print(f"    drill-down served from: {drilled.served_from}")
    print(f"    routes: {intel['routes']}  "
          f"entries={intel['entries']}/{intel['capacity']}")
    st = session.stats()
    print(f"  store: {st['store']['kind']} ({st['store']['n_keys']} aggregate "
          f"keys over {st['store']['n_shards']} shard(s))")
    for key, entry in st["store"]["keys"].items():
        print(f"    {key}: fill={entry['n']}/{entry['capacity']} "
              f"placement={entry['placement']} "
              f"ingest_high_water={entry['ingest']['high_water']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI: checks the path end-to-end")
    main(**vars(ap.parse_args()))
