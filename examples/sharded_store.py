"""Sharded learned state: one mesh drives the scan AND the synopsis store.

Forces a multi-device CPU topology (8 fake host devices — the same trick the
``sharded-smoke`` CI job uses), opens a ``repro.verdict`` Session with a
mesh, and shows the placement seam end to end:

  - ``explain`` reports, per aggregate key, which shard/device the learned
    state lives on (before the key even exists);
  - queries run the fused scan through the masked ``ShardedScanPlacement``
    over the mesh while each key's synopsis model is committed to its
    assigned device;
  - ``Session.stats()`` shows shard occupancy and ingest back-pressure;
  - the checkpoint round-trip re-places the sharded state onto a SMALLER
    device set (elastic re-scale) and keeps answering bit-for-bit.

    PYTHONPATH=src python examples/sharded_store.py [--smoke]

The sharded scan is shape-agnostic: sample batches of ANY size shard over
ANY mesh (the tuple axis pads to a power-of-two tile with a validity mask,
``repro.aqp.executor.ScanPlacement``), so — like the store — the scan
imposes no constraint on the relation/mesh combination, and reported
scanned-tuple counts stay true counts.
"""
import argparse
import os
import tempfile

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

import repro.verdict as vd  # noqa: E402
from repro.aqp import workload as W  # noqa: E402
from repro.ft.checkpoint import CheckpointManager  # noqa: E402


def main(smoke: bool = False):
    devices = jax.devices()
    mesh = Mesh(np.array(devices), ("data",))
    n_rows, n_queries = (8_100, 10) if smoke else (40_500, 30)
    rel = W.make_relation(seed=0, n_rows=n_rows, n_num=2, cat_sizes=(4,),
                          n_measures=2, lengthscale=0.4, noise=0.2)
    # 8100*0.2/5 = 324 rows per sample batch — NOT divisible by 8 devices;
    # the masked padded scan shards it anyway (and counts 324, not the
    # padded 512-row tile, as scanned).
    cfg = vd.EngineConfig(sample_rate=0.2, n_batches=5, capacity=512)
    session = vd.connect(rel, cfg, mesh=mesh)
    st = session.stats()
    print(f"mesh: {len(devices)} devices; store kind: "
          f"{st['store']['kind']}; scan: {st['scan']['kind']}")

    q = (session.query().avg("v0").avg("v1").count()
         .where(vd.between("x0", 2.0, 8.0)).group_by("c0"))
    print("\nexplain (note per-key placement before any state exists):")
    print(session.explain(q))

    queries = W.make_workload(1, rel.schema, n_queries,
                              agg_kinds=("AVG", "COUNT", "SUM"),
                              cat_pred_prob=0.3)
    session.execute_many(queries)
    session.refit(steps=10 if smoke else 40)
    st = session.stats()
    print("\nshard occupancy after the workload:")
    for shard in st["store"]["shards"]:
        print(f"  {shard['device']}: keys={shard['n_keys']} "
              f"fill={shard['fill']}")
    scan = st["scan"]
    print(f"scan plane: {scan['kind']} over {scan['n_shards']} shards — "
          f"{scan['tuples_scanned']} true tuples scanned in "
          f"{scan['blocks_evaluated']} blocks (+{scan['pad_rows']} masked "
          f"padding rows, invisible in every count)")
    print(f"ingest back-pressure: "
          f"{ {k: v['ingest']['high_water'] for k, v in st['store']['keys'].items()} }")

    # Elastic re-placement: checkpoint the 8-way store, restore onto 2
    # devices (the scan keeps the full mesh so only placement changes).
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=1)
        session.save(mgr, step=1)
        narrow = vd.Session(rel, cfg, mesh=mesh)
        narrow.engine.store = vd.ShardedSynopsisStore(
            rel.schema, cfg, devices=devices[:2])
        narrow.load(mgr)
        test_q = queries[: 3]
        a = session.execute_many(test_q, vd.ErrorBudget(max_batches=2))
        b = narrow.execute_many(test_q, vd.ErrorBudget(max_batches=2))
        same = all(x.cells == y.cells for x, y in zip(a, b))
        print(f"\ncheckpoint re-placed onto 2 devices; answers identical: {same}")
        assert same
    print("\nThe synopsis — not the data — is the asset: it now shards, "
          "drains, and re-places like one.")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI: checks the path end-to-end")
    main(**vars(ap.parse_args()))
