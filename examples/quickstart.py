"""Quickstart: a database that gets smarter with every query.

Builds a synthetic relation, connects a ``repro.verdict`` Session, and runs
a stream of aggregate queries; the printout shows how the error bound and
the data budget needed per query shrink as the synopsis grows — the paper's
Figure 1 in terminal form, through the public Session API.

    PYTHONPATH=src python examples/quickstart.py [--smoke]
"""
import argparse

import numpy as np

import repro.verdict as vd
from repro.aqp import workload as W


def main(smoke: bool = False):
    n_rows, n_queries = (4_000, 8) if smoke else (30_000, 40)
    rel = W.make_relation(seed=0, n_rows=n_rows, n_num=2, cat_sizes=(4,),
                          n_measures=1, lengthscale=0.4, noise=0.2)
    session = vd.connect(rel, vd.EngineConfig(sample_rate=0.15, n_batches=8,
                                              capacity=512))
    queries = W.make_workload(1, rel.schema, n_queries, agg_kinds=("AVG",),
                              width_range=(0.15, 0.5))
    budget = vd.ErrorBudget(target_rel_error=0.02)

    print(f"{'query':>5} {'batches used':>12} {'max rel err':>11} "
          f"{'truncated':>9}")
    for i, q in enumerate(queries):
        a = session.execute(q, budget)
        print(f"{i:5d} {a.batches_used:12d} {a.max_rel_error():11.4f} "
              f"{a.truncated_groups:9d}")
        if i == min(19, n_queries // 2):
            print("--- offline refit (Algorithm 1) ---")
            session.refit(steps=10 if smoke else 60)

    # The typed builder resolves column names through the schema:
    q = (session.query().avg("v0")
         .where(vd.between("x0", 2.0, 8.0))
         .group_by("c0"))
    print("\nexplain before running:")
    print(session.explain(q))
    print("\nstreaming refinement (online aggregation):")
    for partial in session.stream(q):
        marker = "final" if partial.final else "....."
        print(f"  [{marker}] after {partial.batches_used} batches: "
              f"max rel err {partial.max_rel_error():.4f}")
    print("\nThe engine needs fewer online-aggregation batches per query as "
          "the synopsis grows: it is learning the data distribution.")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI: checks the path end-to-end")
    main(**vars(ap.parse_args()))
