"""Quickstart: a database that gets smarter with every query.

Builds a synthetic relation, runs a stream of aggregate queries through
Verdict, and prints how the error bound and the data budget needed per query
shrink as the synopsis grows — the paper's Figure 1 in terminal form.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.aqp import workload as W
from repro.core.engine import EngineConfig, VerdictEngine


def main():
    rel = W.make_relation(seed=0, n_rows=30_000, n_num=2, cat_sizes=(4,),
                          n_measures=1, lengthscale=0.4, noise=0.2)
    engine = VerdictEngine(rel, EngineConfig(sample_rate=0.15, n_batches=8,
                                             capacity=512))
    queries = W.make_workload(1, rel.schema, 40, agg_kinds=("AVG",),
                              width_range=(0.15, 0.5))

    print(f"{'query':>5} {'batches used':>12} {'raw bound':>10} "
          f"{'improved':>10} {'accepted':>9}")
    for i, q in enumerate(queries):
        r = engine.execute(q, target_rel_error=0.02)
        imp = r.snippet_answer
        raw_b = float(np.sqrt(np.asarray(imp.raw_beta2)).mean())
        imp_b = float(np.sqrt(np.asarray(imp.beta2)).mean())
        acc = int(np.asarray(imp.accepted).sum())
        print(f"{i:5d} {r.batches_used:12d} {raw_b:10.4f} {imp_b:10.4f} "
              f"{acc:9d}/{imp.accepted.shape[0]}")
        if i == 19:
            print("--- offline refit (Algorithm 1) ---")
            engine.refit(steps=60)
    total = sum(len(b) for b in engine.batches.batch_rows)
    print("\nThe engine needs fewer online-aggregation batches per query as "
          "the synopsis grows: it is learning the data distribution.")


if __name__ == "__main__":
    main()
