"""TPC-H-flavoured demo: Verdict vs NoLearn on a star-schema fact table.

Reproduces the Table-4 experience at laptop scale through the public
``repro.verdict`` Session API: same accuracy sooner, or better accuracy for
the same budget — including group-by and SUM/COUNT queries (decomposed into
AVG/FREQ snippets per paper §2.3).

    PYTHONPATH=src python examples/tpch_demo.py [--smoke]
"""
import argparse

import repro.verdict as vd
from repro.aqp import workload as W


def main(smoke: bool = False):
    n_rows = 8_000 if smoke else 100_000
    n_train, n_test = (6, 3) if smoke else (30, 10)
    rel = W.tpch_like(seed=0, n_rows=n_rows)
    train_q = W.tpch_workload(1, rel.schema, n_queries=n_train)
    test_q = W.tpch_workload(2, rel.schema, n_queries=n_test)

    verdict = vd.connect(rel, vd.EngineConfig(sample_rate=0.1, n_batches=8,
                                              capacity=512, seed=0))
    nolearn = vd.connect(rel, vd.EngineConfig(sample_rate=0.1, n_batches=8,
                                              seed=0, learning=False))
    print(f"training on {n_train} queries (first half of the trace, "
          f"one fused scan)...")
    verdict.execute_many(train_q)
    verdict.refit(steps=10 if smoke else 60)

    print("\nplan for the first test query:")
    print(verdict.explain(test_q[0]))

    two = vd.ErrorBudget(max_batches=2)
    tight = vd.ErrorBudget(target_rel_error=0.025)
    print(f"\n{'#':>3} {'kind':>6} {'cells':>5} {'NoLearn bound%':>15} "
          f"{'Verdict bound%':>15} {'V batches@2.5%':>15} {'N batches@2.5%':>15}")
    for i, q in enumerate(test_q):
        rv = verdict.execute(q, two)
        rn = nolearn.execute(q, two)
        vb = sum(c.rel_error() for c in rv.cells) / max(len(rv.cells), 1) * 100
        nb = sum(c.rel_error() for c in rn.cells) / max(len(rn.cells), 1) * 100
        sv = verdict.execute(q, tight)
        sn = nolearn.execute(q, tight)
        kind = rv.cells[0].kind if rv.cells else "-"
        print(f"{i:3d} {kind:>6} {len(rv.cells):5d} {nb:15.2f} {vb:15.2f} "
              f"{sv.batches_used:15d} {sn.batches_used:15d}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI: checks the path end-to-end")
    main(**vars(ap.parse_args()))
