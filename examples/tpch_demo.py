"""TPC-H-flavoured demo: Verdict vs NoLearn on a star-schema fact table.

Reproduces the Table-4 experience at laptop scale: same accuracy sooner, or
better accuracy for the same budget — including group-by and SUM/COUNT
queries (decomposed into AVG/FREQ snippets per paper §2.3).

    PYTHONPATH=src python examples/tpch_demo.py
"""
import numpy as np

from repro.aqp import workload as W
from repro.core.engine import EngineConfig, VerdictEngine


def main():
    rel = W.tpch_like(seed=0, n_rows=100_000)
    train_q = W.tpch_workload(1, rel.schema, n_queries=30)
    test_q = W.tpch_workload(2, rel.schema, n_queries=10)

    verdict = VerdictEngine(rel, EngineConfig(sample_rate=0.1, n_batches=8,
                                              capacity=512, seed=0))
    nolearn = VerdictEngine(rel, EngineConfig(sample_rate=0.1, n_batches=8,
                                              seed=0, learning=False))
    print("training on 30 queries (first half of the trace, one fused scan)...")
    verdict.execute_many(train_q)
    verdict.refit(steps=60)

    print(f"\n{'#':>3} {'kind':>6} {'cells':>5} {'NoLearn bound%':>15} "
          f"{'Verdict bound%':>15} {'V batches@2.5%':>15} {'N batches@2.5%':>15}")
    for i, q in enumerate(test_q):
        rv = verdict.execute(q, max_batches=2)
        rn = nolearn.execute(q, max_batches=2)
        vb = np.mean([np.sqrt(c["beta2"]) / max(abs(c["estimate"]), 1e-9)
                      for c in rv.cells]) * 100
        nb = np.mean([np.sqrt(c["beta2"]) / max(abs(c["estimate"]), 1e-9)
                      for c in rn.cells]) * 100
        sv = verdict.execute(q, target_rel_error=0.025)
        sn = nolearn.execute(q, target_rel_error=0.025)
        kind = rv.cells[0]["kind"] if rv.cells else "-"
        print(f"{i:3d} {kind:>6} {len(rv.cells):5d} {nb:15.2f} {vb:15.2f} "
              f"{sv.batches_used:15d} {sn.batches_used:15d}")


if __name__ == "__main__":
    main()
