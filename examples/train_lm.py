"""End-to-end LM training driver on the shared substrate (smoke scale).

Any of the 10 assigned architectures trains through the same launcher the
dry-run validates at 256/512 chips; on this CPU container we run the reduced
config for a few hundred steps with checkpoint/restart enabled.

    PYTHONPATH=src python examples/train_lm.py --arch gemma2-2b --steps 200
"""
import argparse
import sys

from repro.launch import train as TR


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_train")
    args = ap.parse_args()
    TR.main(["--arch", args.arch, "--smoke", "--steps", str(args.steps),
             "--batch", "4", "--seq", "32", "--accum", "2",
             "--ckpt-every", "50", "--ckpt-dir", args.ckpt_dir])


if __name__ == "__main__":
    main()
